"""Microbenchmarks: mixing-program classes and fused multi-step dispatch.

One row per *program class* — circulant (ring), matching (pairwise
averaging), edge_colored (star: the PR-3 sparse decomposition), and gather
(the dense GatherRow all-gather the star used to compile to) — with
median/p90 apply wall time and the analytic bytes-on-wire per node.  A
second block measures multi-step fusion: a full one-peer exponential cycle
as H separate dispatches vs ONE fused executable (``GossipProgram.fuse``).

Timing uses per-call samples (best/median/p90) because the 2-CPU CI box is
noisy; bytes come from ``program_comm_bytes`` (mean per node) and
``program_max_node_bytes`` (busiest node), both validated against HLO
collective parses elsewhere.  Everything lands in the committed
``BENCH_step_time.json`` so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, save_bench_section, save_json
from repro.core.graphs import Star, make_graph, one_peer_period, random_matching
from repro.core.schedule import (
    GossipProgram, compile_graph, dense_program, program_comm_bytes,
    program_max_node_bytes,
)


def _sample(fn, *args, reps=20):
    """Per-call wall-time samples in µs (first call = compile, excluded)."""
    jax.block_until_ready(fn(*args))
    out = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        out.append(1e6 * (time.perf_counter() - t0))
    return out


def _stats(samples):
    return {
        "best_us": float(np.min(samples)),
        "median_us": float(np.median(samples)),
        "p90_us": float(np.percentile(samples, 90)),
    }


def _program_classes(n: int):
    """One representative compiled program per class."""
    star = Star(n)
    return {
        "circulant": compile_graph(make_graph("ring", n)),
        "matching": compile_graph(random_matching(n, seed=0)),
        "edge_colored": compile_graph(star),
        "gather": dense_program(star),
    }


def run(*, quick: bool = False) -> list[Row]:
    rows, payload = [], {}
    n = 16
    reps = 8 if quick else 20
    sizes = (1 << 14,) if quick else (1 << 16, 1 << 20)
    for size in sizes:
        x = {"w": jax.random.normal(jax.random.PRNGKey(0), (n, size))}
        param_bytes = 4 * size
        for cls, prog in _program_classes(n).items():
            fn = jax.jit(prog.apply_stacked)
            stats = _stats(_sample(fn, x, reps=reps))
            stats["bytes_per_node"] = program_comm_bytes(prog, param_bytes)
            stats["max_node_bytes"] = program_max_node_bytes(prog, param_bytes)
            stats["n_collectives"] = prog.num_collectives
            payload[f"{cls}/n{n}/p{size}"] = stats
            rows.append(
                Row(
                    f"mixing/{cls}/p{size}",
                    stats["median_us"],
                    f"median_us={stats['median_us']:.0f} "
                    f"p90_us={stats['p90_us']:.0f} "
                    f"bytes_per_node={stats['bytes_per_node']} "
                    f"ops={stats['n_collectives']}",
                )
            )

    # -- multi-step fusion: H one-peer dispatches vs one fused executable ----
    size = sizes[0]
    x = {"w": jax.random.normal(jax.random.PRNGKey(1), (n, size))}
    period = one_peer_period(n)
    progs = [
        compile_graph(make_graph("one_peer_exponential", n, step=t))
        for t in range(period)
    ]
    fns = [jax.jit(p.apply_stacked) for p in progs]

    def run_separate(v):
        for f in fns:
            v = f(v)
        return v

    fused = GossipProgram.fuse(progs)
    fused_fn = jax.jit(fused.apply_stacked)
    sep = _stats(_sample(run_separate, x, reps=reps))
    fus = _stats(_sample(fused_fn, x, reps=reps))
    fusion = {
        "period": period,
        "separate": {**sep, "executables": len(fns)},
        "fused": {**fus, "executables": 1},
        "dispatch_reduction": f"{len(fns)}->1",
    }
    payload["fusion/one_peer"] = fusion
    rows.append(
        Row(
            "fusion/one_peer",
            fus["median_us"],
            f"H={period} separate_us={sep['median_us']:.0f} "
            f"fused_us={fus['median_us']:.0f} executables={len(fns)}->1",
        )
    )

    save_json("step_time", payload)
    save_bench_section("step_time", payload)
    return rows
