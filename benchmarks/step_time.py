"""Microbenchmarks: mixing-program classes, fusion, and overlap scheduling.

One row per *program class* — circulant (ring), matching (pairwise
averaging), edge_colored (star: the PR-3 sparse decomposition), and gather
(the dense GatherRow all-gather the star used to compile to) — with
median/p90 apply wall time and the analytic bytes-on-wire per node.  A
second block measures multi-step fusion: a full one-peer exponential cycle
as H separate dispatches vs ONE fused executable (``GossipProgram.fuse``).

``run_overlap`` (the ``overlap`` section) measures bucketed overlap
scheduling at the gossip-dispatch level on an 8-host-device mesh: one
closed-loop mixing step — SGD update, program permutes, Ξ_t probe — as
(a) a monolithic executable plus the standalone whole-tree probe
dispatch, vs (b) token-chained per-bucket dispatches with the probe
FOLDED into the bucket passes (``core/buckets.py``).  It runs in a
subprocess because the 8-device ``xla_force_host_platform_device_count``
flag must be set before jax initializes, and the other sections time
single-device dispatches.  Expected shape: deep permute schedules
(edge-colored star: Δ+1 sequential matching rounds) win from pipelining
bucket i's rendezvous against bucket i+1's compute; shallow one-permute
schedules (ring, one-peer) pay the extra dispatches instead.

Timing uses per-call samples (best/median/p90) because the 2-CPU CI box is
noisy; bytes come from ``program_comm_bytes`` (mean per node) and
``program_max_node_bytes`` (busiest node), both validated against HLO
collective parses elsewhere.  Everything lands in the committed
``BENCH_step_time.json`` so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, save_bench_section, save_json
from repro.core.graphs import Star, make_graph, one_peer_period, random_matching
from repro.core.schedule import (
    GossipProgram, compile_graph, dense_program, program_comm_bytes,
    program_max_node_bytes,
)

DEFAULT_BUCKET_MB = 1.0  # the sweep value the acceptance row is read at


def _sample(fn, *args, reps=20):
    """Per-call wall-time samples in µs (first call = compile, excluded)."""
    jax.block_until_ready(fn(*args))
    out = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        out.append(1e6 * (time.perf_counter() - t0))
    return out


def _stats(samples):
    return {
        "best_us": float(np.min(samples)),
        "median_us": float(np.median(samples)),
        "p90_us": float(np.percentile(samples, 90)),
    }


def _program_classes(n: int):
    """One representative compiled program per class."""
    star = Star(n)
    return {
        "circulant": compile_graph(make_graph("ring", n)),
        "matching": compile_graph(random_matching(n, seed=0)),
        "edge_colored": compile_graph(star),
        "gather": dense_program(star),
    }


def run(*, quick: bool = False) -> list[Row]:
    rows, payload = [], {}
    n = 16
    reps = 8 if quick else 20
    sizes = (1 << 14,) if quick else (1 << 16, 1 << 20)
    for size in sizes:
        x = {"w": jax.random.normal(jax.random.PRNGKey(0), (n, size))}
        param_bytes = 4 * size
        for cls, prog in _program_classes(n).items():
            fn = jax.jit(prog.apply_stacked)
            stats = _stats(_sample(fn, x, reps=reps))
            stats["bytes_per_node"] = program_comm_bytes(prog, param_bytes)
            stats["max_node_bytes"] = program_max_node_bytes(prog, param_bytes)
            stats["n_collectives"] = prog.num_collectives
            payload[f"{cls}/n{n}/p{size}"] = stats
            rows.append(
                Row(
                    f"mixing/{cls}/p{size}",
                    stats["median_us"],
                    f"median_us={stats['median_us']:.0f} "
                    f"p90_us={stats['p90_us']:.0f} "
                    f"bytes_per_node={stats['bytes_per_node']} "
                    f"ops={stats['n_collectives']}",
                )
            )

    # -- multi-step fusion: H one-peer dispatches vs one fused executable ----
    size = sizes[0]
    x = {"w": jax.random.normal(jax.random.PRNGKey(1), (n, size))}
    period = one_peer_period(n)
    progs = [
        compile_graph(make_graph("one_peer_exponential", n, step=t))
        for t in range(period)
    ]
    fns = [jax.jit(p.apply_stacked) for p in progs]

    def run_separate(v):
        for f in fns:
            v = f(v)
        return v

    fused = GossipProgram.fuse(progs)
    fused_fn = jax.jit(fused.apply_stacked)
    sep = _stats(_sample(run_separate, x, reps=reps))
    fus = _stats(_sample(fused_fn, x, reps=reps))
    fusion = {
        "period": period,
        "separate": {**sep, "executables": len(fns)},
        "fused": {**fus, "executables": 1},
        "dispatch_reduction": f"{len(fns)}->1",
    }
    payload["fusion/one_peer"] = fusion
    rows.append(
        Row(
            "fusion/one_peer",
            fus["median_us"],
            f"H={period} separate_us={sep['median_us']:.0f} "
            f"fused_us={fus['median_us']:.0f} executables={len(fns)}->1",
        )
    )

    save_json("step_time", payload)
    save_bench_section("step_time", payload)
    return rows


# -- overlap-scheduled gossip: monolithic+probe vs bucketed+fold -------------

OVERLAP_TOPOS = ("d_ring", "d_star", "d_one_peer_exp")


def _overlap_worker(quick: bool) -> dict:
    """Subprocess body (8 host devices): one closed-loop mixing step per
    variant.  Monolithic = jitted update+permutes over the whole (n, P)
    matrix, then the standalone Ξ probe executable.  Bucketed = the
    engines' per-bucket chain — ``build_bucket_step`` dispatches threaded
    on the Ξ² token under the bounded window, probe folded, host √ last.
    """
    from collections import deque

    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.core.buckets import (
        MAX_INFLIGHT_BUCKETS, BucketLayout, build_bucket_step,
        xi_from_folded_sq,
    )
    from repro.core.dsgd import make_topology
    from repro.optim.sgd import sgd

    n = 8
    size = (1 << 18) if quick else (1 << 20)
    reps = 8 if quick else 16
    mbs = (0.25, DEFAULT_BUCKET_MB) if quick else (0.5, DEFAULT_BUCKET_MB, 2.0)

    mesh = compat.make_mesh((n,), ("gossip",))
    lead2 = NamedSharding(mesh, P("gossip", None))
    rep_s = NamedSharding(mesh, P())
    gvec = NamedSharding(mesh, P("gossip"))
    hyper = sgd(momentum=0.9).hyper
    beta = hyper["momentum"]
    rng = np.random.default_rng(0)
    theta = jax.device_put(
        jnp.asarray(rng.normal(size=(n, size)).astype(np.float32)), lead2
    )
    mom = jax.device_put(jnp.zeros((n, size), jnp.float32), lead2)
    grad = jax.device_put(
        jnp.asarray(rng.normal(size=(n, size)).astype(np.float32)), lead2
    )
    lr = jnp.float32(0.05)

    payload = {}
    for topo_name in OVERLAP_TOPOS:
        prog = make_topology(topo_name, n).program_at(step=0, epoch=0)
        rounds = len(prog.ops)

        def mono_step(t, m, g, lr):
            new_m = beta * m + g
            return prog.apply_stacked(t - lr * new_m), new_m

        def probe(t):
            d = t - t.mean(axis=0)
            return jnp.sqrt(jnp.mean(jnp.sum(d * d, axis=-1)))

        mono = jax.jit(
            mono_step, in_shardings=(lead2, lead2, lead2, rep_s),
            out_shardings=(lead2, lead2),
        )
        probe_j = jax.jit(probe, in_shardings=(lead2,), out_shardings=rep_s)

        def run_mono():
            t2, m2 = mono(theta, mom, grad, lr)
            xi = probe_j(t2)
            jax.block_until_ready((t2, m2, xi))
            return float(xi)

        stats = _stats(_sample(run_mono, reps=reps))
        stats.update(probe="standalone", permute_rounds=rounds,
                     bucket_mb=None, num_buckets=1)
        payload[f"{topo_name}/mono/n{n}"] = stats

        step = build_bucket_step(prog, hyper=hyper, has_momentum=True)
        for mb in mbs:
            layout = BucketLayout.for_stacked({"w": theta}, mb)
            fns = {
                w: jax.jit(
                    step,
                    in_shardings=(lead2, lead2, lead2, rep_s, gvec),
                    out_shardings=(lead2, lead2, gvec),
                )
                for w in set(layout.widths)
            }
            bounds = layout.bounds

            def run_buck():
                tok = jax.device_put(jnp.zeros((n,), jnp.float32), gvec)
                outs = []
                window: deque = deque()
                for b, w in enumerate(layout.widths):
                    if len(window) >= MAX_INFLIGHT_BUCKETS:
                        jax.block_until_ready(window.popleft())
                    lo, hi = bounds[b], bounds[b + 1]
                    t2, m2, tok = fns[w](
                        theta[:, lo:hi], mom[:, lo:hi], grad[:, lo:hi],
                        lr, tok,
                    )
                    outs.append((t2, m2))
                    window.append(tok)
                jax.block_until_ready((outs, tok))
                return xi_from_folded_sq(tok)

            stats = _stats(_sample(run_buck, reps=reps))
            stats.update(probe="folded", permute_rounds=rounds,
                         bucket_mb=mb, num_buckets=layout.num_buckets)
            payload[f"{topo_name}/mb{mb}/n{n}"] = stats
    return payload


def run_overlap(*, quick: bool = False) -> list[Row]:
    """The ``overlap`` section — spawned as a subprocess so the 8-device
    host-platform flag never leaks into the other sections' timings."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    cmd = [sys.executable, "-m", "benchmarks.step_time", "--overlap-worker"]
    if quick:
        cmd.append("--quick")
    r = subprocess.run(
        cmd, capture_output=True, text=True, timeout=1800, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    if r.returncode != 0:
        raise RuntimeError(f"overlap worker failed:\n{r.stderr[-3000:]}")
    payload = json.loads(r.stdout)
    # no engine runs here (raw-kernel microbench), so derive each entry's
    # provenance by billing the measured program through a recorder: one
    # application at the worker's vector width = the bytes each rep moved
    from repro.core.dsgd import make_topology
    from repro.telemetry import MemorySink, MetricsRecorder

    n, size = 8, (1 << 18) if quick else (1 << 20)
    recs = {}
    for key in payload:
        topo_name = key.split("/")[0]
        rec = MetricsRecorder(sinks=[MemorySink()], metrics_every=0)
        rec.comm(
            make_topology(topo_name, n).program_at(step=0, epoch=0),
            size * 4, step=0,
        )
        recs[key] = rec
    rows = [
        Row(
            f"overlap/{key}",
            stats["median_us"],
            f"median_us={stats['median_us']:.0f} "
            f"p90_us={stats['p90_us']:.0f} probe={stats['probe']} "
            f"buckets={stats['num_buckets']} rounds={stats['permute_rounds']}",
        )
        for key, stats in payload.items()
    ]
    save_json("overlap", payload)
    save_bench_section("overlap", payload, telemetry=recs)
    return rows


if __name__ == "__main__":
    if "--overlap-worker" in sys.argv:
        print(json.dumps(_overlap_worker(quick="--quick" in sys.argv)))
    else:
        sys.exit("usage: python -m benchmarks.step_time --overlap-worker "
                 "[--quick]  (sections run via benchmarks.run)")
