"""Microbenchmarks: mixing implementations and kernel oracles (wall-clock).

Derived: relative speed of dense-matrix vs circulant-shift mixing (the
faithful-baseline vs optimized-schedule gap, measurable even on CPU) and
per-step simulator overhead.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row, save_json
from repro.core.graphs import make_graph
from repro.core.mixing import mix_dense, mix_shift


def _time(fn, *args, reps=20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return 1e6 * (time.perf_counter() - t0) / reps


def run() -> list[Row]:
    rows, payload = [], {}
    n = 16
    for size in (1 << 16, 1 << 20):
        x = {"w": jax.random.normal(jax.random.PRNGKey(0), (n, size))}
        for kind in ("ring", "exponential", "complete"):
            g = make_graph(kind, n)
            w = jnp.asarray(g.mixing_matrix(), jnp.float32)
            t_dense = _time(jax.jit(lambda t: mix_dense(t, w)), x)
            t_shift = _time(jax.jit(lambda t: mix_shift(t, g)), x)
            rows.append(
                Row(
                    f"mixing/{kind}/p{size}",
                    t_shift,
                    f"dense_us={t_dense:.0f} shift_us={t_shift:.0f} "
                    f"speedup={t_dense/max(t_shift,1e-9):.2f}x",
                )
            )
            payload[f"{kind}/p{size}"] = {"dense": t_dense, "shift": t_shift}
    save_json("step_time", payload)
    return rows
