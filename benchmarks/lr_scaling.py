"""Paper §3.2 / Observation 3: linear vs sqrt LR scaling at scale.

Reproduces the tuned_* rescue experiment: with aggressive linear scaling a
large-scale decentralized run destabilizes; square-root scaling of the same
base LR recovers convergence.  Derived: final loss under each policy.
"""
from __future__ import annotations

import jax

from benchmarks.common import Row, save_json, sweep_topologies
from repro.models.common import init_params
from repro.models.paper_models import lstm_defs, lstm_loss
from repro.optim.schedules import lr_scale
from repro.optim.sgd import sgd
from benchmarks.variance import _lm_batch_fn

N = 16
BASE_LR = 1.0


def run(steps: int = 50) -> list[Row]:
    rows, payload = [], {}
    for policy in ("linear", "sqrt"):
        scale = lr_scale(
            policy, global_batch=4 * N, base_batch=24, graph_degree=N - 1
        )
        params0 = init_params(lstm_defs(vocab=128, d=64), jax.random.PRNGKey(2))
        res = sweep_topologies(
            loss_fn=lstm_loss,
            params0=params0,
            batch_fn=_lm_batch_fn(128, 24),
            eval_fn=None,
            topologies=["d_complete"],
            n_nodes=N,
            steps=steps,
            lr=BASE_LR * scale,
            optimizer=sgd(momentum=0.9),
            collect_norms=False,
        )
        r = res["d_complete"]
        import numpy as np

        final = float(np.mean(r["losses"][-5:]))
        diverged = not np.isfinite(final) or final > r["losses"][0]
        rows.append(
            Row(
                f"obs3/lr_{policy}/n{N}",
                r["us_per_step"],
                f"lr={BASE_LR*scale:.3f} final_loss={final:.3f} diverged={diverged}",
            )
        )
        payload[policy] = {"lr": BASE_LR * scale, "final": final, "diverged": bool(diverged)}
    save_json("lr_scaling", payload)
    return rows
