"""Paper Table 1 (derived): per-step communication volume per graph vs scale.

Analytic wire-cost model (validated against HLO collective parses in the
dry-run artifact): bytes each node sends per mixing step for a 25.56M-param
ResNet50-sized replica (the paper's main subject).
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import Row, save_json
from repro.core.graphs import make_graph, spectral_gap
from repro.core.mixing import mixing_comm_bytes

PARAMS = {"resnet50": 25_560_000, "lstm": 28_950_000}
SCALES = (12, 24, 48, 96, 1008)
# one_peer_exponential: degree-1 time-varying gossip (arXiv:2410.11998) —
# the per-step wire-cost floor; its per-step gap is small by design (a full
# p-step cycle mixes like the dense exponential graph).
KINDS = ("ring", "torus", "exponential", "one_peer_exponential", "complete")


def run() -> list[Row]:
    rows, payload = [], {}
    fake = {"w": jnp.zeros((PARAMS["resnet50"],), jnp.float32)}
    for n in SCALES:
        for kind in KINDS:
            g = make_graph(kind, n)
            mb = mixing_comm_bytes(g, fake) / 2**20
            # circulant graphs get the exact DFT fast path at every scale
            # (n=1008 included); nothing here needs the dense eigensolver.
            gap = spectral_gap(g)
            rows.append(
                Row(
                    f"table1/{kind}/n{n}",
                    0.0,
                    f"degree={g.degree} edges={g.num_edges} MB_per_step={mb:.1f}"
                    f" spectral_gap={gap:.6f}",
                )
            )
            payload[f"{kind}/n{n}"] = {
                "degree": g.degree, "edges": g.num_edges, "mb": mb,
                "spectral_gap": gap,
            }
    save_json("comm_cost", payload)
    return rows
