"""Paper Table 1 (derived): per-step communication volume per graph vs scale.

Analytic wire-cost model (validated against HLO collective parses in the
dry-run artifact): bytes each node sends per mixing step for a 25.56M-param
ResNet50-sized replica (the paper's main subject).

Beyond the paper's five graphs, the sweep includes the star — compiled by
the PR-3 edge-coloring pass into ≤ Δ+1 permute matchings, whose mean
per-node cost stays ~2P at every scale, versus the (n−1)·P ring all-gather
its old GatherRow fallback moved ("gather" rows keep that dense baseline
visible).  The star section also lands in the committed
``BENCH_step_time.json`` to track the O(n·P) → O(Δ·P) reduction across PRs.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import Row, save_bench_section, save_json
from repro.core.graphs import make_graph, spectral_gap
from repro.core.mixing import mixing_comm_bytes
from repro.core.schedule import (
    compile_graph, dense_program, program_comm_bytes, program_max_node_bytes,
)

PARAMS = {"resnet50": 25_560_000, "lstm": 28_950_000}
SCALES = (12, 24, 48, 96, 1008)
# one_peer_exponential: degree-1 time-varying gossip (arXiv:2410.11998) —
# the per-step wire-cost floor; its per-step gap is small by design (a full
# p-step cycle mixes like the dense exponential graph).  star: the PR-3
# edge-colored irregular representative.
KINDS = ("ring", "torus", "exponential", "one_peer_exponential", "complete", "star")


def run(*, quick: bool = False) -> list[Row]:
    rows, payload = [], {}
    bench = {}
    scales = SCALES[:3] if quick else SCALES
    param_bytes = 4 * PARAMS["resnet50"]
    fake = {"w": jnp.zeros((PARAMS["resnet50"],), jnp.float32)}
    for n in scales:
        for kind in KINDS:
            g = make_graph(kind, n)
            mb = mixing_comm_bytes(g, fake) / 2**20
            # circulant graphs get the exact DFT fast path at every scale
            # (n=1008 included); nothing here needs the dense eigensolver.
            gap = spectral_gap(g)
            rows.append(
                Row(
                    f"table1/{kind}/n{n}",
                    0.0,
                    f"degree={g.degree} edges={g.num_edges} MB_per_step={mb:.1f}"
                    f" spectral_gap={gap:.6f}",
                )
            )
            payload[f"{kind}/n{n}"] = {
                "degree": g.degree, "edges": g.num_edges, "mb": mb,
                "spectral_gap": gap,
            }
            if kind == "star":
                # edge-colored vs the dense GatherRow baseline it replaced
                sparse = compile_graph(g)
                gather = dense_program(g)
                bench[f"star/n{n}"] = {
                    "edge_colored_bytes_per_node": program_comm_bytes(
                        sparse, param_bytes
                    ),
                    "edge_colored_max_node_bytes": program_max_node_bytes(
                        sparse, param_bytes
                    ),
                    "edge_colored_permutes": sparse.num_collectives,
                    "gather_bytes_per_node": program_comm_bytes(
                        gather, param_bytes
                    ),
                }
                rows.append(
                    Row(
                        f"table1/star_vs_gather/n{n}",
                        0.0,
                        f"edge_colored_MB={bench[f'star/n{n}']['edge_colored_bytes_per_node']/2**20:.1f}"
                        f" gather_MB={bench[f'star/n{n}']['gather_bytes_per_node']/2**20:.1f}"
                        f" permutes={sparse.num_collectives}",
                    )
                )
    save_json("comm_cost", payload)
    save_bench_section("comm_cost", bench)
    return rows
