"""Paper Figures 2–3: model accuracy across communication graphs × scales.

Mini-ResNet image classification (the paper's CIFAR10 track) trained with
the five SGD implementations at two training scales.  Derived column:
final test accuracy — the paper's claim is the connectivity ordering
ring <= torus/exponential <= complete at matched iterations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, save_json, sweep_topologies
from repro.models.common import init_params
from repro.models.paper_models import (
    mini_resnet_apply, mini_resnet_defs, mini_resnet_loss, synthetic_images,
)
from repro.optim.sgd import sgd

TOPOLOGIES = ["c_complete", "d_complete", "d_exponential", "d_torus", "d_ring"]


def _batch_fn(key, step, n):
    b = synthetic_images(jax.random.fold_in(key, step), batch=8 * n)
    return {
        "images": b["images"].reshape(n, 8, *b["images"].shape[1:]),
        "labels": b["labels"].reshape(n, 8),
    }


def _eval_fn(params):
    b = synthetic_images(jax.random.PRNGKey(999), batch=256, noise=0.6)
    logits = mini_resnet_apply(params, b["images"])
    return jnp.mean((jnp.argmax(logits, -1) == b["labels"]).astype(jnp.float32))


def run(steps: int = 120, scales=(8, 16)) -> list[Row]:
    rows = []
    payload = {}
    for n in scales:
        params0 = init_params(mini_resnet_defs(), jax.random.PRNGKey(0))
        res = sweep_topologies(
            loss_fn=mini_resnet_loss,
            params0=params0,
            batch_fn=_batch_fn,
            eval_fn=_eval_fn,
            topologies=TOPOLOGIES,
            n_nodes=n,
            steps=steps,
            lr=0.1,
            optimizer=sgd(momentum=0.9),
            seed=n,
        )
        for name, r in res.items():
            rows.append(
                Row(
                    f"fig3/resnet/{name}/n{n}",
                    r["us_per_step"],
                    f"acc={r['final_eval']:.3f}",
                )
            )
        payload[f"n{n}"] = {
            k: {"acc": v["final_eval"], "losses": v["losses"][::5]}
            for k, v in res.items()
        }
    save_json("accuracy_graphs", payload)
    return rows
