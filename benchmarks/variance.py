"""Paper Figures 4–5: parameter-tensor variance (gini) across graphs +
rank-integration analysis.

Derived columns: early-stage mean gini (iterations 0–15) per topology —
the paper's Observation 4 is that early variance orders inversely with
connectivity — and the mean variance rank (Figure 5).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Row, save_json, sweep_topologies
from repro.core.dbench import rank_analysis
from repro.models.common import init_params
from repro.models.paper_models import lstm_defs, lstm_loss
from repro.optim.sgd import sgd

TOPOLOGIES = ["c_complete", "d_complete", "d_exponential", "d_torus", "d_ring"]


def _lm_batch_fn(vocab, seq):
    from repro.data import SyntheticLM

    src = SyntheticLM(vocab=vocab, seq_len=seq, seed=0)

    def fn(key, step, n):
        import jax.numpy as jnp

        b = src.stacked(n, step, 4)
        return {k: jnp.asarray(v) for k, v in b.items()}

    return fn


def run(steps: int = 50, n_nodes: int = 16) -> list[Row]:
    params0 = init_params(lstm_defs(vocab=128, d=64), jax.random.PRNGKey(1))
    res = sweep_topologies(
        loss_fn=lstm_loss,
        params0=params0,
        batch_fn=_lm_batch_fn(128, 24),
        eval_fn=None,
        topologies=TOPOLOGIES,
        n_nodes=n_nodes,
        steps=steps,
        lr=0.5,
        optimizer=sgd(momentum=0.9),
    )
    rows, payload = [], {}
    gini_series = {}
    for name, r in res.items():
        g = r["recorder"].metric_series("gini")  # (steps, n_leaves)
        gini_series[name] = g
        early = float(g[:15].mean())
        late = float(g[-10:].mean())
        rows.append(
            Row(f"fig4/gini/{name}/n{n_nodes}", r["us_per_step"],
                f"early_gini={early:.4f} late_gini={late:.4f}")
        )
        payload[name] = {"early_gini": early, "late_gini": late,
                         "gini_mean": g.mean(-1).tolist()[::5]}
    ranks = rank_analysis({k: v for k, v in gini_series.items()})
    for name, rk in ranks.items():
        rows.append(Row(f"fig5/rank/{name}", 0.0, f"mean_rank={float(rk.mean()):.2f}"))
        payload[name]["mean_rank"] = float(rk.mean())
    save_json("variance", payload)
    return rows
