"""Resilience benchmark: fault rate × topology class (the `faults` section).

The paper motivates decentralized training with production stability but
only ever benchmarks pristine graphs; this suite measures what faults
actually cost.  For each topology class — circulant (`d_ring`),
edge-colored irregular (`d_star`), time-varying (`d_one_peer_exp`) — and
each transient-dropout rate, a seeded fault run (`core/faults.py`) records

  * final accuracy of the node-averaged model (the paper's figure of
    merit) — how much convergence the dropped gossip rounds cost,
  * the consensus-distance trajectory Ξ_t over the alive nodes
    (`consensus_distance_masked`) — the on-device signal faults spike and
    the controller re-arms on,
  * wall-clock us/step (the masked runtime path must not change the
    executable count, so step time should match the fault-free row), and
  * total bytes per node billed by *surviving* edges only
    (`benchmarks/ada.py::_total_comm` replaying the same realization).

A permanent-crash + elastic-rejoin row per topology exercises the
degraded-program path end to end.  Everything lands in the committed
``BENCH_step_time.json`` ``faults`` section (`save_bench_section`), keyed
``<topo>/<model><rate>/n<nodes>``.

``run_elastic`` (the ``elastic`` section, keyed the same way) stresses the
membership dynamics instead: k>=2 CONCURRENT crashes composed over runtime
masks (the executables column pins the zero-recompile invariant), a planned
preemption DRAIN against an unannounced hard crash, a true mid-run JOIN
growing membership past the initial n, and an n=512 time-varying one-peer
dropout sweep on virtual-node shards (``shard_nodes=True``).  PR 8 adds:

  * SPMD-*trainer* rows (``spmd_join``, ``spmd_deadline<rate>``) run in an
    8-host-device subprocess: a spare-rank pool whose mid-run join
    activates a ghost rank, and a gossip-deadline straggler sweep with
    exponential-backoff readmission — both on the production engine, the
    executables column pinning the zero-recompile bar there too, and
  * a ``d_ada`` MONOTONE-vs-REDENSIFY pair under the same deadline storm:
    the non-monotone (Ξ-spike) ladder walks back to a denser rung after
    each storm, and the committed rows let the schema test assert it wins
    on accuracy at comparable comm bytes.

Quick tier:  PYTHONPATH=src:. python -m benchmarks.run --quick --only faults
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.accuracy_graphs import _batch_fn, _eval_fn
from benchmarks.ada import _total_comm
from benchmarks.common import Row, save_bench_section, save_json
from repro.core.consensus import consensus_distance_masked_jit
from repro.core.dsgd import make_topology
from repro.core.faults import make_fault_model
from repro.core.simulator import DecentralizedSimulator
from repro.models.common import init_params
from repro.models.paper_models import mini_resnet_defs, mini_resnet_loss
from repro.optim.sgd import sgd
from repro.telemetry import MemorySink, MetricsRecorder

N = 16
STEPS_PER_EPOCH = 5
PROBE_EVERY = 5
TOPOLOGIES = ("d_ring", "d_star", "d_one_peer_exp")
DROPOUT_RATES = (0.0, 0.1, 0.3)


def _run_one(topo_name: str, fault_kind: str, rate: float, steps: int,
             params0, seed: int = 0):
    fm = make_fault_model(
        fault_kind, N, rate=rate, seed=seed,
        down_steps=steps // 2 if fault_kind == "crash" else None,
    )
    topo = make_topology(topo_name, N, fault_model=fm)
    # counters/events only (record_spans=False): the recorder must not sync
    # on loss mid-run or the us_per_step column would absorb the overhead
    rec = MetricsRecorder(sinks=[MemorySink()], metrics_every=0)
    sim = DecentralizedSimulator(
        mini_resnet_loss, sgd(momentum=0.9), topo, collect_norms=False,
        telemetry=rec,
    )
    state = sim.init(params0)
    key = jax.random.PRNGKey(seed)
    xi_trace = []
    step_us = []
    for t in range(steps):
        key, sub = jax.random.split(key)
        batch = _batch_fn(sub, t, N)
        t0 = time.perf_counter()
        state, loss, _ = sim.train_step(
            state, batch, 0.1, epoch=t // STEPS_PER_EPOCH
        )
        jax.block_until_ready(loss)
        step_us.append(1e6 * (time.perf_counter() - t0))
        if t % PROBE_EVERY == 0:
            alive = (
                fm.at(t).alive if fm is not None else np.ones(N, bool)
            )
            xi = float(consensus_distance_masked_jit(
                state.params, jnp.asarray(alive, jnp.float32)
            ))
            xi_trace.append([t, xi])
            rec.gauge("xi", xi, step=t)
    acc = float(_eval_fn(state.mean_params()))
    comm = _total_comm(topo, steps, params0)
    return {
        "_telemetry": rec,
        "acc": acc,
        "xi_trace": xi_trace,
        # median per-step time: compile-at-first-use steps (one per distinct
        # program — more of them for crash runs) are outliers; the column
        # must reflect STEADY-STATE step time or the committed artifact
        # would appear to refute the zero-recompile invariant it pins
        "us_per_step": float(np.median(step_us)),
        "comm_bytes_per_node": comm,
        "steps": steps,
        "fault_model": fault_kind if fm is not None else "none",
        "rate": rate,
        "executables": len(sim._step_cache),
    }


def _run_elastic_one(topo_name: str, fault_kind: str, steps: int, params0, *,
                     n: int = N, fkw=None, tkw=None, mixing: str = "dense",
                     shard_nodes: bool = False, seed: int = 0):
    """One elastic-membership run; like ``_run_one`` but takes the fault
    model's kwargs verbatim (k, drain_steps, join_steps, ...) and sizes
    each batch by the CURRENT membership (joins grow it mid-run).  Comm
    billing replays the same membership-sized stream ``_total_comm`` now
    understands: a grown step is billed the family re-derived at its
    ``fm.n_at(t)``, so join rows carry honest bytes instead of skipping
    the column."""
    fkw = dict(fkw or {})
    fm = make_fault_model(fault_kind, n, seed=seed, **fkw)
    topo = make_topology(topo_name, n, fault_model=fm, **dict(tkw or {}))
    rec = MetricsRecorder(sinks=[MemorySink()], metrics_every=0)
    sim = DecentralizedSimulator(
        mini_resnet_loss, sgd(momentum=0.9), topo, mixing=mixing,
        shard_nodes=shard_nodes, collect_norms=False, telemetry=rec,
    )
    state = sim.init(params0)
    key = jax.random.PRNGKey(seed)
    elastic = fm is not None and fm.elastic
    xi_trace, step_us = [], []
    for t in range(steps):
        key, sub = jax.random.split(key)
        nb = fm.n_at(t) if elastic else n
        batch = _batch_fn(sub, t, nb)
        t0 = time.perf_counter()
        state, loss, _ = sim.train_step(
            state, batch, 0.1, epoch=t // STEPS_PER_EPOCH
        )
        jax.block_until_ready(loss)
        step_us.append(1e6 * (time.perf_counter() - t0))
        if t % PROBE_EVERY == 0:
            alive = fm.at(t).alive if fm is not None else np.ones(sim.n, bool)
            # float drain boosts are still alive; Xi is over membership
            mask = jnp.asarray(np.asarray(alive) != 0, jnp.float32)
            xi = float(consensus_distance_masked_jit(state.params, mask))
            xi_trace.append([t, xi])
            rec.gauge("xi", xi, step=t)
    acc = float(_eval_fn(state.mean_params()))
    out = {
        "_telemetry": rec,
        "acc": acc,
        "xi_trace": xi_trace,
        "us_per_step": float(np.median(step_us)),
        "comm_bytes_per_node": _total_comm(topo, steps, params0),
        "steps": steps,
        "fault_model": fault_kind if fm is not None else "none",
        # the elastic acceptance bar in artifact form: composed concurrent
        # crashes must not grow this beyond the fault-free count
        "executables": len(sim._step_cache),
        "n_final": sim.n,
    }
    if topo.controller is not None:
        ctl = topo.controller
        out["controller"] = {
            "transitions": [list(t) for t in ctl.transitions],
            "events": [list(e) for e in ctl.events],
            "ladder": list(ctl.ladder),
        }
    return out


def _spmd_worker(quick: bool) -> dict:
    """Body of the 8-host-device subprocess: elastic rows on the PRODUCTION
    engine.  A spare-rank pool (one ghost rank activated by a mid-run join)
    and a gossip-deadline straggler sweep, both on a fixed (4, 2) mesh —
    the ``executables`` column pins the zero-recompile bar on the trainer
    exactly as the simulator rows pin it on the oracle."""
    import dataclasses

    from repro.configs import get_config
    from repro.data import SyntheticLM
    from repro.launch.mesh import make_mesh
    from repro.launch.train import SPMDTrainer
    from repro.models import transformer as tfm

    G = 4
    steps = 8 if quick else 24
    cfg = dataclasses.replace(
        get_config("granite-8b-reduced"), name="granite-8b",
        dtype=jnp.float32, remat=False,
    )
    mesh = make_mesh((G, 2), ("data", "model"))
    src = SyntheticLM(vocab=cfg.vocab, seq_len=16, seed=0)
    node_params = tfm.init_model(cfg, jax.random.PRNGKey(0), tp_size=2)
    payload = {}
    cases = [
        ("spmd_join", "join",
         dict(seed=5, join_steps=(steps // 2,), spare_ranks=1)),
        ("spmd_deadline0.3", "deadline", dict(seed=4, rate=0.3)),
        ("spmd_deadline0.6", "deadline", dict(seed=4, rate=0.6)),
    ]
    for label, kind, fkw in cases:
        fm = make_fault_model(kind, G, **fkw)
        topo = make_topology("d_ring", G, fault_model=fm)
        trainer = SPMDTrainer(cfg, mesh, topo, sgd(momentum=0.9), donate=False)
        state = trainer.init_state(jax.random.PRNGKey(0))
        step_us, xi_trace = [], []
        loss = None
        for t in range(steps):
            batch = {
                k: jnp.asarray(v) for k, v in src.stacked(G, t, 2).items()
            }
            t0 = time.perf_counter()
            state, loss, _ = trainer.train_step(state, batch, 0.05, epoch=0)
            jax.block_until_ready(loss)
            step_us.append(1e6 * (time.perf_counter() - t0))
            if t % 2 == 0:
                mask = jnp.asarray(
                    np.asarray(fm.at(t).alive) != 0, jnp.float32
                )
                xi_trace.append([t, float(
                    consensus_distance_masked_jit(state.params, mask)
                )])
        payload[f"d_ring/{label}/n{G}"] = {
            # the trainer rows train a transformer LM, not the mini-resnet
            # classifier — the figure of merit is the final mean loss
            "final_loss": float(np.mean(jax.device_get(loss))),
            "xi_trace": xi_trace,
            "us_per_step": float(np.median(step_us)),
            "comm_bytes_per_node": _total_comm(topo, steps, node_params),
            "steps": steps,
            "fault_model": kind,
            "executables": len(trainer._step_cache),
            "n_final": G,
            "deadline_overruns": trainer.deadline_overruns,
        }
    return payload


def _run_spmd_rows(quick: bool) -> dict:
    """Spawn ``_spmd_worker`` in a subprocess so the 8-device host-platform
    flag never leaks into the in-process sections' timings."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    cmd = [sys.executable, "-m", "benchmarks.faults", "--spmd-worker"]
    if quick:
        cmd.append("--quick")
    r = subprocess.run(
        cmd, capture_output=True, text=True, timeout=1800, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    if r.returncode != 0:
        raise RuntimeError(f"spmd elastic worker failed:\n{r.stderr[-3000:]}")
    return json.loads(r.stdout)


def run(steps: int = 120, quick: bool = False) -> list[Row]:
    if quick:  # 2-CPU box tier
        steps = min(steps, 20)
    params0 = init_params(mini_resnet_defs(), jax.random.PRNGKey(0))
    rows, payload = [], {}
    for topo_name in TOPOLOGIES:
        for rate in DROPOUT_RATES:
            kind = "dropout" if rate > 0 else "none"
            res = _run_one(topo_name, kind, rate, steps, params0)
            key = f"{topo_name}/{kind}{rate}/n{N}"
            payload[key] = res
            rows.append(
                Row(
                    f"faults/{topo_name}/{kind}{rate}",
                    res["us_per_step"],
                    f"acc={res['acc']:.3f} xi_final={res['xi_trace'][-1][1]:.3g}"
                    f" comm_MB={res['comm_bytes_per_node'] / 2**20:.1f}",
                )
            )
        # one permanent crash + elastic rejoin per topology class
        res = _run_one(topo_name, "crash", 0.5, steps, params0)
        key = f"{topo_name}/crash0.5/n{N}"
        payload[key] = res
        rows.append(
            Row(
                f"faults/{topo_name}/crash0.5",
                res["us_per_step"],
                f"acc={res['acc']:.3f} xi_final={res['xi_trace'][-1][1]:.3g}"
                f" comm_MB={res['comm_bytes_per_node'] / 2**20:.1f}",
            )
        )
    # recorders ride the result dicts host-side only — pop before the JSON
    # writes, then stamp each committed entry's provenance from its run
    recs = {k: v.pop("_telemetry", None) for k, v in payload.items()}
    save_json("faults", payload)
    save_bench_section("faults", payload, telemetry=recs)
    return rows


def run_elastic(steps: int = 120, quick: bool = False) -> list[Row]:
    """Elastic-membership sweep (the ``elastic`` section): concurrent-crash
    count x drain-vs-hard-crash x a true mid-run join, plus an n=512
    one-peer dropout sweep on virtual-node shards.

    Quick tier:  PYTHONPATH=src:. python -m benchmarks.run --quick --only elastic
    """
    if quick:
        steps = min(steps, 20)
    steps512 = 6 if quick else max(steps // 5, 10)
    params0 = init_params(mini_resnet_defs(), jax.random.PRNGKey(0))
    payload = {}
    # concurrent-crash count: k simultaneous failures composed over runtime
    # masks — the executables column must match the fault-free count
    for k in (2, 3):
        payload[f"d_ring/concurrent{k}/n{N}"] = _run_elastic_one(
            "d_ring", "concurrent", steps, params0,
            fkw=dict(rate=0.8, k=k, down_steps=max(steps // 4, 2)), seed=2,
        )
    # planned drain-then-leave vs an unannounced hard crash that never
    # rejoins: the drain's boosted gossip + exact handoff should show up as
    # a smaller Xi excursion and better averaged-model accuracy
    payload[f"d_ring/preempt/n{N}"] = _run_elastic_one(
        "d_ring", "preempt", steps, params0,
        fkw=dict(rate=0.8, drain_steps=5), seed=1,
    )
    payload[f"d_ring/crash/n{N}"] = _run_elastic_one(
        "d_ring", "crash", steps, params0,
        fkw=dict(rate=0.8, down_steps=steps), seed=1,
    )
    # true join: membership grows past the initial n mid-run
    payload[f"d_ring/join/n{N}"] = _run_elastic_one(
        "d_ring", "join", steps, params0,
        fkw=dict(join_steps=(max(steps // 2, 1),)), seed=0,
    )
    # n=512 time-varying one-peer under transient dropout, node axis
    # sharded over the host's devices (the scale the 2-CPU box can't hold
    # unsharded); "shift" engine so mixing stays a stacked roll, not a
    # 512x512 dense product
    for rate in (0.1, 0.3):
        payload[f"d_one_peer_exp/dropout{rate}/n512"] = _run_elastic_one(
            "d_one_peer_exp", "dropout", steps512, params0, n=512,
            fkw=dict(rate=rate), mixing="shift", shard_nodes=True, seed=3,
        )
    # monotone vs Ξ-spike re-densify under the SAME deadline storm: the
    # closed-loop ladder that can walk back up to a denser rung after each
    # storm should buy averaged-model accuracy the monotone ladder cannot,
    # at comparable comm bytes (both replay their realized rung schedule).
    # These two rows need enough steps for the ladder to actually descend
    # (first down-fire lands ~step 21 at target 0.4) and then meet a
    # readmission Ξ-spike, so they run 60 steps even in the quick tier;
    # spike=1.3 sits above post-transition noise (~1.36x phase peak on
    # straggler readmission) while still firing within the run.
    ladder_steps = max(steps, 60)
    for label, tkw in (
        ("monotone", dict(k0=6, consensus_target=0.4)),
        ("redensify", dict(k0=6, consensus_target=0.4, consensus_spike=1.3)),
    ):
        payload[f"d_ada/{label}/n{N}"] = _run_elastic_one(
            "d_ada", "deadline", ladder_steps, params0,
            fkw=dict(rate=0.5, deadline_ms=30.0), tkw=tkw, seed=4,
        )
    # production-engine rows (8-host-device subprocess): spare-pool join
    # activation + deadline straggler sweep on the SPMD trainer
    payload.update(_run_spmd_rows(quick))
    rows = [
        Row(
            f"elastic/{key}",
            res["us_per_step"],
            (f"acc={res['acc']:.3f}" if "acc" in res
             else f"loss={res['final_loss']:.3f}")
            + f" xi_final={res['xi_trace'][-1][1]:.3g}"
            f" comm_MB={res['comm_bytes_per_node'] / 2**20:.1f}"
            f" exec={res['executables']} n_final={res['n_final']}",
        )
        for key, res in payload.items()
    ]
    recs = {k: v.pop("_telemetry", None) for k, v in payload.items()}
    save_json("elastic", payload)
    save_bench_section("elastic", payload, telemetry=recs)
    return rows


if __name__ == "__main__":
    if "--spmd-worker" in sys.argv:
        print(json.dumps(_spmd_worker(quick="--quick" in sys.argv)))
    else:
        sys.exit("usage: python -m benchmarks.faults --spmd-worker [--quick]"
                 "  (sections run via benchmarks.run)")
