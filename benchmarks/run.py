"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (harness contract).  JSON payloads
land in benchmarks/results/ and feed EXPERIMENTS.md.

  accuracy_graphs  Fig 2–3   accuracy vs communication graph × scale
  variance         Fig 4–5   gini dispersion + variance-rank integration
  ada              Fig 7     Ada vs static graphs (+ comm volume)
  comm_cost        Table 1   per-graph communication model
  faults           —         resilience: fault rate × topology class
  elastic          —         elastic membership: concurrent crashes, drains,
                             joins, n=512 virtual-node shards
  lr_scaling       §3.2      linear vs sqrt LR scaling rescue
  step_time        —         mixing-implementation microbench
  overlap          —         bucketed overlap-scheduled gossip vs monolithic
                             (8-host-device subprocess; probe fold included)

Run everything:       PYTHONPATH=src python -m benchmarks.run
Run one:              PYTHONPATH=src python -m benchmarks.run --only ada
Quick smoke:          PYTHONPATH=src python -m benchmarks.run --fast
CI-box tier:          PYTHONPATH=src python -m benchmarks.run --quick
                      (reduced n/steps/scales everywhere — completes on the
                      2-CPU box in a few minutes; never run concurrently
                      with pytest, the timings share the same two cores)
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true", help="fewer steps/scales")
    ap.add_argument("--quick", action="store_true",
                    help="smallest tier: reduced n/steps for every suite so "
                         "the whole run fits the 2-CPU box")
    args = ap.parse_args()

    from benchmarks import (
        accuracy_graphs, ada, comm_cost, faults, lr_scaling, step_time,
        variance,
    )

    small = args.fast or args.quick
    suites = {
        "comm_cost": lambda: comm_cost.run(quick=args.quick),
        "step_time": lambda: step_time.run(quick=args.quick),
        "overlap": lambda: step_time.run_overlap(quick=args.quick),
        "accuracy_graphs": lambda: accuracy_graphs.run(
            steps=20 if args.quick else (40 if args.fast else 120),
            scales=(8,) if small else (8, 16),
        ),
        "variance": lambda: variance.run(steps=15 if args.quick else (30 if args.fast else 50)),
        "ada": lambda: ada.run(
            steps=20 if args.quick else (40 if args.fast else 120),
            quick=args.quick,
        ),
        "faults": lambda: faults.run(
            steps=20 if args.quick else (40 if args.fast else 120),
            quick=args.quick,
        ),
        "elastic": lambda: faults.run_elastic(
            steps=20 if args.quick else (40 if args.fast else 120),
            quick=args.quick,
        ),
        "lr_scaling": lambda: lr_scaling.run(steps=15 if args.quick else (30 if args.fast else 40)),
    }
    if args.only:
        suites = {k: v for k, v in suites.items() if k == args.only}
        if not suites:
            sys.exit(f"unknown suite {args.only!r}")

    print("name,us_per_call,derived")
    for name, fn in suites.items():
        t0 = time.time()
        for row in fn():
            print(f"{row.name},{row.us_per_call:.1f},{row.derived}")
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
