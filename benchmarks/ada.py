"""Paper Figure 7 + the Ada accuracy-vs-cost frontier.

Ada vs static graphs (convergence + communication cost), extended with the
ROADMAP's frontier sweep: *fixed-γ open-loop* Ada (epoch time law,
one-peer floor) vs *closed-loop* Ada (consensus-distance-triggered decay
and handoff, ``core/consensus.py``) vs the static baselines, with total
communication volume as the cost axis.  The paper's claim: Ada converges
like the highly-connected graphs while its late-stage cost decays to
ring/one-peer cost; the closed-loop variant finds the handoff from the
run's own variance signal.

Communication accounting is **step-granular**: each step is billed the
bytes of the compiled ``GossipProgram`` actually in force at that step
(``Topology.program_at(step=t, epoch=e)`` + ``program_comm_bytes``), so
time-varying phases — the one-peer floor, matchings — cost what they move,
not the step-0 graph.  Closed-loop runs replay the controller's recorded
rung trace (``ConsensusController.rung_at``).

Results: accuracy is mean±std over seeds, us_per_step is averaged over
seeds, and the frontier lands both in benchmarks/results/ada.json and in
the committed ``BENCH_step_time.json`` ``ada`` section
(``save_bench_section``) so it is comparable across PRs.
"""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import Row, save_bench_section, save_json, sweep_topologies
from repro.core.dsgd import Topology
from repro.core.graphs import Complete
from repro.core.mixing import _tree_bytes
from repro.core.schedule import compile_graph, program_comm_bytes
from repro.models.common import init_params
from repro.models.paper_models import mini_resnet_defs, mini_resnet_loss
from repro.optim.sgd import sgd
from benchmarks.accuracy_graphs import _batch_fn, _eval_fn

N = 16
STEPS_PER_EPOCH = 5

# label -> (topology name, make_topology kwargs).  Both Ada variants decay
# onto the one-peer family; the closed-loop one replaces the γ time law
# with the measured consensus-distance trigger.
CONFIGS = [
    ("c_complete", "c_complete", {}),
    ("d_torus", "d_torus", {}),
    ("d_ring", "d_ring", {}),
    ("d_ada_fixed", "d_ada",
     {"k0": 12, "gamma_k": 1.0, "k_floor": "one_peer"}),
    ("d_ada_closed", "d_ada",
     {"k0": 12, "k_floor": "one_peer", "consensus_target": 0.7,
      "consensus_probe_every": STEPS_PER_EPOCH}),  # probe once per epoch
]


def _total_comm(
    topo: Topology, steps: int, params0, steps_per_epoch: int = STEPS_PER_EPOCH
) -> int:
    """Total bytes each node sends over ``steps``, billed per step.

    ``topo`` should be the Topology the run actually used: a closed-loop
    controller's realized schedule is replayed from its transition log, so
    the cost reflects the graphs the run selected, not a fixed time law.
    Closed-loop runs are additionally billed their consensus probes —
    computing x̄ is one all-reduce of the parameter tree per probe
    (2·P·(n-1)/n per node, like any ring all-reduce), the honest price of
    the control signal.

    Fault runs (``topo.fault_model``) replay the seeded realization stream
    and bill each step's *surviving* edges only: a crashed node's program
    is the degraded one (its permutes are gone from the wire), and a
    transiently dropped edge moves no payload — at high fault rates a
    naive full-program mask would make dead-edge bytes the dominant term.

    Elastic runs (``fm.elastic``) replay the membership-sized stream: each
    step is billed the SAME graph family re-derived at that step's
    membership ``fm.n_at(t)`` (``Topology.resized``, exactly how the
    engine executes a join), with that step's realization masks — the
    arrays a grown step draws are sized for the grown n, so the fixed-n
    replay the pre-elastic version did would either crash or silently
    bill the stale graph.
    """
    pbytes = _tree_bytes(params0)
    ctl = topo.controller
    fm = topo.fault_model
    elastic = fm is not None and fm.elastic
    sized = {topo.n_nodes: topo}
    total = 0
    for t in range(steps):
        epoch = t // steps_per_epoch
        m = fm.n_at(t) if elastic else topo.n_nodes
        topo_t = sized.get(m)
        if topo_t is None:
            # membership grew mid-run: re-derive the family at the new
            # size; the resized topology drops the fault model (elastic
            # realizations are all-ones at grown sizes anyway)
            topo_t = dataclasses.replace(topo.resized(m), fault_model=None)
            sized[m] = topo_t
        if ctl is not None and topo_t is topo:
            with ctl.pinned(ctl.rung_at(t)):
                prog = topo_t.program_at(step=t, epoch=epoch)
        else:
            # grown membership rebuilds the controller; its rung trace
            # belongs to the initial n, so the grown steps bill the plain
            # family schedule
            prog = topo_t.program_at(step=t, epoch=epoch)
        if ctl is not None and ctl.should_probe(t):
            total += int(2 * pbytes * (m - 1) / m)
        if prog is None:  # centralized: gradient all-reduce == complete graph
            prog = compile_graph(Complete(m))
        if fm is not None:
            fr = fm.at(t)
            if not fr.program_alive.all():
                prog = prog.degrade(fr.program_alive)
            total += program_comm_bytes(
                prog, pbytes, alive=fr.alive, link_up=fr.link_up
            )
        else:
            total += program_comm_bytes(prog, pbytes)
    return total


def run(steps: int = 120, seeds=(0, 1, 2), quick: bool = False) -> list[Row]:
    """Multi-seed: single-run accuracy noise at this scale (~±0.05) would
    otherwise swamp the topology effect the paper reports."""
    import numpy as np

    if quick:  # 2-CPU box tier: benchmarks/run.py --quick --only ada
        steps, seeds = min(steps, 20), tuple(seeds)[:2]

    params0 = init_params(mini_resnet_defs(), jax.random.PRNGKey(0))
    labels = [label for label, _, _ in CONFIGS]
    accs = {l: [] for l in labels}
    us = {l: [] for l in labels}
    comms = {l: [] for l in labels}
    handoffs = {l: [] for l in labels}
    recorders = {}
    for seed in seeds:
        res = sweep_topologies(
            loss_fn=mini_resnet_loss,
            params0=params0,
            batch_fn=_batch_fn,
            eval_fn=_eval_fn,
            topologies=[(label, name) for label, name, _ in CONFIGS],
            n_nodes=N,
            steps=steps,
            lr=0.1,
            optimizer=sgd(momentum=0.9),
            steps_per_epoch=STEPS_PER_EPOCH,
            topo_kwargs={label: kw for label, _, kw in CONFIGS},
            seed=seed,
            collect_norms=False,
        )
        for label, r in res.items():
            accs[label].append(r["final_eval"])
            us[label].append(r["us_per_step"])
            comms[label].append(_total_comm(r["topology"], steps, params0))
            ctl = r["topology"].controller
            if ctl is not None:
                handoffs[label].append(ctl.handoff_step)
            # last seed's recorder stamps the committed entry's provenance
            recorders[f"{label}/n{N}"] = r["telemetry"]

    rows, payload, frontier = [], {}, {}
    for label in labels:
        acc_mean = float(np.mean(accs[label]))
        acc_std = float(np.std(accs[label]))
        us_mean = float(np.mean(us[label]))
        us_std = float(np.std(us[label]))
        comm_mean = float(np.mean(comms[label]))
        rows.append(
            Row(
                f"fig7/{label}/n{N}",
                us_mean,
                f"acc={acc_mean:.3f}±{acc_std:.3f} comm_MB={comm_mean/2**20:.1f}",
            )
        )
        payload[label] = {
            "acc_mean": acc_mean, "acc_std": acc_std, "accs": accs[label],
            "us_per_step_mean": us_mean, "us_per_step_std": us_std,
            "comm_bytes_mean": comm_mean, "comm_bytes": comms[label],
            "handoff_steps": handoffs[label],
        }
        frontier[f"{label}/n{N}"] = {
            "acc_mean": acc_mean,
            "acc_std": acc_std,
            "comm_bytes_per_node": comm_mean,
            "us_per_step_mean": us_mean,
            "steps": steps,
            "seeds": len(accs[label]),
            **(
                {"handoff_steps": handoffs[label]}
                if handoffs[label]
                else {}
            ),
        }
    save_json("ada", payload)
    save_bench_section("ada", frontier, telemetry=recorders)
    return rows
