"""Paper Figure 7: Ada vs static graphs (convergence + communication cost).

Derived: final eval + total communication volume.  The paper's claim: Ada
converges like the highly-connected graphs while its late-stage cost decays
to ring cost.
"""
from __future__ import annotations

import jax

from benchmarks.common import Row, save_json, sweep_topologies
from repro.core.dsgd import make_topology
from repro.core.mixing import mixing_comm_bytes
from repro.models.common import init_params, param_count
from repro.models.paper_models import (
    mini_resnet_defs, mini_resnet_loss,
)
from repro.optim.sgd import sgd
from benchmarks.accuracy_graphs import _batch_fn, _eval_fn

TOPOLOGIES = ["c_complete", "d_torus", "d_ring", "d_ada"]
N = 16
STEPS_PER_EPOCH = 5


def _total_comm(topology_name, n, steps, params0, **kw):
    topo = make_topology(topology_name, n, **kw)
    total = 0
    for t in range(steps):
        g = topo.graph_at(t // STEPS_PER_EPOCH)
        if g is None:  # centralized: gradient all-reduce
            from repro.core.graphs import Complete

            total += mixing_comm_bytes(Complete(n), params0)
        else:
            total += mixing_comm_bytes(g, params0)
    return total


ADA_KW = {"k0": 12, "gamma_k": 1.0}  # dense first ~10 epochs, ring after


def run(steps: int = 120, seeds=(0, 1, 2)) -> list[Row]:
    """Multi-seed: single-run accuracy noise at this scale (~±0.05) would
    otherwise swamp the topology effect the paper reports."""
    import numpy as np

    params0 = init_params(mini_resnet_defs(), jax.random.PRNGKey(0))
    accs = {t: [] for t in TOPOLOGIES}
    us = {t: 0.0 for t in TOPOLOGIES}
    for seed in seeds:
        res = sweep_topologies(
            loss_fn=mini_resnet_loss,
            params0=params0,
            batch_fn=_batch_fn,
            eval_fn=_eval_fn,
            topologies=TOPOLOGIES,
            n_nodes=N,
            steps=steps,
            lr=0.1,
            optimizer=sgd(momentum=0.9),
            steps_per_epoch=STEPS_PER_EPOCH,
            topo_kwargs={"d_ada": ADA_KW},
            seed=seed,
            collect_norms=False,
        )
        for name, r in res.items():
            accs[name].append(r["final_eval"])
            us[name] = r["us_per_step"]
    rows, payload = [], {}
    for name in TOPOLOGIES:
        kw = ADA_KW if name == "d_ada" else {}
        comm = _total_comm(name, N, steps, params0, **kw)
        mean, std = float(np.mean(accs[name])), float(np.std(accs[name]))
        rows.append(
            Row(
                f"fig7/{name}/n{N}",
                us[name],
                f"acc={mean:.3f}±{std:.3f} comm_MB={comm/2**20:.1f}",
            )
        )
        payload[name] = {"acc_mean": mean, "acc_std": std, "accs": accs[name],
                         "comm_bytes": comm}
    save_json("ada", payload)
    return rows
