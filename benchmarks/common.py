"""Shared harness for the DBench benchmark reproductions.

Each benchmark module exposes ``run() -> list[Row]`` where a Row is
``(name, us_per_call, derived)`` — one CSV line per paper table/figure cell.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dbench import DBenchRecorder
from repro.core.dsgd import make_topology
from repro.core.simulator import DecentralizedSimulator
from repro.optim.sgd import Optimizer

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


class Row(NamedTuple):
    name: str
    us_per_call: float
    derived: str


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_step_time.json")


def save_bench_section(section: str, payload, telemetry=None) -> str:
    """Merge one section into the committed BENCH_step_time.json artifact.

    Unlike benchmarks/results/ (generated, untracked), this file IS
    committed: it records per-program-class step time and bytes-on-wire so
    the perf trajectory is comparable across PRs.  step_time and comm_cost
    each own a section; a partial run only refreshes its own keys.

    The payload is schema-gated through the static verifier before any
    write: a malformed section would silently corrupt the cross-PR
    trajectory at merge time, long after the run that produced it.

    ``telemetry`` stamps recorder provenance into the entries so the
    committed numbers are traceable to the run that measured them:
    either one ``MetricsRecorder`` (applied to every entry) or a
    ``{key: recorder}`` map aligned with the payload's keys.  Entries
    without a recorder are left untouched.
    """
    from repro.analysis.invariants import verify_bench_payload

    if telemetry is not None and isinstance(payload, dict):
        recs = (
            telemetry if isinstance(telemetry, dict)
            else {k: telemetry for k in payload}
        )
        for k, rec in recs.items():
            if rec is None or k not in payload:
                continue
            if isinstance(payload[k], dict):
                payload[k] = {**payload[k], "provenance": rec.provenance()}
    verify_bench_payload(section, payload)
    path = os.path.abspath(BENCH_PATH)
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            data = {}
    # merge per KEY, not per section: a --quick run must refresh only the
    # small-scale keys it measured, never clobber the committed full-tier
    # entries (star/n1008 etc.) it did not
    merged = data.get(section)
    if isinstance(merged, dict) and isinstance(payload, dict):
        merged = {**merged, **payload}
    else:
        merged = payload
    data[section] = merged
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def sweep_topologies(
    *,
    loss_fn: Callable,
    params0,
    batch_fn: Callable[[jax.Array, int, int], dict],  # (key, step, n) -> stacked batch
    eval_fn: Callable | None,
    topologies: list,
    n_nodes: int,
    steps: int,
    lr: float,
    optimizer: Optimizer,
    steps_per_epoch: int = 10,
    seed: int = 0,
    topo_kwargs: dict | None = None,
    collect_norms: bool = True,
):
    """Run every SGD implementation on identical data; return per-topo results.

    ``topologies`` entries are either a topology name, or a ``(label,
    name)`` pair so the same topology can appear twice with different
    hyperparameters (``topo_kwargs`` is keyed by label) — e.g. open-loop vs
    closed-loop Ada in the frontier sweep.
    """
    from repro.telemetry import MemorySink, MetricsRecorder

    out = {}
    for entry in topologies:
        label, name = (entry, entry) if isinstance(entry, str) else entry
        kw = (topo_kwargs or {}).get(label, {})
        topo = make_topology(name, n_nodes, **kw)
        # counters/events only — record_spans stays False so the recorder
        # never syncs on loss mid-run and us_per_step is unperturbed
        recorder = MetricsRecorder(sinks=[MemorySink()], metrics_every=0)
        sim = DecentralizedSimulator(
            loss_fn, optimizer, topo, collect_norms=collect_norms,
            telemetry=recorder,
        )
        # capture BEFORE the run: a closed-loop controller's graph_at
        # follows its live rung, which ends the run at the final graph
        degree0 = topo.degree_at(0)
        state = sim.init(params0)
        rec = DBenchRecorder(impl=label, n_nodes=n_nodes)
        key = jax.random.PRNGKey(seed)
        t0 = time.perf_counter()
        losses = []
        for t in range(steps):
            key, sub = jax.random.split(key)
            batch = batch_fn(sub, t, n_nodes)
            state, loss, norms = sim.train_step(
                state, batch, lr, epoch=t // steps_per_epoch
            )
            losses.append(float(jnp.mean(loss)))
            rec.record(t, np.asarray(loss), np.asarray(norms))
        wall = time.perf_counter() - t0
        final_eval = (
            float(eval_fn(state.mean_params())) if eval_fn is not None else float("nan")
        )
        out[label] = {
            "losses": losses,
            "final_eval": final_eval,
            "us_per_step": 1e6 * wall / steps,
            "recorder": rec,
            "comm_degree": degree0,
            # the run's Topology: closed-loop controllers carry the realized
            # schedule trace, which comm accounting replays
            "topology": topo,
            # the run's MetricsRecorder: measured comm-bytes/permute
            # counters + controller events, ready for save_bench_section's
            # telemetry= provenance pathway
            "telemetry": recorder,
        }
    return out
