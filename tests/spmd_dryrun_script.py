"""Subprocess body for test_spmd.py: mini dry-run (8 host devices).

Mirrors launch/dryrun.py on a (2, 2, 2) pod×data×model mesh with reduced
configs: train + prefill + decode must lower AND compile for one arch per
family, including the multi-pod gossip axes.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core.dsgd import make_topology
from repro.launch.dryrun import collective_stats
from repro.launch.mesh import gossip_axes_for, gossip_size, make_mesh
from repro.launch.serve import ServeEngine
from repro.launch.train import SPMDTrainer
from repro.optim.sgd import sgd

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
train_shape = InputShape("mini_train", 64, 8, "train")
prefill_shape = InputShape("mini_prefill", 256, 4, "prefill")
decode_shape = InputShape("mini_decode", 256, 8, "decode")

for arch in ["granite-8b", "phi3.5-moe-42b-a6.6b", "rwkv6-1.6b", "zamba2-7b", "kimi-k2-1t-a32b"]:
    cfg = dataclasses.replace(get_config(arch + "-reduced"), name=arch)
    gx = gossip_axes_for(cfg.name, mesh)
    g = gossip_size(mesh, gx)
    topo = make_topology("d_ada" if g > 2 else "d_ring", g)
    trainer = SPMDTrainer(cfg, mesh, topo, sgd(momentum=0.9))
    compiled = trainer.lower_step(train_shape).compile()
    stats = collective_stats(compiled.as_text())
    assert compiled.cost_analysis()["flops"] > 0
    if g > 1:
        assert (
            "collective-permute" in stats or "all-reduce" in stats
        ), f"{arch}: no gossip collectives found"
    eng = ServeEngine(cfg, mesh)
    eng.lower_prefill(prefill_shape).compile()
    eng.lower_decode(decode_shape).compile()
    print(f"{arch}: gossip_axes={gx} G={g} ok", flush=True)

print("MINI_DRYRUN_OK")
