"""Equivalence of the three mixing realizations + comm-cost model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.graphs import make_graph
from repro.core.mixing import mix_dense, mix_shift, mixing_comm_bytes

KINDS = ["ring", "torus", "exponential", "complete"]


@given(
    st.sampled_from(KINDS),
    st.integers(min_value=2, max_value=24),
    st.integers(min_value=0, max_value=10_000),
)
@settings(max_examples=40, deadline=None)
def test_dense_equals_shift(kind, n, seed):
    g = make_graph(kind, n)
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 3, 5))
    tree = {"a": x, "b": x[:, 0]}
    d = mix_dense(tree, g.mixing_matrix())
    s = mix_shift(tree, g)
    for k in tree:
        np.testing.assert_allclose(d[k], s[k], atol=1e-5)


@given(st.sampled_from(KINDS), st.integers(min_value=3, max_value=32))
@settings(max_examples=30, deadline=None)
def test_mixing_preserves_mean(kind, n):
    """Doubly-stochastic W preserves the replica mean (consensus invariant)."""
    g = make_graph(kind, n)
    if not g.is_symmetric:
        return  # directed exponential is only row-stochastic
    x = jax.random.normal(jax.random.PRNGKey(n), (n, 7))
    mixed = mix_dense({"w": x}, g.mixing_matrix())["w"]
    np.testing.assert_allclose(mixed.mean(0), x.mean(0), atol=1e-5)


def test_complete_mixing_is_mean():
    n = 8
    g = make_graph("complete", n)
    x = jax.random.normal(jax.random.PRNGKey(0), (n, 4))
    mixed = mix_shift({"w": x}, g)["w"]
    np.testing.assert_allclose(
        mixed, jnp.broadcast_to(x.mean(0), x.shape), atol=1e-5
    )


def test_repeated_mixing_reaches_consensus():
    """W^t x -> mean(x): the gossip fixed point (paper §2.2)."""
    n = 16
    x = np.random.default_rng(0).normal(size=(n, 3)).astype(np.float32)
    for kind in KINDS:
        g = make_graph(kind, n)
        y = {"w": jnp.asarray(x)}
        for _ in range(300):
            y = mix_shift(y, g)
        spread = float(jnp.abs(y["w"] - y["w"].mean(0)).max())
        assert spread < 1e-3, (kind, spread)


def test_comm_bytes_ordering():
    """ring <= torus <= exponential per-step wire cost (degree-proportional).

    The complete graph is realized as a ring all-reduce (2P(n-1)/n), so its
    per-step *wire bytes* undercut high-degree gossip — the decentralized
    advantage at scale is the absence of global synchronization (and the
    2(n-1) sequential all-reduce phases), not raw bytes. Assert the model
    reflects exactly that."""
    n = 96
    params = {"w": jnp.zeros((1000,), jnp.float32)}
    costs = [
        mixing_comm_bytes(make_graph(k, n), params)
        for k in ("ring", "torus", "exponential")
    ]
    assert costs == sorted(costs)
    complete = mixing_comm_bytes(make_graph("complete", n), params)
    assert complete < (n - 1) * 4000  # all-reduce model, not n-1 unicasts
    # ring gossip and ring all-reduce both move ~2P per node per step
    assert abs(costs[0] - complete) < 0.05 * costs[0] + 4000
