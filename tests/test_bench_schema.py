"""Schema pin for the committed BENCH_step_time.json perf artifact.

Benchmark sections were drifting silently: a suite could rename or drop a
key and the cross-PR perf trajectory would quietly stop being comparable.
This test pins the section layout — which sections exist, how their keys
are shaped, and which fields every entry must carry — so any drift fails
loudly here and forces a deliberate schema bump.
"""
import json
import os
import re

import pytest

BENCH = os.path.join(os.path.dirname(__file__), "..", "BENCH_step_time.json")

# section -> (key regex, required fields per entry)
SCHEMA = {
    "step_time": (
        r"^(circulant|matching|edge_colored|gather)/n\d+/p\d+$|^fusion/one_peer$",
        (),  # two entry shapes; field checks below are shape-specific
    ),
    "comm_cost": (
        r"^star/n\d+$",
        ("edge_colored_bytes_per_node", "edge_colored_max_node_bytes",
         "edge_colored_permutes", "gather_bytes_per_node"),
    ),
    "ada": (
        r"^(c_complete|d_torus|d_ring|d_ada_fixed|d_ada_closed)/n\d+$",
        ("acc_mean", "acc_std", "comm_bytes_per_node", "us_per_step_mean",
         "steps", "seeds"),
    ),
    "faults": (
        r"^(d_ring|d_star|d_one_peer_exp)/(none|dropout|link|straggler|crash)"
        r"[\d.]*/n\d+$",
        ("acc", "xi_trace", "us_per_step", "comm_bytes_per_node", "steps",
         "fault_model", "rate"),
    ),
    "elastic": (
        r"^(d_ring|d_one_peer_exp|d_ada)/(concurrent\d+|preempt|crash|join"
        r"|dropout|monotone|redensify|spmd_join|spmd_deadline)[\d.]*/n\d+$",
        ("acc", "xi_trace", "us_per_step", "comm_bytes_per_node", "steps",
         "fault_model", "executables", "n_final"),
    ),
    "overlap": (
        r"^(d_ring|d_star|d_one_peer_exp)/(mono|mb[\d.]+)/n\d+$",
        ("best_us", "median_us", "p90_us", "probe", "permute_rounds",
         "bucket_mb", "num_buckets"),
    ),
}

MIXING_FIELDS = ("best_us", "median_us", "p90_us", "bytes_per_node",
                 "max_node_bytes", "n_collectives")
FUSION_FIELDS = ("period", "separate", "fused", "dispatch_reduction")
# SPMD-trainer elastic rows train a transformer LM, not the mini-resnet
# classifier: the figure of merit is the final mean loss, not "acc"
ELASTIC_SPMD_FIELDS = ("final_loss", "xi_trace", "us_per_step",
                       "comm_bytes_per_node", "steps", "fault_model",
                       "executables", "n_final", "deadline_overruns")


@pytest.fixture(scope="module")
def bench():
    assert os.path.exists(BENCH), "committed BENCH_step_time.json is missing"
    with open(BENCH) as f:
        return json.load(f)


def test_all_pinned_sections_present(bench):
    missing = set(SCHEMA) - set(bench)
    assert not missing, f"BENCH_step_time.json lost sections: {sorted(missing)}"


@pytest.mark.parametrize("section", sorted(SCHEMA))
def test_section_key_and_field_layout(bench, section):
    key_re, fields = SCHEMA[section]
    entries = bench.get(section)
    assert isinstance(entries, dict) and entries, section
    for key, entry in entries.items():
        assert re.match(key_re, key), (
            f"{section} key {key!r} does not match the pinned layout "
            f"{key_re!r} — update tests/test_bench_schema.py deliberately "
            "if the schema changed"
        )
        assert isinstance(entry, dict), (section, key)
        want = fields
        if section == "step_time":
            want = FUSION_FIELDS if key.startswith("fusion/") else MIXING_FIELDS
        elif section == "elastic" and "/spmd_" in key:
            want = ELASTIC_SPMD_FIELDS
        missing = set(want) - set(entry)
        assert not missing, f"{section}/{key} lost fields {sorted(missing)}"


def test_elastic_section_covers_membership_dynamics(bench):
    """PR acceptance in artifact form: the elastic sweep spans concurrent
    crash counts, drain-vs-hard-crash, a true join that GREW membership,
    and an n=512 virtual-node row; composed concurrent crashes compile no
    more executables than a base run (one program on a static ring)."""
    kinds = {k.split("/")[1] for k in bench["elastic"]}
    assert {"concurrent2", "concurrent3", "preempt", "crash", "join"} <= kinds
    for key, v in bench["elastic"].items():
        kind = key.split("/")[1]
        if kind.startswith("concurrent"):
            assert v["executables"] == 1, key
        if kind == "join":
            assert v["n_final"] > int(key.rsplit("/n", 1)[1]), key
    big = [k for k in bench["elastic"] if k.endswith("/n512")]
    assert big, "n=512 virtual-node rows missing"
    for k in big:
        assert bench["elastic"][k]["n_final"] == 512


def test_elastic_section_covers_spmd_trainer_rows(bench):
    """PR 8 acceptance in artifact form: the production SPMD trainer runs
    a spare-pool join activation and a deadline straggler sweep on the
    fixed mesh, compiling exactly the fault-free executable count (one
    static-ring program)."""
    kinds = {k.split("/")[1] for k in bench["elastic"]}
    assert "spmd_join" in kinds
    assert any(k.startswith("spmd_deadline") for k in kinds)
    for key, v in bench["elastic"].items():
        if "/spmd_" not in key:
            continue
        assert v["executables"] == 1, key  # zero extra executables
        assert v["comm_bytes_per_node"] > 0, key
        assert v["final_loss"] > 0 and v["xi_trace"], key


def test_elastic_redensify_beats_monotone_ladder(bench):
    """PR 8 acceptance: under the same deadline storm, the non-monotone
    (Ξ-spike re-densify) ladder at least matches the monotone ladder on
    averaged-model accuracy at comparable comm bytes, demonstrably fired
    a redensify transition, and logged it."""
    mono = bench["elastic"]["d_ada/monotone/n16"]
    re_ = bench["elastic"]["d_ada/redensify/n16"]
    assert re_["acc"] >= mono["acc"], (re_["acc"], mono["acc"])
    # comparable comm: re-densified rungs are denser, never free — but the
    # win must not come from silently running a near-complete graph
    assert re_["comm_bytes_per_node"] <= 3 * mono["comm_bytes_per_node"]
    events = [r for _, r in re_["controller"]["events"]]
    assert "redensify" in events
    assert all(r != "redensify" for _, r in mono["controller"]["events"])
    # the transition list is non-monotone: some rung steps back DENSER
    rungs = [r for _, r in re_["controller"]["transitions"]]
    assert any(b < a for a, b in zip(rungs, rungs[1:])), rungs


def test_overlap_section_pins_bucketed_win_and_probe_fold(bench):
    """Overlap-scheduling acceptance in artifact form: every topology has
    a monolithic row (standalone probe) and a bucket_mb sweep (folded
    probe), and at the default bucket_mb at least one topology class runs
    bucketed at or below the monolithic step time — the deep edge-colored
    schedule is the expected winner; shallow one-permute schedules may
    honestly pay for their extra dispatches."""
    from benchmarks.step_time import DEFAULT_BUCKET_MB

    topos = {k.split("/")[0] for k in bench["overlap"]}
    assert {"d_ring", "d_star", "d_one_peer_exp"} <= topos
    default_wins = []
    for topo in sorted(topos):
        mono = [v for k, v in bench["overlap"].items()
                if k.startswith(f"{topo}/mono/")]
        assert len(mono) == 1 and mono[0]["probe"] == "standalone", topo
        swept = {v["bucket_mb"]: v for k, v in bench["overlap"].items()
                 if k.startswith(f"{topo}/mb")}
        assert swept, topo
        for v in swept.values():
            assert v["probe"] == "folded" and v["num_buckets"] >= 1
        at_default = swept.get(DEFAULT_BUCKET_MB)
        assert at_default is not None, (topo, sorted(swept))
        default_wins.append(
            at_default["median_us"] <= mono[0]["median_us"]
        )
    assert any(default_wins), (
        "no topology class runs bucketed <= monolithic at the default "
        "bucket_mb — the overlap schedule lost its win"
    )


def test_faults_section_covers_three_topology_classes(bench):
    """PR acceptance: accuracy + Ξ trajectory vs fault rate for >= 3
    topology classes (circulant, edge-colored, time-varying)."""
    topos = {k.split("/")[0] for k in bench["faults"]}
    assert {"d_ring", "d_star", "d_one_peer_exp"} <= topos
    rates = {
        (k.split("/")[0], v["rate"]) for k, v in bench["faults"].items()
    }
    for topo in ("d_ring", "d_star", "d_one_peer_exp"):
        assert len([r for t, r in rates if t == topo]) >= 3, topo
    for v in bench["faults"].values():
        assert isinstance(v["xi_trace"], list) and v["xi_trace"]
        step, xi = v["xi_trace"][-1]
        assert step >= 0 and xi >= 0.0
