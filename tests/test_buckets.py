"""Bucketed, overlap-scheduled gossip (core/buckets.py).

Covers: ``BucketLayout`` round-trips exactly on random pytrees (stacked
and local views, buckets crossing leaf boundaries, dtype preservation,
<= 2 distinct widths so the jit shape cache stays at <= 2 executables per
program), the bucketed ``apply_*`` interpreters == the monolithic apply ==
the dense mixing-matrix oracle <= 1e-6 on random connected graphs —
including the runtime-masked fault paths — the per-bucket executor
(``build_bucket_step``) against a hand-rolled SGD+mix oracle for every
SGD-family flavor, the Ξ² probe-fold identity (summed bucket partials ==
``consensus_sq`` of the merged tree), end-to-end simulator equivalence
(bucketed engine == monolithic engine bit-for-bit on fault-free AND
faulty runs), the executable-accounting bar (bucket executables scale
with distinct programs x widths, never with realizations), and the
eligibility gates (SGD family only, decentralized only, post-mixing only).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.buckets import (
    BucketLayout, bucket_eligible_optimizer, build_bucket_step,
    xi_from_folded_sq,
)
from repro.core.consensus import consensus_distance_jit
from repro.core.dsgd import make_topology
from repro.core.faults import degraded_matrix, make_fault_model
from repro.core.graphs import Ring, from_adjacency
from repro.core.schedule import GossipProgram, compile_graph
from repro.core.simulator import DecentralizedSimulator
from repro.optim.sgd import adamw, lars, sgd


def _random_connected_graph(n, seed):
    rng = np.random.default_rng(seed)
    edges = set()
    perm = rng.permutation(n)
    for a, b in zip(perm[:-1], perm[1:]):
        edges.add((min(a, b), max(a, b)))
    for _ in range(int(rng.integers(0, n))):
        i, j = rng.integers(0, n, size=2)
        if i != j:
            edges.add((min(i, j), max(i, j)))
    return from_adjacency(sorted((int(i), int(j)) for i, j in edges))


def _random_tree(rng, n, n_leaves, dtype=np.float32):
    """Random pytree with a leading (n, ...) node axis and mixed leaf ranks."""
    tree = {}
    for k in range(n_leaves):
        rank = int(rng.integers(1, 4))
        dims = [int(rng.integers(1, 5)) for _ in range(rank - 1)]
        tree[f"leaf{k}"] = jnp.asarray(
            rng.normal(size=[n] + dims).astype(dtype)
        )
    return tree


# ---------------------------------------------------------------------------
# BucketLayout: deterministic partition, exact round-trip
# ---------------------------------------------------------------------------

@given(
    st.integers(min_value=1, max_value=12),
    st.integers(min_value=1, max_value=6),
    st.integers(min_value=1, max_value=64),
    st.integers(min_value=0, max_value=10_000),
)
@settings(max_examples=40, deadline=None)
def test_layout_roundtrip_is_identity(n, n_leaves, bucket_elems, seed):
    """split -> merge == identity on random pytrees, for both the stacked
    (n, ...) and the local per-node views, at every bucket width."""
    rng = np.random.default_rng(seed)
    tree = _random_tree(rng, n, n_leaves)
    layout = BucketLayout(
        tuple(int(np.prod(x.shape[1:], dtype=np.int64)) for x in tree.values()),
        bucket_elems,
    )
    mats = layout.split_stacked(tree)
    assert [m.shape for m in mats] == [(n, w) for w in layout.widths]
    back = layout.merge_stacked(mats, tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(tree[k]))
        assert back[k].dtype == tree[k].dtype
    local = {k: v[0] for k, v in tree.items()}
    vecs = layout.split_local(local)
    back_l = layout.merge_local(vecs, local)
    for k in local:
        np.testing.assert_array_equal(np.asarray(back_l[k]), np.asarray(local[k]))


@given(
    st.integers(min_value=1, max_value=10),
    st.integers(min_value=1, max_value=40),
)
@settings(max_examples=40, deadline=None)
def test_layout_partition_invariants(n_leaves, bucket_elems):
    """Bounds tile [0, P) exactly; at most TWO distinct widths (full +
    tail) — the executable-count bar; segments cover every leaf slice."""
    rng = np.random.default_rng(n_leaves * 1000 + bucket_elems)
    sizes = tuple(int(rng.integers(0, 30)) for _ in range(n_leaves))
    layout = BucketLayout(sizes, bucket_elems)
    p = sum(sizes)
    b = layout.bounds
    assert b[0] == 0 and b[-1] == p
    assert sum(layout.widths) == p
    assert len(layout.widths) == layout.num_buckets
    assert len(set(layout.widths)) <= 2
    covered = [0] * n_leaves
    for segs in layout.segments:
        for li, s, e in segs:
            assert 0 <= s < e <= sizes[li]
            covered[li] += e - s
    assert tuple(covered) == sizes


def test_layout_is_dtype_and_value_independent():
    """bf16 and f32 trees of the same shapes bucket identically, and the
    layout builds from ShapeDtypeStructs (abstract init) too."""
    shapes = {"a": (4, 3, 5), "b": (4, 7)}
    t32 = {k: jnp.zeros(s, jnp.float32) for k, s in shapes.items()}
    t16 = {k: jnp.zeros(s, jnp.bfloat16) for k, s in shapes.items()}
    abstract = {k: jax.ShapeDtypeStruct(s, jnp.float32) for k, s in shapes.items()}
    a = BucketLayout.for_stacked(t32, 1e-5)
    assert a == BucketLayout.for_stacked(t16, 1e-5)
    assert a == BucketLayout.for_stacked(abstract, 1e-5)
    assert a.total == 15 + 7
    # MiB accounting: 1 MiB == 262144 f32 elements
    assert BucketLayout.elems_for_mb(1.0) == (1 << 20) // 4
    assert BucketLayout.elems_for_mb(1e-9) == 1  # floor at one element


def test_layout_rejects_mismatched_tree():
    tree = {"a": jnp.zeros((4, 6))}
    layout = BucketLayout.for_stacked(tree, 1e-5)
    with pytest.raises(ValueError, match="do not match layout"):
        layout.split_stacked({"a": jnp.zeros((4, 7))})
    with pytest.raises(ValueError, match="bucket_elems"):
        BucketLayout((6,), 0)


# ---------------------------------------------------------------------------
# Bucketed apply == monolithic apply == dense oracle (incl. masked paths)
# ---------------------------------------------------------------------------

@given(
    st.integers(min_value=2, max_value=14),
    st.integers(min_value=0, max_value=10_000),
    st.integers(min_value=1, max_value=20),
)
@settings(max_examples=30, deadline=None)
def test_bucketed_apply_matches_monolithic_and_dense_oracle(n, seed, be):
    """ISSUE acceptance: on random connected graphs, per-bucket apply ==
    monolithic apply == W @ x <= 1e-6, fault-free and runtime-masked."""
    rng = np.random.default_rng(seed)
    g = _random_connected_graph(n, seed)
    prog = compile_graph(g)
    tree = _random_tree(rng, n, 3)
    sizes = tuple(
        int(np.prod(x.shape[1:], dtype=np.int64)) for x in tree.values()
    )
    layout = BucketLayout(sizes, be)
    flat = np.concatenate(
        [np.asarray(v).reshape(n, -1) for v in tree.values()], axis=1
    )
    w = np.asarray(prog.matrix())

    def _flat(t):
        return np.concatenate(
            [np.asarray(v).reshape(n, -1) for v in t.values()], axis=1
        )

    # fault-free
    got = prog.apply_stacked_bucketed(tree, layout)
    mono = prog.apply_stacked(tree)
    np.testing.assert_allclose(_flat(got), _flat(mono), atol=1e-6)
    np.testing.assert_allclose(_flat(got), w @ flat, atol=1e-6)
    # masked: random alive set + random link failures
    alive = rng.random(n) > 0.3
    if not alive.any():
        alive[int(rng.integers(n))] = True
    up = np.triu(rng.random((n, n)) > 0.3, 1)
    link = up | up.T
    np.fill_diagonal(link, True)
    af = jnp.asarray(alive, jnp.float32)
    lf = jnp.asarray(link, jnp.float32)
    wd = degraded_matrix(w, alive, link)
    got_m = prog.apply_masked_bucketed(tree, af, link_up=lf, layout=layout)
    mono_m = prog.apply_masked(tree, af, link_up=lf)
    np.testing.assert_allclose(_flat(got_m), _flat(mono_m), atol=1e-6)
    np.testing.assert_allclose(_flat(got_m), wd @ flat, atol=1e-5)


def test_bucketed_apply_identity_program_shortcircuits():
    from repro.core.schedule import identity_program

    prog = identity_program(4)
    layout = BucketLayout((6,), 5)
    x = jnp.arange(24.0).reshape(4, 6)
    assert prog.apply_stacked_bucketed(x, layout) is x


# ---------------------------------------------------------------------------
# The per-bucket executor vs a hand-rolled SGD + mix oracle
# ---------------------------------------------------------------------------

def _sgd_oracle(theta, mom, grad, lr, hyper, update_mask=None):
    """Reference elementwise SGD on (n, w) matrices (float64 NumPy)."""
    beta = hyper.get("momentum", 0.0)
    wd = hyper.get("weight_decay", 0.0)
    nest = hyper.get("nesterov", False)
    t, m, g = (np.asarray(x, np.float64) for x in (theta, mom, grad))
    g = g + wd * t
    new_m = beta * m + g
    step = g + beta * new_m if nest else (new_m if beta else g)
    t_new = t - lr * step
    if update_mask is not None:
        u = np.asarray(update_mask, bool)[:, None]
        t_new = np.where(u, t_new, t)
        new_m = np.where(u, new_m, m)
    return t_new, new_m


@pytest.mark.parametrize(
    "hyper",
    [
        {"kind": "sgd", "momentum": 0.0, "weight_decay": 0.0, "nesterov": False},
        {"kind": "sgd", "momentum": 0.9, "weight_decay": 0.0, "nesterov": False},
        {"kind": "sgd", "momentum": 0.9, "weight_decay": 1e-3, "nesterov": True},
    ],
)
def test_bucket_step_matches_oracle_and_folds_xi(hyper):
    """Per-bucket executor == oracle update then W-mix; the threaded token
    accumulates exactly Σ_c (x_ic − x̄_c)² of the merged post-mix tree."""
    n, lr = 8, 0.05
    rng = np.random.default_rng(0)
    g = _random_connected_graph(n, 3)
    prog = compile_graph(g)
    w = np.asarray(prog.matrix())
    theta = rng.normal(size=(n, 17)).astype(np.float32)
    grad = rng.normal(size=(n, 17)).astype(np.float32)
    mom = rng.normal(size=(n, 17)).astype(np.float32)
    layout = BucketLayout((17,), 5)
    has_m = hyper["momentum"] != 0.0
    fn = build_bucket_step(prog, hyper=hyper, has_momentum=has_m)
    tok = jnp.zeros((n,), jnp.float32)
    out = np.empty_like(theta)
    for (lo, hi), width in zip(
        zip(layout.bounds[:-1], layout.bounds[1:]), layout.widths
    ):
        tb = jnp.asarray(theta[:, lo:hi])
        gb = jnp.asarray(grad[:, lo:hi])
        if has_m:
            t2, _, tok = fn(tb, jnp.asarray(mom[:, lo:hi]), gb, lr, tok)
        else:
            t2, tok = fn(tb, gb, lr, tok)
        out[:, lo:hi] = np.asarray(t2)
    t_star, _ = _sgd_oracle(theta, mom if has_m else 0 * mom, grad, lr, hyper)
    want = w @ t_star
    np.testing.assert_allclose(out, want, atol=1e-5)
    # probe fold: token == per-node Σ (x - x̄)² of the full post-mix matrix
    d = out - out.mean(axis=0, keepdims=True)
    np.testing.assert_allclose(
        np.asarray(tok), (d * d).sum(axis=1), rtol=1e-4, atol=1e-5
    )
    assert xi_from_folded_sq(tok) == pytest.approx(
        float(np.sqrt(np.mean((d * d).sum(axis=1)))), rel=1e-4
    )


def test_bucket_step_faulty_matches_masked_oracle():
    """Fault path: stragglers skip their update, the mix renormalizes over
    surviving edges — per-bucket == gated oracle update then degraded W."""
    n, lr = 10, 0.1
    rng = np.random.default_rng(7)
    g = _random_connected_graph(n, 11)
    prog = compile_graph(g)
    hyper = {"kind": "sgd", "momentum": 0.9, "weight_decay": 0.0,
             "nesterov": False}
    theta = rng.normal(size=(n, 9)).astype(np.float32)
    grad = rng.normal(size=(n, 9)).astype(np.float32)
    mom = rng.normal(size=(n, 9)).astype(np.float32)
    alive = np.ones(n, bool)
    alive[[2, 5]] = False
    update = np.ones(n, np.float32)
    update[[2, 5, 7]] = 0.0  # 7 straggles but stays in the mix
    fault = {
        "update": jnp.asarray(update),
        "alive": jnp.asarray(alive, jnp.float32),
    }
    layout = BucketLayout((9,), 4)
    fn = build_bucket_step(prog, hyper=hyper, has_momentum=True, faulty=True)
    tok = jnp.zeros((n,), jnp.float32)
    out = np.empty_like(theta)
    for lo, hi in zip(layout.bounds[:-1], layout.bounds[1:]):
        t2, _, tok = fn(
            jnp.asarray(theta[:, lo:hi]), jnp.asarray(mom[:, lo:hi]),
            jnp.asarray(grad[:, lo:hi]), lr, tok, fault,
        )
        out[:, lo:hi] = np.asarray(t2)
    t_star, _ = _sgd_oracle(theta, mom, grad, lr, hyper, update_mask=update)
    want = degraded_matrix(np.asarray(prog.matrix()), alive) @ t_star
    np.testing.assert_allclose(out, want, atol=1e-5)


def test_bucket_step_validation_gates():
    prog = compile_graph(Ring(4))
    sgd_h = {"kind": "sgd", "momentum": 0.9}
    with pytest.raises(ValueError, match="mix_order"):
        build_bucket_step(prog, hyper=sgd_h, has_momentum=True, mix_order="pre")
    with pytest.raises(ValueError, match="SGD family"):
        build_bucket_step(prog, hyper={"kind": "adamw"}, has_momentum=True)
    with pytest.raises(ValueError, match="plain momentum-SGD"):
        build_bucket_step(
            prog,
            hyper={"kind": "sgd", "momentum": 0.9, "weight_decay": 1e-4},
            has_momentum=True,
            kernel_split=(prog, ()),
        )


def test_bucket_eligibility():
    assert bucket_eligible_optimizer(sgd())
    assert bucket_eligible_optimizer(sgd(momentum=0.0))
    assert not bucket_eligible_optimizer(adamw())
    assert not bucket_eligible_optimizer(lars())


# ---------------------------------------------------------------------------
# End-to-end: bucketed simulator == monolithic simulator
# ---------------------------------------------------------------------------

def _lin_loss(params, batch):
    y = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((y - batch["y"]) ** 2)


def _lin_setup(n, steps, seed=0):
    rng = np.random.default_rng(seed)
    params = {
        "w": jnp.asarray(rng.normal(size=(3, 2)).astype(np.float32)),
        "b": jnp.zeros((2,), jnp.float32),
    }
    batches = [
        {
            "x": jnp.asarray(rng.normal(size=(n, 4, 3)).astype(np.float32)),
            "y": jnp.asarray(rng.normal(size=(n, 4, 2)).astype(np.float32)),
        }
        for _ in range(steps)
    ]
    return params, batches


@pytest.mark.parametrize("topo_name", ["d_ring", "d_one_peer_exp"])
def test_simulator_bucketed_equals_monolithic(topo_name):
    """Multi-bucket engine == monolithic engine on the final params
    (<= 1e-6; observed bit-exact) and the folded Ξ² == the jit probe."""
    n, steps = 8, 6
    params, batches = _lin_setup(n, steps)
    finals = {}
    for mb in (None, 1e-5):  # 1e-5 MiB -> 2-elem buckets -> 4 buckets of 8
        sim = DecentralizedSimulator(
            _lin_loss, sgd(momentum=0.9), make_topology(topo_name, n),
            bucket_mb=mb,
        )
        st_ = sim.init(params)
        for t in range(steps):
            st_, _, _ = sim.train_step(st_, batches[t], 0.05)
        finals[mb] = st_.params
        if mb is not None:
            assert sim._bucket_layout.num_buckets == 4
            assert sim._folded_for_step == st_.step
            np.testing.assert_allclose(
                xi_from_folded_sq(sim._folded_sq),
                float(consensus_distance_jit(st_.params)),
                rtol=1e-5, atol=1e-7,
            )
    for a, b in zip(
        jax.tree.leaves(finals[None]), jax.tree.leaves(finals[1e-5])
    ):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-6
        )


def test_simulator_bucketed_equals_monolithic_under_faults():
    """Same equivalence with runtime fault masks (straggler model), and
    the executable-accounting bar: bucket executables count distinct
    (program, width) pairs, NOT realizations."""
    n, steps = 8, 8
    params, batches = _lin_setup(n, steps, seed=3)
    finals = {}
    for mb in (None, 2e-5):
        fm = make_fault_model("straggler", n, rate=0.4, seed=5)
        sim = DecentralizedSimulator(
            _lin_loss, sgd(momentum=0.9),
            make_topology("d_ring", n, fault_model=fm),
            bucket_mb=mb,
        )
        st_ = sim.init(params)
        for t in range(steps):
            st_, _, _ = sim.train_step(st_, batches[t], 0.05)
        finals[mb] = st_.params
        if mb is not None:
            keys = [
                k for k in sim._step_cache
                if isinstance(k, tuple) and k[0] == "__bucket__"
            ]
            # one ring program x two widths (full=5, tail=3) x one fault
            # signature: realizations never mint new executables
            assert len(keys) == len(set(keys)) == 2
    for a, b in zip(
        jax.tree.leaves(finals[None]), jax.tree.leaves(finals[2e-5])
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@pytest.mark.parametrize("kind,kw,n_bucket_execs", [
    # preemption: float boost masks during the drain, then the depart's
    # degraded program — a SECOND (program, width) pair per width
    ("preempt", dict(rate=0.8, seed=1, drain_steps=3), 4),
    # deadline: transient masks over the base program only
    ("deadline", dict(rate=0.5, seed=4), 2),
])
def test_simulator_bucketed_faults_preempt_and_deadline(kind, kw, n_bucket_execs):
    """Satellite (PR 8): Preemption drain/boost masks and gossip-deadline
    masks dispatched per-bucket are bit-identical to the monolithic step,
    and bucket executables still count (program, width) pairs only."""
    n, steps = 8, 8
    params, batches = _lin_setup(n, steps, seed=3)
    finals = {}
    for mb in (None, 2e-5):
        fm = make_fault_model(kind, n, **kw)
        sim = DecentralizedSimulator(
            _lin_loss, sgd(momentum=0.9),
            make_topology("d_ring", n, fault_model=fm),
            bucket_mb=mb,
        )
        st_ = sim.init(params)
        for t in range(steps):
            st_, _, _ = sim.train_step(st_, batches[t], 0.05)
        finals[mb] = st_.params
        if mb is not None:
            keys = [
                k for k in sim._step_cache
                if isinstance(k, tuple) and k[0] == "__bucket__"
            ]
            assert len(keys) == len(set(keys)) == n_bucket_execs
    for a, b in zip(
        jax.tree.leaves(finals[None]), jax.tree.leaves(finals[2e-5])
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_simulator_bucketed_respects_mix_every():
    """Off-cycle steps (mix_every=2) take the plain path; the bucketed
    dispatches only fire on gossip steps — and the two engines agree."""
    n, steps = 6, 6
    params, batches = _lin_setup(n, steps, seed=9)
    finals = {}
    for mb in (None, 2e-5):
        sim = DecentralizedSimulator(
            _lin_loss, sgd(momentum=0.9), make_topology("d_ring", n),
            mix_every=2, bucket_mb=mb,
        )
        st_ = sim.init(params)
        for t in range(steps):
            st_, _, _ = sim.train_step(st_, batches[t], 0.05)
        finals[mb] = st_.params
    for a, b in zip(
        jax.tree.leaves(finals[None]), jax.tree.leaves(finals[2e-5])
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_simulator_bucket_validation():
    with pytest.raises(ValueError, match="SGD-family"):
        DecentralizedSimulator(
            _lin_loss, adamw(), make_topology("d_ring", 4), bucket_mb=1.0
        )
    with pytest.raises(ValueError, match="decentralized"):
        DecentralizedSimulator(
            _lin_loss, sgd(), make_topology("c_complete", 4), bucket_mb=1.0
        )
    with pytest.raises(ValueError, match="mix_order"):
        DecentralizedSimulator(
            _lin_loss, sgd(),
            make_topology("d_ring", 4, mix_order="pre"), bucket_mb=1.0
        )
