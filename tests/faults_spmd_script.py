"""Subprocess body for test_spmd.py: fault injection on both engines.

Runs the SAME seeded fault stream — transient dropout, a permanent crash
with elastic rejoin, a 2-node CONCURRENT crash (composed runtime masks),
and a preemption DRAIN-then-leave — through (a) the production SPMD
trainer and (b) the vmap/dense-matrix simulator with identical init/data,
and checks:

  * both engines draw identical fault realizations from the shared seeded
    model (no cross-engine channel needed),
  * final parameters agree to float32 round-off — the fault-aware step
    (masked mixing + gated updates + degraded programs + boosted drains +
    mean-preserving handoff + rejoin) is engine-equivalent,
  * the trainer compiles nothing beyond its pre-enumerated program set
    (base + single-node-out degrades), and a transient run's — AND a
    composed concurrent-crash run's — executable count equals the
    fault-free count (the elastic acceptance bar: k simultaneous failures
    ride runtime masks, zero extra executables).
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp

from repro.analysis.recompile import assert_executables_preenumerated
from repro.configs import get_config
from repro.core.dsgd import make_topology
from repro.core.faults import make_fault_model
from repro.core.simulator import DecentralizedSimulator
from repro.data import SyntheticLM
from repro.launch.mesh import make_mesh
from repro.launch.train import SPMDTrainer
from repro.models import transformer as tfm
from repro.optim.sgd import sgd

STEPS = 8
G = 4  # gossip nodes (data axis), model axis = 2

cfg = dataclasses.replace(
    get_config("granite-8b-reduced"), name="granite-8b", dtype=jnp.float32,
    remat=False,
)
mesh = make_mesh((G, 2), ("data", "model"))
opt = sgd(momentum=0.9)
src = SyntheticLM(vocab=cfg.vocab, seq_len=16, seed=0)
key = jax.random.PRNGKey(42)

maxdiff = 0.0
elastic_diff = 0.0  # the two elastic-SPMD acceptance cases: join + deadline
for kind, kw in [
    ("dropout", dict(rate=0.35, seed=3)),
    ("crash", dict(rate=0.8, seed=1, down_steps=3)),
    # 2-node concurrent crash, composed execution: overlapping windows ride
    # the runtime alive mask over the BASE program
    ("concurrent", dict(rate=0.8, seed=2, k=2, down_steps=3)),
    # planned preemption: announce -> boosted drain -> exact handoff -> leave
    ("preempt", dict(rate=0.8, seed=1, drain_steps=3)),
    # mid-run Join on the FIXED mesh: the pool over-provisions one spare
    # rank riding as an alive-masked zero-weight ghost; the step-4 join
    # activates it via the trainer's rejoin/adopt path, zero recompiles
    ("join", dict(rate=0.0, seed=5, join_steps=(4,), spare_ranks=1)),
    # per-round gossip deadline: seeded latency spikes mask stragglers out
    # of that round's averaging (local-step fallback), exponential-backoff
    # benching before readmission — all runtime masks over the base program
    ("deadline", dict(rate=0.6, seed=4, deadline_ms=30.0)),
]:
    # --- SPMD engine -------------------------------------------------------
    fm = make_fault_model(kind, G, **kw)
    topo_spmd = make_topology("d_ring", G, fault_model=fm)
    trainer = SPMDTrainer(cfg, mesh, topo_spmd, opt, donate=False)
    allowed = {p.cache_key for p in trainer.precompile_programs()}
    state = trainer.init_state(key)
    for t in range(STEPS):
        batch = {k: jnp.asarray(v) for k, v in src.stacked(G, t, 2).items()}
        state, loss, _ = trainer.train_step(state, batch, 0.05, epoch=0)
    used = assert_executables_preenumerated(trainer)
    assert used <= allowed, f"{kind}: executables beyond the set: {used - allowed}"
    if kind in ("dropout", "concurrent", "join", "deadline"):
        # transient masks, composed concurrent crashes, spare-rank joins,
        # and deadline masking compile exactly as many executables as the
        # fault-free run
        base = SPMDTrainer(
            cfg, mesh, make_topology("d_ring", G), opt, donate=False
        )
        b_state = base.init_state(key)
        for t in range(2):
            batch = {k: jnp.asarray(v) for k, v in src.stacked(G, t, 2).items()}
            b_state, *_ = base.train_step(b_state, batch, 0.05, epoch=0)
        assert len(trainer._step_cache) == len(base._step_cache), (
            trainer._step_cache.keys(), base._step_cache.keys(),
        )

    # --- simulator oracle --------------------------------------------------
    fm_sim = make_fault_model(kind, G, **kw)
    for t in range(STEPS):  # identical realization stream, engine-free
        fa, fb = fm.at(t), fm_sim.at(t)
        assert (fa.alive == fb.alive).all() and (fa.update == fb.update).all()
    topo_sim = make_topology("d_ring", G, fault_model=fm_sim)
    sim = DecentralizedSimulator(
        lambda p, b: tfm.loss_fn(p, cfg, b), opt, topo_sim, mixing="dense"
    )
    sim_state = sim.init(tfm.init_model(cfg, key, tp_size=2))
    for t in range(STEPS):
        batch = {k: jnp.asarray(v) for k, v in src.stacked(G, t, 2).items()}
        sim_state, loss, _ = sim.train_step(sim_state, batch, 0.05, epoch=0)

    pd = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()),
        jax.device_get(state.params), jax.device_get(sim_state.params),
    )
    diff = max(jax.tree.leaves(pd))
    maxdiff = max(maxdiff, diff)
    if kind in ("join", "deadline"):
        elastic_diff = max(elastic_diff, diff)
    print(f"{kind}: diff={diff:.3e} executables={len(used)}/{len(allowed)}")

print(f"MAXDIFF={maxdiff:.3e} ELASTIC_MAXDIFF={elastic_diff:.3e}")
if maxdiff < 5e-5 and elastic_diff < 1e-5:
    print("FAULTS_EQUIV_OK")
else:
    sys.exit(1)
