"""Subprocess body for test_spmd.py: SPMD shard_map engine == simulator.

Runs the same decentralized training (same init, same per-node data, same
topology) through (a) the production shard_map/ppermute engine on 8 host
devices and (b) the vmap/dense-matrix simulator, then prints the max
parameter difference.  Executed with XLA_FLAGS set by the parent test.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.dsgd import make_topology
from repro.core.simulator import DecentralizedSimulator
from repro.data import SyntheticLM
from repro.launch.mesh import make_mesh
from repro.launch.train import SPMDTrainer
from repro.models import transformer as tfm
from repro.optim.sgd import sgd

TOPO = sys.argv[1] if len(sys.argv) > 1 else "d_ring"
# "ppermute" | "dense" | "fused" (fused = compiled programs executed by the
# fused Pallas optimizer+gossip kernel, still vs the dense-matrix oracle)
MIXING = sys.argv[2] if len(sys.argv) > 2 else "ppermute"
STEPS = 4
G = 4  # gossip nodes (data axis), model axis = 2

cfg = dataclasses.replace(
    get_config("granite-8b-reduced"), name="granite-8b", dtype=jnp.float32, remat=False
)
mesh = make_mesh((G, 2), ("data", "model"))
topo = make_topology(TOPO, G)
opt = sgd(momentum=0.9)
src = SyntheticLM(vocab=cfg.vocab, seq_len=16, seed=0)

key = jax.random.PRNGKey(42)

# --- SPMD engine -------------------------------------------------------------
trainer = SPMDTrainer(
    cfg, mesh, topo, opt, collect_norms=True,
    mixing="ppermute" if MIXING == "fused" else MIXING,
    fused_apply=MIXING == "fused", donate=False,
)
state = trainer.init_state(key)
losses_spmd = []
for t in range(STEPS):
    batch = {k: jnp.asarray(v) for k, v in src.stacked(G, t, 2).items()}
    state, loss, norms = trainer.train_step(state, batch, 0.05, epoch=0)
    losses_spmd.append(jax.device_get(loss))

# --- simulator oracle ----------------------------------------------------------
sim = DecentralizedSimulator(
    lambda p, b: tfm.loss_fn(p, cfg, b), opt, topo, mixing="dense", collect_norms=True
)
sim_state = sim.init(tfm.init_model(cfg, key, tp_size=2))
losses_sim = []
for t in range(STEPS):
    batch = {k: jnp.asarray(v) for k, v in src.stacked(G, t, 2).items()}
    sim_state, loss, norms = sim.train_step(sim_state, batch, 0.05, epoch=0)
    losses_sim.append(jax.device_get(loss))

pd = jax.tree.map(
    lambda a, b: float(jnp.abs(a - b).max()), jax.device_get(state.params), jax.device_get(sim_state.params)
)
maxdiff = max(jax.tree.leaves(pd))
loss_diff = max(
    float(abs(a - b).max()) for a, b in zip(losses_spmd, losses_sim)
)
print(f"MAXDIFF={maxdiff:.3e}")
print(f"LOSSDIFF={loss_diff:.3e}")
print(f"FINALLOSS={float(losses_spmd[-1].mean()):.4f}")
