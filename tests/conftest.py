import os
import sys

# Tests see exactly one (CPU) device — the 512-device override lives ONLY in
# launch/dryrun.py.  Keep retracing cheap and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# hypothesis is not installed in the container (and pip install is not
# allowed): register the deterministic sampling shim under the same name.
try:  # pragma: no cover
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback

    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
