import os
import sys

# Tests see exactly one (CPU) device — the 512-device override lives ONLY in
# launch/dryrun.py.  Keep retracing cheap and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
