"""Subprocess body for test_spmd.py: the edge-colored star on 8 host devices.

Locks in the PR-3 acceptance bar with ``assert_no_all_gather``:
  1. the star's compiled program is <= Δ+1 PPermutes, zero GatherRow;
  2. its shard-interpreter HLO carries collective-permutes ONLY (the dense
     all-gather fallback must not leak back onto the hot path) and matches
     the dense mixing-matrix oracle;
  3. ``fused_apply_shard`` (Pallas kernel + real ppermute landing buffers
     inside shard_map) equals optimizer-then-dense-mix to <= 1e-5.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.graphs import Star, from_adjacency
from repro.core.schedule import GatherRow, PPermute, compile_graph
from repro.launch.hlo_analysis import assert_no_all_gather

N = 8
mesh = compat.make_mesh((N,), ("gossip",))

for graph in [Star(N), from_adjacency([(0, 1), (1, 2), (2, 3), (0, 3), (0, 2), (4, 5), (5, 6), (6, 7)], name="irregular")]:
    prog = compile_graph(graph)
    assert not any(isinstance(op, GatherRow) for op in prog.ops), prog.describe()
    assert all(isinstance(op, PPermute) for op in prog.ops)
    assert len(prog.ops) <= graph.degree + 1, (len(prog.ops), graph.degree)

    x = np.random.default_rng(0).normal(size=(N, 4, 3)).astype(np.float32)
    f = jax.jit(
        compat.shard_map(
            lambda v: prog.apply_shard(v, "gossip"),
            mesh=mesh, in_specs=P("gossip"), out_specs=P("gossip"),
        )
    )
    counts = assert_no_all_gather(f, jnp.asarray(x))
    assert counts.get("collective-permute", 0) == len(prog.ops), counts
    got = np.asarray(f(jnp.asarray(x)))
    want = np.einsum("ij,j...->i...", graph.mixing_matrix(), x)
    err = float(np.abs(got - want).max())
    assert err < 1e-5, err
    print(f"{graph.name}: {len(prog.ops)} permutes, no all-gather, err={err:.2e}")

# --- fused Pallas apply inside shard_map == optimizer + dense mix oracle ----
from repro.kernels.gossip_update import fused_apply_shard

prog = compile_graph(Star(N))
rng = np.random.default_rng(1)
P_LEN = 96
theta = rng.normal(size=(N, P_LEN)).astype(np.float32)
grads = rng.normal(size=(N, P_LEN)).astype(np.float32)
mom = rng.normal(size=(N, P_LEN)).astype(np.float32)
lr, beta = 0.05, 0.9


def node_fused(t, g, m):
    new_p, new_m = fused_apply_shard(
        prog, {"w": t}, {"w": g}, {"w": m}, "gossip", lr=lr, beta=beta,
        block=32,
    )
    return new_p["w"], new_m["w"]


ff = jax.jit(
    compat.shard_map(
        node_fused, mesh=mesh,
        in_specs=(P("gossip"), P("gossip"), P("gossip")),
        out_specs=(P("gossip"), P("gossip")),
    )
)
got_p, got_m = ff(jnp.asarray(theta), jnp.asarray(grads), jnp.asarray(mom))
m_new = beta * mom + grads
theta_star = theta - lr * m_new
want_p = prog.matrix() @ theta_star
np.testing.assert_allclose(np.asarray(got_p), want_p, atol=1e-5)
np.testing.assert_allclose(np.asarray(got_m), m_new, atol=1e-6)
assert_no_all_gather(ff, jnp.asarray(theta), jnp.asarray(grads), jnp.asarray(mom))
print("fused_apply_shard == dense oracle, no all-gather")

# --- fault rows inside shard_map == masked update + degraded dense mix ------
from repro.core.schedule import degraded_matrix

update = np.array([1, 1, 0, 1, 1, 1, 1, 0], bool)
alive = np.array([1, 0, 1, 1, 1, 1, 1, 1], bool)
fault = {
    "update": jnp.asarray(update, jnp.float32),
    "alive": jnp.asarray(alive, jnp.float32),
    "link": None,
}


def node_fused_faulty(t, g, m):
    new_p, new_m = fused_apply_shard(
        prog, {"w": t}, {"w": g}, {"w": m}, "gossip", lr=lr, beta=beta,
        fault=fault, block=32,
    )
    return new_p["w"], new_m["w"]


fff = jax.jit(
    compat.shard_map(
        node_fused_faulty, mesh=mesh,
        in_specs=(P("gossip"), P("gossip"), P("gossip")),
        out_specs=(P("gossip"), P("gossip")),
    )
)
got_p, got_m = fff(jnp.asarray(theta), jnp.asarray(grads), jnp.asarray(mom))
m_want = np.where(update[:, None], beta * mom + grads, mom)
theta_star = np.where(update[:, None], theta - lr * m_want, theta)
want_p = degraded_matrix(prog.matrix(), alive) @ theta_star
np.testing.assert_allclose(np.asarray(got_p), want_p, atol=1e-5)
np.testing.assert_allclose(np.asarray(got_m), m_want, atol=1e-6)
print("fused_apply_shard fault rows == masked oracle")
print("STAR_HLO_OK")
