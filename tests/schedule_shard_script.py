"""Subprocess body for test_schedule.py: the shard interpreter on 8 host
devices.

Checks, for every registered topology family:
  1. ``GossipProgram.apply_shard`` inside a full-manual shard_map equals the
     dense mixing-matrix oracle to <= 1e-5;
  2. the compiled HLO carries exactly the collectives the program promises —
     a circulant graph lowers to ONE collective-permute per offset with no
     all-gather (the no-regression acceptance bar), complete to one
     all-reduce, and only the dense/irregular fallback may all-gather.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.graphs import (
    Complete, Exponential, Ring, RingLattice, Star, Torus,
    one_peer_exponential, random_matching,
)
from repro.core.schedule import (
    AllReduce, GatherRow, PPermute, compile_graph, dense_program,
)

N = 8
mesh = compat.make_mesh((N,), ("gossip",))
x = np.random.default_rng(0).normal(size=(N, 4, 3)).astype(np.float32)

graphs = [
    Ring(N), Torus(N), Torus(N, grid=(2, 4)), RingLattice(N, 4),
    Exponential(N), Complete(N), Star(N),
    one_peer_exponential(N, 1), random_matching(N, seed=2),
]
programs = [compile_graph(g) for g in graphs] + [dense_program(Ring(N))]
oracles = [g.mixing_matrix() for g in graphs] + [Ring(N).mixing_matrix()]

failures = []
for prog, w in zip(programs, oracles):
    f = compat.shard_map(
        lambda v: prog.apply_shard(v, "gossip"),
        mesh=mesh, in_specs=P("gossip"), out_specs=P("gossip"),
    )
    jf = jax.jit(f)
    got = np.asarray(jf(jnp.asarray(x)))
    want = np.einsum("ij,j...->i...", w, x)
    err = float(np.abs(got - want).max())
    hlo = jf.lower(jnp.asarray(x)).compile().as_text()
    n_cp = hlo.count(" collective-permute(")
    n_ag = hlo.count(" all-gather(")
    n_ar = hlo.count(" all-reduce(")
    want_cp = sum(isinstance(op, PPermute) for op in prog.ops)
    want_ar = sum(isinstance(op, AllReduce) for op in prog.ops)
    want_ag = sum(isinstance(op, GatherRow) for op in prog.ops)
    ok = (
        err < 1e-5
        and n_cp == want_cp
        and n_ar == want_ar
        and n_ag == want_ag
    )
    print(
        f"{prog.name:24s} err={err:.2e} cp={n_cp}/{want_cp} "
        f"ar={n_ar}/{want_ar} ag={n_ag}/{want_ag} {'OK' if ok else 'FAIL'}"
    )
    if not ok:
        failures.append(prog.name)

if failures:
    print(f"SHARD_FAILURES={','.join(failures)}")
    sys.exit(1)

# --- consensus distance: shard realization == stacked realization ----------
from repro.core.consensus import (
    consensus_distance_shard, consensus_distance_stacked, consensus_sq_shard,
)

tree = {
    "a": jnp.asarray(
        np.random.default_rng(1).normal(size=(N, 4, 3)).astype(np.float32)
    ),
    "b": jnp.asarray(
        np.random.default_rng(2).normal(size=(N, 5)).astype(np.float32)
    ),
}
xi_stacked = float(consensus_distance_stacked(tree))
f_xi = jax.jit(
    compat.shard_map(
        lambda v: (
            consensus_distance_shard(v, "gossip")[None],
            consensus_sq_shard(v, "gossip")[None],
        ),
        mesh=mesh,
        in_specs=P("gossip"),
        out_specs=(P("gossip"), P("gossip")),
    )
)
xi_shard, sq_shard = f_xi(tree)
xi_shard = np.asarray(xi_shard)  # (N,): the same scalar on every node
from repro.core.consensus import consensus_sq_stacked

sq_stacked = np.asarray(consensus_sq_stacked(tree))
err_xi = float(np.abs(xi_shard - xi_stacked).max())
err_sq = float(np.abs(np.asarray(sq_shard) - sq_stacked).max())
print(f"consensus shard==stacked xi_err={err_xi:.2e} sq_err={err_sq:.2e}")
if err_xi > 1e-5 or err_sq > 1e-4:
    print("CONSENSUS_SHARD_FAIL")
    sys.exit(1)

print("SHARD_INTERPRETER_OK")
