import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..', 'src'))
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models.common import init_params
from repro.models.moe import apply_moe, apply_moe_manual_ep, moe_defs
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
d, f, e = 16, 32, 8
params = init_params(moe_defs(d, f, e), key)
x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, d))

with jax.set_mesh(mesh):
    want, aux0 = apply_moe(params, x, top_k=2, capacity=16)
    shardings = {
        "router": NamedSharding(mesh, P(None, None)),
        "w_gate": NamedSharding(mesh, P("model", None, None)),
        "w_up": NamedSharding(mesh, P("model", None, None)),
        "w_down": NamedSharding(mesh, P("model", None, None)),
    }
    ps = {k: jax.device_put(v, shardings[k]) for k, v in params.items()}
    fn = jax.jit(lambda p, xx: apply_moe_manual_ep(p, xx, top_k=2, capacity=16))
    got, aux1 = fn(ps, x)
    import numpy as np
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    assert abs(float(aux0) - float(aux1)) < 1e-6
    # check the collective schedule: exactly psum (all-reduce), no gathers of buffers
    txt = fn.lower(ps, x).compile().as_text()
    import re
    ar = len(re.findall(r' all-reduce\(', txt)); ag = len(re.findall(r' all-gather\(', txt))
    print(f"manual EP == gather oracle OK; all-reduce={ar} all-gather={ag}")
    # grad flows
    g = jax.grad(lambda p: apply_moe_manual_ep(p, x, top_k=2, capacity=16)[0].sum())(ps)
    assert all(float(jnp.abs(v).sum()) > 0 for v in jax.tree.leaves(g))
    print("grads OK")
