"""Decode-vs-forward equivalence and chunked-recurrence correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.models.recurrence import (
    rwkv_chunked, rwkv_scan_reference, ssd_chunked, ssd_scan_reference,
)

B, S = 2, 12


def _decode_chain(cfg, params, tokens, n_slots, window=None):
    state = tfm.init_decode_state(cfg, tokens.shape[0], n_slots, window=window)
    logits = []
    for t in range(tokens.shape[1]):
        lg, state = tfm.decode_step(
            params, cfg, tokens[:, t : t + 1], jnp.int32(t), state, window=window
        )
        logits.append(lg)
    return jnp.stack(logits, axis=1)


@pytest.mark.parametrize(
    "arch", ["granite-8b", "qwen2.5-14b", "phi3.5-moe-42b-a6.6b", "rwkv6-1.6b", "zamba2-7b"]
)
def test_decode_matches_forward(arch):
    """Token-by-token decode == full causal forward (all families).

    MoE needs ample capacity here: full-sequence forward drops tokens at the
    capacity limit, single-token decode never does — that's routing
    semantics, not a bug."""
    cfg = get_config(arch + "-reduced")
    if cfg.n_experts:
        import dataclasses

        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    key = jax.random.PRNGKey(1)
    params = tfm.init_model(cfg, key, tp_size=1)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full, _, _ = tfm.forward(params, cfg, tokens)
    dec = _decode_chain(cfg, params, tokens, n_slots=S)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=3e-3, rtol=1e-3)


def test_sliding_window_decode_matches_windowed_forward():
    cfg = get_config("granite-8b-reduced")
    key = jax.random.PRNGKey(2)
    params = tfm.init_model(cfg, key, tp_size=1)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    w = 5
    full, _, _ = tfm.forward(params, cfg, tokens, window=w)
    dec = _decode_chain(cfg, params, tokens, n_slots=w, window=w)  # ring cache
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=3e-3, rtol=1e-3)


def test_chunked_attention_equals_reference():
    import dataclasses

    cfg = get_config("granite-8b-reduced")
    key = jax.random.PRNGKey(3)
    params = tfm.init_model(cfg, key, tp_size=1)
    tokens = jax.random.randint(key, (B, 2 * S), 0, cfg.vocab)
    ref, _, _ = tfm.forward(params, cfg, tokens)
    ch, _, _ = tfm.forward(
        params, dataclasses.replace(cfg, attn_impl="chunked", attn_chunk=8), tokens
    )
    np.testing.assert_allclose(np.asarray(ch), np.asarray(ref), atol=3e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# Chunked linear recurrences vs step-by-step scan oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("l,chunk", [(16, 4), (15, 4), (8, 8), (21, 5)])
def test_rwkv_chunked_equals_scan(l, chunk):
    key = jax.random.PRNGKey(0)
    b, h, n = 2, 3, 8
    ks = jax.random.split(key, 6)
    r, k, v = (jax.random.normal(ks[i], (b, l, h, n)) for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, l, h, n)) * 0.5)
    u = jax.random.normal(ks[4], (h, n)) * 0.1
    s0 = jax.random.normal(ks[5], (b, h, n, n)) * 0.1
    o1, s1 = rwkv_chunked(r, k, v, logw, u, s0, chunk=chunk)
    o2, s2 = rwkv_scan_reference(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("l,chunk", [(16, 4), (13, 4), (32, 8)])
def test_ssd_chunked_equals_scan(l, chunk):
    key = jax.random.PRNGKey(7)
    b, h, p, n = 2, 3, 4, 8
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.3
    b_in = jax.random.normal(ks[3], (b, l, n))
    c_in = jax.random.normal(ks[4], (b, l, n))
    d_skip = jax.random.normal(ks[5], (h,)) * 0.2
    h0 = jnp.zeros((b, h, p, n))
    y1, h1 = ssd_chunked(x, dt, a_log, b_in, c_in, d_skip, h0, chunk=chunk)
    y2, h2 = ssd_scan_reference(x, dt, a_log, b_in, c_in, d_skip, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4, rtol=1e-4)


def test_rwkv_strong_decay_no_overflow():
    """Strongly-decaying channels must not overflow the chunked form."""
    b, l, h, n = 1, 64, 2, 4
    key = jax.random.PRNGKey(9)
    r = jax.random.normal(key, (b, l, h, n))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, l, h, n))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, l, h, n))
    logw = jnp.full((b, l, h, n), -7.0)  # w = e^-7 per step
    u = jnp.zeros((h, n))
    s0 = jnp.zeros((b, h, n, n))
    o, s = rwkv_chunked(r, k, v, logw, u, s0, chunk=32)
    assert bool(jnp.all(jnp.isfinite(o))) and bool(jnp.all(jnp.isfinite(s)))
    o2, _ = rwkv_scan_reference(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o2), atol=1e-4)
