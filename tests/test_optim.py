"""Optimizers, schedules, and the paper's LR scaling policies."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import constant, get_optimizer, lr_scale, one_cycle, warmup_multistep


def test_sgd_momentum_manual():
    opt = get_optimizer("sgd", momentum=0.9)
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -1.0])}
    st = opt.init(p)
    p1, st1 = opt.update(g, st, p, 0.1)
    np.testing.assert_allclose(p1["w"], [1.0 - 0.05, 2.0 + 0.1], atol=1e-6)
    p2, st2 = opt.update(g, st1, p1, 0.1)
    # m2 = 0.9*0.5 + 0.5 = 0.95
    np.testing.assert_allclose(p2["w"][0], p1["w"][0] - 0.1 * 0.95, atol=1e-6)


def test_sgd_weight_decay():
    opt = get_optimizer("sgd", momentum=0.0, weight_decay=0.1)
    p = {"w": jnp.array([2.0])}
    g = {"w": jnp.array([0.0])}
    p1, _ = opt.update(g, opt.init(p), p, 1.0)
    np.testing.assert_allclose(p1["w"], [2.0 - 0.2], atol=1e-6)


def test_adamw_first_step_is_lr_sized():
    opt = get_optimizer("adamw", weight_decay=0.0)
    p = {"w": jnp.array([0.0])}
    g = {"w": jnp.array([3.0])}
    p1, _ = opt.update(g, opt.init(p), p, 1e-2)
    np.testing.assert_allclose(p1["w"], [-1e-2], rtol=1e-4)


def test_lars_trust_ratio_scales_step():
    opt = get_optimizer("lars", momentum=0.0, weight_decay=0.0, trust_coefficient=0.01)
    p = {"w": jnp.full((4,), 10.0)}
    g = {"w": jnp.full((4,), 1.0)}
    p1, _ = opt.update(g, opt.init(p), p, 1.0)
    # trust = 0.01 * |p| / |g| = 0.01 * 20 / 2 = 0.1 -> step 0.1*g
    np.testing.assert_allclose(p1["w"], 10.0 - 0.1, rtol=1e-5)


def test_all_optimizers_descend_quadratic():
    target = jnp.arange(4.0)
    for name, lr in [("sgd", 0.1), ("adamw", 0.05), ("lars", 5.0)]:
        opt = get_optimizer(name)
        p = {"w": jnp.zeros(4)}
        st = opt.init(p)
        for _ in range(200):
            g = jax.grad(lambda pp: jnp.sum((pp["w"] - target) ** 2))(p)
            p, st = opt.update(g, st, p, lr)
        err = float(jnp.linalg.norm(p["w"] - target))
        assert err < 0.5, (name, err)


# -- paper Table 2 scaling policies --------------------------------------------

def test_lr_scale_linear_vs_sqrt():
    """Obs. 3: sqrt scaling is the rescue at large scale/degree."""
    lin = lr_scale("linear", global_batch=1024, base_batch=256, graph_degree=3)
    sq = lr_scale("sqrt", global_batch=1024, base_batch=256, graph_degree=3)
    assert lin == pytest.approx(16.0)
    assert sq == pytest.approx(4.0)
    assert sq < lin  # sqrt reduces the resulting LR significantly (§3.2)


def test_lr_scale_grows_with_connectivity():
    """Table 2: s = batch * (k+1) / base — degree-aware scaling."""
    s_ring = lr_scale("linear", global_batch=256, graph_degree=2)
    s_complete = lr_scale("linear", global_batch=256, graph_degree=95)
    assert s_complete / s_ring == pytest.approx(96 / 3)


def test_warmup_multistep_shape():
    f = warmup_multistep(0.1, steps_per_epoch=10, warmup_epochs=5,
                         milestones=(30, 60, 80), decay=0.1, scale=2.0)
    assert f(0) < f(49)                       # warming up
    assert f(49) == pytest.approx(0.2, rel=1e-2)
    assert f(10 * 30) == pytest.approx(0.02, rel=1e-6)
    assert f(10 * 80) == pytest.approx(0.0002, rel=1e-6)


def test_one_cycle_shape():
    f = one_cycle(0.15, steps_per_epoch=10)
    assert f(10) < f(230)        # rising phase
    assert f(230) > f(2990)      # annealing
    assert f(2990) == pytest.approx(0.015, rel=0.1)


def test_constant():
    assert constant(0.3)(12345) == 0.3
