"""End-to-end behaviour: decentralized LM training on synthetic data
learns, Ada adapts its graph mid-run, and the serving loop generates."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.dsgd import make_topology
from repro.core.simulator import DecentralizedSimulator
from repro.core.dbench import DBenchRecorder
from repro.data import SyntheticLM, node_batch_iterator
from repro.models import transformer as tfm
from repro.optim import constant, get_optimizer


def _tiny_cfg():
    return dataclasses.replace(
        get_config("granite-8b-reduced"),
        n_layers=2, d_model=64, d_ff=128, vocab=64,
        n_heads=4, n_kv=2, d_head=16, dtype=jnp.float32, remat=False,
    )


def test_decentralized_lm_training_learns():
    cfg = _tiny_cfg()
    n = 6
    topo = make_topology("d_ada", n, k0=4, gamma_k=1.0)
    sim = DecentralizedSimulator(
        lambda p, b: tfm.loss_fn(p, cfg, b),
        get_optimizer("adamw", weight_decay=0.0),
        topo,
        collect_norms=True,
    )
    src = SyntheticLM(vocab=cfg.vocab, seq_len=16, seed=0, structure=0.95)
    batches = node_batch_iterator(src, n, 4)
    rec = DBenchRecorder(impl="d_ada", n_nodes=n)
    params0 = tfm.init_model(cfg, jax.random.PRNGKey(0), tp_size=1)
    state, hist = sim.run(
        params0,
        batches,
        n_steps=30,
        lr_schedule=constant(3e-3),
        steps_per_epoch=10,  # Ada: k=3 (epoch 0) -> k=2 (epoch 1+)
        recorder=rec,
    )
    first, last = hist["loss"][0], np.mean(hist["loss"][-3:])
    assert last < first - 0.3, (first, last)
    # ada actually changed graphs across the run
    assert topo.graph_at(0).degree != topo.graph_at(2).degree
    # dbench collected per-node norms
    assert rec.metric_series("gini").shape[0] == 30


def test_generation_loop_produces_tokens():
    from repro.launch.mesh import make_mesh
    from repro.launch.serve import ServeEngine

    cfg = _tiny_cfg()
    mesh = make_mesh((1, 1), ("data", "model"))
    eng = ServeEngine(cfg, mesh)
    params = tfm.init_model(cfg, jax.random.PRNGKey(1), tp_size=1)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 5), 0, cfg.vocab)
    out = eng.generate(params, prompts, n_new=4, max_len=16)
    assert out.shape == (2, 4)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab)))


def test_checkpoint_resume_bitwise(tmp_path):
    """Stop/restore mid-run reproduces the exact continuation."""
    from repro.checkpoint import load_checkpoint, save_checkpoint

    cfg = _tiny_cfg()
    n = 4
    topo = make_topology("d_ring", n)
    opt = get_optimizer("sgd", momentum=0.9)
    sim = DecentralizedSimulator(lambda p, b: tfm.loss_fn(p, cfg, b), opt, topo)
    src = SyntheticLM(vocab=cfg.vocab, seq_len=16, seed=0)
    params0 = tfm.init_model(cfg, jax.random.PRNGKey(0), tp_size=1)

    state = sim.init(params0)
    for t in range(4):
        batch = {k: jnp.asarray(v) for k, v in src.stacked(n, t, 2).items()}
        state, *_ = sim.train_step(state, batch, 0.01)
    save_checkpoint(str(tmp_path), 4, {"p": state.params, "o": state.opt_state})

    # continue original
    cont = state
    for t in range(4, 6):
        batch = {k: jnp.asarray(v) for k, v in src.stacked(n, t, 2).items()}
        cont, *_ = sim.train_step(cont, batch, 0.01)

    # restore and replay
    restored, step = load_checkpoint(
        str(tmp_path), {"p": state.params, "o": state.opt_state}
    )
    from repro.core.simulator import SimState

    st2 = SimState(
        jax.tree.map(jnp.asarray, restored["p"]),
        jax.tree.map(jnp.asarray, restored["o"]),
        step,
    )
    for t in range(4, 6):
        batch = {k: jnp.asarray(v) for k, v in src.stacked(n, t, 2).items()}
        st2, *_ = sim.train_step(st2, batch, 0.01)

    for a, b in zip(jax.tree.leaves(cont.params), jax.tree.leaves(st2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
