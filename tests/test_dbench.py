"""DBench variance metrics vs direct numpy oracles + rank analysis."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import dbench

ARRS = st.lists(
    st.floats(min_value=0.01, max_value=100.0, allow_nan=False),
    min_size=3, max_size=16,
)


@given(ARRS)
@settings(max_examples=50, deadline=None)
def test_gini_bounds_and_oracle(vals):
    x = np.array(vals)
    g = dbench.gini(x)
    # brute-force oracle
    n = len(x)
    want = np.abs(x[:, None] - x[None, :]).sum() / (2 * n * n * x.mean())
    assert np.allclose(g, want, atol=1e-9)
    assert 0.0 <= float(g) < 1.0


def test_metrics_zero_on_constant():
    x = np.full((8, 3), 7.0)
    rep = dbench.variance_report(x)
    for name, v in rep.items():
        assert np.allclose(v, 0.0), name


@given(ARRS, st.floats(min_value=1.5, max_value=10.0))
@settings(max_examples=30, deadline=None)
def test_metrics_scale_invariance(vals, c):
    """gini/CoV/QCD are scale-invariant; index of dispersion is not."""
    x = np.array(vals)
    for fn in (dbench.gini, dbench.coefficient_of_variation, dbench.quartile_coefficient):
        assert np.allclose(fn(x), fn(c * x), atol=1e-8), fn.__name__


def test_more_dispersion_higher_gini():
    rng = np.random.default_rng(0)
    base = 10 + rng.normal(size=64) * 0.1
    wide = 10 + rng.normal(size=64) * 3.0
    assert dbench.gini(wide) > dbench.gini(base)


def test_param_l2_norms():
    params = {"a": jnp.ones((3, 4)), "b": 2.0 * jnp.ones((5,))}
    norms = dbench.param_l2_norms(params)
    want = sorted([np.sqrt(12.0), np.sqrt(20.0)])
    assert sorted(np.asarray(norms).tolist()) == [float(w) for w in want] or \
        np.allclose(sorted(np.asarray(norms)), want, atol=1e-6)


def test_rank_analysis_orders_implementations():
    iters, leaves = 5, 4
    low = np.full((iters, leaves), 0.1)
    mid = np.full((iters, leaves), 0.5)
    high = np.full((iters, leaves), 0.9)
    ranks = dbench.rank_analysis({"c_complete": low, "d_torus": mid, "d_ring": high})
    assert np.all(ranks["c_complete"] == 1)
    assert np.all(ranks["d_torus"] == 2)
    assert np.all(ranks["d_ring"] == 3)


def test_rank_analysis_ties_get_average_ranks():
    """Equal-dispersion impls must TIE (scipy-style average ranks), not be
    assigned arbitrary distinct ranks by stable argsort order."""
    iters, leaves = 4, 3
    equal = np.full((iters, leaves), 0.5)
    ranks = dbench.rank_analysis(
        {"d_ring": equal, "d_torus": equal.copy(), "c_complete": equal.copy()}
    )
    for name, r in ranks.items():
        assert np.allclose(r, 2.0), (name, r)  # (1+2+3)/3 on every iteration

    # partial tie: two impls equal, one strictly lower
    low = np.full((iters, leaves), 0.1)
    ranks = dbench.rank_analysis(
        {"d_ring": equal, "d_torus": equal.copy(), "c_complete": low}
    )
    assert np.allclose(ranks["c_complete"], 1.0)
    assert np.allclose(ranks["d_ring"], 2.5)   # mean of positions 2 and 3
    assert np.allclose(ranks["d_torus"], 2.5)


def test_recorder_roundtrip():
    rec = dbench.DBenchRecorder(impl="d_ring", n_nodes=4)
    for t in range(3):
        rec.record(t, np.ones(4) * (3 - t), np.abs(np.random.default_rng(t).normal(size=(4, 2))) + 1)
    s = rec.summary()
    assert s["impl"] == "d_ring" and len(s["mean_loss"]) == 3
    assert s["mean_loss"][0] > s["mean_loss"][-1]
    g = rec.metric_series("gini")
    assert g.shape == (3, 2)
