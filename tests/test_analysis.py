"""The static-analysis pipeline (src/repro/analysis/).

Clean-path coverage plus the seeded mutation suite: for every pass, a
deliberately corrupted artifact (non-stochastic row, colliding permute
pair, corrupted bucket-layout caches, an extra retrace, a forbidden
all-gather, an unbounded dispatch loop, an over-budget kernel signature)
must be CAUGHT — a verifier nobody has seen fail is itself unverified.
Also pins the f8 dtype-width regression in the HLO wire accounting and
the structured CollectiveReport (PR 10 satellites).
"""
import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis.budget import (
    SMEM_BUDGET_BYTES, VMEM_BUDGET_BYTES, check_kernel_budget,
    kernel_cell_cost, verify_program_budget,
)
from repro.analysis.collectives import (
    assert_signatures_consistent, collective_signature, lint_dispatch_loops,
    lint_engine_sources, lint_no_forbidden,
)
from repro.analysis.invariants import (
    verify_bench_payload, verify_bucket_layout, verify_degraded,
    verify_program, verify_topology,
)
from repro.analysis.recompile import (
    assert_executables_preenumerated, assert_no_retrace, used_program_keys,
    watch_retrace,
)
from repro.analysis.report import (
    BudgetViolation, CollectiveViolation, InvariantViolation, PassReport,
    RetraceError, run_pass,
)
from repro.core.buckets import BucketLayout
from repro.core.dsgd import make_topology
from repro.core.faults import make_fault_model
from repro.core.graphs import Ring, Star, from_adjacency
from repro.core.schedule import GossipProgram, compile_graph, dense_program
from repro.core.simulator import DecentralizedSimulator
from repro.launch.hlo_analysis import (
    _dtype_width, assert_no_all_gather, collective_counts,
)
from repro.optim.sgd import sgd


def _quad_loss(p, b):
    return jnp.mean((b - p["w"]) ** 2)


def _random_connected_graph(n, seed):
    rng = np.random.default_rng(seed)
    edges = set()
    perm = rng.permutation(n)
    for a, b in zip(perm[:-1], perm[1:]):
        edges.add((min(a, b), max(a, b)))
    for _ in range(int(rng.integers(0, n))):
        i, j = rng.integers(0, n, size=2)
        if i != j:
            edges.add((min(i, j), max(i, j)))
    return from_adjacency(sorted((int(i), int(j)) for i, j in edges))


# ---------------------------------------------------------------------------
# Pass 1 — program verifier: clean path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo_name", ["d_ring", "d_star", "d_one_peer_exp"])
def test_verifier_accepts_registered_families(topo_name):
    topo = make_topology(topo_name, 8)
    assert verify_topology(topo, n_epochs=2) >= 1


def test_verifier_accepts_degraded_and_elastic_realizations():
    fm = make_fault_model("dropout", 8, rate=0.3, seed=3, spare_ranks=2)
    topo = make_topology("d_ring", 8, fault_model=fm)
    verify_topology(topo, n_epochs=1, fault_steps=12)


# ---------------------------------------------------------------------------
# Pass 1 — mutation suite
# ---------------------------------------------------------------------------

def test_mutation_non_stochastic_row_is_caught():
    prog = compile_graph(Ring(8))
    bad = dataclasses.replace(prog, self_weight=0.9)  # rows now sum to > 1
    with pytest.raises(InvariantViolation, match="row .* sums"):
        verify_program(bad)


def test_mutation_colliding_permute_pair_is_caught():
    prog = compile_graph(Ring(8))
    op = prog.ops[0]
    perm = list(op.perm)
    s0, _ = perm[0]
    _, d1 = perm[1]
    perm[0] = (s0, d1)  # two sends now land on one receiver
    bad_op = dataclasses.replace(op, perm=tuple(perm), offset=None)
    bad = dataclasses.replace(prog, ops=(bad_op,) + prog.ops[1:])
    with pytest.raises(InvariantViolation, match="duplicate destination"):
        verify_program(bad)


def test_mutation_swapped_pair_breaks_offset_contract():
    prog = compile_graph(Ring(8))
    op = prog.ops[0]
    assert op.offset is not None  # ring compiles to circulant shifts
    perm = list(op.perm)
    (s0, d0), (s1, d1) = perm[0], perm[1]
    perm[0], perm[1] = (s0, d1), (s1, d0)  # still a bijection, wrong shift
    bad_op = dataclasses.replace(op, perm=tuple(perm))
    bad = dataclasses.replace(prog, ops=(bad_op,) + prog.ops[1:])
    with pytest.raises(InvariantViolation, match="offset"):
        verify_program(bad)


def test_mutation_overlapping_bucket_segments_are_caught():
    layout = BucketLayout((1000, 24, 1000), 256)
    verify_bucket_layout(layout, sizes=(1000, 24, 1000))  # clean first
    segs = [list(b) for b in layout.segments]
    li, start, stop = segs[1][0]
    segs[1][0] = (li, max(0, start - 16), stop)  # overlaps bucket 0's tail
    object.__setattr__(layout, "_segments", tuple(tuple(b) for b in segs))
    with pytest.raises(InvariantViolation):
        verify_bucket_layout(layout, sizes=(1000, 24, 1000))


def test_mutation_non_monotonic_bounds_are_caught():
    layout = BucketLayout((512, 512), 256)
    bounds = list(layout.bounds)
    bounds[1], bounds[2] = bounds[2], bounds[1]
    object.__setattr__(layout, "_bounds", bounds)
    with pytest.raises(InvariantViolation, match="increasing"):
        verify_bucket_layout(layout)


# ---------------------------------------------------------------------------
# Pass 1 — property tests: degraded realizations on random connected graphs
# ---------------------------------------------------------------------------

@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=4, max_value=10))
@settings(max_examples=25, deadline=None)
def test_degraded_matrix_always_verifies(seed, n):
    """Any boolean alive × symmetric link realization of a random connected
    graph's program passes the verifier — ``degraded_matrix`` is closed
    over the invariants (row-stochastic, dead-rank identity, symmetry)."""
    prog = compile_graph(_random_connected_graph(n, seed))
    rng = np.random.default_rng(seed + 1)
    alive = rng.random(n) > 0.35
    link = rng.random((n, n)) > 0.2
    link = np.asarray(link & link.T) | np.eye(n, dtype=bool)
    verify_program(prog)
    verify_degraded(prog, alive)
    verify_degraded(prog, alive, link)


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=10, deadline=None)
def test_drain_boost_realizations_verify(seed):
    """Float (drain-boost) masks stay row-stochastic and verify too."""
    n = 8
    prog = compile_graph(_random_connected_graph(n, seed))
    boost = np.ones(n)
    boost[int(np.random.default_rng(seed).integers(n))] = 1.5
    verify_degraded(prog, boost)


# ---------------------------------------------------------------------------
# Pass 2 — collective linter
# ---------------------------------------------------------------------------

_PERMUTE_HLO = """\
ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64] parameter(0)
  ROOT %cp = f32[64] collective-permute(%p0), channel_id=1, source_target_pairs={{0,1},{1,0}}
}
"""

_PERMUTE_HLO_SWAPPED = """\
ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64] parameter(0)
  ROOT %cp = f32[64] collective-permute(%p0), channel_id=1, source_target_pairs={{0,1},{1,2}}
}
"""

_ALLGATHER_HLO = """\
ENTRY %main (p0: f32[64]) -> f32[512] {
  %p0 = f32[64] parameter(0)
  ROOT %ag.leak = f32[512] all-gather(%p0), channel_id=1, replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
}
"""


def test_collective_signature_reads_rendezvous_identity():
    sig = collective_signature(_PERMUTE_HLO)
    assert len(sig) == 1
    kind, attrs = sig[0]
    assert kind == "collective-permute" and "{0,1}" in attrs
    # channel ids are per-module noise, not rendezvous identity
    assert "channel_id" not in attrs


def test_mutation_diverging_signatures_are_caught():
    assert_signatures_consistent({
        "a": collective_signature(_PERMUTE_HLO),
        "b": collective_signature(_PERMUTE_HLO),
    })
    with pytest.raises(CollectiveViolation, match="diverge"):
        assert_signatures_consistent({
            "masked": collective_signature(_PERMUTE_HLO),
            "unmasked": collective_signature(_PERMUTE_HLO_SWAPPED),
        })


def test_mutation_all_gather_leak_is_caught_with_op_name():
    lint_no_forbidden(_PERMUTE_HLO)  # clean path
    with pytest.raises(CollectiveViolation, match="ag.leak"):
        lint_no_forbidden(_ALLGATHER_HLO)
    # the refactored assert keeps raising AND names the op (satellite)
    with pytest.raises(AssertionError, match="ag.leak"):
        assert_no_all_gather(_ALLGATHER_HLO)


_UNBOUNDED_LOOP_SRC = """\
def dispatch(layout, fn, parts):
    out = []
    for b, w in enumerate(layout.widths):
        out.append(fn(parts[b], w))
    return out
"""

_BOUNDED_LOOP_SRC = """\
import collections, jax

def dispatch(layout, fn, parts):
    out, window = [], collections.deque()
    for b, w in enumerate(layout.widths):
        if len(window) >= MAX_INFLIGHT_BUCKETS:
            jax.block_until_ready(window.popleft())
        r = fn(parts[b], w)
        window.append(r)
        out.append(r)
    return out
"""

_HOST_SEGMENT_SRC = """\
def slice_up(layout, leaves):
    out = []
    for segs in layout.segments:
        out.append([leaves[li][a:b] for li, a, b in segs])
    return out
"""


def test_mutation_unbounded_dispatch_loop_is_caught():
    findings = lint_dispatch_loops(_UNBOUNDED_LOOP_SRC, "fake.py")
    assert len(findings) == 1 and "MAX_INFLIGHT_BUCKETS" in findings[0].message
    assert lint_dispatch_loops(_BOUNDED_LOOP_SRC, "fake.py") == []
    # host-side slicing loops launch nothing and must stay unflagged
    assert lint_dispatch_loops(_HOST_SEGMENT_SRC, "fake.py") == []


def test_engine_dispatch_sources_are_bounded():
    assert lint_engine_sources() == []


# ---------------------------------------------------------------------------
# Pass 3 — recompile sanitizer
# ---------------------------------------------------------------------------

def test_assert_no_retrace_catches_shape_driven_recompile():
    f = jax.jit(lambda x: x * 2 + 1)
    f(jnp.ones(3))  # warm
    with assert_no_retrace("warm shape"):
        f(jnp.ones(3))
    with pytest.raises(RetraceError, match="mid-run recompile"):
        with assert_no_retrace("mutated shape"):
            f(jnp.ones(4))  # the seeded corruption: a new avals signature


def test_watch_retrace_counts_and_allowances():
    g = jax.jit(lambda x: x - 1)
    with watch_retrace() as stats:
        g(jnp.ones(5))
    assert stats.traces >= 1 and stats.compiles >= 1 and not stats.clean
    with assert_no_retrace("declared warmup", allow_traces=4, allow_compiles=4):
        jax.jit(lambda x: x + 3)(jnp.ones(6))


def test_preenumeration_rejects_stray_and_vacuous_caches():
    topo = make_topology("d_ring", 4)
    allowed_key = topo.distinct_programs()[0][1].cache_key
    fake = types.SimpleNamespace(
        _step_cache={(allowed_key, "faulty"): None, ("__grads__", 4): None},
        topology=topo,
    )
    assert assert_executables_preenumerated(fake) == {allowed_key}
    fake._step_cache[("rogue", 4, "deadbeef")] = None
    with pytest.raises(RetraceError, match="beyond the pre-enumerated"):
        assert_executables_preenumerated(fake)
    empty = types.SimpleNamespace(_step_cache={}, topology=topo)
    with pytest.raises(RetraceError, match="vacuous"):
        assert_executables_preenumerated(empty)
    assert assert_executables_preenumerated(empty, require_used=False) == set()


def test_used_program_keys_unwraps_engine_taxonomy():
    key = ("d_ring", 8, "abc")
    cache = {
        key: 1,                                     # bare program
        (key, "faulty"): 1,                         # fault signature
        ("__bucket__", key, 128, True, False): 1,   # bucketed executable
        ("__local__", 8): 1,                        # internal closure
        (("__local__", 8), "faulty"): 1,
        "__bucket_grads__": 1,                      # SPMD string key
        None: 1,                                    # programless trainer key
    }
    assert used_program_keys(cache) == {key}


def test_simulator_debug_mode_runs_clean():
    topo = make_topology("d_ring", 4)
    sim = DecentralizedSimulator(
        _quad_loss, sgd(momentum=0.9), topo, debug_no_retrace=True
    )
    state = sim.init({"w": jnp.zeros(3)})
    for t in range(4):  # warm + guarded steady state: must not raise
        b = jax.random.normal(jax.random.PRNGKey(t), (4, 2, 3))
        state, *_ = sim.train_step(state, b, 0.05)
    assert_executables_preenumerated(sim)


# ---------------------------------------------------------------------------
# Pass 4 — kernel budget checker
# ---------------------------------------------------------------------------

def test_budget_accepts_documented_defaults():
    cost = check_kernel_budget(3, 1024)
    assert cost["smem_bytes"] == 8 + 2 * 4 * 4
    assert cost["vmem_tiles"] == 3 + 3 + 2 and cost["aligned"]
    assert kernel_cell_cost(3, 1024, has_momentum=False)["vmem_tiles"] == 6


def test_mutation_oversized_smem_row_is_caught():
    deg = (SMEM_BUDGET_BYTES // 8) + 8
    with pytest.raises(BudgetViolation, match="SMEM"):
        check_kernel_budget(deg, 1024)


def test_mutation_oversized_vmem_tile_is_caught_compiled_only():
    block = VMEM_BUDGET_BYTES  # tiles * 4 * block far over budget
    with pytest.raises(BudgetViolation, match="VMEM"):
        check_kernel_budget(2, block)
    # the interpreter's host-loop grid is exempt (2^20 default block)
    assert check_kernel_budget(2, 1 << 20, interpret=True)["aligned"]


def test_budget_guard_is_wired_into_kernel_dispatch():
    from repro.kernels.gossip_update import fused_apply_stacked

    prog = compile_graph(Star(8))
    k = jax.random.split(jax.random.PRNGKey(0), 3)
    trees = tuple({"w": jax.random.normal(kk, (8, 32))} for kk in k)
    with pytest.raises(BudgetViolation, match="non-positive"):
        fused_apply_stacked(prog, *trees, lr=0.1, beta=0.9, block=-4)


def test_program_budget_covers_tables_and_skips_dense():
    ring = compile_graph(Ring(8))
    assert verify_program_budget(ring)["smem_bytes"] <= SMEM_BUDGET_BYTES
    assert verify_program_budget(dense_program(Star(8))) is None


# ---------------------------------------------------------------------------
# Satellite — f8 dtype widths + structured CollectiveReport
# ---------------------------------------------------------------------------

def test_f8_dtype_widths_and_fallback():
    for dt in ("f8e4m3", "f8e4m3fn", "f8e5m2", "f8e4m3fnuz", "f8e5m2fnuz"):
        assert _dtype_width(dt) == 1
    assert _dtype_width("f8e8m0fnu") == 1   # unknown f8 variant: bit fallback
    assert _dtype_width("s4") == 1          # sub-byte rounds up
    assert _dtype_width("bf16") == 2 and _dtype_width("u64") == 8
    assert _dtype_width("pred") == 1


def test_f8_collective_wire_bytes_regression():
    hlo = _PERMUTE_HLO.replace("f32", "f8e4m3fn")
    report = collective_counts(hlo)
    # 64 one-byte elements on the wire — the old table billed 4 B/elt
    assert report.wire_bytes["collective-permute"] == 64
    assert report.total == 1


def test_collective_report_is_structured():
    report = collective_counts(_ALLGATHER_HLO)
    assert report["all-gather"] == 1
    assert report.op_names["all-gather"] == ("ag.leak",)
    assert report.offending(("all-gather",)) == {"all-gather": ("ag.leak",)}
    assert report.offending(("all-reduce",)) == {}
    clean = assert_no_all_gather(_PERMUTE_HLO)  # returns the report now
    assert clean["collective-permute"] == 1 and clean.total == 1


# ---------------------------------------------------------------------------
# Satellite — bench payload schema gate
# ---------------------------------------------------------------------------

def test_bench_payload_gate():
    verify_bench_payload("step_time", {"ring/n8": {"mean_ms": 1.0}})
    with pytest.raises(InvariantViolation, match="non-empty dict"):
        verify_bench_payload("step_time", [])
    with pytest.raises(InvariantViolation, match="not a dict"):
        verify_bench_payload("step_time", {"ring/n8": 3.0})
    with pytest.raises(InvariantViolation, match="key"):
        verify_bench_payload("step_time", {"ring n8!": {"mean_ms": 1.0}})
    with pytest.raises(InvariantViolation, match="JSON"):
        verify_bench_payload("step_time", {"ring/n8": {"x": float("nan")}})


def test_save_bench_section_is_gated(tmp_path, monkeypatch):
    import benchmarks.common as common

    monkeypatch.setattr(common, "BENCH_PATH", str(tmp_path / "BENCH.json"))
    path = common.save_bench_section("step_time", {"ring/n8": {"ms": 2.0}})
    assert "BENCH" in path
    with pytest.raises(InvariantViolation):
        common.save_bench_section("step_time", {"bad key!": {"ms": 2.0}})


# ---------------------------------------------------------------------------
# Report plumbing
# ---------------------------------------------------------------------------

def test_run_pass_collects_findings_per_subject():
    def boom():
        raise InvariantViolation("synthetic")

    report = run_pass("invariants", [("good", lambda: None), ("bad", boom)])
    assert report.checked == 2 and not report.ok
    assert [f.subject for f in report.findings] == ["bad"]
    with pytest.raises(AssertionError, match="synthetic"):
        report.raise_if_failed()
    clean = PassReport("x", checked=3)
    assert clean.ok and "ok" in clean.summary()
