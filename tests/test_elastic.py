"""Elastic membership at scale (PR 6): concurrent failures, preemption
drains, true mid-run joins, virtual-node sharding, crash-consistent resume.

The load-bearing claims pinned here:

* Mask composition — degrading a program by mask A and runtime-masking by
  mask B realizes exactly ``degraded_matrix(W, A & B)``, so a k-node
  concurrent crash rides runtime masks over the existing single-node-out
  programs and compiles ZERO extra executables.
* The composed result stays symmetric + doubly stochastic over the
  survivor set (dead rows identity).
* A preemption drain's float boost mask keeps W doubly stochastic (mean
  preserved every drain step), and ``drain_handoff`` makes the survivors'
  post-departure mean EXACTLY the pre-departure global mean.
* Same-step membership events coalesce into ONE controller re-arm log
  entry.
* Joins grow the simulator past its initial n, re-derive the topology
  family, and compile nothing beyond the pre-declared growth set.
* ``shard_nodes`` (virtual-node sharding) is a numeric no-op.
* Interrupted + resumed == uninterrupted, bit-identically, including the
  controller's transition/event/trace logs.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.recompile import assert_executables_preenumerated
from repro.core.ada import AdaSchedule
from repro.core.consensus import ConsensusController
from repro.core.dsgd import make_topology
from repro.core.faults import (
    ConcurrentCrash, Join, Preemption, admit_node, degraded_matrix,
    drain_handoff, make_fault_model,
)
from repro.core.graphs import from_adjacency
from repro.core.schedule import compile_graph
from repro.core.simulator import DecentralizedSimulator
from repro.optim.sgd import sgd


def _quad_loss(p, b):
    return jnp.mean((b - p["w"]) ** 2)


def _random_connected_graph(n, seed):
    rng = np.random.default_rng(seed)
    edges = set()
    perm = rng.permutation(n)
    for a, b in zip(perm[:-1], perm[1:]):
        edges.add((min(a, b), max(a, b)))
    for _ in range(int(rng.integers(0, n))):
        i, j = rng.integers(0, n, size=2)
        if i != j:
            edges.add((min(i, j), max(i, j)))
    return from_adjacency(sorted((int(i), int(j)) for i, j in edges))


def _realized_matrix(program, alive_a, alive_b):
    """The matrix actually applied by degrade(A) + runtime-mask(B)."""
    n = program.n
    eye = {"w": jnp.eye(n, dtype=jnp.float32)}
    out = program.degrade(tuple(bool(a) for a in alive_a)).apply_masked(
        eye, jnp.asarray(alive_b, jnp.float32)
    )
    return np.asarray(out["w"], dtype=np.float64)


# ---------------------------------------------------------------------------
# Satellite: composed-mask property test vs the dense oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_composed_masks_equal_dense_oracle_two_crashes(seed):
    """degrade(kill a) then runtime-mask(kill b) == degraded_matrix(W, both
    dead) <= 1e-6 on random connected graphs — the identity that lets
    ``ConcurrentCrash`` compose k crashes over single-node-out programs."""
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(5, 12))
    g = _random_connected_graph(n, seed)
    program = compile_graph(g)
    a, b = rng.choice(n, size=2, replace=False)
    mask_a = np.ones(n, dtype=bool)
    mask_a[a] = False
    mask_b = np.ones(n, dtype=bool)
    mask_b[b] = False

    realized = _realized_matrix(program, mask_a, mask_b)
    oracle = degraded_matrix(g.mixing_matrix(), mask_a & mask_b)
    assert np.max(np.abs(realized - oracle)) <= 1e-6

    # survivor-set structure: symmetric + doubly stochastic rows AND cols,
    # dead rows exactly identity
    surv = mask_a & mask_b
    block = realized[np.ix_(surv, surv)]
    assert np.max(np.abs(block - block.T)) <= 1e-6
    np.testing.assert_allclose(block.sum(axis=0), 1.0, atol=1e-6)
    np.testing.assert_allclose(block.sum(axis=1), 1.0, atol=1e-6)
    for d in np.nonzero(~surv)[0]:
        row = np.zeros(n)
        row[d] = 1.0
        np.testing.assert_allclose(realized[d], row, atol=1e-6)


def test_composed_masks_match_direct_multinode_degrade():
    """Composing over DISJOINT dead sets equals direct multi-node
    degradation — order-free, so the engines need no event ordering."""
    g = _random_connected_graph(9, 3)
    program = compile_graph(g)
    mask_a = np.array([True] * 9)
    mask_a[2] = False
    mask_b = np.array([True] * 9)
    mask_b[6] = False
    ab = _realized_matrix(program, mask_a, mask_b)
    ba = _realized_matrix(program, mask_b, mask_a)
    direct = np.asarray(
        program.degrade(tuple(mask_a & mask_b)).apply_masked(
            {"w": jnp.eye(9, dtype=jnp.float32)},
            jnp.ones(9, jnp.float32),
        )["w"],
        dtype=np.float64,
    )
    assert np.max(np.abs(ab - ba)) <= 1e-6
    assert np.max(np.abs(ab - direct)) <= 1e-6


# ---------------------------------------------------------------------------
# ConcurrentCrash
# ---------------------------------------------------------------------------

def test_concurrent_crash_timeline_and_modes():
    m = ConcurrentCrash(n=10, rate=0.6, seed=4, k=3, down_steps=4)
    assert len(set(m.victims)) == 3
    # pure fn(seed, step): same realization from a twin model
    twin = ConcurrentCrash(n=10, rate=0.6, seed=4, k=3, down_steps=4)
    for t in range(15):
        np.testing.assert_array_equal(m.at(t).alive, twin.at(t).alive)
    # composed mode: selection mask stays all-ones even while nodes are dead
    t_dead = max(o for o in m.onsets)
    fr = m.at(t_dead)
    assert not fr.program_alive.all()
    assert fr.selection_mask().all()
    # rejoins fire per victim at its own off step
    rejoined = {v for t in range(30) for v in m.at(t).rejoin}
    assert rejoined == set(m.victims)


def test_concurrent_enumerated_masks_are_bounded_and_realized():
    m = ConcurrentCrash(
        n=10, rate=0.6, seed=4, k=3, down_steps=4, enumerate_programs=True
    )
    masks = m.program_masks()
    # <= 2k timeline-realized masks, never the C(n, k) combinatorial set
    assert 1 <= len(masks) <= 2 * 3
    realized = set()
    for t in range(40):
        key = tuple(bool(a) for a in m.at(t).program_alive)
        if not all(key):
            realized.add(key)
    assert realized == set(masks)
    # enumerated mode selects the true membership
    t_dead = max(o for o in m.onsets)
    assert not m.at(t_dead).selection_mask().all()


def test_concurrent_compiles_no_more_executables_than_fault_free():
    """Acceptance bar (engine-level, simulator): a composed concurrent-
    crash run's executable cache is no larger than the fault-free run's."""
    def _run(fault_model):
        topo = make_topology("d_ring", 8, fault_model=fault_model)
        sim = DecentralizedSimulator(_quad_loss, sgd(0.1), topo)
        state = sim.init({"w": jnp.zeros((3,), jnp.float32)})
        rng = np.random.default_rng(0)
        for _ in range(10):
            b = jnp.asarray(rng.normal(size=(8, 2, 3)).astype(np.float32))
            state, _, _ = sim.train_step(state, b, 0.05)
        assert_executables_preenumerated(sim)
        return len(sim._step_cache)

    base = _run(None)
    composed = _run(make_fault_model("concurrent", 8, rate=0.7, seed=1, k=2))
    assert composed <= base


# ---------------------------------------------------------------------------
# Preemption: drain boost + exact mean-preserving handoff
# ---------------------------------------------------------------------------

def test_drain_boost_keeps_matrix_doubly_stochastic():
    g = _random_connected_graph(8, 7)
    program = compile_graph(g)
    boost = np.ones(8)
    boost[3] = 1.5
    realized = np.asarray(
        program.apply_masked(
            {"w": jnp.eye(8, dtype=jnp.float32)},
            jnp.asarray(boost, jnp.float32),
        )["w"],
        dtype=np.float64,
    )
    oracle = degraded_matrix(g.mixing_matrix(), boost)
    assert np.max(np.abs(realized - oracle)) <= 1e-6
    np.testing.assert_allclose(realized.sum(axis=0), 1.0, atol=1e-6)
    np.testing.assert_allclose(realized.sum(axis=1), 1.0, atol=1e-6)
    assert np.max(np.abs(realized - realized.T)) <= 1e-6


def test_preemption_departs_once_after_drain():
    m = Preemption(n=8, rate=0.5, seed=2, drain_steps=3)
    a, d = m.announce_step, m.depart_step
    assert d == a + 3
    for t in range(a, d):
        fr = m.at(t)
        assert fr.alive[m.victim] == pytest.approx(1.5)
        assert fr.update.all() and fr.program_alive.all()
        assert fr.faulty  # float boost must route through the masked step
    departs = [t for t in range(d + 10) if m.at(t).depart]
    assert departs == [d]
    assert not m.at(d + 5).program_alive[m.victim]
    # one single-node-out degraded program, like a hard crash
    assert len(m.program_masks()) == 1


def test_drain_handoff_preserves_global_mean_exactly():
    rng = np.random.default_rng(11)
    n, node = 9, 4
    stacked = {"w": jnp.asarray(rng.normal(size=(n, 5)).astype(np.float32))}
    alive = np.ones(n, dtype=bool)
    alive[node] = False
    out = drain_handoff(stacked, node, [3, 5, 8], alive)
    pre_mean = np.asarray(stacked["w"], np.float64).mean(axis=0)
    post = np.asarray(out["w"], np.float64)
    surv_mean = post[alive].mean(axis=0)
    np.testing.assert_allclose(surv_mean, pre_mean, atol=1e-6)
    # non-neighbors untouched
    untouched = [i for i in range(n) if i not in (3, 5, 8)]
    np.testing.assert_array_equal(
        post[untouched], np.asarray(stacked["w"])[untouched]
    )


def test_preemption_preserves_survivor_mean_hard_crash_does_not():
    """The drain's whole point: a planned departure (boosted drain + exact
    handoff) leaves the survivors' mean AT the pre-event global mean, while
    a hard crash of a node holding distinct state jumps it — the Xi_t
    discontinuity the elastic benchmark measures.  Pure gossip (lr=0) so
    the membership event is the only mean-moving force."""
    from repro.core.simulator import SimState

    def _mean_jump(kind):
        fm = make_fault_model(kind, 8, rate=0.5, seed=2, drain_steps=3)
        topo = make_topology("d_ring", 8, fault_model=fm)
        sim = DecentralizedSimulator(_quad_loss, sgd(0.1), topo)
        state = sim.init({"w": jnp.zeros((4,), jnp.float32)})
        rng = np.random.default_rng(5)
        state = SimState(
            {"w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))},
            state.opt_state, 0,
        )
        event = fm.depart_step if kind == "preempt" else fm.crash_step
        zero = jnp.zeros((8, 2, 4), jnp.float32)
        for _ in range(event):
            state, _, _ = sim.train_step(state, zero, 0.0)
        pre_mean = np.asarray(state.params["w"], np.float64).mean(axis=0)
        state, _, _ = sim.train_step(state, zero, 0.0)
        surv = np.asarray(fm.at(event).alive) != 0
        post_mean = (
            np.asarray(state.params["w"], np.float64)[surv].mean(axis=0)
        )
        return float(np.abs(post_mean - pre_mean).max())

    assert _mean_jump("preempt") <= 1e-6
    assert _mean_jump("crash") > 1e-3


# ---------------------------------------------------------------------------
# Satellite: same-step membership events coalesce into one re-arm entry
# ---------------------------------------------------------------------------

def _controller(n=8):
    return ConsensusController(
        schedule=AdaSchedule(n_nodes=n, k0=3, gamma_k=0.02, k_floor="one_peer"),
        target=0.5,
    )


def test_rearm_coalesces_same_step_events():
    ctl = _controller()
    ctl.rearm(5, "membership")
    ctl.rearm(5, "membership")
    ctl.rearm(5, "rejoin")
    ctl.rearm(9, "membership")
    assert ctl.events == [(5, "membership+rejoin"), (9, "membership")]


def test_simultaneous_concurrent_crash_logs_single_rearm():
    """A k-node same-step crash changes the membership key once; the
    controller log must carry ONE entry for that step, not k."""
    fm = ConcurrentCrash(n=8, rate=0.999, seed=0, k=3)
    # near-1 rate => geometric onsets all equal 1: a simultaneous crash
    assert len(set(fm.onsets)) == 1
    topo = make_topology("d_ada", 8, consensus_target=0.25,
                         k_floor="one_peer", fault_model=fm)
    sim = DecentralizedSimulator(_quad_loss, sgd(0.1), topo)
    state = sim.init({"w": jnp.zeros((3,), jnp.float32)})
    rng = np.random.default_rng(0)
    for _ in range(4):
        b = jnp.asarray(rng.normal(size=(8, 2, 3)).astype(np.float32))
        state, _, _ = sim.train_step(state, b, 0.05)
    events = topo.controller.events
    assert len(events) == 1 and events[0][0] == fm.onsets[0]


# ---------------------------------------------------------------------------
# Join: true mid-run growth
# ---------------------------------------------------------------------------

def test_join_grows_membership_and_topology():
    fm = Join(n=4, rate=0.0, seed=0, join_steps=(3, 5))
    assert fm.elastic and fm.membership_sizes() == (4, 5, 6)
    topo = make_topology("d_ring", 4, fault_model=fm)
    sim = DecentralizedSimulator(_quad_loss, sgd(0.1), topo)
    state = sim.init({"w": jnp.zeros((3,), jnp.float32)})
    rng = np.random.default_rng(0)
    for t in range(8):
        m = fm.n_at(t)
        b = jnp.asarray(rng.normal(size=(m, 2, 3)).astype(np.float32))
        state, loss, _ = sim.train_step(state, b, 0.05)
        assert state.params["w"].shape[0] == m
        assert loss.shape[0] == m
    assert sim.n == 6 and sim.topology.n_nodes == 6
    assert np.isfinite(np.asarray(state.params["w"])).all()


def test_join_compiles_only_predeclared_sizes():
    """Programs for every pre-declared size are enumerable up front; the
    run compiles nothing beyond that set (zero mid-run surprises)."""
    fm = Join(n=4, rate=0.0, seed=0, join_steps=(2,))
    topo = make_topology("d_ring", 4, fault_model=fm)
    allowed = {p.cache_key for _, p in topo.distinct_programs()}
    assert {p.n for _, p in topo.distinct_programs()} == {4, 5}
    sim = DecentralizedSimulator(_quad_loss, sgd(0.1), topo)
    state = sim.init({"w": jnp.zeros((3,), jnp.float32)})
    rng = np.random.default_rng(0)
    for t in range(6):
        m = fm.n_at(t)
        b = jnp.asarray(rng.normal(size=(m, 2, 3)).astype(np.float32))
        state, _, _ = sim.train_step(state, b, 0.05)
    used = assert_executables_preenumerated(sim)
    assert used <= allowed


def test_joining_node_adopts_neighbor_average():
    stacked = {"w": jnp.asarray(np.arange(8, dtype=np.float32).reshape(4, 2))}
    grown = admit_node(stacked, [0, 2])
    assert grown["w"].shape == (5, 2)
    np.testing.assert_allclose(
        np.asarray(grown["w"])[4],
        np.asarray(stacked["w"])[[0, 2]].mean(axis=0),
    )
    # empty neighborhood: global mean
    grown2 = admit_node(stacked, [])
    np.testing.assert_allclose(
        np.asarray(grown2["w"])[4], np.asarray(stacked["w"]).mean(axis=0)
    )


def test_controller_adopt_clamps_rung_to_new_ladder():
    old = _controller(n=16)
    old.rung = len(old.ladder) - 1
    old.transitions.append((7, old.rung))
    old.events.append((3, "membership"))
    new = _controller(n=17)
    new.adopt(old)
    assert new.rung == min(old.rung, len(new.ladder) - 1)
    assert new.transitions == old.transitions
    assert new.events == old.events


def test_spmd_trainer_rejects_elastic_models():
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.launch.train import SPMDTrainer
    from repro.optim.sgd import get_optimizer

    fm = Join(n=1, rate=0.0, seed=0, join_steps=(2,))
    topo = make_topology("d_ring", 1, fault_model=fm)
    mesh = make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="elastic"):
        SPMDTrainer(
            get_config("granite-8b-reduced"), mesh, topo, get_optimizer("sgd")
        )


# ---------------------------------------------------------------------------
# Virtual-node sharding
# ---------------------------------------------------------------------------

def test_shard_nodes_is_numeric_noop():
    """Virtual-node sharding changes placement, never numerics (on one
    device the mesh is trivial; on more it partitions the node axis)."""
    def _run(shard):
        fm = make_fault_model("dropout", 8, rate=0.3, seed=3)
        topo = make_topology("d_one_peer_exp", 8, fault_model=fm)
        sim = DecentralizedSimulator(
            _quad_loss, sgd(0.1), topo, mixing="shift", shard_nodes=shard
        )
        state = sim.init({"w": jnp.zeros((4,), jnp.float32)})
        rng = np.random.default_rng(2)
        for _ in range(6):
            b = jnp.asarray(rng.normal(size=(8, 2, 4)).astype(np.float32))
            state, _, _ = sim.train_step(state, b, 0.05)
        return np.asarray(state.params["w"])

    np.testing.assert_array_equal(_run(False), _run(True))


def test_shard_nodes_runs_large_n_quickly():
    """n=512 one-peer steps run through the sharded path (the elastic
    benchmark's --quick tier depends on this staying cheap)."""
    topo = make_topology(
        "d_one_peer_exp", 512,
        fault_model=make_fault_model("dropout", 512, rate=0.1, seed=0),
    )
    sim = DecentralizedSimulator(
        _quad_loss, sgd(0.1), topo, mixing="shift", shard_nodes=True
    )
    state = sim.init({"w": jnp.zeros((4,), jnp.float32)})
    rng = np.random.default_rng(0)
    for _ in range(3):
        b = jnp.asarray(rng.normal(size=(512, 1, 4)).astype(np.float32))
        state, loss, _ = sim.train_step(state, b, 0.05)
    assert loss.shape == (512,)
    assert np.isfinite(np.asarray(state.params["w"])).all()


# ---------------------------------------------------------------------------
# Satellite: crash-consistent resume determinism
# ---------------------------------------------------------------------------

def _resume_sim():
    fm = make_fault_model("dropout", 8, rate=0.35, seed=3)
    topo = make_topology(
        "d_ada", 8, consensus_target=0.25, k_floor="one_peer", fault_model=fm
    )
    return DecentralizedSimulator(_quad_loss, sgd(0.1), topo)


def _batch(t):
    rng = np.random.default_rng(1000 + t)
    return jnp.asarray(rng.normal(size=(8, 2, 3)).astype(np.float32))


def test_resume_bit_identical_to_uninterrupted(tmp_path):
    """Checkpoint mid-run under TransientDropout + closed-loop Ada, resume
    in a FRESH engine, and the continued run matches the uninterrupted one
    bit-for-bit — parameters AND the controller's transition/event/trace
    logs (fault realizations are pure fn(seed, step))."""
    from repro.checkpoint import (
        load_checkpoint, load_checkpoint_extra, save_checkpoint,
    )

    total, cut = 12, 6

    # uninterrupted reference
    sim_a = _resume_sim()
    state = sim_a.init({"w": jnp.zeros((3,), jnp.float32)})
    for t in range(total):
        state, _, _ = sim_a.train_step(state, _batch(t), 0.05)
    ref_params = np.asarray(state.params["w"])
    ref_ctl = sim_a.topology.controller.state_dict()

    # interrupted: run to the cut, checkpoint with the engine extra payload
    sim_b = _resume_sim()
    state = sim_b.init({"w": jnp.zeros((3,), jnp.float32)})
    for t in range(cut):
        state, _, _ = sim_b.train_step(state, _batch(t), 0.05)
    ckpt = os.path.join(str(tmp_path), "ckpt")
    save_checkpoint(
        ckpt, cut, {"p": state.params, "o": state.opt_state},
        extra=sim_b.snapshot_extra(),
    )
    del sim_b, state

    # resumed: a fresh engine restores arrays + extra and continues
    sim_c = _resume_sim()
    template = sim_c.init({"w": jnp.zeros((3,), jnp.float32)})
    restored, step = load_checkpoint(
        ckpt, {"p": template.params, "o": template.opt_state}
    )
    assert step == cut
    sim_c.restore_extra(load_checkpoint_extra(ckpt))
    from repro.core.simulator import SimState

    state = SimState(restored["p"], restored["o"], cut)
    for t in range(cut, total):
        state, _, _ = sim_c.train_step(state, _batch(t), 0.05)

    np.testing.assert_array_equal(np.asarray(state.params["w"]), ref_params)
    assert sim_c.topology.controller.state_dict() == ref_ctl


def test_checkpoint_extra_roundtrip(tmp_path):
    from repro.checkpoint import (
        load_checkpoint, load_checkpoint_extra, save_checkpoint,
    )

    tree = {"p": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}
    extra = {"controller": {"rung": 2}, "last_membership": [True, False]}
    d = str(tmp_path)
    save_checkpoint(d, 3, tree, extra=extra)
    assert load_checkpoint_extra(d) == extra
    back, step = load_checkpoint(d, tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(back["p"]), np.asarray(tree["p"]))
    # checkpoints without an extra payload read back as None
    save_checkpoint(d, 4, tree)
    assert load_checkpoint_extra(d, 4) is None
