"""Integration: decentralized SGD dynamics reproduce the paper's observations
(at CPU scale) on controlled problems via the simulator engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dsgd import make_topology
from repro.core.simulator import DecentralizedSimulator
from repro.optim.sgd import sgd

N = 16


def _noisy_quadratic_loss(target):
    """Per-node least squares with node-dependent data noise."""

    def loss(params, batch):
        # batch: (B, D) noisy observations of target
        return jnp.mean(jnp.sum((batch - params["w"]) ** 2, -1))

    return loss


def _batches(key, n, b, d, target, noise):
    while True:
        key, sub = jax.random.split(key)
        obs = target + noise * jax.random.normal(sub, (n, b, d))
        yield obs


def _run(topology_name, steps=150, lr=0.05, noise=1.0, seed=0, **kw):
    d = 8
    target = jnp.arange(d, dtype=jnp.float32)
    topo = make_topology(topology_name, N, **kw)
    sim = DecentralizedSimulator(
        _noisy_quadratic_loss(target), sgd(momentum=0.0), topo, collect_norms=True
    )
    state = sim.init({"w": jnp.zeros(d)})
    bs = _batches(jax.random.PRNGKey(seed), N, 4, d, target, noise)
    ginis = []
    for t in range(steps):
        state, loss, norms = sim.train_step(state, next(bs), lr, epoch=t // 10)
        ginis.append(np.abs(np.asarray(norms)).std())
    mean_w = state.mean_params()["w"]
    err = float(jnp.linalg.norm(mean_w - target))
    spread = float(
        jnp.abs(state.params["w"] - state.params["w"].mean(0)).max()
    )
    return err, spread, state


@pytest.mark.parametrize(
    "topo", ["c_complete", "d_complete", "d_ring", "d_torus", "d_exponential", "d_ada"]
)
def test_all_topologies_converge(topo):
    err, spread, _ = _run(topo)
    assert err < 0.3, (topo, err)


def test_centralized_replicas_stay_identical():
    _, spread, state = _run("c_complete")
    assert spread < 1e-5


def test_consensus_error_orders_by_connectivity():
    """ring >= torus >= complete replica spread (paper Obs. 4 mechanism)."""
    spreads = {}
    for topo in ("d_ring", "d_torus", "d_complete"):
        _, spread, _ = _run(topo, steps=40, noise=2.0)
        spreads[topo] = spread
    assert spreads["d_ring"] >= spreads["d_torus"] >= spreads["d_complete"]
    assert spreads["d_complete"] < 1e-4  # full averaging every step


def test_mix_pre_and_post_orders_both_converge():
    """Lian et al. 2017: update order does not break convergence (§2.2)."""
    for order in ("post", "pre"):
        topo = make_topology("d_ring", N, mix_order=order)
        sim = DecentralizedSimulator(
            _noisy_quadratic_loss(jnp.ones(4)), sgd(momentum=0.0), topo
        )
        state = sim.init({"w": jnp.zeros(4)})
        bs = _batches(jax.random.PRNGKey(1), N, 4, 4, jnp.ones(4), 0.5)
        for t in range(120):
            state, loss, _ = sim.train_step(state, next(bs), 0.05)
        err = float(jnp.linalg.norm(state.mean_params()["w"] - 1.0))
        assert err < 0.2, (order, err)


def test_ada_interpolates_ring_and_complete_comm_cost():
    """Ada's early graphs are dense (accuracy), late graphs sparse (cost)."""
    topo = make_topology("d_ada", 96, k0=10, gamma_k=0.02)
    assert topo.graph_at(0).degree > topo.graph_at(299).degree
    # paper Table 4 settings: k = 10 - int(0.02*299) = 5 -> 4 neighbors
    assert topo.graph_at(299).degree == 4
    # a faster decay does reach the ring (floor k=2 -> 2 neighbors)
    fast = make_topology("d_ada", 96, k0=10, gamma_k=1.0)
    assert fast.graph_at(50).degree == 2


def test_dense_and_shift_mixing_agree_in_training():
    """Full training equivalence of the two simulator mixing backends."""
    target = jnp.ones(6)
    loss = _noisy_quadratic_loss(target)
    outs = []
    for mixing in ("dense", "shift"):
        topo = make_topology("d_exponential", 8)
        sim = DecentralizedSimulator(loss, sgd(momentum=0.9), topo, mixing=mixing)
        state = sim.init({"w": jnp.zeros(6)})
        bs = _batches(jax.random.PRNGKey(3), 8, 2, 6, target, 0.3)
        for t in range(25):
            state, *_ = sim.train_step(state, next(bs), 0.03)
        outs.append(np.asarray(state.params["w"]))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4)
