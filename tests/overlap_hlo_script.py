"""Subprocess body for test_spmd.py: bucketed-overlap lowering + probe fold.

Locks in the overlap-scheduling acceptance bar:
  1. the bucketed shard interpreter lowers to collective-permutes ONLY —
     one ppermute chain per bucket, ``ops × buckets`` permutes, zero
     all-gathers — and matches the dense mixing-matrix oracle;
  2. a single per-bucket executor (``build_bucket_step`` under GSPMD)
     carries its gossip permutes AND the optimizer compute in the SAME
     executable — the dispatch-pipelining evidence: bucket i's permutes
     have no dependency on bucket i+1's compute, only the tiny Ξ² token
     chains them — with no all-gather and at most the fold's one
     all-reduce;
  3. the Ξ_t probe fold removes the standalone probe executable from a
     closed-loop run: with ``bucket_mb`` set, ``consensus_distance_jit``
     runs only for the very first probe (no fold exists yet); every later
     probe reads the token accumulated inside the bucket dispatches, and
     the controller sees the same signal either way.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.buckets import BucketLayout, build_bucket_step
from repro.core.graphs import Ring
from repro.core.schedule import compile_graph
from repro.launch.hlo_analysis import assert_no_all_gather, collective_counts
from repro.optim.sgd import sgd

N = 8
mesh = compat.make_mesh((N,), ("gossip",))

# --- 1. bucketed shard interpreter: permutes only, ops x buckets ------------
prog = compile_graph(Ring(N))
rng = np.random.default_rng(0)
local_tmpl = {"a": np.zeros((5, 3), np.float32), "b": np.zeros((17,), np.float32)}
layout = BucketLayout.for_local(local_tmpl, 10 * 4 / (1 << 20))  # 10-elem buckets
assert layout.num_buckets == 4, layout.widths

x = {
    "a": rng.normal(size=(N, 5, 3)).astype(np.float32),
    "b": rng.normal(size=(N, 17)).astype(np.float32),
}
f = jax.jit(
    compat.shard_map(
        lambda v: prog.apply_shard_bucketed(v, "gossip", layout),
        mesh=mesh, in_specs=P("gossip"), out_specs=P("gossip"),
    )
)
xj = jax.tree.map(jnp.asarray, x)
counts = assert_no_all_gather(f, xj)
want_permutes = len(prog.ops) * layout.num_buckets
assert counts.get("collective-permute", 0) == want_permutes, (counts, want_permutes)
got = jax.device_get(f(xj))
W = prog.matrix()
for k in x:
    want = np.einsum("ij,j...->i...", W, x[k])
    err = float(np.abs(got[k] - want).max())
    assert err < 1e-5, (k, err)
print(f"bucketed shard interpreter: {want_permutes} permutes "
      f"({len(prog.ops)} ops x {layout.num_buckets} buckets), no all-gather")

# --- 2. per-bucket executor: permutes + compute in ONE executable -----------
WIDTH = 96
lead2 = NamedSharding(mesh, P("gossip", None))
rep = NamedSharding(mesh, P())
gvec = NamedSharding(mesh, P("gossip"))
step = jax.jit(
    build_bucket_step(prog, hyper=sgd(momentum=0.9).hyper, has_momentum=True),
    in_shardings=(lead2, lead2, lead2, rep, gvec),
    out_shardings=(lead2, lead2, gvec),
)
theta = jnp.asarray(rng.normal(size=(N, WIDTH)).astype(np.float32))
mom = jnp.asarray(rng.normal(size=(N, WIDTH)).astype(np.float32))
grad = jnp.asarray(rng.normal(size=(N, WIDTH)).astype(np.float32))
tok = jnp.zeros((N,), jnp.float32)
args = (theta, mom, grad, jnp.float32(0.05), tok)
counts = collective_counts(step, *args)
assert counts.get("collective-permute", 0) == len(prog.ops), counts
assert counts.get("all-gather", 0) == 0, counts
assert counts.get("all-reduce", 0) <= 1, counts  # the fold's mean, nothing else
compiled = step.lower(*args).compile().as_text()
assert "collective-permute" in compiled
assert any(op in compiled for op in ("fusion", "subtract", "multiply")), (
    "executor lost its compute: permutes were split into their own module"
)
print(f"per-bucket executor: {len(prog.ops)} permutes + optimizer compute "
      "in one executable, no all-gather")

# --- 3. probe fold: no standalone probe executable in closed-loop runs ------
from repro.core import consensus
from repro.core.dsgd import make_topology
from repro.core.simulator import DecentralizedSimulator

_orig_probe = consensus.consensus_distance_jit


def _run_closed_loop(bucket_mb):
    calls = []
    consensus.consensus_distance_jit = lambda p: calls.append(1) or _orig_probe(p)
    try:
        topo = make_topology("d_ada", N, k0=4, k_floor="one_peer",
                             consensus_target=0.6)
        sim = DecentralizedSimulator(
            lambda p, b: jnp.mean((p["w"] - b["t"]) ** 2),
            sgd(momentum=0.9), topo, bucket_mb=bucket_mb,
        )
        state = sim.init({"w": jnp.zeros((24,))})
        r = np.random.default_rng(0)
        for t in range(12):
            tgt = jnp.asarray(r.normal(size=(N, 24)).astype(np.float32))
            state, _, _ = sim.train_step(state, {"t": tgt}, 0.4 * 0.8 ** t,
                                         epoch=t // 5)
        return len(calls), topo.controller.trace
    finally:
        consensus.consensus_distance_jit = _orig_probe


mono_calls, mono_trace = _run_closed_loop(None)
fold_calls, fold_trace = _run_closed_loop(16 * 4 / (1 << 20))  # 16-elem buckets
assert mono_calls == len(mono_trace) and mono_calls > 1, (mono_calls, mono_trace)
# only the step-0 probe predates the first fold; every later one is folded
assert fold_calls == 1, fold_calls
assert [s for s, _, _ in fold_trace] == [s for s, _, _ in mono_trace]
xi_err = max(
    abs(a - b) for (_, a, _), (_, b, _) in zip(fold_trace, mono_trace)
)
assert xi_err < 1e-5, xi_err
print(f"probe fold: {mono_calls} standalone probes -> {fold_calls}, "
      f"same controller signal (max xi err {xi_err:.1e})")

print("OVERLAP_HLO_OK")
