"""The resilience subsystem (core/faults.py + degraded programs).

Covers: seeded step-deterministic fault models (identical realizations
from the same seed — the property both engines rely on), the
``GossipProgram.degrade`` transform against the dense degraded-matrix
oracle on random connected graphs, the runtime-masked interpreters and the
fused Pallas kernel's in-kernel renormalization (zero retraces across
realizations), engine behavior under every fault class (stragglers skip
updates but mix, dropouts mix out but update, crashes freeze and rejoin by
neighbor average), the zero-recompile acceptance bar (fault runs compile
exactly as many executables as fault-free runs), controller re-arming, and
surviving-edges-only communication billing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis.recompile import (
    assert_executables_preenumerated, assert_no_retrace,
)
from repro.core.consensus import consensus_distance_masked_jit
from repro.core.dsgd import make_topology
from repro.core.faults import (
    FAULT_MODELS, LinkFailure, PermanentCrash, Straggler, TransientDropout,
    adopt_neighbor_average, degraded_matrix, make_fault_model,
    realization_arrays,
)
from repro.core.graphs import Ring, Star, from_adjacency, one_peer_period
from repro.core.schedule import (
    GossipProgram, compile_graph, program_comm_bytes, program_max_node_bytes,
)
from repro.core.simulator import DecentralizedSimulator
from repro.optim.sgd import sgd


def _quad_loss(p, b):
    return jnp.mean((b - p["w"]) ** 2)


def _random_connected_graph(n, seed):
    rng = np.random.default_rng(seed)
    edges = set()
    perm = rng.permutation(n)
    for a, b in zip(perm[:-1], perm[1:]):
        edges.add((min(a, b), max(a, b)))
    for _ in range(int(rng.integers(0, n))):
        i, j = rng.integers(0, n, size=2)
        if i != j:
            edges.add((min(i, j), max(i, j)))
    return from_adjacency(sorted((int(i), int(j)) for i, j in edges))


# ---------------------------------------------------------------------------
# Fault models: seeded, step-deterministic, engine-independent
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", [k for k in FAULT_MODELS if k != "none"])
def test_fault_realizations_deterministic_in_seed_and_step(kind):
    """Two independently constructed models with the same seed draw the
    SAME realization stream — the property that lets the simulator and the
    SPMD trainer inject identical faults with no cross-engine channel."""
    a = make_fault_model(kind, 12, rate=0.4, seed=7)
    b = make_fault_model(kind, 12, rate=0.4, seed=7)
    for t in [0, 1, 5, 17, 17, 3]:  # repeated step: stateless in t
        fa, fb = a.at(t), b.at(t)
        np.testing.assert_array_equal(fa.alive, fb.alive)
        np.testing.assert_array_equal(fa.update, fb.update)
        np.testing.assert_array_equal(fa.program_alive, fb.program_alive)
        assert fa.rejoin == fb.rejoin
        if fa.link_up is not None:
            np.testing.assert_array_equal(fa.link_up, fb.link_up)
    if kind == "crash":
        # crash realizations are rare events: compare the seeded draw itself
        assert any(
            (a.victim, a.crash_step)
            != (m.victim, m.crash_step)
            for m in (make_fault_model(kind, 12, rate=0.4, seed=s)
                      for s in range(8, 14))
        )
        return
    differs = False
    c = make_fault_model(kind, 12, rate=0.4, seed=8)
    for t in range(20):
        fa, fc = a.at(t), c.at(t)
        if fa.link_up is not None:
            differs |= not np.array_equal(fa.link_up, fc.link_up)
        differs |= not (
            np.array_equal(fa.alive, fc.alive)
            and np.array_equal(fa.update, fc.update)
        )
    assert differs, "different seeds should yield different realizations"


def test_fault_model_kinds_and_validation():
    assert make_fault_model("none", 8) is None
    assert make_fault_model("dropout", 8, rate=0.0) is None
    assert isinstance(make_fault_model("dropout", 8, rate=0.2), TransientDropout)
    assert isinstance(make_fault_model("link", 8, rate=0.2), LinkFailure)
    assert isinstance(make_fault_model("straggler", 8, rate=0.2), Straggler)
    crash = make_fault_model("crash", 8, rate=0.5, seed=3, down_steps=4)
    assert isinstance(crash, PermanentCrash)
    assert crash.rejoin_step == crash.crash_step + 4
    with pytest.raises(ValueError, match="unknown fault model"):
        make_fault_model("cosmic_ray", 8)
    with pytest.raises(ValueError, match="rate"):
        make_fault_model("dropout", 8, rate=1.5)
    with pytest.raises(ValueError, match="crash"):
        make_fault_model("dropout", 8, rate=0.2, down_steps=3)
    # down_steps=0 would rejoin a node that never went down (overwriting
    # healthy state); negatives would silently empty the crash window
    with pytest.raises(ValueError, match="down_steps"):
        make_fault_model("crash", 8, rate=0.5, down_steps=0)
    with pytest.raises(ValueError, match="down_steps"):
        make_fault_model("crash", 8, rate=0.5, down_steps=-3)
    with pytest.raises(ValueError, match="decentralized"):
        make_topology("c_complete", 8,
                      fault_model=make_fault_model("dropout", 8, rate=0.2))
    with pytest.raises(ValueError, match="covers"):
        make_topology("d_ring", 8,
                      fault_model=make_fault_model("dropout", 4, rate=0.2))


def test_fault_semantics_per_class():
    """dropout: skips gossip, keeps update; straggler: the reverse; link:
    symmetric; crash: permanent membership change + single-node-out mask."""
    drop = TransientDropout(n=16, rate=0.5, seed=1).at(3)
    assert drop.update.all() and not drop.alive.all()
    assert drop.program_alive.all()  # transient: base program stays

    strag = Straggler(n=16, rate=0.5, seed=1).at(3)
    assert strag.alive.all() and not strag.update.all()

    link = LinkFailure(n=16, rate=0.5, seed=1).at(3)
    assert link.alive.all() and link.update.all()
    np.testing.assert_array_equal(link.link_up, link.link_up.T)
    assert np.diagonal(link.link_up).all()
    # only link models pay for the (n, n) mask operand on the hot path
    assert realization_arrays(link)["link"] is not None
    assert realization_arrays(drop)["link"] is None
    assert LinkFailure(n=4, rate=0.5).has_link_faults
    assert not TransientDropout(n=4, rate=0.5).has_link_faults

    crash = PermanentCrash(n=16, rate=0.9, seed=1, down_steps=5)
    c = crash.crash_step
    before, during = crash.at(c - 1), crash.at(c)
    assert before.alive.all() and not during.alive[crash.victim]
    assert not during.update[crash.victim]
    assert not during.program_alive.all()  # crash selects a degraded program
    assert crash.program_masks() == (during.membership_key(),)
    after = crash.at(crash.rejoin_step)
    assert after.alive.all() and after.rejoin == (crash.victim,)


# ---------------------------------------------------------------------------
# degrade(alive): the property test (satellite)
# ---------------------------------------------------------------------------

@given(
    st.integers(min_value=2, max_value=14),
    st.integers(min_value=0, max_value=10_000),
)
@settings(max_examples=40, deadline=None)
def test_degrade_matches_dense_oracle_on_random_graphs(n, seed):
    """On a random connected graph with a random alive mask, the degraded
    program stays symmetric and doubly stochastic, matches the dense
    degraded-matrix oracle <= 1e-6 under both interpreters, and dead nodes
    get exact identity rows (their replicas frozen)."""
    rng = np.random.default_rng(seed)
    g = _random_connected_graph(n, seed)
    prog = compile_graph(g)
    alive = rng.random(n) > 0.35
    if not alive.any():
        alive[int(rng.integers(n))] = True
    want = degraded_matrix(g.mixing_matrix(), alive)
    deg = prog.degrade(alive)
    np.testing.assert_allclose(deg.matrix(), want, atol=1e-12)
    # symmetric + doubly stochastic survives degradation
    np.testing.assert_allclose(want, want.T, atol=1e-12)
    np.testing.assert_allclose(want.sum(axis=1), 1.0, atol=1e-12)
    np.testing.assert_allclose(want.sum(axis=0), 1.0, atol=1e-12)
    assert (want >= -1e-12).all()
    for i in np.nonzero(~alive)[0]:
        row = np.zeros(n)
        row[i] = 1.0
        np.testing.assert_array_equal(want[i], row)
    # interpreters: degraded program AND runtime-masked base program agree
    x = jnp.asarray(rng.normal(size=(n, 5)).astype(np.float32))
    want_x = want @ np.asarray(x)
    af = jnp.asarray(alive, jnp.float32)
    for engine in ("dense", "stacked"):
        got = np.asarray(deg.apply({"w": x}, engine=engine)["w"])
        np.testing.assert_allclose(got, want_x, atol=1e-6, err_msg=engine)
        got_masked = np.asarray(
            prog.apply_masked({"w": x}, af, engine=engine)["w"]
        )
        np.testing.assert_allclose(got_masked, want_x, atol=1e-6, err_msg=engine)


def test_degrade_caches_and_noops_when_all_alive():
    prog = compile_graph(Ring(8))
    assert prog.degrade(np.ones(8, bool)) is prog
    alive = np.ones(8, bool)
    alive[3] = False
    a, b = prog.degrade(alive), prog.degrade(alive)
    assert a is b  # cached: one program (and one executable) per alive-set
    assert a.cache_key != prog.cache_key
    with pytest.raises(ValueError, match="alive mask"):
        prog.degrade(np.ones(5, bool))


def test_degrade_nonpermute_falls_back_to_dense_row():
    from repro.core.graphs import Complete
    from repro.core.schedule import GatherRow

    prog = compile_graph(Complete(6))
    alive = np.ones(6, bool)
    alive[0] = False
    deg = prog.degrade(alive)
    assert any(isinstance(op, GatherRow) for op in deg.ops)
    np.testing.assert_allclose(
        deg.matrix(), degraded_matrix(prog.matrix(), alive), atol=1e-12
    )


def test_apply_masked_link_failures_match_oracle():
    g = _random_connected_graph(10, 5)
    prog = compile_graph(g)
    rng = np.random.default_rng(0)
    up = np.triu(rng.random((10, 10)) > 0.4, 1)
    link = up | up.T
    np.fill_diagonal(link, True)
    alive = np.ones(10, bool)
    want = degraded_matrix(g.mixing_matrix(), alive, link)
    x = jnp.asarray(rng.normal(size=(10, 3)).astype(np.float32))
    for engine in ("dense", "stacked"):
        got = np.asarray(
            prog.apply_masked(
                {"w": x}, jnp.asarray(alive, jnp.float32),
                link_up=jnp.asarray(link, jnp.float32), engine=engine,
            )["w"]
        )
        np.testing.assert_allclose(got, want @ np.asarray(x), atol=1e-5)


# ---------------------------------------------------------------------------
# Fused Pallas kernel: runtime weight/fault rows, zero retraces
# ---------------------------------------------------------------------------

def test_fused_kernel_consumes_runtime_rows_without_retrace():
    """Acceptance: the kernel's weight AND fault rows are runtime operands —
    sweeping realizations (and degraded weight rows) leaves exactly one
    cached executable, and the all-ones fault row is the fault-free math."""
    from repro.kernels.gossip_update import (
        _gossip_program_update, fused_apply_stacked,
    )

    prog = compile_graph(Star(8))
    kp = jax.random.split(jax.random.PRNGKey(0), 3)
    params = {"w": jax.random.normal(kp[0], (8, 96))}
    grads = {"w": jax.random.normal(kp[1], (8, 96))}
    mom = {"w": jax.random.normal(kp[2], (8, 96))}
    rng = np.random.default_rng(1)
    _gossip_program_update._clear_cache()

    def sweep_step(t):
        alive = rng.random(8) > 0.3
        alive[0] = True
        fault = {
            "update": jnp.asarray(rng.random(8) > 0.2, jnp.float32),
            "alive": jnp.asarray(alive, jnp.float32),
            "link": jnp.asarray(rng.random((8, 8)) > 0.1, jnp.float32),
        }
        fused_apply_stacked(
            prog, params, grads, mom, lr=0.01 + 0.01 * t, beta=0.9,
            fault=fault, block=96,
        )

    # warm-up: one faulty + one fault-free call (the all-ones row is built
    # host-side on first fault-free use) — then a hard zero-retrace window
    sweep_step(0)
    fused_apply_stacked(prog, params, grads, mom, lr=0.03, beta=0.9, block=96)
    with assert_no_retrace("fused-kernel realization sweep"):
        for t in range(1, 5):
            sweep_step(t)
        fused_apply_stacked(
            prog, params, grads, mom, lr=0.07, beta=0.9, block=96
        )
    assert _gossip_program_update._cache_size() == 1


def test_fused_kernel_fault_row_matches_masked_oracle():
    """Kernel renormalizes in-kernel: masked update + degraded dense mix."""
    from repro.kernels.gossip_update import fused_apply_stacked

    for graph in (Star(8), Ring(8)):
        prog = compile_graph(graph)
        kp = jax.random.split(jax.random.PRNGKey(graph.n), 3)
        params = {"w": jax.random.normal(kp[0], (8, 50))}
        grads = {"w": jax.random.normal(kp[1], (8, 50))}
        mom = {"w": jax.random.normal(kp[2], (8, 50))}
        update = np.array([1, 1, 0, 1, 1, 1, 0, 1], bool)
        alive = np.array([1, 0, 1, 1, 1, 1, 1, 0], bool)
        fault = {
            "update": jnp.asarray(update, jnp.float32),
            "alive": jnp.asarray(alive, jnp.float32),
            "link": jnp.ones((8, 8), jnp.float32),
        }
        lr, beta = 0.07, 0.9
        new_p, new_m = fused_apply_stacked(
            prog, params, grads, mom, lr=lr, beta=beta, fault=fault, block=64
        )
        th, g, m = (np.asarray(x["w"]) for x in (params, grads, mom))
        m_want = np.where(update[:, None], beta * m + g, m)
        theta_star = np.where(update[:, None], th - lr * m_want, th)
        want = degraded_matrix(prog.matrix(), alive) @ theta_star
        np.testing.assert_allclose(np.asarray(new_p["w"]), want, atol=1e-5)
        np.testing.assert_allclose(np.asarray(new_m["w"]), m_want, atol=1e-6)


# ---------------------------------------------------------------------------
# Engines under faults
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo_name", ["d_one_peer_exp", "d_star"])
def test_zero_recompile_invariant_under_transient_faults(topo_name):
    """Acceptance: a transient-fault run compiles exactly as many step
    executables as the fault-free run — realizations ride runtime masks."""
    n = 8

    def run(fault_model):
        topo = make_topology(topo_name, n, fault_model=fault_model)
        sim = DecentralizedSimulator(_quad_loss, sgd(momentum=0.9), topo)
        state = sim.init({"w": jnp.zeros(4)})
        period = one_peer_period(n)

        def step(state, t):
            b = jax.random.normal(jax.random.PRNGKey(t), (n, 2, 4))
            state, *_ = sim.train_step(state, b, 0.05)
            return state

        # warm-up: one executable per distinct program (realizations are
        # runtime masks — they share it), then a hard zero-retrace window
        for t in range(period):
            state = step(state, t)
        with assert_no_retrace(f"{topo_name} steady state"):
            for t in range(period, 3 * period):
                state = step(state, t)
        assert_executables_preenumerated(sim)
        return len(sim._step_cache)

    fault_free = run(None)
    faulted = run(make_fault_model("dropout", n, rate=0.4, seed=3))
    assert faulted == fault_free


def test_sim_engines_agree_and_share_realizations_under_faults():
    """dense (paper-faithful oracle) and stacked engines consume the same
    seeded realization stream and land on identical parameters."""
    n = 8
    for kind in ("dropout", "link", "straggler", "crash"):
        finals = []
        for mixing in ("dense", "shift"):
            fm = make_fault_model(kind, n, rate=0.4, seed=2,
                                  down_steps=4 if kind == "crash" else None)
            topo = make_topology("d_ring", n, fault_model=fm)
            sim = DecentralizedSimulator(
                _quad_loss, sgd(momentum=0.9), topo, mixing=mixing
            )
            st = sim.init({"w": jnp.full((4,), 0.3)})
            for t in range(10):
                b = jax.random.normal(jax.random.PRNGKey(100 + t), (n, 2, 4))
                st, *_ = sim.train_step(st, b, 0.05)
            finals.append(np.asarray(st.params["w"]))
        np.testing.assert_allclose(finals[0], finals[1], atol=1e-5,
                                   err_msg=kind)


def test_straggler_skips_update_but_still_mixes():
    """A straggling node's parameters move ONLY by gossip (no descent), and
    its momentum stays untouched that step."""
    n = 4
    prog = compile_graph(Ring(n))

    class OneStraggler(Straggler):
        def at(self, step):
            fr = super().at(step)
            update = np.ones(n, bool)
            update[2] = False
            object.__setattr__(fr, "update", update)
            return fr

    fm = OneStraggler(n=n, rate=0.0, seed=0)
    topo = make_topology("d_ring", n, fault_model=fm)
    sim = DecentralizedSimulator(_quad_loss, sgd(momentum=0.9), topo)
    rng = np.random.default_rng(0)
    state = sim.init({"w": jnp.asarray(rng.normal(size=4).astype(np.float32))})
    # de-sync replicas so gossip does something
    state.params["w"] = jnp.asarray(
        rng.normal(size=(n, 4)).astype(np.float32)
    )
    params0 = np.asarray(state.params["w"])
    b = jnp.asarray(rng.normal(size=(n, 2, 4)).astype(np.float32))
    state, *_ = sim.train_step(state, b, 0.1)
    g = jax.vmap(jax.grad(_quad_loss))({"w": jnp.asarray(params0)}, b)["w"]
    theta_star = params0 - 0.1 * np.asarray(g)
    theta_star[2] = params0[2]  # straggler skipped its descent
    want = prog.matrix() @ theta_star
    np.testing.assert_allclose(np.asarray(state.params["w"]), want, atol=1e-5)
    # momentum untouched on the straggler, updated elsewhere
    mom = np.asarray(state.opt_state["w"])
    np.testing.assert_allclose(mom[2], 0.0, atol=1e-7)
    assert np.abs(mom[[0, 1, 3]]).max() > 1e-3


def test_crash_freezes_victim_and_rejoin_adopts_neighbor_average():
    n = 8
    fm = make_fault_model("crash", n, rate=0.5, seed=1, down_steps=4)
    assert fm.crash_step is not None
    topo = make_topology("d_ring", n, fault_model=fm)
    allowed = {p.cache_key for _, p in topo.distinct_programs()}
    assert len(allowed) == 2  # base ring + its single-node-out degrade
    sim = DecentralizedSimulator(_quad_loss, sgd(momentum=0.9), topo)
    state = sim.init({"w": jnp.zeros(4)})
    v = fm.victim
    rejoin_checked = False
    for t in range(fm.rejoin_step + 3):
        b = jax.random.normal(jax.random.PRNGKey(t), (n, 2, 4))
        prev = np.asarray(state.params["w"])
        state, *_ = sim.train_step(state, b, 0.05)
        if fm.crash_step <= t < fm.rejoin_step:
            # dead: frozen params, untouched by neighbors' gossip
            np.testing.assert_allclose(
                np.asarray(state.params["w"][v]), prev[v], atol=0
            )
        if t == fm.rejoin_step:
            # re-entry adopted the ring neighbors' average BEFORE the step
            nbrs = [(v - 1) % n, (v + 1) % n]
            adopted = np.asarray(
                adopt_neighbor_average(
                    {"w": jnp.asarray(prev)}, v, nbrs
                )["w"][v]
            )
            np.testing.assert_allclose(adopted, prev[nbrs].mean(0), atol=1e-6)
            rejoin_checked = True
    assert rejoin_checked
    # cache bound: every executable keyed by a pre-enumerated program
    used = assert_executables_preenumerated(sim)
    assert used <= allowed


def test_controller_rearms_on_membership_change():
    n = 16
    fm = make_fault_model("crash", n, rate=0.9, seed=4, down_steps=3)
    topo = make_topology("d_ada", n, k0=4, k_floor="one_peer",
                         consensus_target=0.5, fault_model=fm)
    sim = DecentralizedSimulator(_quad_loss, sgd(momentum=0.9), topo)
    state = sim.init({"w": jnp.zeros(4)})
    ctl = topo.controller
    for t in range(fm.rejoin_step + 2):
        b = jax.random.normal(jax.random.PRNGKey(t), (n, 2, 4))
        state, *_ = sim.train_step(state, b, 0.2)
    events = dict(ctl.events)
    assert fm.crash_step in events      # crash re-armed the phase reference
    assert fm.rejoin_step in events     # so did the re-entry
    # rearm clears the reference without touching the rung walk
    ctl2 = make_topology("d_ada", n, k0=4, k_floor="one_peer",
                         consensus_target=0.5).controller
    ctl2.observe(10.0, 0)
    ctl2.rearm(1)
    assert ctl2.xi0 is None and ctl2.rung == 0
    assert not ctl2.observe(1.0, 2)  # next observation seeds, cannot trigger
    assert ctl2.rung == 0


def test_consensus_distance_masked_matches_oracle_and_unmasked():
    from repro.core.consensus import consensus_distance_stacked

    rng = np.random.default_rng(0)
    tree = {"a": jnp.asarray(rng.normal(size=(6, 3, 4)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(6, 7)).astype(np.float32))}
    alive = np.array([1, 0, 1, 1, 0, 1], bool)
    flat = np.concatenate(
        [np.asarray(x).reshape(6, -1) for x in jax.tree.leaves(tree)], axis=1
    )
    sub = flat[alive]
    want = float(np.sqrt(((sub - sub.mean(0)) ** 2).sum(1).mean()))
    got = float(consensus_distance_masked_jit(
        tree, jnp.asarray(alive, jnp.float32)
    ))
    assert abs(got - want) < 1e-5 * max(want, 1.0)
    all_alive = float(consensus_distance_masked_jit(
        tree, jnp.ones(6, jnp.float32)
    ))
    assert abs(all_alive - float(consensus_distance_stacked(tree))) < 1e-6


# ---------------------------------------------------------------------------
# Communication billing: surviving edges only (satellite bugfix)
# ---------------------------------------------------------------------------

def test_comm_bytes_skip_dead_edges():
    P = 4096
    prog = compile_graph(Star(8))
    base = program_comm_bytes(prog, P)
    # hub dead: the whole star is down — billing must be 0, not 14 links
    hub_dead = np.ones(8, bool)
    hub_dead[0] = False
    assert program_comm_bytes(prog, P, alive=hub_dead) == 0
    assert program_max_node_bytes(prog, P, alive=hub_dead) == 0
    assert program_comm_bytes(prog.degrade(hub_dead), P) == 0
    # one leaf dead: exactly its 2 directed links disappear
    leaf_dead = np.ones(8, bool)
    leaf_dead[3] = False
    want = base - int(P * 2 / 8) if base else 0
    assert program_comm_bytes(prog, P, alive=leaf_dead) == \
        program_comm_bytes(prog.degrade(leaf_dead), P)
    assert abs(program_comm_bytes(prog, P, alive=leaf_dead) - want) <= 1
    # link masks bill surviving links only
    link = np.ones((8, 8), bool)
    link[0, 1] = link[1, 0] = False
    ring = compile_graph(Ring(8))
    full = program_comm_bytes(ring, P)
    masked = program_comm_bytes(ring, P, link_up=link)
    assert masked == full - int(P * 2 / 8)


def test_total_comm_replays_fault_realizations():
    """benchmarks/ada.py comm replay bills degraded programs per step."""
    from benchmarks.ada import _total_comm

    P_TREE = {"w": jnp.zeros((1000,), jnp.float32)}
    pbytes = 4000
    n = 8
    fm = make_fault_model("crash", n, rate=0.9, seed=0)
    topo = make_topology("d_ring", n, fault_model=fm)
    steps = fm.crash_step + 4
    total = _total_comm(topo, steps, P_TREE)
    ring_step = 2 * pbytes  # two offsets, full participation
    # after the crash the victim's 4 directed links are gone: (2n-4)/n links
    degraded_step = int(pbytes * (2 * n - 4) / n)
    want = fm.crash_step * ring_step + 4 * degraded_step
    assert total == want
    # fault-free replay unchanged
    assert _total_comm(make_topology("d_ring", n), steps, P_TREE) == \
        steps * ring_step


def test_fault_benchmark_run_one_payload_shape():
    """The faults benchmark payload carries accuracy, the Ξ trajectory, and
    surviving-edge comm billing (smoke-run at tiny steps)."""
    import benchmarks.faults as bf
    from repro.models.common import init_params
    from repro.models.paper_models import mini_resnet_defs

    params0 = init_params(mini_resnet_defs(), jax.random.PRNGKey(0))
    res = bf._run_one("d_ring", "dropout", 0.3, 4, params0, seed=0)
    assert set(res) >= {"acc", "xi_trace", "us_per_step",
                        "comm_bytes_per_node", "steps", "rate"}
    assert len(res["xi_trace"]) >= 1
    assert res["steps"] == 4
    assert res["comm_bytes_per_node"] > 0
