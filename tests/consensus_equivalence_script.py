"""Subprocess body for test_spmd.py: closed-loop Ada on both engines.

Runs consensus-distance-triggered Ada (``consensus_target``) through (a)
the production SPMD trainer and (b) the vmap/dense-matrix simulator with
identical init/data, and checks that BOTH engines

  * observe the same consensus signal and pick the SAME graph sequence
    (identical controller transition logs — the closed loop is engine-
    agnostic),
  * hand off to the one-peer family at a measured step (not the open-loop
    k<2 epoch), and
  * agree on the final parameters to float32 round-off, while compiling
    no executable beyond the pre-enumerated ladder programs.

A sharply decaying lr makes the consensus ratio cross the target within a
few steps so the whole ladder is exercised in a short run.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp

from repro.analysis.recompile import assert_executables_preenumerated
from repro.configs import get_config
from repro.core.dsgd import make_topology
from repro.core.simulator import DecentralizedSimulator
from repro.data import SyntheticLM
from repro.launch.mesh import make_mesh
from repro.launch.train import SPMDTrainer
from repro.models import transformer as tfm
from repro.optim.sgd import sgd

STEPS = 8
G = 4  # gossip nodes (data axis), model axis = 2
TARGET = 0.6
ADA_KW = dict(k0=3, k_floor="one_peer", consensus_target=TARGET)

cfg = dataclasses.replace(
    get_config("granite-8b-reduced"), name="granite-8b", dtype=jnp.float32,
    remat=False,
)
mesh = make_mesh((G, 2), ("data", "model"))
opt = sgd(momentum=0.9)
src = SyntheticLM(vocab=cfg.vocab, seq_len=16, seed=0)
key = jax.random.PRNGKey(42)


def lr_at(t):
    return 0.05 * (0.5 ** t)  # sharp decay -> the ratio crosses in-run


# --- SPMD engine -----------------------------------------------------------
topo_spmd = make_topology("d_ada", G, **ADA_KW)
trainer = SPMDTrainer(cfg, mesh, topo_spmd, opt, donate=False)
allowed = {p.cache_key for p in trainer.precompile_programs()}
state = trainer.init_state(key)
for t in range(STEPS):
    batch = {k: jnp.asarray(v) for k, v in src.stacked(G, t, 2).items()}
    state, loss, _ = trainer.train_step(state, batch, lr_at(t), epoch=0)

used = assert_executables_preenumerated(trainer)
assert used <= allowed, f"executables beyond the ladder: {used - allowed}"

# --- simulator oracle ------------------------------------------------------
topo_sim = make_topology("d_ada", G, **ADA_KW)
sim = DecentralizedSimulator(
    lambda p, b: tfm.loss_fn(p, cfg, b), opt, topo_sim, mixing="dense"
)
sim_state = sim.init(tfm.init_model(cfg, key, tp_size=2))
for t in range(STEPS):
    batch = {k: jnp.asarray(v) for k, v in src.stacked(G, t, 2).items()}
    sim_state, loss, _ = sim.train_step(sim_state, batch, lr_at(t), epoch=0)

ctl_spmd, ctl_sim = topo_spmd.controller, topo_sim.controller
print("spmd transitions:", ctl_spmd.transitions)
print("sim  transitions:", ctl_sim.transitions)
assert ctl_spmd.transitions == ctl_sim.transitions, "engines disagree on schedule"
assert ctl_spmd.handoff_step is not None, "one-peer handoff never fired"

pd = jax.tree.map(
    lambda a, b: float(jnp.abs(a - b).max()),
    jax.device_get(state.params), jax.device_get(sim_state.params),
)
maxdiff = max(jax.tree.leaves(pd))
print(f"MAXDIFF={maxdiff:.3e}")
print(f"HANDOFF={ctl_spmd.handoff_step}")
print(f"EXECUTABLES={len(used)}/{len(allowed)}")
if maxdiff < 5e-5:
    print("CONSENSUS_EQUIV_OK")
else:
    sys.exit(1)
