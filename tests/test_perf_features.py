"""Tests for the §Perf machinery: loop-aware HLO accounting, exact head
padding, causal-skip chunked attention, and infrequent gossip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.launch.hlo_analysis import analyze_hlo
from repro.models.attention import (
    active_head_mask, head_padding, multihead_attention,
)


# ---------------------------------------------------------------------------
# hlo_analysis: loop-aware costs
# ---------------------------------------------------------------------------

def _scan_module_text(length):
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=length)
        return out

    return jax.jit(f).lower(jnp.ones((32, 32)), jnp.ones((32, 32))).compile().as_text()


def test_hlo_dot_flops_scale_with_trip_count():
    r4 = analyze_hlo(_scan_module_text(4))
    r8 = analyze_hlo(_scan_module_text(8))
    assert r4["dot_flops"] == pytest.approx(4 * 2 * 32**3)
    assert r8["dot_flops"] == pytest.approx(2 * r4["dot_flops"])


def test_hlo_nested_loops_multiply():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    txt = jax.jit(g).lower(jnp.ones((16, 16)), jnp.ones((16, 16))).compile().as_text()
    r = analyze_hlo(txt)
    assert r["dot_flops"] == pytest.approx(15 * 2 * 16**3)


def test_hlo_traffic_positive_and_collectives_empty_on_single_device():
    r = analyze_hlo(_scan_module_text(2))
    assert r["traffic_bytes"] > 0
    assert r["total_wire_bytes"] == 0


# ---------------------------------------------------------------------------
# head padding (exactness + algebraic properties)
# ---------------------------------------------------------------------------

@given(
    st.integers(min_value=1, max_value=8),   # group size
    st.integers(min_value=1, max_value=32),  # kv heads
    st.sampled_from([2, 4, 8, 16]),
    st.booleans(),
)
@settings(max_examples=80, deadline=None)
def test_head_padding_properties(group, kv, tp, pad_kv):
    h = group * kv
    h_pad, kv_pad, g_pad = head_padding(h, kv, tp, pad_kv=pad_kv)
    assert h_pad == kv_pad * g_pad
    assert h_pad % tp == 0
    if pad_kv:
        assert kv_pad % tp == 0
    assert h_pad >= h and kv_pad >= kv and g_pad >= group
    mask = np.asarray(active_head_mask(h, kv, h_pad, kv_pad, g_pad))
    assert mask.sum() == h  # exactly the original heads stay active
    # every active head's kv index is an original kv head
    idx = np.nonzero(mask)[0]
    assert (idx // g_pad < kv).all()


def test_padding_noop_when_divisible():
    assert head_padding(32, 8, 16) in [(32, 8, 4)]
    assert head_padding(32, 8, 1) == (32, 8, 4)


def test_padded_attention_matches_unpadded():
    """Zero-padded q/k/v + masked output == original attention."""
    b, s, h, kv, d = 2, 16, 6, 2, 8
    tp = 4
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    base = multihead_attention(q, k, v, q_positions=pos, k_positions=pos, causal=True)

    h_pad, kv_pad, g_pad = head_padding(h, kv, tp)
    g = h // kv
    qp = jnp.zeros((b, s, h_pad, d))
    for kvi in range(kv):
        qp = qp.at[:, :, kvi * g_pad : kvi * g_pad + g].set(
            q[:, :, kvi * g : (kvi + 1) * g]
        )
    kp = jnp.zeros((b, s, kv_pad, d)).at[:, :, :kv].set(k)
    vp = jnp.zeros((b, s, kv_pad, d)).at[:, :, :kv].set(v)
    out = multihead_attention(qp, kp, vp, q_positions=pos, k_positions=pos, causal=True)
    mask = active_head_mask(h, kv, h_pad, kv_pad, g_pad)
    active = out[:, :, np.nonzero(np.asarray(mask))[0]]
    np.testing.assert_allclose(np.asarray(active), np.asarray(base), atol=1e-5)


def test_chunked_skip_equals_reference():
    b, s, h, kv, d = 1, 40, 4, 2, 8
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    ref = multihead_attention(q, k, v, q_positions=pos, k_positions=pos, causal=True)
    for window in (None, 7):
        want = multihead_attention(q, k, v, q_positions=pos, k_positions=pos,
                                   causal=True, window=window)
        got = multihead_attention(q, k, v, q_positions=pos, k_positions=pos,
                                  causal=True, window=window,
                                  impl="chunked_skip", chunk_size=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    assert ref.shape == (b, s, h, d)


# ---------------------------------------------------------------------------
# infrequent gossip (mix_every)
# ---------------------------------------------------------------------------

def test_mix_every_still_converges_to_consensus():
    from repro.core.dsgd import make_topology
    from repro.core.simulator import DecentralizedSimulator
    from repro.optim.sgd import sgd

    target = jnp.arange(4.0)

    def loss(p, b):
        return jnp.mean(jnp.sum((b - p["w"]) ** 2, -1))

    sim = DecentralizedSimulator(
        loss, sgd(momentum=0.0), make_topology("d_ring", 8), mix_every=5
    )
    st = sim.init({"w": jnp.zeros(4)})
    key = jax.random.PRNGKey(0)
    for t in range(200):
        key, sub = jax.random.split(key)
        b = target + 0.5 * jax.random.normal(sub, (8, 2, 4))
        st, _, _ = sim.train_step(st, b, 0.05)
    err = float(jnp.linalg.norm(st.mean_params()["w"] - target))
    spread = float(jnp.abs(st.params["w"] - st.params["w"].mean(0)).max())
    assert err < 0.3
    assert spread < 0.5  # gossip every 5th step still binds the replicas
