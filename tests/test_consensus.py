"""Consensus distance + the closed-loop Ada controller (core/consensus.py).

Covers the on-device Ξ realizations against numpy oracles, the
ConsensusController contract (reference arming, trigger-iff-crossed,
monotone walk, bounded ladder), and the end-to-end closed-loop simulator
run: the one-peer handoff comes from the measured signal, the stacked
engine matches the dense oracle to float32 round-off, and the executable
cache stays inside the pre-enumerated ``distinct_programs`` set.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import consensus
from repro.core.ada import AdaSchedule
from repro.core.consensus import ConsensusController
from repro.core.dsgd import make_topology
from repro.core.simulator import DecentralizedSimulator
from repro.optim.sgd import sgd


# ---------------------------------------------------------------------------
# On-device consensus distance
# ---------------------------------------------------------------------------

def _stacked_tree(n=6, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(n, 3, 4)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(n, 7)).astype(np.float32)),
    }


def _flat(tree, n):
    return np.concatenate(
        [np.asarray(x).reshape(n, -1) for x in jax.tree.leaves(tree)], axis=1
    )


def test_consensus_distance_matches_numpy_oracle():
    n = 6
    tree = _stacked_tree(n)
    flat = _flat(tree, n)
    want_sq = np.sum((flat - flat.mean(axis=0)) ** 2, axis=1)
    got_sq = np.asarray(consensus.consensus_sq_stacked(tree))
    assert got_sq.shape == (n,)
    assert np.allclose(got_sq, want_sq, rtol=1e-5)
    want = np.sqrt(want_sq.mean())
    got = float(consensus.consensus_distance_stacked(tree))
    assert abs(got - want) < 1e-5 * max(want, 1.0)


def test_consensus_distance_zero_for_identical_replicas():
    x = jnp.ones((4, 5, 2))
    tree = {"w": x, "b": 3.0 * jnp.ones((4, 9))}
    assert float(consensus.consensus_distance_stacked(tree)) == 0.0


def test_consensus_distance_jits():
    tree = _stacked_tree(5, seed=3)
    eager = float(consensus.consensus_distance_stacked(tree))
    jitted = float(jax.jit(consensus.consensus_distance_stacked)(tree))
    assert abs(eager - jitted) < 1e-6


# ---------------------------------------------------------------------------
# Controller contract
# ---------------------------------------------------------------------------

def _controller(n=16, k0=4, target=0.5, k_floor="one_peer", **kw):
    sched = AdaSchedule(n_nodes=n, k0=k0, gamma_k=1.0, k_floor=k_floor)
    return ConsensusController(schedule=sched, target=target, **kw)


def test_ladder_covers_k0_down_to_floor_plus_one_peer():
    # RingLattice uses k//2 hops per side, so odd k == k-1: graph-identical
    # rungs collapse (every transition must actually sparsify)
    ctl = _controller(n=16, k0=5)
    assert ctl.ladder == (4, 2, "one_peer")
    ctl_int = _controller(n=16, k0=5, k_floor=3)
    assert ctl_int.ladder == (4, 3)
    # k0 above n-1 clips; k0 below the floor still yields the floor rung
    assert _controller(n=6, k0=50).ladder == (4, 2, "one_peer")
    assert _controller(n=16, k0=2).ladder == (2, "one_peer")


def test_trigger_fires_iff_ratio_crossed():
    ctl = _controller(target=0.5)
    assert not ctl.observe(0.0, 0)        # zero: no reference yet
    assert ctl.xi0 is None
    assert not ctl.observe(10.0, 1)       # arms the phase reference
    assert ctl.xi0 == 10.0
    assert not ctl.observe(12.0, 2)       # peak tracking raises it
    assert ctl.xi0 == 12.0
    assert not ctl.observe(6.1, 3)        # 6.1 > 0.5 * 12: no trigger
    assert ctl.rung == 0
    assert ctl.observe(6.0, 4)            # 6.0 <= 0.5 * 12: fires once
    assert ctl.rung == 1 and ctl.current == 2
    assert ctl.xi0 is None                # reference re-armed for new phase
    assert ctl.transitions == [(4, 1)]


def test_controller_walk_is_monotone_and_bounded():
    ctl = _controller(n=16, k0=4, target=0.5)
    rng = np.random.default_rng(7)
    last = ctl.rung
    for t in range(200):
        before = ctl.rung
        fired = ctl.observe(float(np.abs(rng.normal()) * 10), t)
        assert ctl.rung - before in (0, 1)          # at most one rung/probe
        assert fired == (ctl.rung == before + 1)
        assert ctl.rung >= last                      # never re-densifies
        last = ctl.rung
    assert 0 <= ctl.rung < len(ctl.ladder)


def test_handoff_fires_only_from_last_lattice_rung():
    ctl = _controller(n=16, k0=4, target=0.5)  # ladder (4, 2, one_peer)
    ctl.observe(10.0, 0)
    assert ctl.handoff_step is None
    ctl.observe(1.0, 1)                        # -> k=2
    assert ctl.current == 2 and ctl.handoff_step is None
    ctl.observe(8.0, 2)                        # new phase reference
    ctl.observe(1.0, 3)                        # -> one_peer
    assert ctl.one_peer_active and ctl.handoff_step == 3
    ctl.observe(8.0, 4)
    ctl.observe(0.1, 5)                        # terminal rung: no-op
    assert ctl.rung == len(ctl.ladder) - 1


def test_pinned_enumeration_and_rung_replay():
    ctl = _controller(n=16, k0=4, target=0.5)  # ladder (4, 2, one_peer)
    with ctl.pinned(2):
        assert ctl.one_peer_active
        assert ctl.period_steps() == 4  # one-peer period at n=16
    assert ctl.rung == 0 and ctl.period_steps() == 1
    with pytest.raises(ValueError):
        with ctl.pinned(99):
            pass
    # replay: transitions recorded at steps 3 and 7
    ctl.observe(10.0, 1)
    ctl.observe(1.0, 3)
    ctl.observe(10.0, 5)
    ctl.observe(1.0, 7)
    assert [ctl.rung_at(t) for t in (0, 2, 3, 6, 7, 100)] == [0, 0, 1, 1, 2, 2]


def test_reset_rearms():
    ctl = _controller()
    ctl.observe(10.0, 0)
    ctl.observe(1.0, 1)
    ctl.reset()
    assert ctl.xi0 is None and ctl.rung == 0
    assert ctl.transitions == [] and ctl.trace == []


def test_make_topology_validation():
    with pytest.raises(ValueError, match="d_ada"):
        make_topology("d_ring", 8, consensus_target=0.5)
    with pytest.raises(ValueError, match="target"):
        make_topology("d_ada", 8, consensus_target=1.5)
    with pytest.raises(ValueError, match="gamma_k"):
        make_topology("d_ada", 8, gamma_k=1.0, consensus_target=0.5)
    topo = make_topology("d_ada", 16, k0=4, k_floor="one_peer",
                         consensus_target=0.5, consensus_probe_every=2)
    assert topo.closed_loop and topo.controller.probe_every == 2
    assert topo.time_varying
    assert "closed-loop" in topo.describe()
    # transitions fire at measured steps even with an integer floor
    assert make_topology("d_ada", 16, k0=6, consensus_target=0.5).time_varying


def test_distinct_programs_enumerates_full_ladder():
    topo = make_topology("d_ada", 16, k0=4, k_floor="one_peer",
                         consensus_target=0.5)
    progs = topo.distinct_programs()
    names = [p.name for _, p in progs]
    # 2 distinct lattices (k=4, k=2 — k=3 is graph-identical to k=2 and
    # deduped out of the ladder) + the 4-step one-peer cycle
    assert len(progs) == 2 + 4
    assert sum(n.startswith("one_peer_exp") for n in names) == 4
    # enumeration must not disturb the live rung
    assert topo.controller.rung == 0


# ---------------------------------------------------------------------------
# Closed-loop simulator: the acceptance run (n=16, quick tier)
# ---------------------------------------------------------------------------

N = 16
TARGET = 0.6
STEPS = 48


def _loss_fn(params, batch):
    return jnp.mean((params["w"] - batch["t"]) ** 2)


def _run_closed_loop(mixing):
    topo = make_topology("d_ada", N, k0=4, k_floor="one_peer",
                         consensus_target=TARGET)  # ladder (4, 2, one_peer)
    sim = DecentralizedSimulator(_loss_fn, sgd(momentum=0.9), topo,
                                 mixing=mixing)
    state = sim.init({"w": jnp.zeros((8,))})
    rng = np.random.default_rng(0)
    for t in range(STEPS):
        tgt = jnp.asarray(rng.normal(size=(N, 8)).astype(np.float32))
        lr = 0.4 * (0.8 ** t)  # decaying noise -> consensus tightens
        state, _, _ = sim.train_step(state, {"t": tgt}, lr, epoch=t // 5)
    return topo.controller, sim, state


def test_closed_loop_sim_handoff_oracle_and_bounded_cache():
    ctl_s, sim_s, st_s = _run_closed_loop("stacked")
    ctl_d, _, st_d = _run_closed_loop("dense")

    # The handoff epoch comes from the measured signal: it fires at the
    # step where the probed ratio crossed the target, with the recorded
    # trace proving the crossing — not at any open-loop k<2 epoch constant.
    assert ctl_s.handoff_step is not None
    xi_at = {s: xi for s, xi, _ in ctl_s.trace}
    assert 0.0 < xi_at[ctl_s.handoff_step]  # a real measurement drove it
    open_loop = AdaSchedule(n_nodes=N, k0=4, gamma_k=1.0, k_floor="one_peer")
    open_handoffs = [e for e in range(STEPS) if open_loop.one_peer_at(e)]
    assert ctl_s.handoff_step != (open_handoffs[0] if open_handoffs else None)

    # Both interpreters pick the same graph sequence and agree to float32
    # round-off (the dense interpreter is the paper-faithful oracle).
    assert ctl_s.transitions == ctl_d.transitions
    diff = float(jnp.abs(st_s.params["w"] - st_d.params["w"]).max())
    assert diff < 1e-5

    # Bounded-executable-set invariant: every executable the run compiled
    # is keyed by a pre-enumerated program.
    topo = make_topology("d_ada", N, k0=4, k_floor="one_peer",
                         consensus_target=TARGET)
    allowed = {p.cache_key for _, p in topo.distinct_programs()}
    used = {
        k for k in sim_s._step_cache
        if k[0] not in ("__centralized__", "__local__")
    }
    assert used and used <= allowed


def test_closed_loop_probe_cadence():
    topo = make_topology("d_ada", N, k0=3, k_floor="one_peer",
                         consensus_target=TARGET, consensus_probe_every=4)
    sim = DecentralizedSimulator(_loss_fn, sgd(momentum=0.9), topo)
    state = sim.init({"w": jnp.zeros((4,))})
    rng = np.random.default_rng(1)
    for t in range(9):
        tgt = jnp.asarray(rng.normal(size=(N, 4)).astype(np.float32))
        state, _, _ = sim.train_step(state, {"t": tgt}, 0.1, epoch=0)
    assert [s for s, _, _ in topo.controller.trace] == [0, 4, 8]
