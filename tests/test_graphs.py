"""Property tests for the communication graphs (paper Table 1)."""
import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.graphs import (
    Complete, Exponential, Ring, RingLattice, Torus, make_graph, spectral_gap,
)

NS = st.integers(min_value=2, max_value=64)


@given(NS)
@settings(max_examples=30, deadline=None)
def test_ring_degree_and_edges(n):
    g = Ring(n)
    assert g.degree == (1 if n == 2 else 2)
    if n > 2:
        assert g.num_edges == n  # Table 1
    assert g.is_symmetric


@given(st.integers(min_value=6, max_value=100))
@settings(max_examples=30, deadline=None)
def test_torus_matches_table(n):
    g = Torus(n)
    a = int(math.isqrt(n))
    while n % a:
        a -= 1
    if a == 1 or a * (n // a) != n or min(a, n // a) < 2:
        pytest.skip("degenerates to ring")
    assert g.degree in (3, 4)  # 3 when offsets coincide (small grids)
    assert g.is_symmetric


@given(NS)
@settings(max_examples=30, deadline=None)
def test_exponential_matches_paper(n):
    g = Exponential(n)
    expected = int(math.floor(math.log2(n - 1))) + 1 if n > 2 else 1
    # offsets 2^m mod n may collide for tiny n; degree <= formula
    assert g.degree <= expected
    if n > 4:
        for i in range(min(n, 5)):
            nbrs = set(g.neighbors(i))
            want = {(i + 2 ** m) % n for m in range(expected)} - {i}
            assert nbrs == want


@given(NS)
@settings(max_examples=30, deadline=None)
def test_complete_graph(n):
    g = Complete(n)
    assert g.degree == n - 1
    assert g.num_edges == n * (n - 1) // 2  # Table 1
    assert abs(spectral_gap(g) - 1.0) < 1e-9


@given(NS, st.integers(min_value=1, max_value=20))
@settings(max_examples=50, deadline=None)
def test_mixing_matrix_stochastic(n, k):
    """W is row-stochastic for every graph; symmetric graphs doubly so."""
    for g in (Ring(n), Torus(n), RingLattice(n, k), Exponential(n), Complete(n)):
        w = g.mixing_matrix()
        assert np.allclose(w.sum(axis=1), 1.0), g.name
        assert (w >= 0).all()
        if g.is_symmetric:
            assert np.allclose(w, w.T), g.name
        # consensus: spectral radius of W - J/n strictly below 1 (n > 1)
        if g.degree > 0:
            j = np.ones((n, n)) / n
            rad = max(abs(np.linalg.eigvals(w - j)))
            assert rad < 1.0 - 1e-12 or n <= 2, (g.name, rad)


@given(st.integers(min_value=4, max_value=64), st.integers(min_value=2, max_value=10))
@settings(max_examples=30, deadline=None)
def test_ring_lattice_alg1(n, k):
    """Algorithm 1: j in [-k//2, k//2], j != 0."""
    g = RingLattice(n, k)
    half = min(max(k // 2, 1), (n - 1) // 2)
    nbrs = set(g.neighbors(0))
    want = {j % n for j in range(-half, half + 1) if j != 0}
    assert nbrs == want


def test_connectivity_orders_spectral_gap():
    """More connections => larger spectral gap (paper Obs. 2 mechanism)."""
    n = 48
    gaps = [spectral_gap(make_graph(k, n)) for k in
            ("ring", "torus", "exponential", "complete")]
    assert gaps == sorted(gaps), gaps


def test_unknown_graph_raises():
    with pytest.raises(ValueError):
        make_graph("hypercube", 8)


@given(st.sampled_from(["ring", "torus", "complete"]), st.integers(min_value=3, max_value=48))
@settings(max_examples=30, deadline=None)
def test_metropolis_weights_doubly_stochastic(kind, n):
    """Beyond-paper MH weights: doubly stochastic on any undirected graph,
    equal to Algorithm-1 uniform weights on regular graphs."""
    g = make_graph(kind, n)
    wm = g.mixing_matrix("metropolis")
    assert np.allclose(wm.sum(axis=0), 1.0) and np.allclose(wm.sum(axis=1), 1.0)
    assert np.allclose(wm, wm.T)
    assert np.allclose(wm, g.mixing_matrix())  # regular graph => identical


def test_metropolis_rejects_directed():
    g = make_graph("exponential", 16)
    with pytest.raises(ValueError):
        g.mixing_matrix("metropolis")


# ---------------------------------------------------------------------------
# Torus degree regression: every factorization up to n=64
# ---------------------------------------------------------------------------

def _torus_reference_w(a: int, b: int) -> np.ndarray:
    """Independent multigraph construction of the twisted-torus W.

    Row neighbors via the flat ring (offsets ±1), column neighbors via ±b on
    the grid; parallel edges (the a == 2 column wrap) accumulate weight.
    Uniform Algorithm-1 weights: 1/5 per unit edge on the 4-regular torus.
    """
    n = a * b
    w = np.zeros((n, n))
    for i in range(n):
        w[i, (i + 1) % n] += 1 / 5
        w[i, (i - 1) % n] += 1 / 5
        r, c = divmod(i, b)
        w[i, ((r + 1) % a) * b + c] += 1 / 5
        w[i, ((r - 1) % a) * b + c] += 1 / 5
    np.fill_diagonal(w, 1 / 5)
    return w


def test_torus_degree_every_factorization_up_to_64():
    """a == 2 grids (e.g. n=8, grid=(2,4)): offsets b and n-b collide; the
    offset must carry multiplicity 2 (weight 2/5), keeping the torus
    4-regular with row sums 1 — not silently degree-3 with 1/4 weights."""
    for n in range(6, 65):
        for a in range(2, n):
            if n % a:
                continue
            b = n // a
            if b < 2:
                continue
            g = Torus(n, grid=(a, b))
            w = g.mixing_matrix()
            assert g.degree == 4, (n, a, b, g.degree)
            assert g.num_edges == 2 * n, (n, a, b)
            assert np.allclose(w.sum(axis=1), 1.0), (n, a, b)
            assert np.allclose(w, w.T), (n, a, b)
            np.testing.assert_allclose(
                w, _torus_reference_w(a, b), atol=1e-12,
                err_msg=f"n={n} grid=({a},{b})",
            )
            if a == 2:
                # the doubled column edge carries exactly 2/5
                assert np.isclose(w[0, b], 2 / 5), (n, a, b, w[0, b])


# ---------------------------------------------------------------------------
# Circulant spectral-gap fast path (DFT of the weight vector)
# ---------------------------------------------------------------------------

@given(st.sampled_from(["ring", "torus", "ring_lattice", "exponential",
                        "complete", "one_peer_exponential"]),
       st.integers(min_value=2, max_value=48))
@settings(max_examples=40, deadline=None)
def test_spectral_gap_fast_path_matches_dense(kind, n):
    g = make_graph(kind, n, k=4)
    fast = spectral_gap(g)
    eig = np.linalg.eigvals(g.mixing_matrix())
    mags = np.sort(np.abs(eig))[::-1]
    dense = 1.0 - mags[1] if n > 1 else 1.0
    assert abs(fast - dense) < 1e-9, (kind, n, fast, dense)


def test_spectral_gap_exact_at_paper_scale():
    """n=1008 (the paper's largest run): exact gaps via the DFT fast path."""
    gaps = {k: spectral_gap(make_graph(k, 1008))
            for k in ("ring", "torus", "exponential", "complete")}
    assert all(np.isfinite(v) for v in gaps.values())
    assert gaps["ring"] < gaps["torus"] < gaps["exponential"] <= gaps["complete"]
    assert abs(gaps["complete"] - 1.0) < 1e-9
