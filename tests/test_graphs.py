"""Property tests for the communication graphs (paper Table 1)."""
import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.graphs import (
    Complete, Exponential, Ring, RingLattice, Torus, make_graph, spectral_gap,
)

NS = st.integers(min_value=2, max_value=64)


@given(NS)
@settings(max_examples=30, deadline=None)
def test_ring_degree_and_edges(n):
    g = Ring(n)
    assert g.degree == (1 if n == 2 else 2)
    if n > 2:
        assert g.num_edges == n  # Table 1
    assert g.is_symmetric


@given(st.integers(min_value=6, max_value=100))
@settings(max_examples=30, deadline=None)
def test_torus_matches_table(n):
    g = Torus(n)
    a = int(math.isqrt(n))
    while n % a:
        a -= 1
    if a == 1 or a * (n // a) != n or min(a, n // a) < 2:
        pytest.skip("degenerates to ring")
    assert g.degree in (3, 4)  # 3 when offsets coincide (small grids)
    assert g.is_symmetric


@given(NS)
@settings(max_examples=30, deadline=None)
def test_exponential_matches_paper(n):
    g = Exponential(n)
    expected = int(math.floor(math.log2(n - 1))) + 1 if n > 2 else 1
    # offsets 2^m mod n may collide for tiny n; degree <= formula
    assert g.degree <= expected
    if n > 4:
        for i in range(min(n, 5)):
            nbrs = set(g.neighbors(i))
            want = {(i + 2 ** m) % n for m in range(expected)} - {i}
            assert nbrs == want


@given(NS)
@settings(max_examples=30, deadline=None)
def test_complete_graph(n):
    g = Complete(n)
    assert g.degree == n - 1
    assert g.num_edges == n * (n - 1) // 2  # Table 1
    assert abs(spectral_gap(g) - 1.0) < 1e-9


@given(NS, st.integers(min_value=1, max_value=20))
@settings(max_examples=50, deadline=None)
def test_mixing_matrix_stochastic(n, k):
    """W is row-stochastic for every graph; symmetric graphs doubly so."""
    for g in (Ring(n), Torus(n), RingLattice(n, k), Exponential(n), Complete(n)):
        w = g.mixing_matrix()
        assert np.allclose(w.sum(axis=1), 1.0), g.name
        assert (w >= 0).all()
        if g.is_symmetric:
            assert np.allclose(w, w.T), g.name
        # consensus: spectral radius of W - J/n strictly below 1 (n > 1)
        if g.degree > 0:
            j = np.ones((n, n)) / n
            rad = max(abs(np.linalg.eigvals(w - j)))
            assert rad < 1.0 - 1e-12 or n <= 2, (g.name, rad)


@given(st.integers(min_value=4, max_value=64), st.integers(min_value=2, max_value=10))
@settings(max_examples=30, deadline=None)
def test_ring_lattice_alg1(n, k):
    """Algorithm 1: j in [-k//2, k//2], j != 0."""
    g = RingLattice(n, k)
    half = min(max(k // 2, 1), (n - 1) // 2)
    nbrs = set(g.neighbors(0))
    want = {j % n for j in range(-half, half + 1) if j != 0}
    assert nbrs == want


def test_connectivity_orders_spectral_gap():
    """More connections => larger spectral gap (paper Obs. 2 mechanism)."""
    n = 48
    gaps = [spectral_gap(make_graph(k, n)) for k in
            ("ring", "torus", "exponential", "complete")]
    assert gaps == sorted(gaps), gaps


def test_unknown_graph_raises():
    with pytest.raises(ValueError):
        make_graph("hypercube", 8)


@given(st.sampled_from(["ring", "torus", "complete"]), st.integers(min_value=3, max_value=48))
@settings(max_examples=30, deadline=None)
def test_metropolis_weights_doubly_stochastic(kind, n):
    """Beyond-paper MH weights: doubly stochastic on any undirected graph,
    equal to Algorithm-1 uniform weights on regular graphs."""
    g = make_graph(kind, n)
    wm = g.mixing_matrix("metropolis")
    assert np.allclose(wm.sum(axis=0), 1.0) and np.allclose(wm.sum(axis=1), 1.0)
    assert np.allclose(wm, wm.T)
    assert np.allclose(wm, g.mixing_matrix())  # regular graph => identical


def test_metropolis_rejects_directed():
    g = make_graph("exponential", 16)
    with pytest.raises(ValueError):
        g.mixing_matrix("metropolis")
