"""Subprocess body for test_spmd.py: crash-consistent --resume round-trip.

Drives the real launcher (``repro.launch.train.main``) three times in one
process: (1) an uninterrupted faulted closed-loop-Ada run to step 8,
(2) the same run stopped at step 4 with a checkpoint, (3) ``--resume`` of
that checkpoint to step 8.  The step-8 checkpoints of (1) and (3) must be
BIT-identical — every parameter/optimizer array and the JSON extra payload
(controller transitions/events/trace + membership tracking): fault
realizations are pure fn(seed, step), data and lr are step-keyed, so an
interrupted run replays exactly.

Second round-trip: a spare-rank pool run whose checkpoint lands BEFORE the
join activates a ghost rank and whose resume crosses the activation —
membership tracking and the seeded SparePool stream must replay the
activation identically.  Finally: a mismatched-config ``--resume``
(different topology) must fail fast with the recorded-vs-configured error,
not a mid-restore shape mismatch.
"""
import os
import sys
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.launch import train


def run(argv):
    sys.argv = ["train"] + argv
    train.main()


base = tempfile.mkdtemp(prefix="resume_cli_")
dir_a = os.path.join(base, "uninterrupted")
dir_b = os.path.join(base, "interrupted")
common = [
    "--arch", "granite-8b", "--reduced",
    "--topology", "d_ada", "--k-floor", "one_peer",
    "--consensus-target", "0.5",
    "--fault-model", "dropout", "--fault-rate", "0.35", "--fault-seed", "3",
    "--steps-per-epoch", "10", "--seq", "16", "--per-node-batch", "2",
    "--mesh", "4,2", "--ckpt-every", "4",
]

run(common + ["--steps", "8", "--ckpt-dir", dir_a])
run(common + ["--steps", "4", "--ckpt-dir", dir_b])
run(common + ["--steps", "8", "--ckpt-dir", dir_b, "--resume"])

ckpt = "step_0000000008.npz"
da = np.load(os.path.join(dir_a, ckpt))
db = np.load(os.path.join(dir_b, ckpt))
assert set(da.files) == set(db.files), (
    sorted(set(da.files) ^ set(db.files))
)
assert "__extra__" in da.files  # the engine run state rode along
bad = [k for k in da.files if not np.array_equal(da[k], db[k])]
assert not bad, f"resume diverged on: {bad[:10]}"
print(f"compared {len(da.files)} arrays (incl. controller/membership extra)")

# --- round-trip crossing a spare-rank activation ---------------------------
# ckpt at step 4, the pre-declared join activates the ghost rank at step 6:
# the resumed half replays the activation (adopt + membership re-arm) from
# the seeded stream alone and must land bit-identical at step 8.
dir_c = os.path.join(base, "spare_uninterrupted")
dir_d = os.path.join(base, "spare_interrupted")
spare = [
    "--arch", "granite-8b", "--reduced",
    "--topology", "d_ada", "--k-floor", "one_peer",
    "--consensus-target", "0.5",
    "--fault-model", "join", "--fault-join-steps", "6",
    "--spare-ranks", "1", "--fault-seed", "5",
    "--steps-per-epoch", "10", "--seq", "16", "--per-node-batch", "2",
    "--mesh", "4,2", "--ckpt-every", "4",
]
run(spare + ["--steps", "8", "--ckpt-dir", dir_c])
run(spare + ["--steps", "4", "--ckpt-dir", dir_d])
run(spare + ["--steps", "8", "--ckpt-dir", dir_d, "--resume"])
dc = np.load(os.path.join(dir_c, ckpt))
dd = np.load(os.path.join(dir_d, ckpt))
assert set(dc.files) == set(dd.files)
bad = [k for k in dc.files if not np.array_equal(dc[k], dd[k])]
assert not bad, f"spare-activation resume diverged on: {bad[:10]}"
print(f"compared {len(dc.files)} arrays across the spare activation")

# --- fail-fast config validation -------------------------------------------
# resuming the dir_b checkpoint under a different topology must raise the
# recorded-vs-configured error, not an opaque restore failure
try:
    run([
        "--arch", "granite-8b", "--reduced", "--topology", "d_ring",
        "--steps-per-epoch", "10", "--seq", "16", "--per-node-batch", "2",
        "--mesh", "4,2", "--steps", "8",
        "--ckpt-dir", dir_b, "--resume",
    ])
    raise SystemExit("mismatched --resume should have failed fast")
except ValueError as e:
    assert "resume config mismatch" in str(e), e
    assert "d_ada" in str(e) and "d_ring" in str(e), e
    print(f"fail-fast resume: {e}")

print("RESUME_ROUNDTRIP_OK")
