"""Subprocess body for test_spmd.py: crash-consistent --resume round-trip.

Drives the real launcher (``repro.launch.train.main``) three times in one
process: (1) an uninterrupted faulted closed-loop-Ada run to step 8,
(2) the same run stopped at step 4 with a checkpoint, (3) ``--resume`` of
that checkpoint to step 8.  The step-8 checkpoints of (1) and (3) must be
BIT-identical — every parameter/optimizer array and the JSON extra payload
(controller transitions/events/trace + membership tracking): fault
realizations are pure fn(seed, step), data and lr are step-keyed, so an
interrupted run replays exactly.
"""
import os
import sys
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.launch import train


def run(argv):
    sys.argv = ["train"] + argv
    train.main()


base = tempfile.mkdtemp(prefix="resume_cli_")
dir_a = os.path.join(base, "uninterrupted")
dir_b = os.path.join(base, "interrupted")
common = [
    "--arch", "granite-8b", "--reduced",
    "--topology", "d_ada", "--k-floor", "one_peer",
    "--consensus-target", "0.5",
    "--fault-model", "dropout", "--fault-rate", "0.35", "--fault-seed", "3",
    "--steps-per-epoch", "10", "--seq", "16", "--per-node-batch", "2",
    "--mesh", "4,2", "--ckpt-every", "4",
]

run(common + ["--steps", "8", "--ckpt-dir", dir_a])
run(common + ["--steps", "4", "--ckpt-dir", dir_b])
run(common + ["--steps", "8", "--ckpt-dir", dir_b, "--resume"])

ckpt = "step_0000000008.npz"
da = np.load(os.path.join(dir_a, ckpt))
db = np.load(os.path.join(dir_b, ckpt))
assert set(da.files) == set(db.files), (
    sorted(set(da.files) ^ set(db.files))
)
assert "__extra__" in da.files  # the engine run state rode along
bad = [k for k in da.files if not np.array_equal(da[k], db[k])]
assert not bad, f"resume diverged on: {bad[:10]}"
print(f"compared {len(da.files)} arrays (incl. controller/membership extra)")
print("RESUME_ROUNDTRIP_OK")
