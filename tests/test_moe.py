"""MoE router/dispatch correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import init_params
from repro.models.moe import apply_moe, moe_defs


def _setup(key, d=16, f=32, e=4, b=2, s=8):
    params = init_params(moe_defs(d, f, e), key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, d))
    return params, x


def _dense_oracle(params, x, top_k):
    """Route every token through all experts, weight by the top-k gate."""
    b, s, d = x.shape
    e = params["router"].shape[1]
    t = b * s
    xt = x.reshape(t, d)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    topw, topi = jax.lax.top_k(probs, top_k)
    topw = topw / topw.sum(-1, keepdims=True)
    gates = jnp.zeros((t, e)).at[jnp.arange(t)[:, None], topi].set(topw)
    g = jnp.einsum("td,edf->tef", xt, params["w_gate"])
    u = jnp.einsum("td,edf->tef", xt, params["w_up"])
    h = jax.nn.silu(g) * u
    out_e = jnp.einsum("tef,efd->ted", h, params["w_down"])
    return jnp.einsum("ted,te->td", out_e, gates).reshape(b, s, d)


@pytest.mark.parametrize("top_k", [1, 2])
def test_dispatch_matches_dense_oracle_with_ample_capacity(top_k):
    key = jax.random.PRNGKey(0)
    params, x = _setup(key)
    out, aux = apply_moe(params, x, top_k=top_k, capacity=64)  # no drops
    want = _dense_oracle(params, x, top_k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-4)
    # near-balanced routing keeps aux close to its 1.0 optimum
    assert 0.9 < float(aux) < 2.0


def test_capacity_drops_tokens_not_correctness():
    key = jax.random.PRNGKey(1)
    params, x = _setup(key, b=1, s=32)
    full, _ = apply_moe(params, x, top_k=2, capacity=64)
    tight, _ = apply_moe(params, x, top_k=2, capacity=2)
    # tight capacity zeroes some token contributions but must stay finite
    assert bool(jnp.all(jnp.isfinite(tight)))
    assert float(jnp.abs(tight).sum()) < float(jnp.abs(full).sum()) + 1e-3


def test_balanced_router_aux_is_near_one():
    """Uniform routing => aux == 1 (its minimum)."""
    key = jax.random.PRNGKey(2)
    params, x = _setup(key, e=4, b=4, s=64)
    params = dict(params)
    params["router"] = jnp.zeros_like(params["router"])  # uniform logits
    _, aux = apply_moe(params, x, top_k=2, capacity=256)
    assert 0.9 < float(aux) < 1.3


def test_shared_expert_adds_contribution():
    key = jax.random.PRNGKey(3)
    d, f, e = 16, 32, 4
    params = init_params(moe_defs(d, f, e, n_shared=1), key)
    x = jax.random.normal(jax.random.fold_in(key, 9), (2, 8, d))
    with_shared, _ = apply_moe(params, x, top_k=2, capacity=64)
    p2 = dict(params)
    p2.pop("shared")
    without, _ = apply_moe(p2, x, top_k=2, capacity=64)
    assert not np.allclose(np.asarray(with_shared), np.asarray(without))


def test_moe_grads_flow_to_router_and_experts():
    key = jax.random.PRNGKey(4)
    params, x = _setup(key)

    def loss(p):
        out, aux = apply_moe(p, x, top_k=2, capacity=64)
        return jnp.mean(out**2) + 0.01 * aux

    g = jax.grad(loss)(params)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.abs(g[name]).sum()) > 0, name
