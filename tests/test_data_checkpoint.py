"""Data pipeline determinism/disjointness + checkpoint roundtrip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.data import SyntheticLM, node_batch_iterator


def test_data_deterministic_across_calls():
    src = SyntheticLM(vocab=100, seq_len=16, seed=3)
    a = src.sample(node=2, step=5, batch=4)
    b = src.sample(node=2, step=5, batch=4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_data_disjoint_across_nodes_and_steps():
    src = SyntheticLM(vocab=50_000, seq_len=32, seed=0)
    a = src.sample(node=0, step=0, batch=2)["tokens"]
    b = src.sample(node=1, step=0, batch=2)["tokens"]
    c = src.sample(node=0, step=1, batch=2)["tokens"]
    assert not np.array_equal(a, b)
    assert not np.array_equal(a, c)


def test_targets_are_shifted_tokens():
    src = SyntheticLM(vocab=100, seq_len=16, seed=1)
    s = src.sample(node=0, step=0, batch=3)
    np.testing.assert_array_equal(s["targets"][:, :-1], s["tokens"][:, 1:])
    assert (s["targets"][:, -1] == -1).all()


def test_stacked_shapes_and_iterator():
    src = SyntheticLM(vocab=100, seq_len=8, seed=0)
    st = src.stacked(n_nodes=4, step=0, per_node_batch=2)
    assert st["tokens"].shape == (4, 2, 8)
    it = node_batch_iterator(src, 4, 2, start_step=0)
    first = next(it)
    np.testing.assert_array_equal(np.asarray(first["tokens"]), st["tokens"])


def test_data_has_learnable_structure():
    """Next-token must be predictable above chance (for convergence benches)."""
    src = SyntheticLM(vocab=64, seq_len=256, seed=0, structure=0.9)
    s = src.sample(0, 0, 4)
    toks = s["tokens"]
    mult = 6364136223846793005 % 64
    pred = (toks[:, :-1] * mult + 12345) % 64
    frac = (pred == toks[:, 1:]).mean()
    assert frac > 0.7


def test_checkpoint_roundtrip(tmp_path):
    state = {
        "params": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.zeros(3)},
        "opt": [jnp.ones(2), {"t": jnp.int32(7)}],
    }
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 42, state)
    assert latest_step(d) == 42
    restored, step = load_checkpoint(d, jax.tree.map(jnp.zeros_like, state))
    assert step == 42
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_prunes_old(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in range(5):
        save_checkpoint(d, s, {"x": jnp.ones(1) * s}, keep=2)
    files = [f for f in os.listdir(d) if f.startswith("step_")]
    assert len(files) == 2
    restored, step = load_checkpoint(d, {"x": jnp.zeros(1)})
    assert step == 4 and float(restored["x"][0]) == 4.0


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 0, {"x": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        load_checkpoint(d, {"x": jnp.zeros((3,))})
