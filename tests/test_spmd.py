"""Multi-device SPMD engine tests (run in subprocesses: they need 8 host
devices, while the rest of the suite runs single-device)."""
import os
import re
import subprocess
import sys

import pytest

_HERE = os.path.dirname(__file__)


def _run(script, *args, timeout=900):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(_HERE, script), *args],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}\nstdout:\n{r.stdout[-1000:]}"
    return r.stdout


def _extract(out, key):
    m = re.search(rf"{key}=([\d.e+-]+)", out)
    assert m, out
    return float(m.group(1))


def test_star_lowers_to_permutes_only():
    """PR-3 acceptance (fast, mixing-only): the edge-colored star and an
    irregular graph lower to collective-permutes with ZERO all-gathers
    (``assert_no_all_gather``), and the fused Pallas shard apply matches
    the dense oracle on 8 host devices."""
    out = _run("star_hlo_script.py", timeout=300)
    assert "STAR_HLO_OK" in out


def test_bucketed_overlap_lowering_and_probe_fold():
    """Overlap-scheduled gossip (fast, lowering-level): the bucketed shard
    interpreter lowers to ``ops × buckets`` collective-permutes with ZERO
    all-gathers; one per-bucket executor carries its permutes AND the
    optimizer compute in the SAME executable (the dispatch-pipelining
    evidence — only the Ξ² token chains buckets); and the probe fold
    removes every standalone ``consensus_distance_jit`` dispatch after
    the first from a closed-loop run without changing the controller
    signal."""
    out = _run("overlap_hlo_script.py", timeout=300)
    assert "OVERLAP_HLO_OK" in out


@pytest.mark.slow
def test_fault_injection_matches_simulator():
    """Resilience subsystem: both engines draw the SAME seeded fault
    realizations (transient dropout; permanent crash + elastic rejoin; a
    2-node concurrent crash composed over runtime masks; a preemption
    drain-then-leave), agree on final parameters to float32 round-off,
    compile nothing beyond the pre-enumerated program set, and transient
    AND composed-concurrent runs' executable counts equal the fault-free
    run's."""
    out = _run("faults_spmd_script.py", timeout=900)
    assert "FAULTS_EQUIV_OK" in out
    assert _extract(out, "MAXDIFF") < 5e-5


@pytest.mark.slow
def test_resume_roundtrip_cli():
    """Crash-consistent resume through the real launcher: an interrupted
    faulted closed-loop run continued with --resume produces a step-8
    checkpoint BIT-identical to the uninterrupted run's (arrays + the
    controller/membership extra payload)."""
    out = _run("resume_cli_script.py", timeout=900)
    assert "RESUME_ROUNDTRIP_OK" in out


@pytest.mark.slow
def test_closed_loop_ada_matches_simulator():
    """Consensus-distance-triggered Ada (8 steps): both engines feed the
    controller the same measured signal, pick the SAME graph sequence
    (identical transition logs), hand off to one-peer at a measured step,
    agree to float32 round-off, and compile nothing beyond the ladder.
    ~50s on an idle 2-CPU box but up to ~10x under pytest contention —
    slow tier, like the other trainer-level equivalence runs."""
    out = _run("consensus_equivalence_script.py", timeout=900)
    assert "CONSENSUS_EQUIV_OK" in out
    assert _extract(out, "MAXDIFF") < 5e-5


@pytest.mark.slow
@pytest.mark.parametrize(
    "topo",
    [
        "d_ring", "d_exponential", "c_complete", "d_complete",
        # time-varying / irregular families ride the same GossipProgram path
        "d_one_peer_exp", "d_random_matching", "d_star",
    ],
)
def test_spmd_engine_matches_simulator(topo):
    """Production engine (compiled GossipProgram) == dense-matrix oracle."""
    out = _run("spmd_equivalence_script.py", topo, "ppermute")
    assert _extract(out, "MAXDIFF") < 5e-5
    assert _extract(out, "LOSSDIFF") < 5e-5


@pytest.mark.slow
def test_spmd_dense_mixing_matches_simulator():
    """The paper-faithful all-gather mixing path agrees too."""
    out = _run("spmd_equivalence_script.py", "d_ring", "dense")
    assert _extract(out, "MAXDIFF") < 5e-5


@pytest.mark.slow
@pytest.mark.parametrize("topo", ["d_star", "d_one_peer_exp"])
def test_spmd_fused_apply_matches_simulator(topo):
    """The fused Pallas optimizer+gossip kernel == dense-matrix oracle at
    trainer level (edge-colored star + time-varying one-peer)."""
    out = _run("spmd_equivalence_script.py", topo, "fused")
    assert _extract(out, "MAXDIFF") < 5e-5
    assert _extract(out, "LOSSDIFF") < 5e-5


@pytest.mark.slow
def test_bucketed_trainer_matches_monolithic_and_oracle():
    """Overlap-scheduled gossip at trainer level: per-bucket dispatches
    (token-chained, bounded dispatch window) reproduce the monolithic
    trainer and the dense oracle — fault-masked and fine-grained
    (num_buckets >> window) runs included."""
    out = _run("bucketed_equivalence_script.py", timeout=900)
    assert "BUCKETED_EQUIV_OK" in out
    assert _extract(out, "MONODIFF") < 1e-5
    assert _extract(out, "ORACLEDIFF") < 1e-5


@pytest.mark.slow
def test_mini_dryrun_lowers_and_compiles():
    """A miniature of launch/dryrun.py: production-mesh pattern on 8 devices."""
    out = _run("spmd_dryrun_script.py")
    assert "MINI_DRYRUN_OK" in out


@pytest.mark.slow
def test_manual_ep_matches_gather_oracle():
    """Hand-scheduled expert parallelism (one psum/layer) == GSPMD dispatch."""
    out = _run("spmd_manual_ep_script.py")
    assert "manual EP == gather oracle OK" in out
    assert "grads OK" in out
