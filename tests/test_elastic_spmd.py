"""Elastic SPMD (PR 8): spare-rank pools, gossip deadlines with backoff
readmission, and Ξ-spike re-densification.

The load-bearing claims pinned here:

* A ``SparePool`` pads any inner fault model to the full mesh size with
  alive-masked zero-weight ghost ranks: ghost rows realize exactly the
  identity (mass renormalized onto self via ``degraded_matrix``), the
  selection mask stays all-ones (composed runtime-mask execution — zero
  extra executables), and an inner ``Join`` surfaces as a spare
  *activation* (outer rejoin) at the same step.
* ``GossipDeadline`` masks deadline-missing nodes out of that round's
  averaging while their ``update`` flag stays 1 (local-step fallback), and
  benches repeat offenders under exponential backoff (1, b, b², ... rounds)
  before readmission; realizations are pure fn(seed, step) under
  out-of-order queries.
* The ``ConsensusController`` ladder is non-monotone with ``spike``: a
  probed Ξ_t spiking past ``spike ×`` the phase's running peak walks the
  ladder back UP one rung, logs a "redensify" event, and the spike
  reference survives a same-event ``rearm`` but resets on every
  transition (one rung per event, no thrash).
* Fail-fast ``--resume``: a checkpoint's recorded run_config (topology,
  bucket layout, trainer gossip size) mismatching the resuming run raises
  a clear both-values error instead of an opaque restore failure.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ada import AdaSchedule
from repro.core.consensus import ConsensusController
from repro.core.dsgd import make_topology
from repro.core.faults import (
    GossipDeadline, Join, SparePool, degraded_matrix, make_fault_model,
)
from repro.core.simulator import DecentralizedSimulator, SimState
from repro.optim.sgd import sgd


def _quad_loss(p, b):
    return jnp.mean((b - p["w"]) ** 2)


# ---------------------------------------------------------------------------
# SparePool: ghost ranks, activation, composition
# ---------------------------------------------------------------------------

def test_spare_pool_pads_ghosts_and_activates_on_join():
    fm = make_fault_model("join", 6, seed=5, join_steps=(4,), spare_ranks=2)
    assert isinstance(fm, SparePool)
    assert fm.n == 6 and fm.spares == 2 and fm.n_active0 == 4
    assert not fm.elastic  # fixed-mesh: the SPMD trainer must accept it
    fr0 = fm.at(0)
    np.testing.assert_array_equal(fr0.alive, [1, 1, 1, 1, 0, 0])
    np.testing.assert_array_equal(fr0.update, [1, 1, 1, 1, 0, 0])
    np.testing.assert_array_equal(fr0.program_alive,
                                  [True, True, True, True, False, False])
    # composed execution: selection mask all-ones, no degraded programs
    assert fr0.selection_mask().all()
    assert fm.program_masks() == ()
    assert fr0.faulty  # ghost masks alone route through the masked step
    # the step-4 inner join becomes an outer spare ACTIVATION (rejoin)
    fr4 = fm.at(4)
    assert fr4.rejoin == (4,)
    np.testing.assert_array_equal(fr4.alive, [1, 1, 1, 1, 1, 0])
    assert fm.activation_steps() == (4,)
    # membership key flips at activation -> controller re-arm fires
    assert fm.at(3).membership_key() != fr4.membership_key()


def test_spare_pool_ghost_rows_renormalize_to_identity():
    """The ghost-rank semantics: a zero-weight (dead-masked) row of the
    doubly-stochastic W renormalizes its mass onto the receiver's diagonal
    — the alive block stays doubly stochastic, ghost rows are exactly
    identity, so ghosts ride from step 0 at zero influence."""
    from repro.core.graphs import Ring

    W = Ring(6).mixing_matrix()
    alive = np.array([True, True, True, True, False, False])
    D = degraded_matrix(W, alive)
    for g in (4, 5):
        row = np.zeros(6)
        row[g] = 1.0
        np.testing.assert_allclose(D[g], row, atol=1e-12)
        np.testing.assert_allclose(D[:, g], row, atol=1e-12)
    block = D[np.ix_(alive, alive)]
    np.testing.assert_allclose(block.sum(axis=0), 1.0, atol=1e-12)
    np.testing.assert_allclose(block.sum(axis=1), 1.0, atol=1e-12)


def test_spare_pool_pure_overprovision_and_inner_composition():
    # spares with NO inner faults: still a pool (ghost masks make it faulty)
    fm = make_fault_model("none", 4, spare_ranks=2)
    assert isinstance(fm, SparePool) and fm.inner is None
    np.testing.assert_array_equal(fm.at(7).alive, [1, 1, 0, 0])
    # spares compose with a transient inner model at n - S active ranks
    fm2 = make_fault_model("deadline", 6, rate=0.6, seed=4, spare_ranks=2)
    assert isinstance(fm2.inner, GossipDeadline) and fm2.inner.n == 4
    assert fm2.deadline_ms == fm2.inner.deadline_ms
    for t in range(10):
        fr = fm2.at(t)
        assert len(fr.alive) == 6
        np.testing.assert_array_equal(fr.alive[4:], [0, 0])  # ghosts stay out
        np.testing.assert_array_equal(fr.alive[:4], fm2.inner.at(t).alive)


def test_spare_pool_validation():
    with pytest.raises(ValueError, match="spares"):
        SparePool(n=4, rate=0.0, seed=0, spares=4, inner=None)
    with pytest.raises(ValueError, match="inner"):
        SparePool(n=4, rate=0.0, seed=0, spares=1,
                  inner=Join(n=4, rate=0.0, seed=0, join_steps=(2,)))
    with pytest.raises(ValueError, match="join"):
        SparePool(n=4, rate=0.0, seed=0, spares=1,
                  inner=Join(n=3, rate=0.0, seed=0, join_steps=(2, 4)))


def test_spare_activation_join_on_simulator_keeps_ghosts_frozen():
    """End-to-end on the oracle: ghost rows stay bit-frozen at init until
    the activation step, then the activated spare adopts its alive
    neighbors' average and participates from the next round on."""
    fm = make_fault_model("join", 6, seed=5, join_steps=(3,), spare_ranks=2)
    topo = make_topology("d_ring", 6, fault_model=fm)
    sim = DecentralizedSimulator(_quad_loss, sgd(0.1), topo)
    state = sim.init({"w": jnp.zeros((3,), jnp.float32)})
    rng = np.random.default_rng(0)
    state = SimState(
        {"w": jnp.asarray(rng.normal(size=(6, 3)).astype(np.float32))},
        state.opt_state, 0,
    )
    init_rows = np.asarray(state.params["w"]).copy()
    for t in range(3):
        b = jnp.asarray(rng.normal(size=(6, 2, 3)).astype(np.float32))
        state, _, _ = sim.train_step(state, b, 0.05)
        np.testing.assert_array_equal(  # both ghosts frozen pre-activation
            np.asarray(state.params["w"])[4:], init_rows[4:]
        )
    # activation step: rank 4 adopts, rank 5 stays a ghost
    b = jnp.asarray(rng.normal(size=(6, 2, 3)).astype(np.float32))
    state, _, _ = sim.train_step(state, b, 0.05)
    post = np.asarray(state.params["w"])
    assert not np.array_equal(post[4], init_rows[4])
    np.testing.assert_array_equal(post[5], init_rows[5])
    assert np.isfinite(post).all()


# ---------------------------------------------------------------------------
# GossipDeadline: masking, local-step fallback, exponential backoff
# ---------------------------------------------------------------------------

def test_deadline_miss_masks_gossip_but_keeps_local_update():
    fm = GossipDeadline(n=8, rate=0.5, seed=4)
    missed_any = False
    for t in range(20):
        fr = fm.at(t)
        np.testing.assert_array_equal(fr.update, np.ones(8))  # local fallback
        assert fr.program_alive.all()  # transient: no membership change
        assert fr.selection_mask().all()  # composed: zero extra executables
        if not fr.alive.all():
            missed_any = True
            assert fr.faulty
    assert missed_any  # rate 0.5 over 20 rounds must realize misses


def test_deadline_backoff_benches_exponentially():
    """A node that misses is benched 1 round; missing again right after
    readmission benches it 2, then 4 ... (factor ``backoff``), and a clean
    participated round resets its penalty to 1."""
    fm = GossipDeadline(n=4, rate=0.5, seed=0, backoff=2.0)
    lat = {t: fm.latency_ms(t) for t in range(64)}
    participates = {t: np.asarray(fm.at(t).alive, bool) for t in range(64)}
    penalty = np.ones(4)
    suspend = np.zeros(4, dtype=np.int64)
    for t in range(64):
        miss = lat[t] > fm.deadline_ms
        benched = suspend > 0
        expect = ~(miss | benched)
        np.testing.assert_array_equal(
            participates[t], expect, err_msg=f"step {t}"
        )
        suspend[benched] -= 1
        fresh = miss & ~benched
        suspend[fresh] += np.round(penalty[fresh]).astype(np.int64)
        penalty[fresh] = np.minimum(penalty[fresh] * 2.0, 64.0)
        penalty[expect] = 1.0
    # the exponential actually engaged: some bench stretch exceeded 1 round
    runs = []
    for i in range(4):
        out = ~np.array([participates[t][i] for t in range(64)])
        run, best = 0, 0
        for v in out:
            run = run + 1 if v else 0
            best = max(best, run)
        runs.append(best)
    assert max(runs) >= 3  # miss + bench(1) + miss + bench(2) chains exist


def test_deadline_determinism_out_of_order():
    a = GossipDeadline(n=6, rate=0.5, seed=9)
    b = GossipDeadline(n=6, rate=0.5, seed=9)
    for t in [0, 1, 5, 17, 17, 3, 11, 2]:  # replay cache: any query order
        np.testing.assert_array_equal(a.at(t).alive, b.at(t).alive)


def test_deadline_validation_and_factory():
    with pytest.raises(ValueError, match="deadline_ms"):
        GossipDeadline(n=4, rate=0.5, seed=0, deadline_ms=0.0)
    with pytest.raises(ValueError, match="backoff"):
        GossipDeadline(n=4, rate=0.5, seed=0, backoff=0.5)
    assert make_fault_model("deadline", 8, rate=0.0) is None
    fm = make_fault_model(
        "deadline", 8, rate=0.3, seed=1, deadline_ms=12.0, deadline_backoff=3.0
    )
    assert fm.deadline_ms == 12.0 and fm.backoff == 3.0
    with pytest.raises(ValueError, match="down_steps"):
        make_fault_model("deadline", 8, rate=0.3, down_steps=4)


def test_deadline_round_trace_is_recorded():
    """Engines record measured wall-clock round durations against the
    model's deadline (observational; masks stay seeded)."""
    fm = make_fault_model("deadline", 4, rate=0.5, seed=4)
    topo = make_topology("d_ring", 4, fault_model=fm)
    sim = DecentralizedSimulator(_quad_loss, sgd(0.1), topo)
    state = sim.init({"w": jnp.zeros((3,), jnp.float32)})
    rng = np.random.default_rng(0)
    for _ in range(5):
        b = jnp.asarray(rng.normal(size=(4, 2, 3)).astype(np.float32))
        state, _, _ = sim.train_step(state, b, 0.05)
    assert len(sim.round_ms) == 5
    assert all(ms > 0 for ms in sim.round_ms)
    assert 0 <= sim.deadline_overruns <= 5


# ---------------------------------------------------------------------------
# Non-monotone ladder: Ξ-spike re-densification
# ---------------------------------------------------------------------------

def _spike_controller(spike=2.0):
    return ConsensusController(
        schedule=AdaSchedule(n_nodes=8, k0=4, gamma_k=0.02, k_floor=2),
        target=0.5, spike=spike,
    )


def test_spike_walks_ladder_back_up_and_logs_redensify():
    c = _spike_controller()
    assert not c.observe(1.0, 0)       # seeds the phase
    assert c.observe(0.4, 1)           # <= target x xi0: down a rung
    assert c.rung == 1
    c.rearm(2, "membership")           # a membership event between probes
    assert not c.observe(0.6, 2)       # re-seeds; also seeds the spike ref
    assert not c.observe(1.5, 3)       # 1.5 >= 2.0 * 0.6: re-densify UP
    assert c.rung == 0
    assert (3, 0) in c.transitions
    assert any(r == "redensify" for _, r in c.events)
    # the redensified phase re-seeds at the spiked level: recovery
    # re-sparsifies through the NORMAL target trigger, closing the loop
    assert c.observe(0.7, 4) is False  # seeds new phase at 0.7... wait
    assert c.observe(0.3, 5)           # 0.3 <= 0.5 * 0.7: back down
    assert c.rung == 1


def test_spike_fires_at_most_one_rung_per_event():
    c = _spike_controller()
    c.observe(1.0, 0)
    c.observe(0.4, 1)                  # down to rung 1
    c.observe(0.5, 2)                  # spike ref = 0.5
    assert not c.observe(5.0, 3)       # huge spike: ONE rung up, ref reset
    assert c.rung == 0
    assert not c.observe(5.0, 4)       # no second fire off the same storm
    assert c.rung == 0


def test_spike_never_fires_at_densest_rung_or_without_ref():
    c = _spike_controller()
    assert not c.observe(1.0, 0)
    assert not c.observe(50.0, 1)      # rung 0: nowhere denser to go
    assert c.rung == 0 and all(r != "redensify" for _, r in c.events)


def test_spike_state_roundtrips_and_default_is_monotone():
    c = _spike_controller()
    c.observe(1.0, 0)
    c.observe(0.4, 1)
    c.observe(0.6, 2)
    d = c.state_dict()
    c2 = _spike_controller()
    c2.load_state_dict(d)
    c2.observe(1.5, 3)                 # the restored spike ref still fires
    assert c2.rung == 0
    assert any(r == "redensify" for _, r in c2.events)
    # spike=None (the default) stays strictly monotone
    m = ConsensusController(
        schedule=AdaSchedule(n_nodes=8, k0=4, gamma_k=0.02, k_floor=2),
        target=0.5,
    )
    m.observe(1.0, 0)
    m.observe(0.4, 1)
    m.observe(99.0, 2)
    assert m.rung == 1 and [r for _, r in m.events] == []


def test_spike_validation_requires_ratio_and_target():
    with pytest.raises(ValueError, match="spike"):
        _spike_controller(spike=0.8)
    with pytest.raises(ValueError, match="consensus_target"):
        make_topology("d_ada", 8, consensus_spike=3.0, k_floor="one_peer")


def test_redensify_on_injected_xi_spike_closed_loop():
    """Acceptance (ISSUE 8): an injected consensus storm — one node's
    replica knocked far off mid-run, as a crash/deadline pile-up does —
    raises the probed Ξ_t past the re-arm threshold and the closed-loop
    controller demonstrably steps BACK to a denser rung, transition in
    the event log."""
    topo = make_topology(
        "d_ada", 8, k0=4, consensus_target=0.3, consensus_spike=2.0,
        k_floor=2,
    )
    sim = DecentralizedSimulator(_quad_loss, sgd(0.1), topo)
    state = sim.init({"w": jnp.zeros((4,), jnp.float32)})
    rng = np.random.default_rng(3)
    state = SimState(
        {"w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))},
        state.opt_state, 0,
    )
    zero = jnp.zeros((8, 2, 4), jnp.float32)
    ctl = topo.controller
    # pure gossip (lr=0) contracts Ξ until the target fires a down-step
    t = 0
    while not ctl.transitions and t < 40:
        state, _, _ = sim.train_step(state, zero, 0.0)
        t += 1
    assert ctl.transitions, "closed loop never sparsified"
    rung_before = ctl.rung
    state, _, _ = sim.train_step(state, zero, 0.0)  # probe seeds spike ref
    t += 1
    # the storm: node 0 blasted away from consensus
    w = np.asarray(state.params["w"]).copy()
    w[0] += 50.0
    state = SimState({"w": jnp.asarray(w)}, state.opt_state, state.step)
    state, _, _ = sim.train_step(state, zero, 0.0)
    assert ctl.rung == rung_before - 1  # denser
    assert any(r == "redensify" for _, r in ctl.events)
    assert ctl.transitions[-1][1] == rung_before - 1


# ---------------------------------------------------------------------------
# Fail-fast resume validation (simulator side; trainer side in test_spmd's
# resume_cli_script)
# ---------------------------------------------------------------------------

def _sim(topo_name="d_ring", bucket_mb=None):
    topo = make_topology(topo_name, 8)
    return DecentralizedSimulator(
        _quad_loss, sgd(momentum=0.9), topo, bucket_mb=bucket_mb
    )


def test_restore_extra_validates_topology_and_buckets():
    snap = _sim("d_ring", bucket_mb=2.0).snapshot_extra()
    assert snap["run_config"]["topology"] == "d_ring"
    assert snap["run_config"]["bucket_mb"] == 2.0
    # matching config restores fine
    _sim("d_ring", bucket_mb=2.0).restore_extra(snap)
    with pytest.raises(ValueError, match="d_ring.*d_one_peer_exp"):
        _sim("d_one_peer_exp", bucket_mb=2.0).restore_extra(snap)
    with pytest.raises(ValueError, match="bucket_mb"):
        _sim("d_ring", bucket_mb=None).restore_extra(snap)
    # pre-run_config checkpoints (old payloads) skip the check
    _sim("d_one_peer_exp").restore_extra({"last_membership": None})


def test_restore_extra_keeps_elastic_resize_for_n():
    """n stays OUTSIDE the validated run_config on the simulator: elastic
    joins legitimately grow it, and restore resizes to match."""
    sim = _sim("d_ring")
    snap = sim.snapshot_extra()
    assert "n" not in snap["run_config"] and snap["n"] == 8
    grown = dict(snap, n=10)
    sim2 = _sim("d_ring")
    sim2.restore_extra(grown)
    assert sim2.n == 10 and sim2.topology.n_nodes == 10
