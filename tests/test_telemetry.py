"""Unified run telemetry (src/repro/telemetry/).

Pins the PR's acceptance bars:

  * the recorder is provably free — with telemetry enabled the simulator
    compiles the SAME executable set as with it disabled, and
    ``debug_no_retrace`` / ``assert_executables_preenumerated`` hold;
  * the streamed ``variance`` records equal the offline
    ``DBenchRecorder`` computation (same function, same array);
  * the JSONL stream round-trips summarize/diff, including a --resume
    crossing where counters continue but per-process ``round_ms`` views
    restart;
  * controller transition/rearm/redensify events route through ONE
    coalescing implementation, so the event stream is engine-independent;
  * the CLI summarize exits clean on the committed fixture.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.invariants import verify_bench_payload
from repro.analysis.report import InvariantViolation
from repro.analysis.recompile import assert_executables_preenumerated
from repro.core.dbench import DBenchRecorder, variance_report
from repro.core.dsgd import make_topology
from repro.core.faults import make_fault_model
from repro.core.schedule import program_comm_bytes
from repro.core.simulator import DecentralizedSimulator
from repro.optim.sgd import sgd
from repro.telemetry import (
    JsonlSink, MemorySink, MetricsRecorder, coalesce_into, read_jsonl,
)
from repro.telemetry.schema import SchemaError, validate_record
from repro.telemetry.summarize import (
    diff_summaries, main as cli_main, render_summary, summarize,
)

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "telemetry_fixture.jsonl")

N = 4


def _quad_loss(p, b):
    return jnp.mean((b - p["w"]) ** 2, axis=(-2, -1))


def _run_sim(steps=5, telemetry=None, topo_name="d_ring", fault_model=None,
             collect_norms=True, **kw):
    topo = make_topology(topo_name, N, fault_model=fault_model)
    sim = DecentralizedSimulator(
        _quad_loss, sgd(momentum=0.9), topo, collect_norms=collect_norms,
        telemetry=telemetry, **kw,
    )
    state = sim.init({"w": jnp.zeros(3)})
    traces = []
    for t in range(steps):
        b = jax.random.normal(jax.random.PRNGKey(t), (N, 2, 3))
        state, loss, norms = sim.train_step(state, b, 0.05)
        traces.append((np.asarray(loss), np.asarray(norms)))
    return sim, traces


# ---------------------------------------------------------------------------
# recorder units
# ---------------------------------------------------------------------------

def test_coalesce_into_merges_same_step_reasons():
    events = []
    assert coalesce_into(events, 3, "depart") == "depart"
    assert coalesce_into(events, 3, "rejoin") == "depart+rejoin"
    assert coalesce_into(events, 3, "depart") is None  # idempotent re-arm
    assert coalesce_into(events, 4, "depart") == "depart"
    assert events == [(3, "depart+rejoin"), (4, "depart")]


def test_counters_accumulate_and_emit_totals():
    sink = MemorySink()
    rec = MetricsRecorder(sinks=[sink])
    rec.counter("comm_bytes", 10, step=0)
    rec.counter("comm_bytes", 5, step=1)
    assert rec.totals["comm_bytes"] == 15
    assert [r["total"] for r in sink.records] == [10, 15]
    for r in sink.records:
        validate_record(r)


def test_inert_recorder_is_free():
    rec = MetricsRecorder()  # the default every engine constructs
    assert not rec.active and not rec.timing
    assert rec.round_start() is None
    rec.round_end(None, step=0)  # no-op, no crash
    rec.gauge("loss", 1.0, step=0)
    rec.counter("x", 1, step=0)
    assert not rec.due(0) and rec.round_ms == []


def test_span_timing_gating():
    # sinks alone do NOT turn on per-step loss syncs (bench safety) …
    assert MetricsRecorder(sinks=[MemorySink()]).round_start() is None
    # … the CLI's record_spans=True does …
    assert MetricsRecorder(
        sinks=[MemorySink()], record_spans=True
    ).round_start() is not None
    # … and a deadline fault model does even without sinks (the old
    # per-engine _record_round behaviour)
    assert MetricsRecorder(deadline_ms=30.0).round_start() is not None


def test_round_overrun_attribution():
    import time

    sink = MemorySink()
    rec = MetricsRecorder(sinks=[sink], record_spans=True, deadline_ms=1.0)
    rec.round_end(time.perf_counter() - 0.05, step=0)   # 50ms > 1ms
    rec.round_end(time.perf_counter(), step=1)          # ~0ms, no overrun
    spans = [r for r in sink.records if r["kind"] == "span"]
    assert [s["overrun"] for s in spans] == [True, False]
    assert spans[0]["deadline_ms"] == 1.0
    assert rec.deadline_overruns == 1 and len(rec.round_ms) == 2


def test_state_dict_roundtrip_continues_totals():
    rec = MetricsRecorder(deadline_ms=1.0)
    rec.counter("comm_bytes", 100, step=0)
    rec.round_end(rec.round_start(), step=0)
    saved = rec.state_dict()
    json.dumps(saved)  # must ride the checkpoint extra payload

    fresh = MetricsRecorder(deadline_ms=1.0)
    fresh.load_state_dict(saved)
    assert fresh.totals["comm_bytes"] == 100
    assert fresh.rounds_total == 1
    assert fresh.round_ms == []  # per-process view restarts
    fresh.round_end(fresh.round_start(), step=1)
    assert fresh.rounds_total == 2 and len(fresh.round_ms) == 1


def test_schema_rejects_malformed_records():
    good = {"kind": "gauge", "step": 0, "name": "xi", "value": 1.0}
    validate_record(good)
    for bad in (
        {"kind": "nope"},
        {"kind": "counter", "step": 0, "name": "x", "inc": 1},  # no total
        {**good, "extra": 1},                         # unknown field
        {**good, "step": "zero"},                     # wrong type
        {"kind": "span", "step": 0, "name": "round"},  # missing ms
    ):
        with pytest.raises(SchemaError):
            validate_record(bad)


# ---------------------------------------------------------------------------
# engine integration: provably free + faithful counters
# ---------------------------------------------------------------------------

def test_telemetry_on_compiles_same_executable_set():
    off, _ = _run_sim(steps=5)
    rec = MetricsRecorder(
        sinks=[MemorySink()], metrics_every=1, record_spans=True
    )
    on, _ = _run_sim(steps=5, telemetry=rec, debug_no_retrace=True)
    assert sorted(map(str, on._step_cache)) == sorted(map(str, off._step_cache))
    assert_executables_preenumerated(on)


def test_comm_counters_match_offline_accounting():
    rec = MetricsRecorder(sinks=[MemorySink()])
    sim, _ = _run_sim(steps=5, telemetry=rec)
    prog = sim.topology.program_at(step=0, epoch=0)
    pbytes = 3 * 4  # {"w": zeros(3)} float32, per node
    assert rec.totals["comm_bytes"] == 5 * program_comm_bytes(prog, pbytes)
    assert rec.totals["program_applications"] == 5
    assert rec.totals["permutes"] == 5 * len(prog.ops)


def test_streamed_variance_equals_offline_dbench():
    sink = MemorySink()
    rec = MetricsRecorder(sinks=[sink], metrics_every=1)
    _, traces = _run_sim(steps=5, telemetry=rec)
    offline = DBenchRecorder(impl="ref", n_nodes=N)
    for t, (loss, norms) in enumerate(traces):
        offline.record(t, loss, norms)
    var_recs = [r for r in sink.records if r["kind"] == "variance"]
    assert len(var_recs) == 5
    for t, r in enumerate(var_recs):
        ref = variance_report(offline.norms[t])
        for name, per_leaf in ref.items():
            np.testing.assert_allclose(
                r["per_layer"][name], per_leaf, rtol=1e-12
            )
            assert r["metrics"][name] == pytest.approx(
                float(np.mean(per_leaf))
            )
    # the gini series the offline recorder derives matches the stream too
    gini = offline.metric_series("gini").mean(axis=-1)
    streamed = [r["metrics"]["gini"] for r in var_recs]
    np.testing.assert_allclose(streamed, gini, rtol=1e-12)


def test_deadline_trace_views_preserved():
    fm = make_fault_model("deadline", N, rate=0.4, seed=5)
    sim, _ = _run_sim(steps=5, fault_model=fm, collect_norms=False)
    # the public attributes survive as views over the shared recorder
    assert len(sim.round_ms) == 5
    assert sim.round_ms is sim.telemetry.round_ms
    assert sim.deadline_overruns == sim.telemetry.deadline_overruns
    assert sim._deadline_ms == fm.deadline_ms


# ---------------------------------------------------------------------------
# controller events: one coalescing implementation for both engines
# ---------------------------------------------------------------------------

def test_controller_event_stream_engine_independent():
    def drive(recorder):
        topo = make_topology("d_ada", 8, k0=6, consensus_target=0.5)
        ctl = topo.controller
        ctl.bind_recorder(recorder)
        ctl.observe(1.0, 0)          # seeds xi0
        ctl.observe(0.4, 1)          # fires: transition to rung 1
        ctl.rearm(3, "depart")       # membership events, same step:
        ctl.rearm(3, "rejoin")       # distinct reasons coalesce …
        ctl.rearm(3, "depart")       # … duplicates are dropped
        ctl.rearm(5, "join")
        return ctl

    a_sink, b_sink = MemorySink(), MemorySink()
    ctl_a = drive(MetricsRecorder(sinks=[a_sink]))
    ctl_b = drive(MetricsRecorder(sinks=[b_sink]))
    assert a_sink.records == b_sink.records  # identical streams
    assert ctl_a.events == ctl_b.events == [(3, "depart+rejoin"), (5, "join")]
    names = [(r["step"], r["name"], (r.get("data") or {}).get("reason"))
             for r in a_sink.records]
    assert names == [
        (1, "transition", None),
        (3, "controller", "depart"),
        (3, "controller", "depart+rejoin"),  # re-emitted on merge
        (5, "controller", "join"),
    ]
    # consumers keep the LAST emission per (step, name): the rendered
    # summary shows the merged entry once
    out = render_summary(summarize(
        [{"kind": "manifest", "schema": 1, "run": {}}] + a_sink.records
    ))
    assert "depart+rejoin" in out
    assert out.count("controller") == 2  # steps 3 and 5, deduped


# ---------------------------------------------------------------------------
# JSONL round-trip: summarize / diff / --resume crossing
# ---------------------------------------------------------------------------

def test_jsonl_resume_roundtrip(tmp_path):
    path = str(tmp_path / "run.jsonl")
    rec = MetricsRecorder(
        sinks=[JsonlSink(path)], metrics_every=2, record_spans=True
    )
    rec.manifest({"engine": "simulator", "topology": "d_ring", "n": N})
    sim, _ = _run_sim(steps=4, telemetry=rec)
    extra = sim.snapshot_extra()
    json.dumps(extra["telemetry"])  # checkpoint-serializable
    rec.close()

    # resumed segment: fresh process = fresh recorder, appending sink
    rec2 = MetricsRecorder(
        sinks=[JsonlSink(path, append=True)], metrics_every=2,
        record_spans=True,
    )
    rec2.manifest({"engine": "simulator", "topology": "d_ring", "n": N,
                   "resumed": True})
    topo = make_topology("d_ring", N)
    sim2 = DecentralizedSimulator(
        _quad_loss, sgd(momentum=0.9), topo, collect_norms=True,
        telemetry=rec2,
    )
    state = sim2.init({"w": jnp.zeros(3)})
    sim2.restore_extra(extra)
    state = dataclasses.replace(state, step=4)  # resume at the ckpt step
    for t in range(4, 8):
        b = jax.random.normal(jax.random.PRNGKey(t), (N, 2, 3))
        state, *_ = sim2.train_step(state, b, 0.05)
    rec2.close()

    # totals continue across the crossing; per-process views restart
    assert rec2.totals["program_applications"] == 8
    assert rec2.rounds_total == 8 and len(rec2.round_ms) == 4

    records = read_jsonl(path)  # validates every line
    s = summarize(records)
    assert s["segments"] == 2 and s["last_step"] == 7
    assert s["counters"]["program_applications"] == 8
    out = render_summary(s)
    assert "segments: 2 (resumed run)" in out
    d = diff_summaries(s, s, labels=("a", "b"))
    assert "last_step" in d


def test_read_jsonl_rejects_corrupt_stream(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text('{"kind": "gauge", "step": 0}\n')
    with pytest.raises(SchemaError, match=r":1:"):
        read_jsonl(str(path))


def test_cli_summarize_exits_clean_on_committed_fixture(capsys):
    assert os.path.exists(FIXTURE), "committed fixture missing"
    assert cli_main(["summarize", FIXTURE]) == 0
    out = capsys.readouterr().out
    for needle in ("per-phase step time", "comm MiB", "xi last",
                   "per-layer variance"):
        assert needle in out, f"summary lost its {needle!r} table"
    assert cli_main(["diff", FIXTURE, FIXTURE]) == 0
    assert cli_main(["summarize", FIXTURE + ".nope"]) == 1


# ---------------------------------------------------------------------------
# bench provenance pathway
# ---------------------------------------------------------------------------

def test_bench_payload_provenance_validation():
    rec = MetricsRecorder(sinks=[MemorySink()])
    rec.counter("comm_bytes", 42, step=0)
    prov = rec.provenance()
    verify_bench_payload("ada", {"d_ring/n8": {"acc": 1.0,
                                               "provenance": prov}})
    for broken in (
        {**prov, "source": "handwritten"},
        {**prov, "schema": "one"},
        {**prov, "counters": {"comm_bytes": "lots"}},
        {**prov, "rounds": None},
        "not-a-dict",
    ):
        with pytest.raises(InvariantViolation, match="provenance"):
            verify_bench_payload(
                "ada", {"d_ring/n8": {"acc": 1.0, "provenance": broken}}
            )
