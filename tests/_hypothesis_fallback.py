"""Minimal stand-in for ``hypothesis`` when it is not installed.

The container cannot ``pip install``; this shim implements the small slice
of the hypothesis API the test-suite uses (``given``, ``settings``, and the
``integers`` / ``floats`` / ``sampled_from`` / ``booleans`` / ``tuples`` /
``lists`` strategies) as deterministic seeded random sampling.  It is
registered by ``tests/conftest.py`` via ``sys.modules`` only when the real
package is missing, so installing hypothesis transparently upgrades the
suite to real property testing.

Not a property-based tester: no shrinking, no coverage-guided generation —
just ``max_examples`` deterministic draws per test (seeded from the test
name, so failures reproduce).
"""
from __future__ import annotations

import functools
import hashlib
import inspect
import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value=None, max_value=None) -> _Strategy:
    lo = -(2**31) if min_value is None else int(min_value)
    hi = 2**31 if max_value is None else int(max_value)
    return _Strategy(lambda rng: rng.randint(lo, hi))


def floats(
    min_value=None,
    max_value=None,
    allow_nan: bool = False,
    allow_infinity: bool = False,
    width: int = 64,
) -> _Strategy:
    lo = -1e9 if min_value is None else float(min_value)
    hi = 1e9 if max_value is None else float(max_value)

    def draw(rng):
        v = rng.uniform(lo, hi)
        # nudge endpoint draws inward so strict bounds stay honest
        return min(max(v, lo), hi)

    return _Strategy(draw)


def sampled_from(elements) -> _Strategy:
    elems = list(elements)
    if not elems:
        raise ValueError("sampled_from needs a non-empty collection")
    return _Strategy(lambda rng: elems[rng.randrange(len(elems))])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def tuples(*strategies) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))


def lists(elements, *, min_size=0, max_size=10) -> _Strategy:
    def draw(rng):
        k = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(k)]

    return _Strategy(draw)


def just(value) -> _Strategy:
    return _Strategy(lambda rng: value)


class _Settings:
    def __init__(self, max_examples: int = 20, deadline=None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._fallback_settings = self
        return fn


settings = _Settings


def given(*strategies, **kw_strategies):
    """Run the test with ``max_examples`` deterministic seeded draws."""

    def decorate(fn):
        cfg = getattr(fn, "_fallback_settings", None) or _Settings()

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            seed0 = int.from_bytes(
                hashlib.sha256(fn.__qualname__.encode()).digest()[:8], "big"
            )
            for i in range(max(int(cfg.max_examples), 1)):
                rng = random.Random(seed0 + i)
                drawn = tuple(s.draw(rng) for s in strategies)
                drawn_kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, *drawn, **drawn_kw, **kwargs)
                except Exception as e:  # re-raise with the failing example
                    raise AssertionError(
                        f"falsifying example (draw {i}): args={drawn} "
                        f"kwargs={drawn_kw}: {e}"
                    ) from e

        # pytest must not treat the drawn parameters as fixtures: hide the
        # wrapped signature (wraps() copies __wrapped__, which pytest follows).
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        wrapper.hypothesis_fallback = True
        return wrapper

    return decorate


class strategies:  # namespace mirror: ``from hypothesis import strategies as st``
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    sampled_from = staticmethod(sampled_from)
    booleans = staticmethod(booleans)
    tuples = staticmethod(tuples)
    lists = staticmethod(lists)
    just = staticmethod(just)
