"""Ada schedule (Algorithm 1) properties."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.ada import AdaSchedule, default_k0
from repro.core.dsgd import make_topology


@given(
    st.integers(min_value=4, max_value=1008),
    st.integers(min_value=2, max_value=112),
    st.floats(min_value=0.001, max_value=2.0),
)
@settings(max_examples=50, deadline=None)
def test_k_monotone_with_floor(n, k0, gamma):
    s = AdaSchedule(n_nodes=n, k0=k0, gamma_k=gamma)
    ks = [s.k_at(e) for e in range(0, 500, 7)]
    assert all(a >= b for a, b in zip(ks, ks[1:]))  # non-increasing
    assert min(ks) >= 2                              # Algorithm 1 floor
    assert max(ks) <= max(n - 1, 1)
    assert s.k_at(0) == min(k0, max(n - 1, 1))


def test_paper_table4_settings():
    """k0=10, gamma=0.02 @96 nodes; k0=112, gamma=1 @1008 nodes."""
    s96 = AdaSchedule(n_nodes=96, k0=10, gamma_k=0.02)
    assert s96.k_at(0) == 10 and s96.k_at(299) == 5
    s1008 = AdaSchedule(n_nodes=1008, k0=112, gamma_k=1.0)
    assert s1008.k_at(0) == 112
    assert s1008.k_at(110) == 2 and s1008.k_at(200) == 2  # floored


def test_default_k0_is_paper_heuristic():
    assert default_k0(96) == 10
    assert default_k0(9) == 1 or default_k0(9) == 2  # max(n//9, 2)
    assert default_k0(9) == 2
    assert default_k0(1008) == 112


def test_distinct_graphs_enumeration():
    s = AdaSchedule(n_nodes=96, k0=10, gamma_k=0.02)
    graphs = s.distinct_graphs(300)
    ks = [g.describe() for _, g in graphs]
    assert len(graphs) == len(set(ks))  # no duplicates
    epochs = [e for e, _ in graphs]
    assert epochs == sorted(epochs) and epochs[0] == 0


def test_ada_topology_evolves_to_sparser():
    t = make_topology("d_ada", 96, k0=10, gamma_k=0.02)
    assert t.adaptive
    d0 = t.degree_at(0)
    d_late = t.degree_at(299)
    assert d0 > d_late >= 2


def test_mixing_matrix_rows_sum_to_one_every_epoch():
    s = AdaSchedule(n_nodes=24, k0=12, gamma_k=0.1)
    for e in range(0, 200, 10):
        w = s.mixing_matrix_at(e)
        assert np.allclose(w.sum(1), 1.0)
