"""The mixing-program IR: compiled programs == dense oracle on every
registered topology, program structure (one collective-permute per circulant
offset, all-reduce for complete, no dense fallback on sparse graphs), and
stochasticity properties of every mixing matrix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.ada import AdaSchedule
from repro.core.dsgd import make_topology
from repro.core.graphs import (
    Complete, Exponential, Ring, RingLattice, Star, Torus, from_adjacency,
    make_graph, one_peer_exponential, one_peer_period, random_matching,
)
from repro.core.schedule import (
    AllReduce, GatherRow, GossipProgram, PPermute, compile_graph,
    dense_program, identity_program, program_comm_bytes,
)


def _all_graphs(n: int):
    """One instance of every registered topology family at size n."""
    gs = [
        Ring(n),
        Torus(n),
        RingLattice(n, 4),
        Exponential(n),
        Complete(n),
        Star(n),
        random_matching(n, seed=7),
        random_matching(max(n - 1, 2), seed=7),  # odd n: one node idles
        from_adjacency(
            [(0, 1), (1, 2), (2, 3), (0, 3), (0, 2)], name="irregular"
        ),
    ]
    gs += [one_peer_exponential(n, t) for t in range(one_peer_period(n))]
    return gs


# ---------------------------------------------------------------------------
# Compiled program == dense mixing-matrix oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [4, 8, 12])
def test_program_interpreters_match_dense_oracle(n):
    """For every topology the compiled program agrees with W θ to <= 1e-5
    under both the dense and the stacked interpreter."""
    rng = np.random.default_rng(0)
    for g in _all_graphs(n):
        prog = compile_graph(g)
        x = jnp.asarray(rng.normal(size=(g.n, 3, 2)).astype(np.float32))
        tree = {"a": x, "b": x[:, 0]}
        want = {
            k: np.einsum("ij,j...->i...", g.mixing_matrix(), np.asarray(v))
            for k, v in tree.items()
        }
        for engine in ("dense", "stacked"):
            got = prog.apply(tree, engine=engine)
            for k in tree:
                np.testing.assert_allclose(
                    np.asarray(got[k]), want[k], atol=1e-5,
                    err_msg=f"{g.name} engine={engine} leaf={k}",
                )
        # program's own matrix view is exact
        np.testing.assert_allclose(prog.matrix(), g.mixing_matrix(), atol=1e-12)
        # the dense (GatherRow) realization is the same matrix
        np.testing.assert_allclose(
            dense_program(g).matrix(), g.mixing_matrix(), atol=1e-12
        )


def test_one_peer_full_cycle_mixes_toward_consensus():
    """A full one-peer cycle (p steps, degree 1 each) contracts the spread;
    repeated cycles reach consensus and always preserve the replica mean."""
    n = 16
    p = one_peer_period(n)
    x = np.random.default_rng(1).normal(size=(n, 3)).astype(np.float32)
    y = jnp.asarray(x)
    for cycle in range(8):
        for t in range(p):
            y = compile_graph(one_peer_exponential(n, t)).apply_stacked(y)
    np.testing.assert_allclose(
        np.asarray(y.mean(0)), x.mean(0), atol=1e-4
    )  # doubly stochastic: mean preserved
    spread = float(jnp.abs(y - y.mean(0)).max())
    assert spread < 1e-3, spread


def test_seeded_random_matching_is_deterministic_and_rotates():
    a = random_matching(10, seed=3, round=2)
    b = random_matching(10, seed=3, round=2)
    c = random_matching(10, seed=3, round=3)
    assert a.edges == b.edges
    assert a.edges != c.edges
    assert compile_graph(a).cache_key == compile_graph(b).cache_key


# ---------------------------------------------------------------------------
# Program structure: the optimized lowering the IR promises
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["ring", "torus", "exponential", "ring_lattice"])
def test_circulant_compiles_to_one_permute_per_offset(kind):
    """No all-gather regression: a circulant graph is exactly one PPermute
    per offset — nothing else."""
    g = make_graph(kind, 12, k=4)
    prog = compile_graph(g)
    assert all(isinstance(op, PPermute) for op in prog.ops)
    assert len(prog.ops) == len(g.offsets)
    offsets = sorted(op.offset for op in prog.ops)
    assert offsets == sorted(g.offsets)


def test_complete_compiles_to_single_allreduce():
    prog = compile_graph(Complete(12))
    assert prog.ops == (AllReduce(),)


def test_matchings_compile_to_single_permute_with_per_node_weights():
    for n in (8, 9):  # even: perfect matching; odd: one idle node
        g = random_matching(n, seed=1)
        prog = compile_graph(g)
        assert len(prog.ops) == 1 and isinstance(prog.ops[0], PPermute)
        assert isinstance(prog.self_weight, tuple)
    prog = compile_graph(one_peer_exponential(8, 2))
    assert len(prog.ops) == 1 and isinstance(prog.ops[0], PPermute)


def test_irregular_graph_falls_back_to_gather_row():
    g = Star(8)
    prog = compile_graph(g)
    assert len(prog.ops) == 1 and isinstance(prog.ops[0], GatherRow)
    np.testing.assert_allclose(prog.matrix(), g.mixing_matrix())


def test_identity_program_is_noop():
    prog = identity_program(4)
    x = {"w": jnp.arange(8.0).reshape(4, 2)}
    for engine in ("dense", "stacked"):
        np.testing.assert_array_equal(
            np.asarray(prog.apply(x, engine=engine)["w"]), np.asarray(x["w"])
        )
    assert program_comm_bytes(prog, 1000) == 0


def test_programs_are_hashable_cache_keys():
    a = compile_graph(Ring(8))
    b = compile_graph(Ring(8))
    c = compile_graph(Ring(12))
    assert a.cache_key == b.cache_key and hash(a) == hash(b)
    assert a.cache_key != c.cache_key
    assert len({a, b, c}) == 2


# ---------------------------------------------------------------------------
# Stochasticity properties over every family
# ---------------------------------------------------------------------------

@given(st.integers(min_value=2, max_value=24), st.integers(min_value=0, max_value=10))
@settings(max_examples=30, deadline=None)
def test_all_mixing_matrices_row_stochastic(n, salt):
    """Every registered topology is row-stochastic and nonnegative; undirected
    (and permutation-based one-peer) graphs are doubly stochastic."""
    graphs = [
        Ring(n), Torus(n), RingLattice(n, 2 + salt % 6), Exponential(n),
        Complete(n), Star(n), random_matching(n, seed=salt),
        one_peer_exponential(n, salt),
    ]
    for g in graphs:
        w = g.mixing_matrix()
        assert np.allclose(w.sum(axis=1), 1.0), g.name
        assert (w >= -1e-12).all(), g.name
        if g.is_symmetric:
            assert np.allclose(w, w.T), g.name
        if not g.directed or g.name.startswith("one_peer"):
            assert np.allclose(w.sum(axis=0), 1.0), (g.name, "doubly")
        # the compiled program realizes exactly this matrix
        np.testing.assert_allclose(
            compile_graph(g).matrix(), w, atol=1e-12, err_msg=g.name
        )


# ---------------------------------------------------------------------------
# Topology-level program schedules
# ---------------------------------------------------------------------------

def test_topology_program_rotation_counts():
    topo = make_topology("d_one_peer_exp", 16)
    progs = topo.distinct_programs(1)
    assert len(progs) == one_peer_period(16) == 4
    # step t uses program t mod p — zero recompiles over a long run
    keys = {topo.program_at(step=t).cache_key for t in range(64)}
    assert keys == {p.cache_key for _, p in progs}

    pool = make_topology("d_random_matching", 16, seed=2, pool=5)
    assert len(pool.distinct_programs(1)) == 5
    assert (
        pool.program_at(step=7).cache_key == pool.program_at(step=12).cache_key
    )


def test_ada_one_peer_floor_schedule():
    s = AdaSchedule(n_nodes=16, k0=4, gamma_k=1.0, k_floor="one_peer")
    assert not s.one_peer_at(0) and s.one_peer_at(3)
    assert s.k_at(3) == 1  # one peer per step
    names = {p.name for _, p in s.distinct_programs(6)}
    assert any(n.startswith("one_peer_exp") for n in names)
    assert any(n.startswith("ring_lattice") for n in names)
    # default floor unchanged: never leaves the lattice family
    base = AdaSchedule(n_nodes=16, k0=4, gamma_k=1.0)
    assert all(
        p.name.startswith("ring_lattice") for _, p in base.distinct_programs(6)
    )


def test_centralized_topology_has_no_program():
    topo = make_topology("c_complete", 8)
    assert topo.program_at(step=0, epoch=0) is None
    assert topo.distinct_programs(3) == []


def test_d_custom_rejects_node_count_mismatch():
    """Edge lists infer n from the max index; Topology must not let the
    replica axis and the mixing program disagree."""
    with pytest.raises(ValueError, match="describes 3 nodes"):
        make_topology("d_custom", 8, adjacency=[(0, 1), (1, 2)])
    # matrix form can express trailing isolated nodes
    adj = np.zeros((8, 8), int)
    adj[0, 1] = adj[1, 0] = 1
    t = make_topology("d_custom", 8, adjacency=adj)
    assert t.static_graph.n == 8


def test_edge_graph_rejects_uniform_weights():
    """MH is the only well-defined scheme on irregular graphs; requesting
    'uniform' must fail loudly, not silently return MH."""
    with pytest.raises(ValueError, match="metropolis"):
        Star(8).mixing_matrix("uniform")


def test_from_adjacency_two_edge_list_is_not_a_matrix():
    """Regression: a 2-pair edge list np.asarray's to shape (2, 2) and was
    misparsed as a 2x2 adjacency matrix."""
    g = from_adjacency([(0, 2), (1, 3)])
    assert g.n == 4 and g.edges == ((0, 2), (1, 3))
    g2 = from_adjacency([(0, 1), (1, 2)])
    assert g2.n == 3 and g2.edges == ((0, 1), (1, 2))


def test_opless_program_with_scaling_self_weight_is_not_identity():
    """Regression: the identity fast path must not swallow self_weight."""
    prog = GossipProgram(name="scale", n=4, ops=(), self_weight=0.5)
    x = {"w": jnp.ones((4, 2))}
    for engine in ("dense", "stacked"):
        np.testing.assert_allclose(
            np.asarray(prog.apply(x, engine=engine)["w"]), 0.5, atol=1e-7
        )
    np.testing.assert_allclose(prog.matrix(), 0.5 * np.eye(4))


def test_mix_every_advances_time_varying_phase():
    """Regression: with mix_every=H the schedule must index by gossip round,
    not raw step — raw-step indexing aliases a period-p family to a single
    phase whenever p divides H (one-peer would gossip the same hop forever,
    partitioning the network)."""
    import jax

    from repro.core.simulator import DecentralizedSimulator
    from repro.optim.sgd import sgd

    def loss(p, b):
        return jnp.mean((b - p["w"]) ** 2)

    n = 8
    period = one_peer_period(n)  # 3
    topo = make_topology("d_one_peer_exp", n)
    sim = DecentralizedSimulator(loss, sgd(momentum=0.0), topo, mix_every=period)
    state = sim.init({"w": jnp.zeros(4)})
    for t in range(3 * period * period):
        b = jax.random.normal(jax.random.PRNGKey(t), (n, 2, 4))
        state, *_ = sim.train_step(state, b, 0.01)
    mix_keys = [
        k for k in sim._step_cache if k not in ("__local__", "__centralized__")
    ]
    assert len(mix_keys) == period, mix_keys


# ---------------------------------------------------------------------------
# shard interpreter + HLO structure (8 host devices, subprocess)
# ---------------------------------------------------------------------------

def test_shard_interpreter_and_hlo_collectives():
    """apply_shard == dense oracle on 8 devices AND the compiled HLO shows
    exactly one collective-permute per circulant offset (no all-gather
    regression), one all-reduce for complete, all-gather only for the dense
    fallback."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run(
        [
            sys.executable,
            os.path.join(os.path.dirname(__file__), "schedule_shard_script.py"),
        ],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}\nstdout:\n{r.stdout}"
    assert "SHARD_INTERPRETER_OK" in r.stdout
