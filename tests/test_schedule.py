"""The mixing-program IR: compiled programs == dense oracle on every
registered topology, program structure (one collective-permute per circulant
offset, all-reduce for complete, no dense fallback on sparse graphs), and
stochasticity properties of every mixing matrix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.ada import AdaSchedule
from repro.core.dsgd import make_topology
from repro.core.graphs import (
    Complete, Exponential, Ring, RingLattice, Star, Torus, from_adjacency,
    make_graph, one_peer_exponential, one_peer_period, random_matching,
)
from repro.core.schedule import (
    AllReduce, GatherRow, GossipProgram, PPermute, compile_graph,
    dense_program, identity_program, program_comm_bytes,
)


def _all_graphs(n: int):
    """One instance of every registered topology family at size n."""
    gs = [
        Ring(n),
        Torus(n),
        RingLattice(n, 4),
        Exponential(n),
        Complete(n),
        Star(n),
        random_matching(n, seed=7),
        random_matching(max(n - 1, 2), seed=7),  # odd n: one node idles
        from_adjacency(
            [(0, 1), (1, 2), (2, 3), (0, 3), (0, 2)], name="irregular"
        ),
    ]
    gs += [one_peer_exponential(n, t) for t in range(one_peer_period(n))]
    return gs


# ---------------------------------------------------------------------------
# Compiled program == dense mixing-matrix oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [4, 8, 12])
def test_program_interpreters_match_dense_oracle(n):
    """For every topology the compiled program agrees with W θ to <= 1e-5
    under both the dense and the stacked interpreter."""
    rng = np.random.default_rng(0)
    for g in _all_graphs(n):
        prog = compile_graph(g)
        x = jnp.asarray(rng.normal(size=(g.n, 3, 2)).astype(np.float32))
        tree = {"a": x, "b": x[:, 0]}
        want = {
            k: np.einsum("ij,j...->i...", g.mixing_matrix(), np.asarray(v))
            for k, v in tree.items()
        }
        for engine in ("dense", "stacked"):
            got = prog.apply(tree, engine=engine)
            for k in tree:
                np.testing.assert_allclose(
                    np.asarray(got[k]), want[k], atol=1e-5,
                    err_msg=f"{g.name} engine={engine} leaf={k}",
                )
        # program's own matrix view is exact
        np.testing.assert_allclose(prog.matrix(), g.mixing_matrix(), atol=1e-12)
        # the dense (GatherRow) realization is the same matrix
        np.testing.assert_allclose(
            dense_program(g).matrix(), g.mixing_matrix(), atol=1e-12
        )


def test_one_peer_full_cycle_mixes_toward_consensus():
    """A full one-peer cycle (p steps, degree 1 each) contracts the spread;
    repeated cycles reach consensus and always preserve the replica mean."""
    n = 16
    p = one_peer_period(n)
    x = np.random.default_rng(1).normal(size=(n, 3)).astype(np.float32)
    y = jnp.asarray(x)
    for cycle in range(8):
        for t in range(p):
            y = compile_graph(one_peer_exponential(n, t)).apply_stacked(y)
    np.testing.assert_allclose(
        np.asarray(y.mean(0)), x.mean(0), atol=1e-4
    )  # doubly stochastic: mean preserved
    spread = float(jnp.abs(y - y.mean(0)).max())
    assert spread < 1e-3, spread


def test_seeded_random_matching_is_deterministic_and_rotates():
    a = random_matching(10, seed=3, round=2)
    b = random_matching(10, seed=3, round=2)
    c = random_matching(10, seed=3, round=3)
    assert a.edges == b.edges
    assert a.edges != c.edges
    assert compile_graph(a).cache_key == compile_graph(b).cache_key


# ---------------------------------------------------------------------------
# Program structure: the optimized lowering the IR promises
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["ring", "torus", "exponential", "ring_lattice"])
def test_circulant_compiles_to_one_permute_per_offset(kind):
    """No all-gather regression: a circulant graph is exactly one PPermute
    per offset — nothing else."""
    g = make_graph(kind, 12, k=4)
    prog = compile_graph(g)
    assert all(isinstance(op, PPermute) for op in prog.ops)
    assert len(prog.ops) == len(g.offsets)
    offsets = sorted(op.offset for op in prog.ops)
    assert offsets == sorted(g.offsets)


def test_complete_compiles_to_single_allreduce():
    prog = compile_graph(Complete(12))
    assert prog.ops == (AllReduce(),)


def test_matchings_compile_to_single_permute_with_per_node_weights():
    for n in (8, 9):  # even: perfect matching; odd: one idle node
        g = random_matching(n, seed=1)
        prog = compile_graph(g)
        assert len(prog.ops) == 1 and isinstance(prog.ops[0], PPermute)
        assert isinstance(prog.self_weight, tuple)
    prog = compile_graph(one_peer_exponential(8, 2))
    assert len(prog.ops) == 1 and isinstance(prog.ops[0], PPermute)


def test_star_compiles_to_edge_colored_permutes():
    """Regression (PR 3 acceptance): the star must NOT dense all-gather —
    it edge-colors into <= Δ+1 per-node-weighted permute rounds that
    reproduce W exactly."""
    for n in (8, 16, 64):
        g = Star(n)
        prog = compile_graph(g)
        assert not any(isinstance(op, GatherRow) for op in prog.ops)
        assert all(isinstance(op, PPermute) for op in prog.ops)
        assert len(prog.ops) <= g.degree + 1
        np.testing.assert_allclose(prog.matrix(), g.mixing_matrix(), atol=1e-12)


def test_irregular_graph_compiles_sparse_and_exact():
    g = from_adjacency([(0, 1), (1, 2), (2, 3), (0, 3), (0, 2)], name="irr")
    prog = compile_graph(g)
    assert not any(isinstance(op, GatherRow) for op in prog.ops)
    assert len(prog.ops) <= g.degree + 1
    np.testing.assert_allclose(prog.matrix(), g.mixing_matrix(), atol=1e-12)


@given(
    st.integers(min_value=2, max_value=16),
    st.integers(min_value=0, max_value=10_000),
)
@settings(max_examples=40, deadline=None)
def test_edge_colored_program_matches_dense_oracle(n, seed):
    """Property (PR 3 acceptance): on a random connected graph up to n=16
    the edge-colored program equals W θ to <= 1e-6 under both interpreters,
    using <= Δ+1 permute rounds and no GatherRow."""
    rng = np.random.default_rng(seed)
    # random spanning tree (guarantees connectivity) + random extra edges
    edges = set()
    perm = rng.permutation(n)
    for a, b in zip(perm[:-1], perm[1:]):
        edges.add((min(a, b), max(a, b)))
    n_extra = int(rng.integers(0, n * (n - 1) // 2 + 1))
    for _ in range(n_extra):
        i, j = rng.integers(0, n, size=2)
        if i != j:
            edges.add((min(i, j), max(i, j)))
    g = from_adjacency(sorted((int(i), int(j)) for i, j in edges))
    prog = compile_graph(g)
    assert not any(isinstance(op, GatherRow) for op in prog.ops)
    assert len(prog.ops) <= g.degree + 1, (len(prog.ops), g.degree)
    x = jnp.asarray(rng.normal(size=(n, 5)).astype(np.float32))
    want = g.mixing_matrix() @ np.asarray(x)
    for engine in ("dense", "stacked"):
        got = np.asarray(prog.apply({"w": x}, engine=engine)["w"])
        np.testing.assert_allclose(got, want, atol=1e-6, err_msg=engine)


@given(
    st.integers(min_value=2, max_value=18),
    st.integers(min_value=0, max_value=10_000),
)
@settings(max_examples=40, deadline=None)
def test_edge_coloring_is_proper_and_vizing_bounded(n, seed):
    """Every color class is a matching, the classes cover each edge exactly
    once, and at most Δ+1 colors are used (Vizing / Misra–Gries bound)."""
    from repro.core.schedule import edge_coloring

    rng = np.random.default_rng(seed)
    all_e = [(i, j) for i in range(n) for j in range(i + 1, n)]
    k = int(rng.integers(1, len(all_e) + 1))
    edges = [all_e[i] for i in rng.choice(len(all_e), size=k, replace=False)]
    deg = [0] * n
    for i, j in edges:
        deg[i] += 1
        deg[j] += 1
    classes = edge_coloring(n, edges)
    seen = set()
    for cls in classes:
        nodes = [v for e in cls for v in e]
        assert len(nodes) == len(set(nodes)), "color class is not a matching"
        seen.update(cls)
    assert seen == set(edges)
    assert len(classes) <= max(deg) + 1, (len(classes), max(deg))


def test_identity_program_is_noop():
    prog = identity_program(4)
    x = {"w": jnp.arange(8.0).reshape(4, 2)}
    for engine in ("dense", "stacked"):
        np.testing.assert_array_equal(
            np.asarray(prog.apply(x, engine=engine)["w"]), np.asarray(x["w"])
        )
    assert program_comm_bytes(prog, 1000) == 0


def test_programs_are_hashable_cache_keys():
    a = compile_graph(Ring(8))
    b = compile_graph(Ring(8))
    c = compile_graph(Ring(12))
    assert a.cache_key == b.cache_key and hash(a) == hash(b)
    assert a.cache_key != c.cache_key
    assert len({a, b, c}) == 2


# ---------------------------------------------------------------------------
# Stochasticity properties over every family
# ---------------------------------------------------------------------------

@given(st.integers(min_value=2, max_value=24), st.integers(min_value=0, max_value=10))
@settings(max_examples=30, deadline=None)
def test_all_mixing_matrices_row_stochastic(n, salt):
    """Every registered topology is row-stochastic and nonnegative; undirected
    (and permutation-based one-peer) graphs are doubly stochastic."""
    graphs = [
        Ring(n), Torus(n), RingLattice(n, 2 + salt % 6), Exponential(n),
        Complete(n), Star(n), random_matching(n, seed=salt),
        one_peer_exponential(n, salt),
    ]
    for g in graphs:
        w = g.mixing_matrix()
        assert np.allclose(w.sum(axis=1), 1.0), g.name
        assert (w >= -1e-12).all(), g.name
        if g.is_symmetric:
            assert np.allclose(w, w.T), g.name
        if not g.directed or g.name.startswith("one_peer"):
            assert np.allclose(w.sum(axis=0), 1.0), (g.name, "doubly")
        # the compiled program realizes exactly this matrix
        np.testing.assert_allclose(
            compile_graph(g).matrix(), w, atol=1e-12, err_msg=g.name
        )


# ---------------------------------------------------------------------------
# Topology-level program schedules
# ---------------------------------------------------------------------------

def test_topology_program_rotation_counts():
    topo = make_topology("d_one_peer_exp", 16)
    progs = topo.distinct_programs(1)
    assert len(progs) == one_peer_period(16) == 4
    # step t uses program t mod p — zero recompiles over a long run
    keys = {topo.program_at(step=t).cache_key for t in range(64)}
    assert keys == {p.cache_key for _, p in progs}

    pool = make_topology("d_random_matching", 16, seed=2, pool=5)
    assert len(pool.distinct_programs(1)) == 5
    assert (
        pool.program_at(step=7).cache_key == pool.program_at(step=12).cache_key
    )


def test_ada_one_peer_floor_schedule():
    s = AdaSchedule(n_nodes=16, k0=4, gamma_k=1.0, k_floor="one_peer")
    assert not s.one_peer_at(0) and s.one_peer_at(3)
    assert s.k_at(3) == 1  # one peer per step
    names = {p.name for _, p in s.distinct_programs(6)}
    assert any(n.startswith("one_peer_exp") for n in names)
    assert any(n.startswith("ring_lattice") for n in names)
    # default floor unchanged: never leaves the lattice family
    base = AdaSchedule(n_nodes=16, k0=4, gamma_k=1.0)
    assert all(
        p.name.startswith("ring_lattice") for _, p in base.distinct_programs(6)
    )


def test_centralized_topology_has_no_program():
    topo = make_topology("c_complete", 8)
    assert topo.program_at(step=0, epoch=0) is None
    assert topo.distinct_programs(3) == []


def test_d_custom_rejects_node_count_mismatch():
    """Edge lists infer n from the max index; Topology must not let the
    replica axis and the mixing program disagree."""
    with pytest.raises(ValueError, match="describes 3 nodes"):
        make_topology("d_custom", 8, adjacency=[(0, 1), (1, 2)])
    # matrix form can express trailing isolated nodes
    adj = np.zeros((8, 8), int)
    adj[0, 1] = adj[1, 0] = 1
    t = make_topology("d_custom", 8, adjacency=adj)
    assert t.static_graph.n == 8


def test_edge_graph_rejects_uniform_weights():
    """MH is the only well-defined scheme on irregular graphs; requesting
    'uniform' must fail loudly, not silently return MH."""
    with pytest.raises(ValueError, match="metropolis"):
        Star(8).mixing_matrix("uniform")


def test_from_adjacency_two_edge_list_is_not_a_matrix():
    """Regression: a 2-pair edge list np.asarray's to shape (2, 2) and was
    misparsed as a 2x2 adjacency matrix."""
    g = from_adjacency([(0, 2), (1, 3)])
    assert g.n == 4 and g.edges == ((0, 2), (1, 3))
    g2 = from_adjacency([(0, 1), (1, 2)])
    assert g2.n == 3 and g2.edges == ((0, 1), (1, 2))


def test_opless_program_with_scaling_self_weight_is_not_identity():
    """Regression: the identity fast path must not swallow self_weight."""
    prog = GossipProgram(name="scale", n=4, ops=(), self_weight=0.5)
    x = {"w": jnp.ones((4, 2))}
    for engine in ("dense", "stacked"):
        np.testing.assert_allclose(
            np.asarray(prog.apply(x, engine=engine)["w"]), 0.5, atol=1e-7
        )
    np.testing.assert_allclose(prog.matrix(), 0.5 * np.eye(4))


def test_mix_every_advances_time_varying_phase():
    """Regression: with mix_every=H the schedule must index by gossip round,
    not raw step — raw-step indexing aliases a period-p family to a single
    phase whenever p divides H (one-peer would gossip the same hop forever,
    partitioning the network)."""
    import jax

    from repro.core.simulator import DecentralizedSimulator
    from repro.optim.sgd import sgd

    def loss(p, b):
        return jnp.mean((b - p["w"]) ** 2)

    n = 8
    period = one_peer_period(n)  # 3
    topo = make_topology("d_one_peer_exp", n)
    sim = DecentralizedSimulator(loss, sgd(momentum=0.0), topo, mix_every=period)
    state = sim.init({"w": jnp.zeros(4)})
    for t in range(3 * period * period):
        b = jax.random.normal(jax.random.PRNGKey(t), (n, 2, 4))
        state, *_ = sim.train_step(state, b, 0.01)
    mix_keys = [  # programless keys are ("__local__"/"__centralized__", n)
        k for k in sim._step_cache
        if k[0] not in ("__local__", "__centralized__")
    ]
    assert len(mix_keys) == period, mix_keys


# ---------------------------------------------------------------------------
# Multi-step program fusion
# ---------------------------------------------------------------------------

def test_fuse_matches_matrix_product_and_interpreters():
    """fuse(P_1..P_H) realizes W_H ··· W_1 under every interpreter."""
    from repro.core.schedule import FusedProgram

    n = 16
    progs = [
        compile_graph(one_peer_exponential(n, t)) for t in range(one_peer_period(n))
    ]
    fused = GossipProgram.fuse(progs)
    assert isinstance(fused, FusedProgram)
    w = np.eye(n)
    for p in progs:
        w = p.matrix() @ w
    np.testing.assert_allclose(fused.matrix(), w, atol=1e-12)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(n, 4)).astype(np.float32))
    want = w @ np.asarray(x)
    for engine in ("dense", "stacked"):
        got = np.asarray(fused.apply({"w": x}, engine=engine)["w"])
        np.testing.assert_allclose(got, want, atol=1e-5, err_msg=engine)
    # collectives add across rounds; fusion changes dispatch count, not wire
    assert fused.num_collectives == sum(p.num_collectives for p in progs)


def test_fuse_cache_keys_and_flattening():
    n = 8
    progs = [compile_graph(one_peer_exponential(n, t)) for t in range(3)]
    a = GossipProgram.fuse(progs)
    b = GossipProgram.fuse(progs)
    assert a.cache_key == b.cache_key
    assert a.cache_key != GossipProgram.fuse(progs[:2]).cache_key
    # nested fusion flattens; a single plain program passes through as-is
    assert GossipProgram.fuse([progs[0]]) is progs[0]
    assert GossipProgram.fuse([a]).cache_key == a.cache_key
    nested = GossipProgram.fuse([GossipProgram.fuse(progs[:2]), progs[2]])
    assert nested.cache_key == a.cache_key
    with pytest.raises(ValueError, match="at least one"):
        GossipProgram.fuse([])
    with pytest.raises(ValueError, match="different node counts"):
        GossipProgram.fuse([progs[0], compile_graph(Ring(4))])


def test_topology_fused_program_advances_phase_by_rounds():
    """fused_program_at(rounds=H) covers schedule steps [sH, sH+H) — the
    mixing budget is preserved, only the dispatch count drops."""
    n = 16
    topo = make_topology("d_one_peer_exp", n)
    p = one_peer_period(n)
    fused = topo.fused_program_at(step=0, rounds=p)
    w = np.eye(n)
    for t in range(p):
        w = topo.program_at(step=t).matrix() @ w
    np.testing.assert_allclose(fused.matrix(), w, atol=1e-12)
    # a full-period fusion is step-invariant: one executable for the run
    assert (
        topo.fused_program_at(step=3, rounds=p).cache_key == fused.cache_key
    )
    # centralized topologies still have no program
    assert make_topology("c_complete", n).fused_program_at(step=0, rounds=2) is None


def test_simulator_mix_rounds_single_executable():
    """H fused rounds land in ONE cached executable (vs H unfused)."""
    import jax

    from repro.core.simulator import DecentralizedSimulator
    from repro.optim.sgd import sgd

    def loss(p, b):
        return jnp.mean((b - p["w"]) ** 2)

    n = 8
    period = one_peer_period(n)
    topo = make_topology("d_one_peer_exp", n)
    fused_sim = DecentralizedSimulator(
        loss, sgd(momentum=0.0), topo, mix_rounds=period
    )
    state = fused_sim.init({"w": jnp.full((4,), 0.3)})
    params0 = state.params
    b = jax.random.normal(jax.random.PRNGKey(9), (n, 2, 4))
    state, *_ = fused_sim.train_step(state, b, 0.05)
    for t in range(1, 2 * period):
        state, *_ = fused_sim.train_step(
            state, jax.random.normal(jax.random.PRNGKey(t), (n, 2, 4)), 0.05
        )
    keys = [
        k for k in fused_sim._step_cache
        if k[0] not in ("__local__", "__centralized__")
    ]
    assert len(keys) == 1, keys
    # numerics: first fused step == grad step then the full one-peer cycle
    g = jax.vmap(jax.grad(loss))(params0, b)
    want = jax.tree.map(lambda p, gg: p - 0.05 * gg, params0, g)
    for t in range(period):
        want = topo.program_at(step=t).apply_dense(want)
    state2 = fused_sim.init({"w": jnp.full((4,), 0.3)})
    state2, *_ = fused_sim.train_step(state2, b, 0.05)
    np.testing.assert_allclose(
        np.asarray(state2.params["w"]), np.asarray(want["w"]), atol=1e-5
    )


# ---------------------------------------------------------------------------
# Hub-balanced round scheduling (ROADMAP open item)
# ---------------------------------------------------------------------------

def test_hub_balanced_rounds_pins_star_peak_send_bytes():
    """Regression (per-step peak send volume, star n=16): the plain star
    program makes the hub send Δ·P every step; hub-balanced H=4 rotation
    caps every step at ⌈Δ/H⌉·P while covering each matching exactly once
    per cycle."""
    from repro.core.schedule import (
        FusedProgram, hub_balanced_rounds, program_max_node_bytes,
    )

    P = 4096
    prog = compile_graph(Star(16))  # Δ = 15 matchings
    assert program_max_node_bytes(prog, P) == 15 * P
    hb = hub_balanced_rounds(prog, 4)
    assert isinstance(hb, FusedProgram) and len(hb.stages) == 4
    peaks = [program_max_node_bytes(s, P) for s in hb.stages]
    assert max(peaks) == 4 * P  # ceil(15/4) matchings per step
    # every matching runs exactly once per cycle
    assert sorted(op.perm for s in hb.stages for op in s.ops) == sorted(
        op.perm for op in prog.ops
    )
    # every stage is symmetric + doubly stochastic (valid gossip step)
    for s in hb.stages:
        w = s.matrix()
        np.testing.assert_allclose(w, w.T, atol=1e-12)
        np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-12)
        assert (w >= -1e-12).all()


def test_hub_balanced_rounds_preserves_mean_and_contracts():
    from repro.core.schedule import hub_balanced_rounds

    prog = compile_graph(Star(16))
    hb = hub_balanced_rounds(prog, 4)
    x = np.random.default_rng(0).normal(size=(16, 3)).astype(np.float32)
    y = jnp.asarray(x)
    spread0 = float(jnp.abs(y - y.mean(0)).max())
    for _ in range(20):
        y = hb.apply_stacked(y)
    np.testing.assert_allclose(np.asarray(y).mean(0), x.mean(0), atol=1e-4)
    assert float(jnp.abs(y - y.mean(0)).max()) < 0.5 * spread0


def test_hub_balanced_rounds_passthrough_and_validation():
    from repro.core.schedule import hub_balanced_rounds

    star = compile_graph(Star(8))
    assert hub_balanced_rounds(star, 1) is star
    one_op = compile_graph(one_peer_exponential(8, 0))
    assert hub_balanced_rounds(one_op, 4) is one_op  # nothing to rotate
    with pytest.raises(ValueError, match="PPermute"):
        hub_balanced_rounds(dense_program(Star(8)), 2)
    # rounds > matchings: surplus stages are pure self-steps, cycle intact
    hb = hub_balanced_rounds(compile_graph(Ring(8)), 4)
    assert len(hb.stages) == 4
    assert sum(len(s.ops) for s in hb.stages) == 2


def test_topology_fused_program_hub_balance_static_only():
    """hub_balance reschedules static multi-matching programs; time-varying
    families (one-peer) keep their own rotation untouched."""
    star_topo = make_topology("d_star", 16)
    p = one_peer_period(16)
    hb = star_topo.fused_program_at(step=0, rounds=4, hub_balance=True)
    from repro.core.schedule import program_max_node_bytes

    assert max(program_max_node_bytes(s, 100) for s in hb.stages) == 400
    op_topo = make_topology("d_one_peer_exp", 16)
    fused = op_topo.fused_program_at(step=0, rounds=p, hub_balance=True)
    plain = op_topo.fused_program_at(step=0, rounds=p)
    assert fused.cache_key == plain.cache_key


# ---------------------------------------------------------------------------
# Permute tables (the fused-kernel view of a program)
# ---------------------------------------------------------------------------

def test_permute_tables_reconstruct_matrix():
    """srcs/weights tables are an exact dense view of any PPermute program."""
    for g in [Star(8), Ring(8), one_peer_exponential(8, 1),
              random_matching(8, seed=4),
              from_adjacency([(0, 1), (1, 2), (2, 3), (0, 3), (0, 2)])]:
        prog = compile_graph(g)
        tables = prog.permute_tables()
        assert tables is not None, prog.describe()
        srcs, weights = tables
        n = prog.n
        assert srcs.shape == (n, len(prog.ops))
        assert weights.shape == (n, len(prog.ops) + 1)
        w = np.zeros((n, n))
        w[np.arange(n), np.arange(n)] += weights[:, 0]
        for k in range(len(prog.ops)):
            for d in range(n):
                w[d, srcs[d, k]] += weights[d, k + 1]
        np.testing.assert_allclose(w, g.mixing_matrix(), atol=1e-6)


def test_permute_tables_none_for_non_permute_programs():
    assert compile_graph(Complete(8)).permute_tables() is None
    assert dense_program(Ring(8)).permute_tables() is None
    fused = GossipProgram.fuse(
        [compile_graph(one_peer_exponential(8, t)) for t in range(2)]
    )
    assert fused.permute_tables() is None


# ---------------------------------------------------------------------------
# shard interpreter + HLO structure (8 host devices, subprocess)
# ---------------------------------------------------------------------------

def test_shard_interpreter_and_hlo_collectives():
    """apply_shard == dense oracle on 8 devices AND the compiled HLO shows
    exactly one collective-permute per circulant offset (no all-gather
    regression), one all-reduce for complete, all-gather only for the dense
    fallback."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run(
        [
            sys.executable,
            os.path.join(os.path.dirname(__file__), "schedule_shard_script.py"),
        ],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}\nstdout:\n{r.stdout}"
    assert "SHARD_INTERPRETER_OK" in r.stdout
