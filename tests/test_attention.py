"""Attention-module unit tests (masks, GQA, chunked online softmax, cache)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    KVCache, cache_update, decode_attention, multihead_attention,
)

B, S, H, KV, D = 2, 16, 4, 2, 8


def _qkv(key, sq=S, sk=S):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, sq, H, D))
    k = jax.random.normal(ks[1], (B, sk, KV, D))
    v = jax.random.normal(ks[2], (B, sk, KV, D))
    pos = jnp.broadcast_to(jnp.arange(sq)[None], (B, sq))
    kpos = jnp.broadcast_to(jnp.arange(sk)[None], (B, sk))
    return q, k, v, pos, kpos


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 4])
def test_chunked_equals_reference(causal, window):
    q, k, v, pos, kpos = _qkv(jax.random.PRNGKey(0))
    a = multihead_attention(q, k, v, q_positions=pos, k_positions=kpos,
                            causal=causal, window=window, impl="reference")
    b = multihead_attention(q, k, v, q_positions=pos, k_positions=kpos,
                            causal=causal, window=window, impl="chunked",
                            chunk_size=5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_causal_mask_blocks_future():
    """Changing future K/V must not change earlier outputs."""
    q, k, v, pos, kpos = _qkv(jax.random.PRNGKey(1))
    a = multihead_attention(q, k, v, q_positions=pos, k_positions=kpos, causal=True)
    k2 = k.at[:, -1].set(99.0)
    v2 = v.at[:, -1].set(99.0)
    b = multihead_attention(q, k2, v2, q_positions=pos, k_positions=kpos, causal=True)
    np.testing.assert_allclose(np.asarray(a[:, :-1]), np.asarray(b[:, :-1]), atol=1e-6)
    assert not np.allclose(np.asarray(a[:, -1]), np.asarray(b[:, -1]))


def test_window_restricts_receptive_field():
    q, k, v, pos, kpos = _qkv(jax.random.PRNGKey(2))
    w = 3
    a = multihead_attention(q, k, v, q_positions=pos, k_positions=kpos,
                            causal=True, window=w)
    # perturbing a key more than w behind the last query leaves it unchanged
    k2 = k.at[:, 0].set(-50.0)
    b = multihead_attention(q, k2, v, q_positions=pos, k_positions=kpos,
                            causal=True, window=w)
    np.testing.assert_allclose(np.asarray(a[:, w:]), np.asarray(b[:, w:]), atol=1e-6)


def test_gqa_grouping_matches_repeated_kv():
    """GQA == MHA with kv heads explicitly repeated."""
    q, k, v, pos, kpos = _qkv(jax.random.PRNGKey(3))
    a = multihead_attention(q, k, v, q_positions=pos, k_positions=kpos, causal=True)
    krep = jnp.repeat(k, H // KV, axis=2)
    vrep = jnp.repeat(v, H // KV, axis=2)
    b = multihead_attention(q, krep, vrep, q_positions=pos, k_positions=kpos, causal=True)
    # repeat puts group g of kv-head j at index j*G+g while _split_gqa assumes
    # contiguous groups — matching layouts:
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_ring_cache_update_and_decode():
    n_slots = 4
    ck = jnp.zeros((B, n_slots, KV, D))
    cv = jnp.zeros((B, n_slots, KV, D))
    cp = jnp.full((B, n_slots), -1, jnp.int32)
    key = jax.random.PRNGKey(4)
    for t in range(6):  # wraps the ring
        kn = jax.random.normal(jax.random.fold_in(key, t), (B, 1, KV, D))
        ck, cv, cp = cache_update(ck, cv, cp, kn, kn, jnp.int32(t), ring=True)
    # slots hold the last 4 positions
    assert sorted(np.asarray(cp[0]).tolist()) == [2, 3, 4, 5]
    q = jax.random.normal(key, (B, 1, H, D))
    out = decode_attention(q, ck, cv, cp, pos=jnp.int32(6), window=4)
    assert out.shape == (B, 1, H, D)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_empty_cache_is_safe():
    ck = jnp.zeros((B, 4, KV, D))
    cp = jnp.full((B, 4), -1, jnp.int32)
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, H, D))
    ck2, cv2, cp2 = cache_update(ck, ck, cp, q[:, :, :KV], q[:, :, :KV], jnp.int32(0), ring=False)
    out = decode_attention(q, ck2, cv2, cp2, pos=jnp.int32(0))
    assert bool(jnp.all(jnp.isfinite(out)))


def test_kvcache_empty_constructor():
    c = KVCache.empty(3, B, 8, KV, D)
    assert c.k.shape == (3, B, 8, KV, D)
    assert (c.positions == -1).all()
