"""Per-architecture smoke tests: reduced config, one train step + one decode
step on CPU; asserts output shapes and finiteness (harness deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import transformer as tfm

B, S = 2, 16


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "targets": tokens}
    if cfg.input_kind == "vlm":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), cfg.dtype
        )
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_shapes_and_finite(arch, key):
    cfg = get_config(arch + "-reduced")
    params = tfm.init_model(cfg, key, tp_size=1)
    batch = _batch(cfg, key)
    loss, grads = jax.jit(jax.value_and_grad(lambda p, b: tfm.loss_fn(p, cfg, b)))(
        params, batch
    )
    assert loss.shape == ()
    assert jnp.isfinite(loss), arch
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0, arch
    # one SGD step strictly changes the params
    new = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype), params, grads)
    changed = any(
        bool(jnp.any(a != b)) for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new))
    )
    assert changed


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_step_shapes_and_finite(arch, key):
    cfg = get_config(arch + "-reduced")
    params = tfm.init_model(cfg, key, tp_size=1)
    state = tfm.init_decode_state(cfg, B, 32)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    step = jax.jit(lambda p, t, pos, s: tfm.decode_step(p, cfg, t, pos, s))
    logits, state = step(params, tok, jnp.int32(0), state)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    logits2, _ = step(params, tok, jnp.int32(1), state)
    assert bool(jnp.all(jnp.isfinite(logits2))), arch


@pytest.mark.parametrize("arch", ["granite-8b", "rwkv6-1.6b", "zamba2-7b"])
def test_forward_batch_invariance(arch, key):
    """Row i of a batched forward == forward of row i alone."""
    cfg = get_config(arch + "-reduced")
    params = tfm.init_model(cfg, key, tp_size=1)
    tokens = jax.random.randint(key, (3, S), 0, cfg.vocab)
    full, _, _ = tfm.forward(params, cfg, tokens)
    one, _, _ = tfm.forward(params, cfg, tokens[1:2])
    assert jnp.allclose(full[1:2], one, atol=2e-4), arch


def test_full_configs_match_assignment():
    """The exact architecture numbers from the assignment block."""
    c = get_config("phi3.5-moe-42b-a6.6b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == (
        32, 4096, 32, 8, 6400, 32064) and (c.n_experts, c.top_k) == (16, 2)
    c = get_config("kimi-k2-1t-a32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == (
        61, 7168, 64, 8, 2048, 163840) and (c.n_experts, c.top_k) == (384, 8)
    c = get_config("stablelm-12b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == (
        40, 5120, 32, 8, 13824, 100352)
    c = get_config("granite-8b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (36, 4096, 14336, 49152)
    c = get_config("rwkv6-1.6b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (24, 2048, 7168, 65536)
    assert c.family == "ssm" and c.n_kv == 0
    c = get_config("musicgen-medium")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == (
        48, 1536, 24, 24, 6144, 2048)
    c = get_config("zamba2-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == (
        81, 3584, 32, 32, 14336, 32000) and c.ssm_state == 64
    c = get_config("starcoder2-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == (
        32, 4608, 36, 4, 18432, 49152)
    c = get_config("internvl2-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == (
        24, 2048, 16, 8, 8192, 92553)
    c = get_config("qwen2.5-14b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == (
        48, 5120, 40, 8, 13824, 152064) and c.qkv_bias


def test_reduced_configs_are_small():
    for arch in ARCH_NAMES:
        c = get_config(arch + "-reduced")
        assert c.n_layers <= 7 and c.d_model <= 512
        if c.n_experts:
            assert c.n_experts <= 4
