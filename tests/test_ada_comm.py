"""Step-granular communication accounting (benchmarks/ada.py).

Regression for the bug where ``_total_comm`` billed time-varying phases
the step-0 graph every step: accounting must be per-step program bytes.
The pinned analytic fact: the one-peer exponential family moves exactly
ONE permute of the full parameter tree per node per step, so its total is
``steps · P`` — the cost floor Ada's advantage decays onto.
"""
import numpy as np
import jax.numpy as jnp

from benchmarks.ada import STEPS_PER_EPOCH, _total_comm, _tree_bytes
from repro.core.dsgd import make_topology


PARAMS = {"w": jnp.zeros((1000,), jnp.float32), "b": jnp.zeros((24,), jnp.float32)}
P = _tree_bytes(PARAMS)  # 4096 bytes


def test_one_peer_comm_is_one_permute_per_step():
    topo = make_topology("d_one_peer_exp", 16)
    steps = 13  # deliberately not a multiple of the period
    assert _total_comm(topo, steps, PARAMS) == steps * P


def test_ada_one_peer_floor_billed_per_step():
    """Open-loop Ada with a one-peer floor: lattice epochs bill the lattice
    program, one-peer epochs bill exactly P per step."""
    # k0=2, gamma=1: epoch 0 is the k=2 ring, epoch >= 1 is one-peer
    topo = make_topology("d_ada", 16, k0=2, gamma_k=1.0, k_floor="one_peer")
    steps = 3 * STEPS_PER_EPOCH
    ring_step = 2 * P  # k=2 ring: two permute offsets
    want = STEPS_PER_EPOCH * ring_step + 2 * STEPS_PER_EPOCH * P
    assert _total_comm(topo, steps, PARAMS) == want


def test_matching_comm_counts_participants_only():
    """An odd-n matching idles one node; billing is per participating link,
    not a dense graph."""
    topo = make_topology("d_random_matching", 9, seed=0, pool=4)
    steps = 8
    # every random_matching on 9 nodes has 4 edges = 8 directed links
    want = steps * int(P * 8 / 9)
    assert _total_comm(topo, steps, PARAMS) == want


def test_closed_loop_comm_replays_recorded_trace():
    """Closed-loop accounting bills the rung actually in force at each
    step, replayed from the controller's transition log."""
    topo = make_topology("d_ada", 16, k0=4, k_floor="one_peer",
                         consensus_target=0.5)  # ladder (4, 2, one_peer)
    ctl = topo.controller
    # synthesize a run: k=4 until step 4, k=2 from 4, one-peer from 8
    ctl.observe(10.0, 0)
    ctl.observe(1.0, 4)
    ctl.observe(10.0, 6)
    ctl.observe(1.0, 8)
    assert ctl.handoff_step == 8
    total = _total_comm(topo, 12, PARAMS)
    # k=4 lattice: ±1,±2 offsets = 4 permutes; k=2 ring: 2 permutes; the
    # one-peer phase is exactly one permute = P per step.  Every probe
    # (probe_every=1 here) additionally bills the x̄ all-reduce.
    probe = int(2 * P * 15 / 16)
    want = 4 * (4 * P) + 4 * (2 * P) + 4 * P + 12 * probe
    assert total == want
    # accounting must not disturb the live rung
    assert ctl.current == "one_peer"


def test_centralized_billed_as_allreduce():
    topo = make_topology("c_complete", 8)
    per_step = int(2 * P * 7 / 8)  # ring all-reduce bytes per node
    assert _total_comm(topo, 5, PARAMS) == 5 * per_step


def test_elastic_join_then_crash_comm_billed_per_membership():
    """Regression: ``_total_comm`` replayed a fixed-n stream, so an elastic
    join silently billed the stale pre-join graph for every grown step
    (and the elastic bench skipped the column entirely).  A join must bill
    the family re-derived at each step's membership; a crash bills the
    degraded program from its onset."""
    from repro.core.faults import make_fault_model

    # join: star(6) for steps 0-1, star(7) from the step-2 join on — the
    # edge-colored star moves 2(n-1)/n parameter trees per node per step,
    # so the grown steps are strictly cheaper per node than a fixed-n
    # replay would claim
    topo = make_topology(
        "d_star", 6, fault_model=make_fault_model("join", 6, join_steps=(2,))
    )

    def star(n):
        return int(P * 2 * (n - 1) / n)

    assert _total_comm(topo, 4, PARAMS) == 2 * star(6) + 2 * star(7)

    # ...then a crash: the victim's four directed ring links leave the
    # wire at its seeded onset (2P per step before, 1.5P after)
    fm = make_fault_model("crash", 8, rate=0.8, seed=1, down_steps=50)
    topo = make_topology("d_ring", 8, fault_model=fm)
    onset = next(t for t in range(50) if not fm.at(t).program_alive.all())
    want = onset * (2 * P) + 3 * int(2 * P * 6 / 8)
    assert _total_comm(topo, onset + 3, PARAMS) == want
