"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize(
    "b,h,kv,sq,sk,d",
    [
        (1, 2, 1, 128, 128, 64),
        (2, 4, 2, 128, 256, 64),
        (1, 8, 8, 256, 256, 32),
        (1, 6, 2, 128, 128, 128),
    ],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, kv, sq, sk, d, causal):
    key = jax.random.PRNGKey(b * 100 + h)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, kv, sk, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, kv, sk, d), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(dtype)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v)
    assert out.dtype == dtype
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol
    )


@pytest.mark.parametrize("window", [32, 96])
def test_flash_attention_sliding_window(window):
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    out = ops.flash_attention(q, k, v, window=window, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("p,deg,block", [(1024, 2, 256), (4096, 6, 1024), (2048, 1, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gossip_update_sweep(p, deg, block, dtype):
    key = jax.random.PRNGKey(p + deg)
    ks = jax.random.split(key, 4)
    theta = jax.random.normal(ks[0], (p,)).astype(dtype)
    nbr = jax.random.normal(ks[1], (deg, p)).astype(dtype)
    w = jnp.full((deg + 1,), 1.0 / (deg + 1))
    g = jax.random.normal(ks[2], (p,)).astype(dtype)
    m = jax.random.normal(ks[3], (p,)).astype(jnp.float32)
    o1, m1 = ops.gossip_update(theta, nbr, w, g, m, lr=0.1, beta=0.9, block=block)
    o2, m2 = ref.gossip_update_ref(theta, nbr, w, g, m, lr=0.1, beta=0.9)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(o1, np.float32), np.asarray(o2, np.float32), atol=tol
    )
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-5)


def test_gossip_update_runtime_lr_beta_no_recompile():
    """LR schedules must not retrigger compiles: lr/beta ride in SMEM at
    runtime, so sweeping them leaves exactly one cached executable."""
    from repro.kernels.gossip_update import _gossip_update

    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    theta = jax.random.normal(ks[0], (512,))
    nbr = jax.random.normal(ks[1], (2, 512))
    w = jnp.full((3,), 1.0 / 3)
    g = jax.random.normal(ks[2], (512,))
    m = jax.random.normal(ks[3], (512,))
    _gossip_update._clear_cache()
    for lr, beta in [(0.1, 0.9), (0.05, 0.9), (0.01, 0.8), (0.2, 0.0)]:
        o, mm = ops.gossip_update(theta, nbr, w, g, m, lr=lr, beta=beta, block=256)
        o2, m2 = ref.gossip_update_ref(theta, nbr, w, g, m, lr=lr, beta=beta)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o2), atol=1e-5)
        np.testing.assert_allclose(np.asarray(mm), np.asarray(m2), atol=1e-5)
    assert _gossip_update._cache_size() == 1


@pytest.mark.parametrize("graph_name", ["star", "ring", "one_peer", "matching", "irregular"])
def test_fused_program_apply_matches_dense_oracle(graph_name):
    """The per-node-weight Pallas executor == optimizer update followed by
    the program's dense interpreter (PR-3 acceptance, <= 1e-6) on every
    PPermute program class: circulant, matching, and edge-colored."""
    from repro.core.graphs import (
        Ring, Star, from_adjacency, one_peer_exponential, random_matching,
    )
    from repro.core.schedule import compile_graph
    from repro.kernels.gossip_update import fused_apply_stacked
    from repro.optim.sgd import sgd

    graph = {
        "star": lambda: Star(8),
        "ring": lambda: Ring(8),
        "one_peer": lambda: one_peer_exponential(8, 1),
        "matching": lambda: random_matching(8, seed=3),
        "irregular": lambda: from_adjacency(
            [(0, 1), (1, 2), (2, 3), (0, 3), (0, 2), (4, 5), (5, 6), (6, 7)]
        ),
    }[graph_name]()
    prog = compile_graph(graph)
    n = prog.n
    kp = jax.random.split(jax.random.PRNGKey(n), 4)
    # deliberately non-block-aligned leaf sizes: exercises the zero-padding
    params = {"a": jax.random.normal(kp[0], (n, 33, 7)),
              "b": jax.random.normal(kp[1], (n, 10))}
    grads = {"a": jax.random.normal(kp[2], (n, 33, 7)),
             "b": jax.random.normal(kp[3], (n, 10))}
    mom = jax.tree.map(jnp.zeros_like, params)
    lr, beta = 0.07, 0.9
    new_p, new_m = fused_apply_stacked(
        prog, params, grads, mom, lr=lr, beta=beta, block=128
    )
    opt = sgd(momentum=beta)
    up, um = jax.vmap(opt.update, in_axes=(0, 0, 0, None))(
        grads, mom, params, jnp.float32(lr)
    )
    want = prog.apply_dense(up)
    for k in params:
        np.testing.assert_allclose(
            np.asarray(new_p[k]), np.asarray(want[k]), atol=1e-6, err_msg=k
        )
        np.testing.assert_allclose(np.asarray(new_m[k]), np.asarray(um[k]), atol=1e-6)


def test_fused_program_apply_momentumless_and_pre_order():
    """beta=0 keeps the empty () optimizer state; mix_order='pre' mixes the
    raw params before descending (no theta* materialization on the wire)."""
    from repro.core.graphs import Ring
    from repro.core.schedule import compile_graph
    from repro.kernels.gossip_update import fused_apply_stacked
    from repro.optim.sgd import sgd

    prog = compile_graph(Ring(8))
    kp = jax.random.split(jax.random.PRNGKey(0), 2)
    params = {"w": jax.random.normal(kp[0], (8, 50))}
    grads = {"w": jax.random.normal(kp[1], (8, 50))}
    new_p, new_m = fused_apply_stacked(
        prog, params, grads, (), lr=0.1, beta=0.0, block=64
    )
    assert new_m == ()
    opt = sgd(momentum=0.0)
    up, _ = jax.vmap(opt.update, in_axes=(0, 0, 0, None))(
        grads, (), params, jnp.float32(0.1)
    )
    want = prog.apply_dense(up)
    np.testing.assert_allclose(np.asarray(new_p["w"]), np.asarray(want["w"]), atol=1e-6)

    # pre-order: mix raw params first, then descend
    mom = jax.tree.map(jnp.zeros_like, params)
    new_p, _ = fused_apply_stacked(
        prog, params, grads, mom, lr=0.1, beta=0.9, mix_order="pre", block=64
    )
    mixed = prog.apply_dense(params)
    want = jax.tree.map(
        lambda mx, g: mx - 0.1 * (0.9 * jnp.zeros_like(g) + g), mixed, grads
    )
    np.testing.assert_allclose(np.asarray(new_p["w"]), np.asarray(want["w"]), atol=1e-6)


def test_fused_kernel_composes_with_multi_round_fusion():
    """fused_apply × mix_rounds: kernel runs update + round 1, the stacked
    interpreter the remaining rounds — together == the fused program's
    dense product oracle (mirrors SPMDTrainer._fused_split)."""
    from repro.core.graphs import one_peer_exponential
    from repro.core.schedule import GossipProgram, compile_graph
    from repro.kernels.gossip_update import fused_apply_stacked
    from repro.optim.sgd import sgd

    n = 8
    progs = [compile_graph(one_peer_exponential(n, t)) for t in range(3)]
    fused = GossipProgram.fuse(progs)
    kp = jax.random.split(jax.random.PRNGKey(1), 2)
    params = {"w": jax.random.normal(kp[0], (n, 40))}
    grads = {"w": jax.random.normal(kp[1], (n, 40))}
    mom = jax.tree.map(jnp.zeros_like, params)
    lr, beta = 0.05, 0.9
    new_p, _ = fused_apply_stacked(
        fused.stages[0], params, grads, mom, lr=lr, beta=beta, block=40
    )
    for stage in fused.stages[1:]:
        new_p = stage.apply_stacked(new_p)
    opt = sgd(momentum=beta)
    up, _ = jax.vmap(opt.update, in_axes=(0, 0, 0, None))(
        grads, mom, params, jnp.float32(lr)
    )
    want = fused.apply_dense(up)
    np.testing.assert_allclose(
        np.asarray(new_p["w"]), np.asarray(want["w"]), atol=1e-5
    )


def test_fused_apply_rejects_non_permute_programs():
    from repro.core.graphs import Complete, Ring
    from repro.core.schedule import compile_graph, dense_program
    from repro.kernels.gossip_update import fused_apply_stacked

    params = {"w": jnp.ones((8, 16))}
    for prog in (compile_graph(Complete(8)), dense_program(Ring(8))):
        with pytest.raises(ValueError, match="PPermute"):
            fused_apply_stacked(prog, params, params, (), lr=0.1, beta=0.0)


@pytest.mark.parametrize("r,p,block", [(1, 512, 512), (7, 3000, 512), (16, 2048, 2048)])
def test_l2_norms_sweep(r, p, block):
    x = jax.random.normal(jax.random.PRNGKey(r), (r, p))
    out = ops.l2_norms(x, block=block)
    want = ref.l2_norms_ref(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_l2_norms_matches_dbench_probe():
    """The kernel agrees with the in-step jnp probe used by the trainer."""
    from repro.core.dbench import param_l2_norms

    params = {
        "a": jax.random.normal(jax.random.PRNGKey(0), (37, 11)),
        "b": jax.random.normal(jax.random.PRNGKey(1), (257,)),
    }
    want = param_l2_norms(params)
    flat = [x.ravel() for x in jax.tree.leaves(params)]
    pmax = max(x.size for x in flat)
    mat = jnp.stack([jnp.pad(x, (0, pmax - x.size)) for x in flat])
    got = ops.l2_norms(mat, block=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
