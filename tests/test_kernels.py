"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize(
    "b,h,kv,sq,sk,d",
    [
        (1, 2, 1, 128, 128, 64),
        (2, 4, 2, 128, 256, 64),
        (1, 8, 8, 256, 256, 32),
        (1, 6, 2, 128, 128, 128),
    ],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, kv, sq, sk, d, causal):
    key = jax.random.PRNGKey(b * 100 + h)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, kv, sk, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, kv, sk, d), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(dtype)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v)
    assert out.dtype == dtype
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol
    )


@pytest.mark.parametrize("window", [32, 96])
def test_flash_attention_sliding_window(window):
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    out = ops.flash_attention(q, k, v, window=window, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("p,deg,block", [(1024, 2, 256), (4096, 6, 1024), (2048, 1, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gossip_update_sweep(p, deg, block, dtype):
    key = jax.random.PRNGKey(p + deg)
    ks = jax.random.split(key, 4)
    theta = jax.random.normal(ks[0], (p,)).astype(dtype)
    nbr = jax.random.normal(ks[1], (deg, p)).astype(dtype)
    w = jnp.full((deg + 1,), 1.0 / (deg + 1))
    g = jax.random.normal(ks[2], (p,)).astype(dtype)
    m = jax.random.normal(ks[3], (p,)).astype(jnp.float32)
    o1, m1 = ops.gossip_update(theta, nbr, w, g, m, lr=0.1, beta=0.9, block=block)
    o2, m2 = ref.gossip_update_ref(theta, nbr, w, g, m, lr=0.1, beta=0.9)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(o1, np.float32), np.asarray(o2, np.float32), atol=tol
    )
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-5)


@pytest.mark.parametrize("r,p,block", [(1, 512, 512), (7, 3000, 512), (16, 2048, 2048)])
def test_l2_norms_sweep(r, p, block):
    x = jax.random.normal(jax.random.PRNGKey(r), (r, p))
    out = ops.l2_norms(x, block=block)
    want = ref.l2_norms_ref(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_l2_norms_matches_dbench_probe():
    """The kernel agrees with the in-step jnp probe used by the trainer."""
    from repro.core.dbench import param_l2_norms

    params = {
        "a": jax.random.normal(jax.random.PRNGKey(0), (37, 11)),
        "b": jax.random.normal(jax.random.PRNGKey(1), (257,)),
    }
    want = param_l2_norms(params)
    flat = [x.ravel() for x in jax.tree.leaves(params)]
    pmax = max(x.size for x in flat)
    mat = jnp.stack([jnp.pad(x, (0, pmax - x.size)) for x in flat])
    got = ops.l2_norms(mat, block=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
