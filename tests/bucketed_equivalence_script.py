"""Subprocess body for test_spmd.py: bucketed trainer == monolithic == oracle.

Runs the same decentralized training through (a) the production SPMD
trainer with ``bucket_mb`` set — per-bucket overlap-scheduled dispatches
threaded on the Ξ² token, with the bounded dispatch window — (b) the same
trainer monolithic (``bucket_mb=None``), and (c) the vmap/dense-matrix
simulator oracle, with identical init/data/topology, and checks:

  * bucketed final parameters match BOTH the monolithic trainer and the
    dense oracle to float32 round-off (the bucket partition, the token
    chain, and the jitted split/merge change scheduling only, never
    values),
  * the fault-masked bucketed path (transient dropout realizations as
    runtime operands on every bucket dispatch) matches the monolithic
    fault-aware step,
  * a fine-grained layout (num_buckets >> window) exercises the bounded
    dispatch window without deadlock or drift.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.buckets import MAX_INFLIGHT_BUCKETS, BucketLayout
from repro.core.dsgd import make_topology
from repro.core.faults import make_fault_model
from repro.core.simulator import DecentralizedSimulator
from repro.data import SyntheticLM
from repro.launch.mesh import make_mesh
from repro.launch.train import SPMDTrainer
from repro.models import transformer as tfm
from repro.optim.sgd import sgd

TOPO = sys.argv[1] if len(sys.argv) > 1 else "d_one_peer_exp"
STEPS = 4
G = 4  # gossip nodes (data axis), model axis = 2

cfg = dataclasses.replace(
    get_config("granite-8b-reduced"), name="granite-8b", dtype=jnp.float32,
    remat=False,
)
mesh = make_mesh((G, 2), ("data", "model"))
opt = sgd(momentum=0.9)
src = SyntheticLM(vocab=cfg.vocab, seq_len=16, seed=0)
key = jax.random.PRNGKey(42)


def run_trainer(bucket_mb, fault_kind=None):
    fm = (
        make_fault_model(fault_kind, G, rate=0.35, seed=3)
        if fault_kind
        else None
    )
    topo = make_topology(TOPO, G, fault_model=fm)
    trainer = SPMDTrainer(
        cfg, mesh, topo, opt, donate=False, bucket_mb=bucket_mb
    )
    state = trainer.init_state(key)
    losses = []
    for t in range(STEPS):
        batch = {k: jnp.asarray(v) for k, v in src.stacked(G, t, 2).items()}
        state, loss, _ = trainer.train_step(state, batch, 0.05, epoch=0)
        losses.append(jax.device_get(loss))
    return jax.device_get(state.params), losses


def tree_maxdiff(a, b):
    return max(
        jax.tree.leaves(
            jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()), a, b)
        )
    )


# --- fault-free: bucketed vs monolithic vs dense oracle ----------------------
p_mono, losses_mono = run_trainer(None)
p_buck, losses_buck = run_trainer(1.0)
# the layout must actually split (several buckets, and more than the
# dispatch window so the window logic runs) or this test proves nothing
nb = BucketLayout.for_stacked(p_buck, 1.0).num_buckets
assert nb > MAX_INFLIGHT_BUCKETS, f"layout too coarse: {nb} buckets"

sim = DecentralizedSimulator(
    lambda p, b: tfm.loss_fn(p, cfg, b), opt, make_topology(TOPO, G),
    mixing="dense",
)
sim_state = sim.init(tfm.init_model(cfg, key, tp_size=2))
for t in range(STEPS):
    batch = {k: jnp.asarray(v) for k, v in src.stacked(G, t, 2).items()}
    sim_state, _, _ = sim.train_step(sim_state, batch, 0.05, epoch=0)
p_oracle = jax.device_get(sim_state.params)

monodiff = tree_maxdiff(p_buck, p_mono)
oraclediff = tree_maxdiff(p_buck, p_oracle)
lossdiff = max(
    float(abs(a - b).max()) for a, b in zip(losses_buck, losses_mono)
)

# --- fault-masked: bucketed vs monolithic under transient dropout ------------
pf_mono, _ = run_trainer(None, fault_kind="dropout")
pf_buck, _ = run_trainer(1.0, fault_kind="dropout")
faultdiff = tree_maxdiff(pf_buck, pf_mono)

# --- fine-grained layout: num_buckets >> window ------------------------------
pfine, _ = run_trainer(0.05)
finediff = tree_maxdiff(pfine, p_mono)

print(f"MONODIFF={monodiff:.3e}")
print(f"ORACLEDIFF={oraclediff:.3e}")
print(f"LOSSDIFF={lossdiff:.3e}")
print(f"FAULTDIFF={faultdiff:.3e}")
print(f"FINEDIFF={finediff:.3e}")
for name, v in [
    ("MONODIFF", monodiff), ("ORACLEDIFF", oraclediff),
    ("LOSSDIFF", lossdiff), ("FAULTDIFF", faultdiff),
    ("FINEDIFF", finediff),
]:
    assert v < 1e-5, f"{name}={v:.3e}"
print("BUCKETED_EQUIV_OK")
