"""Batched serving demo: prefill-free replayed generation with KV cache,
greedy and sampled, on the ServeEngine used by the decode dry-runs.

  PYTHONPATH=src python examples/serve_decode.py [--arch granite-8b] [--new 16]
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.launch.serve import ServeEngine
from repro.models import transformer as tfm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config(args.arch + "-reduced"), dtype=jnp.float32, remat=False
    )
    mesh = make_mesh((1, 1), ("data", "model"))
    eng = ServeEngine(cfg, mesh)
    params = tfm.init_model(cfg, jax.random.PRNGKey(0), tp_size=1)

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    print(f"arch={cfg.name} (reduced) | batch={args.batch} | "
          f"prompt={args.prompt_len} | generating {args.new} tokens")

    t0 = time.time()
    greedy = eng.generate(params, prompts, n_new=args.new,
                          max_len=args.prompt_len + args.new)
    t1 = time.time()
    sampled = eng.generate(params, prompts, n_new=args.new,
                           max_len=args.prompt_len + args.new,
                           temperature=args.temperature,
                           key=jax.random.PRNGKey(2))
    t2 = time.time()

    for i in range(args.batch):
        print(f"  req{i}: prompt={prompts[i].tolist()}")
        print(f"        greedy  -> {greedy[i].tolist()}")
        print(f"        sampled -> {sampled[i].tolist()}")
    tok_s = args.batch * args.new / (t1 - t0)
    print(f"\ngreedy: {t1-t0:.2f}s ({tok_s:.1f} tok/s incl. prompt replay); "
          f"sampled: {t2-t1:.2f}s")
    assert greedy.shape == (args.batch, args.new)


if __name__ == "__main__":
    main()
