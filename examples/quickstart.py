"""Quickstart: decentralized data-parallel training in 60 lines.

Trains a small transformer LM on 8 simulated gossip nodes with the Ada
adaptive communication graph and prints the DBench variance probe as the
graph anneals from dense to sparse.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import make_topology
from repro.core.dbench import DBenchRecorder, gini
from repro.core.simulator import DecentralizedSimulator
from repro.data import SyntheticLM, node_batch_iterator
from repro.models import transformer as tfm
from repro.optim import constant, get_optimizer

N_NODES = 8
STEPS = 60
STEPS_PER_EPOCH = 10

# a small dense-family config (same code path as the 8B assigned arch)
cfg = dataclasses.replace(
    get_config("granite-8b-reduced"),
    d_model=128, n_heads=4, n_kv=2, d_head=32, d_ff=256, vocab=256,
    dtype=jnp.float32, remat=False,
)

# Ada: start densely connected, anneal to a ring (paper Algorithm 1)
topology = make_topology("d_ada", N_NODES, k0=6, gamma_k=1.0)
print(topology.describe())

sim = DecentralizedSimulator(
    loss_fn=lambda p, b: tfm.loss_fn(p, cfg, b),
    optimizer=get_optimizer("adamw", weight_decay=0.0),
    topology=topology,
    collect_norms=True,
)

src = SyntheticLM(vocab=cfg.vocab, seq_len=32, seed=0, structure=0.9)
params0 = tfm.init_model(cfg, jax.random.PRNGKey(0), tp_size=1)
recorder = DBenchRecorder(impl="d_ada", n_nodes=N_NODES)

state, hist = sim.run(
    params0,
    node_batch_iterator(src, N_NODES, per_node_batch=4),
    n_steps=STEPS,
    lr_schedule=constant(1e-2),
    steps_per_epoch=STEPS_PER_EPOCH,
    recorder=recorder,
)

print(f"\n{'step':>5} {'loss':>8} {'gini(param norms)':>18} {'graph degree':>13}")
for i, t in enumerate(recorder.iterations):
    if t % 10 == 0:
        g = float(gini(recorder.norms[i]).mean())
        deg = topology.degree_at(t // STEPS_PER_EPOCH)
        print(f"{t:5d} {recorder.losses[i].mean():8.4f} {g:18.5f} {deg:13d}")

final = state.mean_params()
print(f"\nfinal mean-replica loss: {hist['loss'][-1]:.4f} "
      f"(from {hist['loss'][0]:.4f})")
print("replica consensus spread:",
      float(max(np.abs(np.asarray(l) - np.asarray(l).mean(0)).max()
                for l in jax.tree.leaves(state.params))))
