"""End-to-end decentralized training driver (the production path).

Runs the SPMD shard_map engine — the same code the 512-chip dry-run proves —
on simulated host devices: 8 devices as a (4 data × 2 model) mesh, 4 gossip
nodes, Ada graph schedule, checkpointing, DBench probes, warmup+multistep LR
with the paper's sqrt scaling policy.

  PYTHONPATH=src python examples/train_100m.py                  # smoke preset
  PYTHONPATH=src python examples/train_100m.py --preset 100m \
      --steps 300                                               # ~134M params

The 100m preset is the harness's "train a ~100M model for a few hundred
steps" deliverable; on a 2-core CPU container budget the smoke preset
demonstrates the identical code path at toy scale.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, latest_step, save_checkpoint
from repro.configs.base import ArchConfig
from repro.core.dbench import DBenchRecorder
from repro.core.dsgd import make_topology
from repro.data import SyntheticLM
from repro.launch.mesh import make_mesh
from repro.launch.train import SPMDTrainer, TrainState
from repro.models.common import param_count
from repro.optim.schedules import lr_scale, warmup_multistep
from repro.optim.sgd import sgd

PRESETS = {
    "smoke": dict(d_model=128, n_layers=4, d_ff=512, vocab=512, seq=64,
                  heads=4, kv=2, per_node_batch=4, base_lr=0.3),
    "100m": dict(d_model=768, n_layers=12, d_ff=3072, vocab=32000, seq=256,
                 heads=12, kv=4, per_node_batch=4, base_lr=0.1),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--topology", default="d_ada")
    ap.add_argument("--mixing", default="ppermute", choices=["ppermute", "dense"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--steps-per-epoch", type=int, default=10)
    args = ap.parse_args()
    p = PRESETS[args.preset]

    mesh = make_mesh((4, 2), ("data", "model"))
    g = 4  # gossip nodes = data axis

    cfg = ArchConfig(
        name="granite-8b",  # dense family code path; gossip over 'data'
        family="dense",
        n_layers=p["n_layers"], d_model=p["d_model"], d_ff=p["d_ff"],
        vocab=p["vocab"], n_heads=p["heads"], n_kv=p["kv"],
        dtype=jnp.float32, remat=False,
    )
    topo = make_topology(
        args.topology, g, **({"k0": 3, "gamma_k": 0.5} if args.topology == "d_ada" else {})
    )
    trainer = SPMDTrainer(
        cfg, mesh, topo, sgd(momentum=0.9), collect_norms=True,
        mixing=args.mixing, donate=False,
    )
    n_params = param_count(trainer.defs)
    print(f"model: {n_params/1e6:.1f}M params | mesh {dict(mesh.shape)} | "
          f"{topo.describe()} | mixing={args.mixing}")

    state = trainer.init_state(jax.random.PRNGKey(0))
    start = 0
    if latest_step(args.ckpt_dir) is not None:
        restored, start = load_checkpoint(
            args.ckpt_dir, {"p": state.params, "o": state.opt_state}
        )
        state = TrainState(
            jax.tree.map(jnp.asarray, restored["p"]),
            jax.tree.map(jnp.asarray, restored["o"]),
            start,
        )
        print(f"resumed from step {start}")

    # paper Table 2: sqrt LR scaling by global batch and graph degree (Obs. 3)
    scale = lr_scale(
        "sqrt", global_batch=g * p["per_node_batch"], base_batch=32,
        graph_degree=topo.degree_at(0),
    )
    sched = warmup_multistep(
        p["base_lr"], steps_per_epoch=args.steps_per_epoch, warmup_epochs=1,
        milestones=(30, 60, 80), scale=scale,
    )

    src = SyntheticLM(vocab=cfg.vocab, seq_len=p["seq"], seed=0, structure=0.9)
    rec = DBenchRecorder(impl=args.topology, n_nodes=g)
    t_start = time.time()
    for t in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in src.stacked(g, t, p["per_node_batch"]).items()}
        epoch = t // args.steps_per_epoch
        state, loss, norms = trainer.train_step(state, batch, sched(t), epoch=epoch)
        rec.record(t, np.asarray(loss), np.asarray(norms))
        if t % 5 == 0 or t == args.steps - 1:
            print(f"step {t:4d} epoch {epoch} k={topo.degree_at(epoch)} "
                  f"lr={sched(t):.4f} loss={float(loss.mean()):.4f} "
                  f"spread={float(loss.max()-loss.min()):.4f}")
        if args.ckpt_every and (t + 1) % args.ckpt_every == 0:
            path = save_checkpoint(
                args.ckpt_dir, t + 1, {"p": state.params, "o": state.opt_state}
            )
            print(f"  checkpoint -> {path}")
    dt = time.time() - t_start
    n_steps = args.steps - start
    print(f"\n{n_steps} steps in {dt:.1f}s ({dt/max(n_steps,1):.2f}s/step)")
    g_series = rec.metric_series("gini")
    print(f"gini(param norms): first={g_series[0].mean():.5f} "
          f"last={g_series[-1].mean():.5f}")


if __name__ == "__main__":
    main()
