"""DBench white-box analysis (paper §3): run the five SGD implementations on
identical data, collect per-replica parameter-norm variance, and print the
accuracy/variance correlation tables that motivate Ada.

    PYTHONPATH=src python examples/dbench_whitebox.py [--steps 60] [--nodes 16]
"""
import argparse
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import jax
import numpy as np

from benchmarks.common import sweep_topologies
from repro.core.dbench import rank_analysis
from repro.models.common import init_params
from repro.models.paper_models import (
    mini_resnet_apply, mini_resnet_defs, mini_resnet_loss, synthetic_images,
)
from repro.optim.sgd import sgd

TOPOLOGIES = ["c_complete", "d_complete", "d_exponential", "d_torus", "d_ring"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--nodes", type=int, default=16)
    args = ap.parse_args()

    def batch_fn(key, step, n):
        b = synthetic_images(jax.random.fold_in(key, step), batch=8 * n)
        return {
            "images": b["images"].reshape(n, 8, *b["images"].shape[1:]),
            "labels": b["labels"].reshape(n, 8),
        }

    def eval_fn(params):
        import jax.numpy as jnp

        b = synthetic_images(jax.random.PRNGKey(999), batch=256)
        logits = mini_resnet_apply(params, b["images"])
        return jnp.mean((jnp.argmax(logits, -1) == b["labels"]).astype(jnp.float32))

    params0 = init_params(mini_resnet_defs(), jax.random.PRNGKey(0))
    res = sweep_topologies(
        loss_fn=mini_resnet_loss, params0=params0, batch_fn=batch_fn,
        eval_fn=eval_fn, topologies=TOPOLOGIES, n_nodes=args.nodes,
        steps=args.steps, lr=0.05, optimizer=sgd(momentum=0.9),
    )

    print(f"\n== accuracy vs communication graph (n={args.nodes}) — paper Fig. 3 ==")
    print(f"{'impl':>15} {'degree':>7} {'final acc':>10} {'early gini':>11} {'late gini':>10}")
    series = {}
    for name in TOPOLOGIES:
        r = res[name]
        g = r["recorder"].metric_series("gini")
        series[name] = g
        print(
            f"{name:>15} {r['comm_degree']:7d} {r['final_eval']:10.3f} "
            f"{g[:args.steps//4].mean():11.5f} {g[-args.steps//4:].mean():10.5f}"
        )

    print("\n== variance-rank integration — paper Fig. 5 (1 = lowest variance) ==")
    ranks = rank_analysis(series)
    for name in TOPOLOGIES:
        print(f"{name:>15}  mean rank {ranks[name].mean():.2f}")

    print("\nObservations reproduced: connectivity ↑ ⇒ accuracy ↑, early variance ↓.")


if __name__ == "__main__":
    main()
