"""The shared run-telemetry recorder both engines emit into.

One :class:`MetricsRecorder` instance rides a whole run.  It is pure
host-side Python: no method traces, compiles, or dispatches device work,
so attaching one is provably free w.r.t. the zero-mid-run-recompile
invariant (``debug_no_retrace`` and ``assert_executables_preenumerated``
hold with telemetry enabled — asserted in ``tests/test_telemetry.py``).

Cost model, so callers know exactly what they pay:

  * no sinks, no deadline model (the default every engine constructs):
    every emitting method returns immediately — the engines' hot path
    gains a handful of attribute checks and nothing else;
  * a deadline fault model (``GossipDeadline``): per-``round`` span
    timing is on, which blocks on the loss once per step — exactly the
    synchronization the old per-engine ``_record_round`` already did;
  * sinks attached (``--telemetry``): counters/gauges/events cost a dict
    update + a JSONL line; span timing additionally requires
    ``record_spans=True`` (the CLI sets it) because the per-step block is
    a real synchronization benches must not silently inherit.

Same-step event coalescing (``coalesce_into``) lives here — ONE
implementation — and the consensus controller routes its transition /
rearm / redensify log through it, so the simulator and the SPMD trainer
produce identical event streams for identical runs.
"""
from __future__ import annotations

import time
from typing import Any, Optional

from repro.telemetry.schema import SCHEMA_VERSION, validate_record

__all__ = ["MetricsRecorder", "coalesce_into", "host_grad_norm"]


def coalesce_into(events: list, step: int, reason: str) -> Optional[str]:
    """Append ``(step, reason)`` to an event log, coalescing same-step
    entries: distinct reasons observed in one step merge into a single
    ``"a+b"`` entry, duplicates are dropped (re-arming is idempotent
    within a step).  Returns the entry's merged reason string, or None
    when the reason was already present.  This is the single coalescing
    implementation — ``ConsensusController._log_event`` delegates here,
    so both engines share its semantics by construction.
    """
    step = int(step)
    reason = str(reason)
    if events and events[-1][0] == step:
        prev = events[-1][1]
        if reason in prev.split("+"):
            return None
        merged = f"{prev}+{reason}"
        events[-1] = (step, merged)
        return merged
    events.append((step, reason))
    return reason


def host_grad_norm(grads) -> float:
    """Global L2 norm of a gradient pytree, computed on the host from
    already-materialized arrays (no device dispatch, no compile)."""
    import jax
    import numpy as np

    total = 0.0
    for leaf in jax.tree.leaves(grads):
        a = np.asarray(leaf, dtype=np.float64)
        total += float(np.vdot(a, a).real)
    return float(total ** 0.5)


class MetricsRecorder:
    """Typed per-run metrics: counters, gauges, spans, events, variance.

    Counters are monotone totals (``comm_bytes``, ``permutes``,
    ``program_applications``) billed at dispatch time; gauges are
    point-in-time scalars; ``round`` spans carry the deadline trace the
    engines used to keep privately (``round_ms`` / ``deadline_overruns``
    remain available as thin views); events record discrete occurrences;
    variance records stream the DBench Fig-5 signal.
    """

    def __init__(
        self,
        *,
        sinks=(),
        metrics_every: int = 0,
        record_spans: bool = False,
        deadline_ms: Optional[float] = None,
    ):
        self.sinks = list(sinks)
        self.metrics_every = max(int(metrics_every), 0)
        self.record_spans = bool(record_spans)
        self.deadline_ms = deadline_ms
        # this-process deadline trace (the engines' former private lists)
        self.round_ms: list = []
        self._overruns = 0
        # totals carried across a --resume (load_state_dict)
        self._rounds_prior = 0
        self._overruns_prior = 0
        self.totals: dict[str, float] = {}
        self.last_gauges: dict[str, Optional[float]] = {}
        self.last_variance: Optional[dict] = None
        self.event_count = 0

    # -- wiring ----------------------------------------------------------------
    def configure(self, *, deadline_ms: Optional[float] = None) -> None:
        """Engine-side late configuration (the deadline rides on the fault
        model, which the recorder's creator does not see)."""
        if deadline_ms is not None:
            self.deadline_ms = float(deadline_ms)

    @property
    def active(self) -> bool:
        """True when records fan out to sinks (telemetry requested)."""
        return bool(self.sinks)

    @property
    def timing(self) -> bool:
        """True when ``round`` spans are measured — which synchronizes the
        host on the loss once per step."""
        return self.deadline_ms is not None or (
            self.active and self.record_spans
        )

    @property
    def deadline_overruns(self) -> int:
        return self._overruns

    @property
    def rounds_total(self) -> int:
        return self._rounds_prior + len(self.round_ms)

    @property
    def overruns_total(self) -> int:
        return self._overruns_prior + self._overruns

    def _emit(self, rec: dict) -> None:
        if not self.sinks:
            return
        validate_record(rec)
        for s in self.sinks:
            s.emit(rec)

    def close(self) -> None:
        for s in self.sinks:
            close = getattr(s, "close", None)
            if close is not None:
                close()

    # -- manifest ----------------------------------------------------------------
    def manifest(self, run: dict) -> None:
        self._emit({"kind": "manifest", "schema": SCHEMA_VERSION, "run": run})

    # -- counters ----------------------------------------------------------------
    def counter(self, name: str, inc, *, step: int) -> None:
        total = self.totals.get(name, 0) + inc
        self.totals[name] = total
        self._emit({"kind": "counter", "step": int(step), "name": name,
                    "inc": inc, "total": total})

    def comm(self, program, param_bytes: int, *, step: int,
             alive=None, link_up=None) -> None:
        """Bill one program application at dispatch time: bytes on the wire
        (``program_comm_bytes`` — the same accounting ``benchmarks/ada.py``
        replays offline) and the PPermute dispatch count."""
        if program is None or not self.active:
            return
        from repro.core.schedule import PPermute, program_comm_bytes

        bytes_ = program_comm_bytes(
            program, int(param_bytes), alive=alive, link_up=link_up
        )
        step = int(step)
        self.counter("comm_bytes", int(bytes_), step=step)
        permutes = sum(1 for op in program.ops if isinstance(op, PPermute))
        if permutes:
            self.counter("permutes", permutes, step=step)
        self.counter("program_applications", 1, step=step)

    # -- gauges ----------------------------------------------------------------
    def gauge(self, name: str, value, *, step: int) -> None:
        value = None if value is None else float(value)
        self.last_gauges[name] = value
        self._emit({"kind": "gauge", "step": int(step), "name": name,
                    "value": value})

    # -- spans ----------------------------------------------------------------
    def round_start(self) -> Optional[float]:
        """Host timestamp opening a ``round`` span, or None when timing is
        off — the engines' former ``t_start = perf_counter() if ...``."""
        return time.perf_counter() if self.timing else None

    def round_end(self, t_start: Optional[float], *, step: int,
                  mix: bool = False) -> None:
        """Close a ``round`` span.  The caller has already blocked on the
        step's output so the duration covers the whole dispatched round.
        Deadline attribution is purely observational — the averaging
        masks stay seeded (determinism + engine equivalence)."""
        if t_start is None:
            return
        ms = (time.perf_counter() - t_start) * 1e3
        self.round_ms.append(ms)
        rec = {"kind": "span", "step": int(step), "name": "round",
               "ms": ms, "mix": bool(mix)}
        if self.deadline_ms is not None:
            overrun = ms > float(self.deadline_ms)
            if overrun:
                self._overruns += 1
            rec["deadline_ms"] = float(self.deadline_ms)
            rec["overrun"] = overrun
        self._emit(rec)

    def bucket_span(self, t_start: Optional[float], *, step: int,
                    index: int) -> None:
        """Close a per-bucket ``bucket`` span: host *dispatch* wall-clock
        (no extra blocking — a per-bucket sync would serialize exactly the
        overlap the bucketed path exists to create)."""
        if t_start is None:
            return
        ms = (time.perf_counter() - t_start) * 1e3
        self._emit({"kind": "span", "step": int(step), "name": "bucket",
                    "ms": ms, "index": int(index)})

    def span_start(self) -> Optional[float]:
        """Timestamp for a non-round span; None when sinks are off or span
        timing was not requested."""
        return (
            time.perf_counter() if self.active and self.record_spans else None
        )

    # -- events ----------------------------------------------------------------
    def event(self, name: str, step: int, *, data: Optional[dict] = None) -> None:
        self.event_count += 1
        rec: dict = {"kind": "event", "step": int(step), "name": name}
        if data is not None:
            rec["data"] = data
        self._emit(rec)

    # -- streamed DBench variance ------------------------------------------------
    def due(self, step: int) -> bool:
        """True when ``step`` is a metrics emission step (``--metrics-every``
        cadence).  Engines gate the host transfer of loss/norms on this, so
        disabled telemetry never forces a synchronization."""
        return (
            self.active
            and self.metrics_every > 0
            and int(step) % self.metrics_every == 0
        )

    def step_metrics(self, step: int, *, loss=None, lr=None,
                     norms=None, grads=None) -> None:
        """Emit one metrics sample: loss/lr gauges, the streamed DBench
        ``variance_report`` over the per-node norm matrix the step already
        computed on device (``collect_norms`` folds ``param_l2_norms``
        into the existing grads/step executable — zero extra executables),
        and, when the bucketed path materializes grads on the host, the
        global gradient norm."""
        import numpy as np

        step = int(step)
        if loss is not None:
            self.gauge("loss", float(np.mean(np.asarray(loss))), step=step)
        if lr is not None:
            self.gauge("lr", float(lr), step=step)
        if grads is not None:
            self.gauge("grad_norm", host_grad_norm(grads), step=step)
        if norms is not None:
            a = np.asarray(norms)
            if a.ndim == 2 and a.shape[1] > 0:
                self.variance(step, a)

    def variance(self, step: int, norms) -> None:
        """The paper's Fig-5 signal as a live metric: ``variance_report``
        (gini, CV, index-of-dispersion, quartile coefficient) over the
        (n_nodes, n_leaves) pre-mixing parameter-norm matrix — numerically
        identical to the offline ``DBenchRecorder`` computation because it
        IS the same function on the same array."""
        import numpy as np

        from repro.core.dbench import variance_report

        report = variance_report(norms)
        metrics, per_layer = {}, {}
        for name, per_leaf in report.items():
            arr = np.asarray(per_leaf, dtype=np.float64)
            mean = float(np.mean(arr)) if arr.size else None
            metrics[name] = (
                mean if mean is not None and np.isfinite(mean) else None
            )
            per_layer[name] = [
                float(v) if np.isfinite(v) else None for v in arr
            ]
        self.last_variance = {"step": int(step), "metrics": metrics}
        self._emit({"kind": "variance", "step": int(step),
                    "metrics": metrics, "per_layer": per_layer})

    # -- resume ----------------------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-serializable run totals for the checkpoint ``extra=``
        payload: a resumed run continues its counters and span/overrun
        totals instead of restarting them at zero."""
        return {
            "schema": SCHEMA_VERSION,
            "counters": dict(self.totals),
            "rounds": self.rounds_total,
            "overruns": self.overruns_total,
            "events": int(self.event_count),
        }

    def load_state_dict(self, d: dict) -> None:
        self.totals.update(d.get("counters") or {})
        self._rounds_prior = int(d.get("rounds", 0))
        self._overruns_prior = int(d.get("overruns", 0))
        self.event_count += int(d.get("events", 0))

    # -- bench provenance --------------------------------------------------------
    def provenance(self) -> dict:
        """The ``provenance`` stamp bench sections carry when derived from
        a recorder (``save_bench_section(..., telemetry=...)``); validated
        by ``repro.analysis.invariants.verify_bench_payload``."""
        return {
            "source": "telemetry",
            "schema": SCHEMA_VERSION,
            "counters": {k: float(v) for k, v in sorted(self.totals.items())},
            "rounds": self.rounds_total,
            "events": int(self.event_count),
        }
