"""Offline consumers of a telemetry stream: ``summarize`` and ``diff``.

``summarize(records)`` folds a validated record stream into per-phase
aggregates — a *phase* is the span between consensus-controller
``transition`` events (the rung in force), or the whole run when no
controller ran — and ``render_summary`` prints the step-time / comm /
Ξ_t / streamed-variance tables.  ``diff_summaries`` aligns two runs and
prints per-metric deltas (phase-count mismatches are reported, not
hidden).

CLI::

    python -m repro.telemetry summarize run.jsonl
    python -m repro.telemetry diff a.jsonl b.jsonl
"""
from __future__ import annotations

import math
from typing import Optional

__all__ = ["summarize", "render_summary", "diff_summaries", "main"]


def _percentile(xs: list, q: float) -> float:
    if not xs:
        return float("nan")
    ys = sorted(xs)
    i = (len(ys) - 1) * q
    lo, hi = int(math.floor(i)), int(math.ceil(i))
    return ys[lo] + (ys[hi] - ys[lo]) * (i - lo)


def _fmt(v, nd: int = 2) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if not math.isfinite(v):
            return str(v)
        if v != 0 and (abs(v) >= 1e5 or abs(v) < 10 ** (-nd)):
            return f"{v:.{nd}e}"
        return f"{v:.{nd}f}"
    return str(v)


def summarize(records: list) -> dict:
    """Fold a record stream into manifest + per-phase + run aggregates."""
    manifests = [r for r in records if r["kind"] == "manifest"]
    steps = [r.get("step", 0) for r in records if r["kind"] != "manifest"]
    last_step = max(steps) if steps else 0

    # phase boundaries: controller transitions (a transition observed at
    # step s governs step s onward — mirrors ConsensusController.rung_at)
    transitions = [
        r for r in records
        if r["kind"] == "event" and r["name"] == "transition"
    ]
    bounds, labels = [0], ["run" if not transitions else "start"]
    for t in transitions:
        data = t.get("data") or {}
        bounds.append(int(t["step"]))
        labels.append(f"k={data.get('k', data.get('rung', '?'))}")
    bounds.append(last_step + 1)

    def phase_of(step: int) -> int:
        p = 0
        for i in range(1, len(bounds) - 1):
            if step >= bounds[i]:
                p = i
        return p

    n_phases = len(bounds) - 1
    phases = [
        {
            "label": labels[i],
            "start": bounds[i],
            "end": bounds[i + 1] - 1,
            "round_ms": [],
            "overruns": 0,
            "comm_bytes": 0,
            "xi": [],       # (step, value)
            "loss": [],     # (step, value)
            "variance": None,   # last variance record's metrics
            "events": [],   # (step, name, reason-or-None)
        }
        for i in range(n_phases)
    ]

    counters: dict[str, float] = {}
    per_layer: Optional[dict] = None
    for r in records:
        kind = r["kind"]
        if kind == "manifest":
            continue
        ph = phases[phase_of(int(r.get("step", 0)))]
        if kind == "span" and r["name"] == "round":
            ph["round_ms"].append(float(r["ms"]))
            if r.get("overrun"):
                ph["overruns"] += 1
        elif kind == "counter":
            counters[r["name"]] = float(r["total"])
            if r["name"] == "comm_bytes":
                ph["comm_bytes"] += float(r["inc"])
        elif kind == "gauge" and r["name"] in ("xi", "loss"):
            if r["value"] is not None:
                ph[r["name"]].append((int(r["step"]), float(r["value"])))
        elif kind == "variance":
            ph["variance"] = r["metrics"]
            per_layer = r.get("per_layer")
        elif kind == "event":
            reason = (r.get("data") or {}).get("reason")
            ph["events"].append((int(r["step"]), r["name"], reason))

    for ph in phases:
        ms = ph.pop("round_ms")
        ph["rounds"] = len(ms)
        ph["median_ms"] = _percentile(ms, 0.5) if ms else None
        ph["p95_ms"] = _percentile(ms, 0.95) if ms else None
        ph["xi_first"] = ph["xi"][0][1] if ph["xi"] else None
        ph["xi_last"] = ph["xi"][-1][1] if ph["xi"] else None
        ph["loss_first"] = ph["loss"][0][1] if ph["loss"] else None
        ph["loss_last"] = ph["loss"][-1][1] if ph["loss"] else None
        del ph["xi"], ph["loss"]

    return {
        "manifest": manifests[0]["run"] if manifests else None,
        "segments": len(manifests),
        "last_step": last_step,
        "counters": counters,
        "phases": phases,
        "per_layer_variance": per_layer,
    }


def _table(headers: list, rows: list) -> str:
    cells = [headers] + [[_fmt(c) if not isinstance(c, str) else c
                          for c in row] for row in rows]
    widths = [max(len(r[i]) for r in cells) for i in range(len(headers))]
    lines = []
    for i, row in enumerate(cells):
        lines.append("  ".join(c.rjust(w) for c, w in zip(row, widths)))
        if i == 0:
            lines.append("  ".join("-" * w for w in widths))
    return "\n".join(lines)


def render_summary(s: dict) -> str:
    out = []
    man = s.get("manifest") or {}
    if man:
        cfg = man.get("config") or {}
        out.append(
            "run: " + " ".join(
                str(man.get(k)) for k in ("topology",) if man.get(k)
            )
        )
        interesting = {
            k: cfg[k] for k in ("steps", "seed", "arch", "mesh") if k in cfg
        }
        if interesting or man.get("git"):
            out.append(
                f"provenance: git={man.get('git') or '?'} {interesting}"
            )
    if s.get("segments", 0) > 1:
        out.append(f"segments: {s['segments']} (resumed run)")
    out.append(f"steps: 0..{s['last_step']}")

    out.append("\nper-phase step time / comm / consensus distance:")
    out.append(_table(
        ["phase", "steps", "rounds", "med ms", "p95 ms", "overruns",
         "comm MiB", "xi first", "xi last", "loss last"],
        [[
            ph["label"], f"{ph['start']}..{ph['end']}", ph["rounds"],
            ph["median_ms"], ph["p95_ms"], ph["overruns"],
            ph["comm_bytes"] / 2**20 if ph["comm_bytes"] else 0.0,
            ph["xi_first"], ph["xi_last"], ph["loss_last"],
        ] for ph in s["phases"]],
    ))

    if s["counters"]:
        out.append("\nrun counters:")
        out.append(_table(
            ["counter", "total"],
            [[k, v] for k, v in sorted(s["counters"].items())],
        ))

    var_phases = [ph for ph in s["phases"] if ph["variance"]]
    if var_phases:
        metrics = sorted(var_phases[-1]["variance"])
        out.append("\nstreamed DBench variance (phase-final, mean over layers):")
        out.append(_table(
            ["phase"] + metrics,
            [[ph["label"]] + [ph["variance"].get(m) for m in metrics]
             for ph in var_phases],
        ))
    pl = s.get("per_layer_variance")
    if pl:
        metrics = sorted(pl)
        n_layers = max((len(v) for v in pl.values()), default=0)
        out.append("\nper-layer variance (final sample — the paper's Fig-5 axis):")
        out.append(_table(
            ["layer"] + metrics,
            [[str(i)] + [pl[m][i] if i < len(pl[m]) else None
                         for m in metrics] for i in range(n_layers)],
        ))

    events = [ev for ph in s["phases"] for ev in ph["events"]]
    if events:
        out.append("\nevents:")
        # controller events re-emit per same-step coalescing update; keep
        # the last emission per (step, name)
        dedup: dict = {}
        for step, name, reason in events:
            dedup[(step, name)] = reason
        out.append(_table(
            ["step", "event", "detail"],
            [[str(step), name, reason or ""]
             for (step, name), reason in sorted(dedup.items())],
        ))
    return "\n".join(out)


def diff_summaries(a: dict, b: dict, labels=("a", "b")) -> str:
    out = []
    la, lb = labels

    def row(name, va, vb):
        delta = None
        if isinstance(va, (int, float)) and isinstance(vb, (int, float)):
            delta = vb - va
        return [name, va, vb, delta]

    rows = [
        row("last_step", a["last_step"], b["last_step"]),
        row("phases", len(a["phases"]), len(b["phases"])),
    ]
    for name in sorted(set(a["counters"]) | set(b["counters"])):
        rows.append(row(
            name, a["counters"].get(name), b["counters"].get(name)
        ))
    for pa, pb in zip(a["phases"], b["phases"]):
        tag = f"[{pa['label']}]"
        rows.append(row(f"{tag} rounds", pa["rounds"], pb["rounds"]))
        rows.append(row(f"{tag} med ms", pa["median_ms"], pb["median_ms"]))
        rows.append(row(f"{tag} overruns", pa["overruns"], pb["overruns"]))
        rows.append(row(f"{tag} xi last", pa["xi_last"], pb["xi_last"]))
        rows.append(row(f"{tag} loss last", pa["loss_last"], pb["loss_last"]))
        va, vb = pa["variance"] or {}, pb["variance"] or {}
        for m in sorted(set(va) | set(vb)):
            rows.append(row(f"{tag} {m}", va.get(m), vb.get(m)))
    if len(a["phases"]) != len(b["phases"]):
        out.append(
            f"note: phase count differs ({len(a['phases'])} vs "
            f"{len(b['phases'])}); trailing phases not compared"
        )
    out.append(_table(["metric", la, lb, "delta"], rows))
    return "\n".join(out)


def main(argv=None) -> int:
    import argparse

    from repro.telemetry.schema import SchemaError
    from repro.telemetry.sinks import read_jsonl

    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry",
        description="summarize / diff run-telemetry JSONL streams",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)
    ps = sub.add_parser("summarize", help="render one run's tables")
    ps.add_argument("path")
    pd = sub.add_parser("diff", help="compare two runs phase by phase")
    pd.add_argument("path_a")
    pd.add_argument("path_b")
    args = ap.parse_args(argv)

    try:
        if args.cmd == "summarize":
            print(render_summary(summarize(read_jsonl(args.path))))
        else:
            a = summarize(read_jsonl(args.path_a))
            b = summarize(read_jsonl(args.path_b))
            print(diff_summaries(a, b, labels=(args.path_a, args.path_b)))
    except BrokenPipeError:
        # piped into head(1) etc. — the consumer got what it wanted
        import os
        import sys

        try:
            sys.stdout.close()
        except BrokenPipeError:
            os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        return 0
    except (OSError, SchemaError) as e:
        print(f"error: {e}")
        return 1
    return 0
