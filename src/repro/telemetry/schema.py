"""Typed record schema for the run-telemetry stream.

Every record a :class:`~repro.telemetry.MetricsRecorder` emits is a flat
JSON-serializable dict with a ``kind`` discriminator.  The schema is
deliberately small — five kinds cover everything both engines observe:

  manifest   run provenance, emitted once per run segment (a ``--resume``
             appends a second manifest with ``resumed: true``)
  counter    monotone accumulations billed at dispatch time
             (``comm_bytes``, ``permutes``, ``program_applications``)
  gauge      point-in-time scalars (``loss``, ``xi``, ``lr``,
             ``grad_norm``)
  span       measured wall-clock durations (``round`` per training step,
             ``bucket`` per overlap-scheduled dispatch) with
             deadline-overrun attribution on ``round`` spans
  event      discrete occurrences: controller ``transition`` /
             ``controller`` (rearm/redensify reasons, same-step
             coalesced), membership changes (``join`` / ``rejoin`` /
             ``depart`` / ``membership``), ``checkpoint_save`` /
             ``checkpoint_restore``
  variance   the streamed DBench signal: ``variance_report`` metrics over
             the per-node parameter-norm matrix (paper Fig. 5), with the
             per-layer breakdown

``validate_record`` is the single structural gate: the JSONL sink, the
in-memory test sink, the ``summarize``/``diff`` CLI, and the
``telemetry`` static-analysis pass all call it, so a malformed emission
fails at the producing site, not in a consumer long after the run.
"""
from __future__ import annotations

from typing import Any

SCHEMA_VERSION = 1

__all__ = ["SCHEMA_VERSION", "SchemaError", "validate_record", "KINDS"]


class SchemaError(ValueError):
    """A record violating the telemetry schema."""


_NUM = (int, float)


def _is_num(v: Any) -> bool:
    return isinstance(v, _NUM) and not isinstance(v, bool)


# kind -> {field: checker}; fields not listed are forbidden except the
# optional ones declared in _OPTIONAL.
KINDS = {
    "manifest": {"schema": lambda v: v == SCHEMA_VERSION,
                 "run": lambda v: isinstance(v, dict)},
    "counter": {"step": lambda v: isinstance(v, int) and v >= 0,
                "name": lambda v: isinstance(v, str) and v,
                "inc": _is_num,
                "total": _is_num},
    "gauge": {"step": lambda v: isinstance(v, int) and v >= 0,
              "name": lambda v: isinstance(v, str) and v,
              "value": lambda v: v is None or _is_num(v)},
    "span": {"step": lambda v: isinstance(v, int) and v >= 0,
             "name": lambda v: isinstance(v, str) and v,
             "ms": lambda v: _is_num(v) and v >= 0},
    "event": {"step": lambda v: isinstance(v, int) and v >= 0,
              "name": lambda v: isinstance(v, str) and v},
    "variance": {"step": lambda v: isinstance(v, int) and v >= 0,
                 "metrics": lambda v: isinstance(v, dict) and v
                 and all(isinstance(k, str) and (x is None or _is_num(x))
                         for k, x in v.items())},
}

_OPTIONAL = {
    "span": {
        # round spans under a GossipDeadline model attribute overruns
        "deadline_ms": _is_num,
        "overrun": lambda v: isinstance(v, bool),
        "mix": lambda v: isinstance(v, bool),
        # bucket spans carry their dispatch index
        "index": lambda v: isinstance(v, int) and v >= 0,
    },
    "event": {"data": lambda v: isinstance(v, dict)},
    "variance": {
        "per_layer": lambda v: isinstance(v, dict)
        and all(isinstance(k, str) and isinstance(x, list)
                for k, x in v.items()),
    },
}


def validate_record(rec: Any) -> None:
    """Raise :class:`SchemaError` unless ``rec`` is a well-formed record."""
    if not isinstance(rec, dict):
        raise SchemaError(f"record must be a dict, got {type(rec).__name__}")
    kind = rec.get("kind")
    if kind not in KINDS:
        raise SchemaError(f"unknown record kind {kind!r}")
    required = KINDS[kind]
    optional = _OPTIONAL.get(kind, {})
    for field, check in required.items():
        if field not in rec:
            raise SchemaError(f"{kind} record missing field {field!r}")
        if not check(rec[field]):
            raise SchemaError(
                f"{kind} record field {field!r} has invalid value "
                f"{rec[field]!r}"
            )
    for field, value in rec.items():
        if field == "kind" or field in required:
            continue
        if field not in optional:
            raise SchemaError(f"{kind} record has unknown field {field!r}")
        if not optional[field](value):
            raise SchemaError(
                f"{kind} record field {field!r} has invalid value {value!r}"
            )
