"""Unified run telemetry shared by both engines (see README.md here).

Typical wiring::

    from repro.telemetry import JsonlSink, MetricsRecorder

    rec = MetricsRecorder(
        sinks=[JsonlSink("run.jsonl")], metrics_every=5, record_spans=True
    )
    rec.manifest({"topology": topo.describe(), ...})
    sim = DecentralizedSimulator(..., telemetry=rec)

Then ``python -m repro.telemetry summarize run.jsonl``.
"""
from repro.telemetry.recorder import (
    MetricsRecorder, coalesce_into, host_grad_norm,
)
from repro.telemetry.schema import (
    KINDS, SCHEMA_VERSION, SchemaError, validate_record,
)
from repro.telemetry.sinks import JsonlSink, MemorySink, read_jsonl
from repro.telemetry.summarize import (
    diff_summaries, render_summary, summarize,
)

__all__ = [
    "MetricsRecorder",
    "JsonlSink",
    "MemorySink",
    "read_jsonl",
    "SCHEMA_VERSION",
    "SchemaError",
    "KINDS",
    "validate_record",
    "coalesce_into",
    "host_grad_norm",
    "summarize",
    "render_summary",
    "diff_summaries",
]
