"""``python -m repro.telemetry summarize|diff run.jsonl``."""
import sys

from repro.telemetry.summarize import main

if __name__ == "__main__":
    sys.exit(main())
