"""Telemetry sinks: where validated records go.

A sink is anything with ``emit(record: dict)`` (and optionally
``close()``).  The recorder validates every record against the schema
*before* fan-out, so sinks can assume well-formed input.
"""
from __future__ import annotations

import json
import os
from typing import Any

__all__ = ["JsonlSink", "MemorySink"]


class MemorySink:
    """In-memory sink for tests and the static-analysis smoke pass."""

    def __init__(self) -> None:
        self.records: list[dict] = []

    def emit(self, rec: dict) -> None:
        self.records.append(rec)

    def close(self) -> None:  # pragma: no cover - symmetry with JsonlSink
        pass


class JsonlSink:
    """One JSON object per line, flushed per record.

    ``append=True`` continues an existing file — the ``--resume`` pathway:
    the resumed segment re-emits its own manifest (``resumed: true``) so
    ``summarize`` can count run segments, while counters continue from the
    checkpointed totals (``MetricsRecorder.load_state_dict``).

    Per-record flush is deliberate: telemetry exists for runs that die —
    a crash must not lose the rounds that led up to it.  The cost is one
    small host write per record, far below the per-step device work.
    """

    def __init__(self, path: str, *, append: bool = False) -> None:
        self.path = os.fspath(path)
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        self._f: Any = open(self.path, "a" if append else "w")

    def emit(self, rec: dict) -> None:
        if self._f is None:
            raise ValueError(f"JsonlSink({self.path!r}) is closed")
        self._f.write(json.dumps(rec, separators=(",", ":")) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


def read_jsonl(path: str) -> list[dict]:
    """Load and schema-validate a JSONL telemetry stream."""
    from repro.telemetry.schema import SchemaError, validate_record

    records = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise SchemaError(f"{path}:{lineno}: not JSON: {e}") from e
            try:
                validate_record(rec)
            except SchemaError as e:
                raise SchemaError(f"{path}:{lineno}: {e}") from e
            records.append(rec)
    return records
