"""internvl2-2b — InternViT + InternLM2 backbone [arXiv:2404.16821].

The ViT/projector frontend is stubbed per the harness spec:
``input_specs()`` supplies 1024 precomputed patch embeddings at d_model.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv=8, d_ff=8192, vocab=92553,
    input_kind="vlm", n_patches=1024,
    source="arXiv:2404.16821",
)
