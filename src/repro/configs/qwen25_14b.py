"""qwen2.5-14b — GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B family].

H=40 does not divide the 16-way model axis: contraction-dim TP fallback.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv=8, d_ff=13824, vocab=152064,
    qkv_bias=True, rope_theta=1_000_000.0,
    pad_heads=True,  # §Perf H3: exact grouped head padding (16x attention win)
    source="hf:Qwen/Qwen2.5-0.5B",
)
