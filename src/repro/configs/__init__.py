"""Architecture config registry: ``get_config("<arch-id>")``."""
from repro.configs.base import SHAPES, ArchConfig, InputShape, input_specs

_MODULES = {
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "stablelm-12b": "stablelm_12b",
    "granite-8b": "granite_8b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "rwkv6-1.6b": "rwkv6_1b6",
    "musicgen-medium": "musicgen_medium",
    "zamba2-7b": "zamba2_7b",
    "starcoder2-7b": "starcoder2_7b",
    "internvl2-2b": "internvl2_2b",
    "qwen2.5-14b": "qwen25_14b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    import importlib

    reduced = name.endswith("-reduced")
    base = name[: -len("-reduced")] if reduced else name
    if base not in _MODULES:
        raise ValueError(f"unknown arch {name!r}; one of {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[base]}")
    cfg = mod.CONFIG
    return cfg.reduced() if reduced else cfg
