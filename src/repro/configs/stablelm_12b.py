"""stablelm-12b — dense GQA [hf:stabilityai/stablelm-2-1_6b family]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv=8, d_ff=13824, vocab=100352,
    norm="layernorm",
    source="hf:stabilityai/stablelm-2-1_6b",
)
