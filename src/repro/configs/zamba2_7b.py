"""zamba2-7b — Mamba2 blocks + shared attention block [arXiv:2411.15242].

81 blocks, every 6th is the (weight-shared) attention+MLP block:
13 groups of [5 mamba2 + shared attn] + 3 tail mamba2 blocks.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv=32, d_ff=14336, vocab=32000,
    ssm_state=64, attn_every=6,
    source="arXiv:2411.15242",
)
