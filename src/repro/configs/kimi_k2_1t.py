"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2].

Gossip placement is hierarchical (DESIGN.md §4): a replica needs a full
256-chip pod (FSDP x EP), so decentralization runs across pods only.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv=8, d_head=112,
    d_ff=2048, vocab=163840,
    n_experts=384, top_k=8, n_shared_experts=1,
    source="arXiv:2501.kimi2",
)
