"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284].

The EnCodec frontend is stubbed per the harness spec: the model consumes the
discrete audio-token stream directly (single-codebook stream modeled;
DESIGN.md §4).  H=24 does not divide the 16-way model axis: attention uses
the contraction-dim TP fallback.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv=24, d_ff=6144, vocab=2048,
    norm="layernorm", act="gelu",
    pad_heads=True,  # §Perf H3: exact grouped head padding (16x attention win)
    source="arXiv:2306.05284",
)
