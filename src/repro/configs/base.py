"""Architecture & input-shape configuration schema.

Every assigned architecture is an ``ArchConfig`` (one module per arch under
``repro/configs``); every benchmark input is an ``InputShape``.  The dry-run
crosses them.  ``reduced()`` yields the CPU smoke-test variant of the same
family (≤2 layers, d_model ≤ 512, ≤4 experts).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["ArchConfig", "InputShape", "SHAPES", "input_specs"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    n_heads: int = 0             # 0 for attention-free
    n_kv: int = 0
    d_head: int = 0              # 0 => d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    act: str = "silu"
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # SSM / hybrid
    ssm_state: int = 0
    attn_every: int = 0          # hybrid: shared attn block every N-th block
    # modality stubs
    input_kind: str = "tokens"   # tokens | vlm
    n_patches: int = 0
    # impl knobs
    attn_impl: str = "reference"     # reference | chunked | chunked_skip
    attn_chunk: int = 1024
    pad_heads: bool = False  # pad GQA groups so heads shard on the model axis
    #   (exact: padded heads are masked; see models/attention.head_padding)
    pad_kv: bool = False     # also pad kv heads to the model axis (shards KV caches)
    sliding_window: Optional[int] = None  # serving window for long_500k
    rec_chunk: int = 64          # recurrence chunk (ssm/hybrid)
    scan_layers: bool = True
    remat: bool = True
    remat_policy: str = "full"   # full | dots (jax.checkpoint_policies.dots_saveable)
    moe_shard_ff: bool = False   # shard expert d_ff over the data axis (2-level
    #   TP) instead of FSDP weight-gathering — kills per-layer expert gathers
    moe_buf_constraint: bool = False  # with_sharding_constraint the (E, C, D)
    #   dispatch buffer to P("model") — only valid on plain-jit (G=1) paths
    moe_impl: str = "gather"  # gather (GSPMD auto) | manual_ep (explicit
    #   shard_map EP: one psum/layer — §Perf H2/H4 follow-up; needs jax.set_mesh)
    dtype: Any = jnp.bfloat16
    # citation for the config numbers
    source: str = ""

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def reduced(self) -> "ArchConfig":
        """CPU smoke-test variant of the same family."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = min(self.n_kv, max(n_heads // 2, 1)) if self.n_kv else 0
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2) if not self.attn_every
            else min(self.n_layers, self.attn_every + 1),
            d_model=d_model,
            d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 512),
            n_heads=n_heads,
            n_kv=n_kv,
            d_head=(d_model // n_heads if n_heads else 0),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            n_patches=min(self.n_patches, 8) if self.n_patches else 0,
            rec_chunk=8,
            attn_chunk=64,
            dtype=jnp.float32,
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def input_specs(
    cfg: ArchConfig, shape: InputShape, *, n_nodes: int = 1
) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the step function's data inputs.

    Training batches carry the gossip-node axis (G, per_node_batch, S);
    serving batches are flat (B, ...).  Modality frontends are stubbed per
    the harness spec: VLM patch embeddings arrive precomputed.
    """
    i32 = jnp.int32
    if shape.kind == "train":
        if shape.global_batch % n_nodes:
            raise ValueError(
                f"{shape.name}: global_batch {shape.global_batch} not divisible "
                f"by {n_nodes} gossip nodes"
            )
        b = shape.global_batch // n_nodes
        s = shape.seq_len
        lead = (n_nodes, b) if n_nodes > 1 else (b,)
        specs = {
            "tokens": jax.ShapeDtypeStruct(lead + (s,), i32),
            "targets": jax.ShapeDtypeStruct(lead + (s,), i32),
        }
        if cfg.input_kind == "vlm":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                lead + (cfg.n_patches, cfg.d_model), cfg.dtype
            )
        return specs
    if shape.kind == "prefill":
        b = shape.global_batch
        specs = {"tokens": jax.ShapeDtypeStruct((b, shape.seq_len), i32)}
        if cfg.input_kind == "vlm":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), cfg.dtype
            )
        return specs
    # decode: one new token against a seq_len-deep cache/state
    b = shape.global_batch
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }
