"""starcoder2-7b — GQA kv=4, RoPE [arXiv:2402.19173].

H=36 does not divide the 16-way model axis: contraction-dim TP fallback.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv=4, d_ff=18432, vocab=49152,
    norm="layernorm", act="gelu",
    pad_heads=True,  # §Perf H3: exact grouped head padding (16x attention win)
    source="arXiv:2402.19173",
)
