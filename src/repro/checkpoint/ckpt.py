"""Checkpointing: flat-keyed npz snapshots of arbitrary pytrees.

Stores (params, opt_state, step, rng) with tree structure recovered from the
flattened key paths.  Host-side (fully addressable) arrays; for the
production mesh, the launcher gathers per-node shards before saving (the
decentralized state is the *stacked* (G, ...) tree, so one file captures
every replica).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

PyTree = Any

__all__ = [
    "save_checkpoint", "load_checkpoint", "load_checkpoint_extra",
    "latest_step", "validate_run_config",
]

_SEP = "/"
# sidecar npz key for the JSON "extra" payload (engine run state beyond the
# array tree: controller phase/rung/logs, membership tracking) — chosen so
# it can never collide with a flattened tree path (those never start with _)
_EXTRA_KEY = "__extra__"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_part(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _part(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return f"#{p.idx}"
    if isinstance(p, jax.tree_util.GetAttrKey):
        return f"@{p.name}"
    return str(p)


def save_checkpoint(
    directory: str, step: int, state: PyTree, *, keep: int = 3,
    extra: dict | None = None,
) -> str:
    """Write ``<dir>/step_<n>.npz`` (+ manifest); prune to ``keep`` newest.

    ``extra``: optional JSON-serializable dict rides in the same npz (one
    atomic artifact) under a reserved key — crash-consistent resume needs
    the engine run state (``snapshot_extra``) saved with the arrays it
    belongs to, never in a second file that could be torn from them.
    """
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"step_{step:010d}.npz")
    flat = _flatten(state)
    if _EXTRA_KEY in flat:
        raise ValueError(f"state tree uses the reserved key {_EXTRA_KEY!r}")
    if extra is not None:
        flat[_EXTRA_KEY] = np.asarray(json.dumps(extra))
    np.savez(path, **flat)
    with open(os.path.join(directory, "manifest.json"), "w") as f:
        json.dump({"latest_step": step}, f)
    ckpts = sorted(p for p in os.listdir(directory) if p.startswith("step_"))
    for old in ckpts[:-keep]:
        os.remove(os.path.join(directory, old))
    return path


def latest_step(directory: str) -> int | None:
    mf = os.path.join(directory, "manifest.json")
    if not os.path.exists(mf):
        return None
    with open(mf) as f:
        return json.load(f)["latest_step"]


def load_checkpoint(directory: str, template: PyTree, step: int | None = None) -> tuple[PyTree, int]:
    """Restore into the structure of ``template`` (shapes validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint manifest in {directory}")
    path = os.path.join(directory, f"step_{step:010d}.npz")
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in paths:
        key = _SEP.join(_part(x) for x in p)
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"checkpoint leaf {key}: shape {arr.shape} != template {np.shape(leaf)}"
            )
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def validate_run_config(
    recorded: dict, *, topology: str, bucket_mb: float | None,
    n: int | None = None, n_label: str = "node count",
) -> None:
    """Fail-fast resume: compare a checkpoint's recorded ``run_config``
    against the resuming run's configuration.

    A mismatched resume (different topology, bucket layout, or — for the
    fixed-mesh trainer — gossip size) would otherwise surface as an opaque
    leaf-shape or tree-structure error mid-restore, or worse, silently
    change the mixing semantics.  Raises a ``ValueError`` naming BOTH the
    checkpointed and the configured value.  Checkpoints written before
    ``run_config`` existed (empty dict) skip the check.
    """
    if not recorded:
        return
    ck_topo = recorded.get("topology")
    if ck_topo is not None and str(ck_topo) != str(topology):
        raise ValueError(
            f"resume config mismatch: checkpoint was written with topology "
            f"{ck_topo!r} but this run is configured with {topology!r}"
        )
    if "bucket_mb" in recorded:
        ck_mb = recorded["bucket_mb"]
        ours = None if bucket_mb is None else float(bucket_mb)
        if (ck_mb is None) != (ours is None) or (
            ck_mb is not None and float(ck_mb) != ours
        ):
            raise ValueError(
                f"resume config mismatch: checkpoint was written with "
                f"bucket_mb={ck_mb} but this run is configured with "
                f"bucket_mb={ours}"
            )
    ck_n = recorded.get("n")
    if n is not None and ck_n is not None and int(ck_n) != int(n):
        raise ValueError(
            f"resume config mismatch: checkpoint was written with "
            f"{n_label} {int(ck_n)} but this run is configured with {int(n)}"
        )


def load_checkpoint_extra(directory: str, step: int | None = None) -> dict | None:
    """The ``extra`` payload saved with a checkpoint (None if it has none)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint manifest in {directory}")
    data = np.load(os.path.join(directory, f"step_{step:010d}.npz"))
    if _EXTRA_KEY not in data:
        return None
    return json.loads(str(data[_EXTRA_KEY]))
