from repro.checkpoint.ckpt import (
    save_checkpoint, load_checkpoint, load_checkpoint_extra, latest_step,
    validate_run_config,
)
