"""Pass 2 — the collective-deadlock linter.

Collective-bearing executables deadlock in two ways this repo has hit:

  1. **Sequence divergence.**  Cross-device collectives rendezvous by
     (kind, source-target pairs, order).  Two realizations of the same
     step that can co-execute — the unmasked ``apply_shard`` program and
     its runtime-masked ``apply_shard_masked`` twin — MUST lower to the
     identical collective sequence: dropped edges still traverse the wire
     with weight zero.  If masking ever changed the permute schedule, one
     rank running masked against a rank running unmasked would wait at
     different rendezvous forever.
  2. **Unbounded dispatch.**  XLA:CPU matches cross-module collectives at
     a global rendezvous; queueing hundreds of collective-bearing bucket
     launches strands ranks there (root-caused at 551 in-flight buckets,
     see ``core/buckets.MAX_INFLIGHT_BUCKETS``).  Any loop dispatching
     per-bucket executables must bound its in-flight window.

Plus the repo-wide hot-path ban: colorable graphs must never lower to an
all-gather (the dense ``GatherRow`` fallback leaking back).

Checks, all built on ``launch/hlo_analysis``'s ``CollectiveReport``:

  * ``collective_signature`` — ordered (kind, source_target_pairs /
    replica_groups) sequence of an HLO module's collectives.
  * ``assert_signatures_consistent`` — equality across co-executable
    realizations, with the first diverging op spelled out.
  * ``lint_no_forbidden`` — the all-gather ban, offending op names named.
  * ``lint_dispatch_loops`` — AST lint of engine source: a loop
    dispatching bucket executables must reference
    ``MAX_INFLIGHT_BUCKETS`` or block on in-flight work inside the loop.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.report import CollectiveViolation, Finding
from repro.launch.hlo_analysis import (
    COLLECTIVE_KINDS,
    CollectiveReport,
    _hlo_text_of,
    collective_counts,
)

__all__ = [
    "collective_signature",
    "assert_signatures_consistent",
    "lint_no_forbidden",
    "lint_dispatch_loops",
    "lint_engine_sources",
]

_COLL_LINE_RE = re.compile(
    r"=\s*[^=]*?\b(" + "|".join(COLLECTIVE_KINDS) + r")(?:-start)?\("
)
# the pair/group lists nest braces ({{0,1},{1,0}}), so the match must run
# to the DOUBLE closing brace — [^}]* would truncate at the first pair
_PAIRS_RE = re.compile(r"source_target_pairs=\{\{.*?\}\}")
_GROUPS_RE = re.compile(
    r"replica_groups=(?:\{\{.*?\}\}|\{[^{}]*\}|\[[^\]]*\])"
)
_CHANNEL_RE = re.compile(r"channel_id=(\d+)")


def collective_signature(fn_or_hlo, *args) -> tuple[tuple[str, str], ...]:
    """Ordered (kind, rendezvous-attrs) sequence of a module's collectives.

    The rendezvous identity of each op is its kind plus its source-target
    pairs (permutes) or replica groups (reductions/gathers) as printed in
    the HLO text, in module order — exactly what two co-executing ranks
    must agree on.  Channel ids are intentionally EXCLUDED: they are
    assigned per-module and may differ between two separately-compiled
    realizations that still rendezvous correctly by structure.
    """
    text = _hlo_text_of(fn_or_hlo, *args)
    sig = []
    for line in text.splitlines():
        m = _COLL_LINE_RE.search(line)
        if m is None or "-done" in line.split("=", 1)[-1][:40]:
            continue
        kind = m.group(1)
        pm = _PAIRS_RE.search(line)
        gm = _GROUPS_RE.search(line)
        attrs = pm.group(0) if pm else (gm.group(0) if gm else "")
        sig.append((kind, attrs))
    return tuple(sig)


def assert_signatures_consistent(signatures: dict) -> None:
    """All labelled realizations must carry the identical collective
    sequence (kinds, order, rendezvous attrs)."""
    if len(signatures) < 2:
        return
    items = sorted(signatures.items())
    ref_label, ref = items[0]
    for label, sig in items[1:]:
        if sig == ref:
            continue
        detail = f"{len(ref)} vs {len(sig)} collectives"
        for i, (a, b) in enumerate(zip(ref, sig)):
            if a != b:
                detail = f"op {i}: {a} vs {b}"
                break
        raise CollectiveViolation(
            f"collective sequences diverge between co-executable "
            f"realizations {ref_label!r} and {label!r} ({detail}) — ranks "
            "selecting different realizations would rendezvous at "
            "different collectives and deadlock"
        )


def lint_no_forbidden(fn_or_hlo, *args, forbid=("all-gather",)) -> CollectiveReport:
    """The hot-path collective ban, with offending op names in the error."""
    report = collective_counts(fn_or_hlo, *args)
    bad = report.offending(forbid)
    if bad:
        raise CollectiveViolation(
            f"forbidden collective(s) on the hot path: "
            + ", ".join(f"{k} × {report[k]} (ops: {list(v)})" for k, v in bad.items())
            + " — the dense GatherRow fallback leaked back in"
        )
    return report


# -- dispatch-window lint ----------------------------------------------------

# Dispatch loops iterate per-bucket widths/work (``for b, w in
# enumerate(layout.widths)``); host-side slicing loops iterate ``segments``
# and launch nothing, so they are deliberately NOT matched.
_BUCKET_NAME = re.compile(r"width|bucket|inflight", re.IGNORECASE)


def _names_in(node) -> set[str]:
    out = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            out.add(sub.id)
        elif isinstance(sub, ast.Attribute):
            out.add(sub.attr)
    return out


def _is_bucket_loop(loop: ast.AST) -> bool:
    """A for/while loop that iterates bucket-shaped work and makes calls."""
    if isinstance(loop, ast.For):
        iter_names = _names_in(loop.iter)
    elif isinstance(loop, ast.While):
        iter_names = _names_in(loop.test)
    else:
        return False
    if not any(_BUCKET_NAME.search(n) for n in iter_names):
        return False
    return any(
        isinstance(sub, ast.Call)
        for stmt in loop.body
        for sub in ast.walk(stmt)
    )


def _loop_is_bounded(loop: ast.AST) -> bool:
    names = set()
    for stmt in loop.body:
        names |= _names_in(stmt)
    return "MAX_INFLIGHT_BUCKETS" in names or "block_until_ready" in names


def lint_dispatch_loops(source: str, path: str = "<string>") -> list[Finding]:
    """Flag loops that can queue unbounded collective-bearing dispatches.

    Rule: any loop iterating per-bucket/per-segment work that makes calls
    must, inside the loop body, either consult ``MAX_INFLIGHT_BUCKETS`` or
    block on in-flight work (``block_until_ready``) — otherwise every
    iteration enqueues another collective-bearing launch and fine bucket
    sizes strand the XLA:CPU rendezvous (551-bucket incident, PR 7).
    """
    findings = []
    tree = ast.parse(source, filename=path)
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for loop in ast.walk(fn):
            if not _is_bucket_loop(loop):
                continue
            if not _loop_is_bounded(loop):
                findings.append(
                    Finding(
                        "collectives",
                        f"{path}:{loop.lineno} ({fn.name})",
                        "per-bucket dispatch loop has no in-flight bound: "
                        "neither MAX_INFLIGHT_BUCKETS nor block_until_ready "
                        "appears in the loop body — can exceed "
                        "MAX_INFLIGHT_BUCKETS collective launches in flight",
                    )
                )
    return findings


def lint_engine_sources(paths=None) -> list[Finding]:
    """Run the dispatch-window lint over the engines' dispatch modules."""
    import os

    if paths is None:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        paths = [
            os.path.join(root, "core", "simulator.py"),
            os.path.join(root, "core", "buckets.py"),
            os.path.join(root, "launch", "train.py"),
            os.path.join(root, "kernels", "gossip_update.py"),
        ]
    findings = []
    for path in paths:
        with open(path) as f:
            findings.extend(lint_dispatch_loops(f.read(), path))
    return findings
