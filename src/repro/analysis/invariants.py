"""Pass 1 — the mixing-program verifier.

Every correctness argument in this repo ultimately rests on properties of
the realized mixing matrix W: consensus-control theory (arXiv:2102.04828)
and the partial-participation analysis (arXiv:2506.00961) assume W is
doubly stochastic under EVERY fault/membership realization, the Pallas
kernel consumes per-node permute tables that must reconstruct W exactly,
and the bucketed dispatcher assumes its layout covers every parameter
byte exactly once.  This pass checks all of that statically — on the IR,
before any step runs:

  * ``verify_program``  — per-round permute bijectivity, non-negative
    weights, row/column stochasticity to tolerance, symmetry preservation
    (recorded from the base W, never assumed: ``d_exponential`` is doubly
    stochastic but directed), ``permute_tables`` ↔ ``matrix()`` agreement,
    and ``FusedProgram`` round-count conservation (``ops`` concat, matrix
    = stage product).
  * ``verify_degraded`` — ``degraded_matrix`` realizations: still row
    stochastic, symmetric bases stay symmetric (⇒ doubly stochastic),
    dead/ghost/spare ranks collapse to an EXACT identity row and column,
    and ``GossipProgram.degrade`` agrees with the dense oracle.
  * ``verify_bucket_layout`` — bounds partition [0, P), widths sum to P,
    and the segment table covers every leaf element exactly once (no
    parameter byte dropped or double-covered).
  * ``verify_topology``  — drives the above over everything a
    ``Topology`` can emit: ``distinct_programs`` (controller rungs ×
    degraded folds × elastic sizes) × sampled fault realizations.
  * ``verify_bench_payload`` — structural gate ``benchmarks.common.
    save_bench_section`` runs before touching the committed artifact.

All checks raise ``InvariantViolation`` (an ``AssertionError``) with the
offending entry spelled out.
"""
from __future__ import annotations

import json
import re

import numpy as np

from repro.analysis.report import InvariantViolation

__all__ = [
    "verify_program",
    "verify_degraded",
    "verify_bucket_layout",
    "verify_topology",
    "verify_bench_payload",
]

_TOL = 1e-8


def _fail(subject, message):
    raise InvariantViolation(f"{subject}: {message}")


def _is_symmetric(w, tol=1e-9) -> bool:
    return bool(np.allclose(w, w.T, atol=tol))


def _check_stochastic(w, subject, *, tol, require_cols=True):
    """Row stochasticity always; column stochasticity when required.

    Every *base* program family shipped here is doubly stochastic (the
    directed ``d_exponential`` included), but a degraded realization of an
    asymmetric W is only row stochastic — the dropped edge's mass moves to
    the RECEIVER's diagonal, which lives in a different column — so
    callers relax the column check for ``!dead[...]`` variants.
    """
    if not np.all(np.isfinite(w)):
        _fail(subject, "non-finite entries in mixing matrix")
    if np.min(w) < -tol:
        i, j = np.unravel_index(int(np.argmin(w)), w.shape)
        _fail(subject, f"negative weight W[{i},{j}] = {w[i, j]:.3e}")
    rows = w.sum(axis=1)
    if not np.allclose(rows, 1.0, atol=tol):
        i = int(np.argmax(np.abs(rows - 1.0)))
        _fail(subject, f"row {i} sums to {rows[i]:.12f}, not 1 (±{tol})")
    if require_cols:
        cols = w.sum(axis=0)
        if not np.allclose(cols, 1.0, atol=tol):
            j = int(np.argmax(np.abs(cols - 1.0)))
            _fail(
                subject,
                f"column {j} sums to {cols[j]:.12f}, not 1 — W is not doubly "
                "stochastic, mixing would not preserve the mean",
            )


def _check_ppermute(op, n, subject):
    """One permute round must be a partial bijection with nonneg weights."""
    srcs = [s for s, _ in op.perm]
    dsts = [d for _, d in op.perm]
    if len(set(srcs)) != len(srcs):
        _fail(subject, f"duplicate source in permute round: {sorted(srcs)}")
    if len(set(dsts)) != len(dsts):
        _fail(
            subject,
            f"duplicate destination in permute round: {sorted(dsts)} — two "
            "sends collide at one receiver (not a collective-permute)",
        )
    for s, d in op.perm:
        if not (0 <= s < n and 0 <= d < n):
            _fail(subject, f"permute pair ({s}, {d}) out of range for n={n}")
    wv = op.weight if isinstance(op.weight, tuple) else (float(op.weight),)
    if any(w < -_TOL for w in wv):
        _fail(subject, f"negative permute weight {min(wv)}")
    if op.offset is not None:
        want = tuple(((i + op.offset) % n, i) for i in range(n))
        if tuple(sorted(op.perm)) != tuple(sorted(want)):
            _fail(
                subject,
                f"offset={op.offset} does not match the perm pairs — the "
                "stacked roll and the shard ppermute would disagree",
            )


def _check_tables(program, w, subject, tol):
    """``permute_tables`` must reconstruct ``matrix()`` exactly: these are
    the rows the fused Pallas kernel consumes."""
    tables = program.permute_tables()
    if tables is None:
        return
    srcs, weights = tables
    n = program.n
    deg = srcs.shape[1] if srcs.ndim == 2 else 0
    if srcs.shape != (n, deg) or weights.shape != (n, deg + 1):
        _fail(
            subject,
            f"table shapes srcs{srcs.shape} / weights{weights.shape} != "
            f"(({n},{deg}), ({n},{deg + 1}))",
        )
    if srcs.size and (srcs.min() < 0 or srcs.max() >= n):
        _fail(subject, f"src index out of range in permute tables (n={n})")
    if weights.size and weights.min() < -tol:
        _fail(subject, f"negative weight in kernel table: {weights.min():.3e}")
    rows = weights.sum(axis=1)
    if not np.allclose(rows, 1.0, atol=1e-5):  # float32 tables
        i = int(np.argmax(np.abs(rows - 1.0)))
        _fail(subject, f"kernel weight row {i} sums to {rows[i]:.7f}, not 1")
    rec = np.diag(weights[:, 0].astype(np.float64))
    for k in range(deg):
        for i in range(n):
            rec[i, srcs[i, k]] += float(weights[i, k + 1])
    if not np.allclose(rec, w, atol=1e-5):
        d = np.abs(rec - w)
        i, j = np.unravel_index(int(np.argmax(d)), d.shape)
        _fail(
            subject,
            f"permute tables reconstruct W[{i},{j}] = {rec[i, j]:.7f} but "
            f"matrix() says {w[i, j]:.7f} — kernel and interpreter disagree",
        )


def verify_program(program, *, tol: float = _TOL) -> np.ndarray:
    """Statically verify one mixing program; returns its matrix W."""
    from repro.core.schedule import FusedProgram, GatherRow, PPermute

    subject = f"program {program.name!r} (n={program.n})"
    n = program.n
    # degraded variants of an asymmetric base are row- but not
    # column-stochastic (mass moves to the receiver's diagonal)
    degraded = "!dead[" in program.name
    if isinstance(program, FusedProgram):
        concat = tuple(op for p in program.stages for op in p.ops)
        if program.ops != concat:
            _fail(
                subject,
                f"round-count conservation broken: fused ops ({len(program.ops)})"
                f" != concatenated stage ops ({len(concat)}) — collective "
                "counts and comm billing would drift from what executes",
            )
        prod = np.eye(n)
        for p in program.stages:
            verify_program(p, tol=tol)
            prod = p.matrix() @ prod
        w = program.matrix()
        if not np.allclose(w, prod, atol=1e-10):
            _fail(subject, "fused matrix() != product of stage matrices")
        _check_stochastic(w, subject, tol=tol, require_cols=not degraded)
        return w

    w = program.matrix()
    sw = program.self_weight
    sw_t = sw if isinstance(sw, tuple) else (float(sw),)
    if any(v < -tol for v in sw_t):
        _fail(subject, f"negative self weight {min(sw_t)}")
    for k, op in enumerate(program.ops):
        if isinstance(op, PPermute):
            _check_ppermute(op, n, f"{subject} op[{k}]")
        elif isinstance(op, GatherRow):
            gw = np.asarray(op.w, dtype=np.float64)
            if gw.shape != (n, n):
                _fail(subject, f"GatherRow matrix shape {gw.shape} != ({n},{n})")
    _check_stochastic(
        w, subject, tol=tol, require_cols=_is_symmetric(w) or not degraded
    )
    _check_tables(program, w, subject, tol)
    return w


def verify_degraded(program, alive, link_up=None, *, tol: float = _TOL) -> None:
    """Verify one fault/membership realization of ``program``.

    ``alive`` may be bool (crash/ghost masks) or float (drain boosts —
    non-negativity is only required for boolean masks, per the documented
    drain bound).  Checks the dense oracle ``degraded_matrix`` AND, for
    boolean masks without link faults, that the pre-enumerated
    ``GossipProgram.degrade`` program realizes exactly the same matrix.
    """
    from repro.core.schedule import degraded_matrix

    w = program.matrix()
    n = program.n
    alive = np.asarray(alive, dtype=np.float64).reshape(-1)
    if alive.shape[0] != n:
        _fail(f"program {program.name!r}", f"alive mask len {alive.shape[0]} != n={n}")
    subject = (
        f"program {program.name!r} degraded "
        f"(dead={[int(i) for i in np.where(alive == 0)[0]]}"
        f"{', link faults' if link_up is not None else ''})"
    )
    d = degraded_matrix(w, alive, link_up)
    boolean = bool(np.all((alive == 0) | (alive == 1)))

    rows = d.sum(axis=1)
    if not np.allclose(rows, 1.0, atol=tol):
        i = int(np.argmax(np.abs(rows - 1.0)))
        _fail(subject, f"row {i} sums to {rows[i]:.12f}, not 1")
    if boolean and np.min(d) < -tol:
        i, j = np.unravel_index(int(np.argmin(d)), d.shape)
        _fail(subject, f"negative weight W'[{i},{j}] = {d[i, j]:.3e}")
    sym_link = link_up is None or np.allclose(
        np.asarray(link_up, dtype=np.float64),
        np.asarray(link_up, dtype=np.float64).T,
        atol=tol,
    )
    if _is_symmetric(w) and sym_link and not _is_symmetric(d, tol):
        _fail(
            subject,
            "symmetric base W degraded to an ASYMMETRIC matrix — doubly "
            "stochastic mixing is lost under this realization",
        )

    # dead / ghost / spare ranks: exact identity row AND column, so a
    # masked-out rank's parameters are bit-untouched and leak nothing.
    for i in np.where(alive == 0)[0]:
        ei = np.zeros(n)
        ei[i] = 1.0
        if not (np.array_equal(d[i], ei) and np.array_equal(d[:, i], ei)):
            _fail(
                subject,
                f"dead rank {i} row/col is not EXACT identity "
                f"(row error {np.abs(d[i] - ei).max():.3e}, "
                f"col error {np.abs(d[:, i] - ei).max():.3e})",
            )

    if boolean and link_up is None:
        dp = program.degrade(tuple(bool(a) for a in alive))
        if not np.allclose(dp.matrix(), d, atol=1e-9):
            _fail(
                subject,
                "GossipProgram.degrade does not realize degraded_matrix — "
                "the pre-enumerated crash program diverges from the oracle",
            )


def verify_bucket_layout(layout, sizes=None) -> None:
    """Exact-coverage check of a ``BucketLayout`` segment table."""
    sizes = tuple(layout.sizes if sizes is None else sizes)
    p = sum(sizes)
    subject = f"BucketLayout(P={p}, target={layout.bucket_elems})"
    b = layout.bounds
    if b[0] != 0 or b[-1] != p:
        _fail(subject, f"bounds {b[:3]}..{b[-3:]} do not span [0, {p}]")
    if any(b[i + 1] <= b[i] for i in range(len(b) - 1)) and p > 0:
        _fail(subject, f"bounds not strictly increasing: {b}")
    widths = layout.widths
    if sum(widths) != p:
        _fail(subject, f"widths sum {sum(widths)} != P={p} — bytes dropped")
    if len(widths) != layout.num_buckets:
        _fail(subject, f"{len(widths)} widths but num_buckets={layout.num_buckets}")
    segments = layout.segments
    if len(segments) != len(widths):
        _fail(subject, f"{len(segments)} segment rows for {len(widths)} buckets")
    covered = [[] for _ in sizes]
    for k, segs in enumerate(segments):
        seg_total = 0
        for li, start, stop in segs:
            if not (0 <= li < len(sizes)):
                _fail(subject, f"bucket {k} references leaf {li} (have {len(sizes)})")
            if not (0 <= start < stop <= sizes[li]):
                _fail(
                    subject,
                    f"bucket {k} slice leaf[{li}][{start}:{stop}] escapes "
                    f"the leaf (size {sizes[li]})",
                )
            seg_total += stop - start
            covered[li].append((start, stop))
        if seg_total != widths[k]:
            _fail(
                subject,
                f"bucket {k} segments cover {seg_total} elements but its "
                f"width is {widths[k]} — dropped or double-covered bytes",
            )
    for li, ivals in enumerate(covered):
        ivals.sort()
        pos = 0
        for start, stop in ivals:
            if start < pos:
                _fail(
                    subject,
                    f"leaf {li} element {start} double-covered "
                    f"(overlapping segments {ivals})",
                )
            if start > pos:
                _fail(subject, f"leaf {li} elements [{pos}:{start}] uncovered")
            pos = stop
        if pos != sizes[li]:
            _fail(subject, f"leaf {li} tail [{pos}:{sizes[li]}] uncovered")


def _realization_masks(model, steps):
    """Distinct (alive, link_up) realizations of ``model`` over ``steps``
    steps, each tagged with the membership size it applies at."""
    seen = set()
    out = []
    for t in range(steps):
        fr = model.at(t)
        alive = np.asarray(fr.alive, dtype=np.float64)
        link = None if fr.link_up is None else np.asarray(fr.link_up)
        key = (alive.tobytes(), None if link is None else link.tobytes())
        if key in seen:
            continue
        seen.add(key)
        out.append((alive, link))
    return out


def verify_topology(topology, *, n_epochs: int = 1, fault_steps: int = 0,
                    tol: float = _TOL) -> int:
    """Verify every program ``topology`` can emit; returns programs checked.

    Covers ``distinct_programs`` (controller rungs × permanent-crash folds
    × elastic sizes) and, when ``fault_steps`` > 0 and the topology carries
    a fault model, every distinct runtime (alive, link) realization the
    model produces over that horizon, applied to every program of the
    matching size.
    """
    if topology.centralized:
        return 0
    programs = [p for _, p in topology.distinct_programs(n_epochs)]
    for p in programs:
        verify_program(p, tol=tol)
    model = topology.fault_model
    if model is not None and fault_steps > 0:
        for alive, link in _realization_masks(model, fault_steps):
            for p in programs:
                if p.n != alive.shape[0]:
                    continue  # elastic fold of a different membership size
                verify_degraded(p, alive, link, tol=tol)
    return len(programs)


_BENCH_KEY_RE = re.compile(r"^[\w.+\-/]+$")


def verify_bench_payload(section: str, payload) -> None:
    """Structural gate for ``save_bench_section``: the committed artifact
    merges per key, so a malformed payload (non-dict entries, unkeyable
    names, non-JSON values) would corrupt the cross-PR perf trajectory
    silently.  The full per-section layout stays pinned by
    ``tests/test_bench_schema.py``; this catches shape corruption before
    it is written.
    """
    subject = f"bench section {section!r}"
    if not isinstance(section, str) or not _BENCH_KEY_RE.match(section or ""):
        _fail(subject, "section name must be a non-empty [\\w.+-/] string")
    if not isinstance(payload, dict) or not payload:
        _fail(subject, f"payload must be a non-empty dict, got {type(payload).__name__}")
    for key, entry in payload.items():
        if not isinstance(key, str) or not _BENCH_KEY_RE.match(key):
            _fail(subject, f"entry key {key!r} is not a [\\w.+-/] string")
        if not isinstance(entry, dict):
            _fail(
                subject,
                f"entry {key!r} is {type(entry).__name__}, not a dict — "
                "the per-key merge would clobber structure",
            )
        try:
            json.dumps(entry, allow_nan=False)
        except (TypeError, ValueError) as e:
            _fail(subject, f"entry {key!r} is not JSON-serializable: {e}")
        prov = entry.get("provenance")
        if prov is not None:
            _verify_bench_provenance(subject, key, prov)


def _verify_bench_provenance(subject: str, key: str, prov) -> None:
    """``provenance`` entries come from ``MetricsRecorder.provenance()``
    via ``save_bench_section(..., telemetry=)`` — pin their shape so a
    half-initialized recorder can't stamp garbage into the committed
    trajectory."""
    if not isinstance(prov, dict):
        _fail(subject, f"entry {key!r} provenance must be a dict")
    if prov.get("source") != "telemetry":
        _fail(subject, f"entry {key!r} provenance source must be 'telemetry'")
    if not isinstance(prov.get("schema"), int):
        _fail(subject, f"entry {key!r} provenance schema must be an int")
    counters = prov.get("counters")
    if not isinstance(counters, dict) or not all(
        isinstance(k, str) and isinstance(v, (int, float))
        and not isinstance(v, bool)
        for k, v in counters.items()
    ):
        _fail(
            subject,
            f"entry {key!r} provenance counters must map str -> number",
        )
    for field in ("rounds", "events"):
        if not isinstance(prov.get(field), int):
            _fail(subject, f"entry {key!r} provenance {field} must be an int")
