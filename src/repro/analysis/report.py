"""Shared result types for the static-analysis pass pipeline.

Every pass has two consumption modes:

  * **assertion mode** (tests, engine debug hooks): the ``verify_*`` /
    ``assert_*`` / ``check_*`` entry points raise a subclass of
    ``AnalysisViolation`` — itself an ``AssertionError``, so existing
    ``pytest.raises(AssertionError)`` call sites keep working — on the
    first violation.
  * **report mode** (the ``python -m repro.analysis`` CLI): ``run_pass``
    wraps any number of checks, converts violations into ``Finding``s and
    returns a ``PassReport`` so one broken invariant doesn't hide the rest.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

__all__ = [
    "AnalysisViolation",
    "InvariantViolation",
    "CollectiveViolation",
    "RetraceError",
    "BudgetViolation",
    "Finding",
    "PassReport",
    "run_pass",
]


class AnalysisViolation(AssertionError):
    """Base class for every failure a static-analysis pass can raise."""


class InvariantViolation(AnalysisViolation):
    """Mixing-program / bucket-layout invariant broken (``invariants``)."""


class CollectiveViolation(AnalysisViolation):
    """Collective sequence inconsistency or forbidden op (``collectives``)."""


class RetraceError(AnalysisViolation):
    """A jit trace/compile fired where none was allowed (``recompile``)."""


class BudgetViolation(AnalysisViolation):
    """Kernel SMEM/VMEM layout exceeds its documented budget (``budget``)."""


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation, attributed to the pass and the object it checked."""

    pass_name: str
    subject: str
    message: str

    def __str__(self):
        return f"[{self.pass_name}] {self.subject}: {self.message}"


@dataclasses.dataclass
class PassReport:
    """Outcome of one pass over a batch of subjects."""

    name: str
    checked: int = 0
    findings: list[Finding] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings

    def add(self, subject: str, message: str) -> None:
        self.findings.append(Finding(self.name, subject, message))

    def merge(self, other: "PassReport") -> None:
        self.checked += other.checked
        self.findings.extend(other.findings)

    def raise_if_failed(self) -> None:
        if self.findings:
            raise AnalysisViolation(
                f"pass {self.name!r}: {len(self.findings)} violation(s)\n"
                + "\n".join(f"  {f}" for f in self.findings)
            )

    def summary(self) -> str:
        status = "ok" if self.ok else f"FAIL ({len(self.findings)})"
        return f"{self.name}: {self.checked} checked, {status}"


def run_pass(
    name: str, subjects: Iterable[tuple[str, Callable[[], object]]]
) -> PassReport:
    """Run ``(label, thunk)`` checks, collecting violations per subject."""
    report = PassReport(name)
    for label, thunk in subjects:
        report.checked += 1
        try:
            thunk()
        except AnalysisViolation as e:
            report.add(label, str(e))
    return report
