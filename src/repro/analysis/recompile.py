"""Pass 3 — the recompile sanitizer.

The zero-mid-run-recompile invariant is the engines' core perf contract:
time-varying topologies rotate through the pre-enumerated
``Topology.distinct_programs`` set, fault masks are runtime operands, so
after warm-up NO training step may trace or compile anything new.  Until
now every test asserted this by hand-counting ``_step_cache`` entries or
diffing executable counts against a fault-free run.  This module replaces
those with two reusable primitives:

``assert_no_retrace`` / ``watch_retrace``
    A context manager hooking jax's monitoring events
    (``jaxpr_trace_duration`` / ``backend_compile_duration`` — the
    counters ``jax.jit`` emits on every trace and XLA compile).  One
    module-level listener is registered lazily and feeds a stack of
    active frames, because jax 0.4.37 has no public unregister.  Works
    for ANY jit — including the engines' internal executables that never
    appear under a program key.

``assert_executables_preenumerated``
    The executable-set half of the invariant: every program-keyed
    executable an engine compiled must belong to the statically
    enumerable set (``Topology.distinct_programs`` for the simulator,
    ``SPMDTrainer.precompile_programs`` for the SPMD engine).  Knows both
    engines' cache-key layouts (bare ``cache_key``, ``(key, "faulty")``,
    ``("__bucket__", key, ...)``, ``__``-prefixed internals).

The simulator exposes the same guard at runtime as
``DecentralizedSimulator(..., debug_no_retrace=True)``: once a step's
executable is warm, re-invoking it under a trace event raises.
"""
from __future__ import annotations

import contextlib
import dataclasses

from repro.analysis.report import RetraceError

__all__ = [
    "RetraceStats",
    "watch_retrace",
    "assert_no_retrace",
    "used_program_keys",
    "allowed_program_keys",
    "assert_executables_preenumerated",
]

try:  # pragma: no cover - exercised on every jax version in CI
    from jax._src.dispatch import BACKEND_COMPILE_EVENT, JAXPR_TRACE_EVENT
except ImportError:  # pragma: no cover - jax moved the constants
    JAXPR_TRACE_EVENT = "/jax/core/compile/jaxpr_trace_duration"
    BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

# jax 0.4.37 has no public listener unregister, so exactly one listener is
# registered for the process lifetime; frames opt in/out via this stack.
_frames: list["RetraceStats"] = []
_registered = False


@dataclasses.dataclass
class RetraceStats:
    """Counts observed while a ``watch_retrace`` frame was active."""

    label: str = ""
    traces: int = 0
    compiles: int = 0

    @property
    def clean(self) -> bool:
        return self.traces == 0 and self.compiles == 0


def _listener(event, duration, **kwargs):
    if not _frames:
        return
    if event == JAXPR_TRACE_EVENT:
        for f in _frames:
            f.traces += 1
    elif event == BACKEND_COMPILE_EVENT:
        for f in _frames:
            f.compiles += 1


def _ensure_listener() -> None:
    global _registered
    if _registered:
        return
    import jax.monitoring

    jax.monitoring.register_event_duration_secs_listener(_listener)
    _registered = True


@contextlib.contextmanager
def watch_retrace(label: str = ""):
    """Count jit traces / XLA compiles inside the ``with`` body."""
    _ensure_listener()
    stats = RetraceStats(label)
    _frames.append(stats)
    try:
        yield stats
    finally:
        _frames.remove(stats)


@contextlib.contextmanager
def assert_no_retrace(label: str = "", *, allow_traces: int = 0,
                      allow_compiles: int | None = None):
    """Raise ``RetraceError`` if the body traced/compiled beyond allowance.

    Steady-state training sections must run at 0/0 (the default).  Warm-up
    phases that legitimately compile at first use (one executable per
    distinct program) should either run OUTSIDE the context or pass an
    explicit allowance.
    """
    cap_c = allow_traces if allow_compiles is None else allow_compiles
    with watch_retrace(label) as stats:
        yield stats
    if stats.traces > allow_traces or stats.compiles > cap_c:
        who = f" in {label!r}" if label else ""
        raise RetraceError(
            f"mid-run recompile{who}: {stats.traces} trace(s) / "
            f"{stats.compiles} compile(s) observed "
            f"(allowed {allow_traces}/{cap_c}) — a step executable was not "
            "pre-enumerated or a static argument changed between steps"
        )


def used_program_keys(step_cache) -> set:
    """Program cache keys behind an engine ``_step_cache``'s entries.

    Strips the engines' wrappers — ``(key, "faulty")`` fault signatures,
    ``("__bucket__", key, width, has_m, faulty)`` bucket executables — and
    drops ``__``-prefixed internal executables (grads, split/merge,
    centralized/local closures) plus the SPMD trainer's ``None``
    programless key.
    """
    used = set()
    for k in step_cache:
        if k is None or isinstance(k, str):
            continue
        if isinstance(k, tuple) and len(k) == 2 and k[1] == "faulty":
            k = k[0]
            if k is None:
                continue
        if isinstance(k, tuple) and k and k[0] == "__bucket__":
            k = k[1]
        if isinstance(k, tuple) and k and isinstance(k[0], str) \
                and k[0].startswith("__"):
            continue
        used.add(k)
    return used


def allowed_program_keys(engine, n_epochs: int = 1) -> set:
    """The statically enumerable program-key set for either engine."""
    if hasattr(engine, "precompile_programs"):  # SPMDTrainer
        return {p.cache_key for p in engine.precompile_programs(n_epochs)}
    return {
        p.cache_key for _, p in engine.topology.distinct_programs(n_epochs)
    }


def assert_executables_preenumerated(engine, *, n_epochs: int = 1,
                                     require_used: bool = True) -> set:
    """Every program-keyed executable must come from the enumerable set.

    Returns the used program-key set for further assertions (e.g. exact
    counts).  ``require_used`` guards against the assertion passing
    vacuously because the run never reached a program-keyed step.
    """
    allowed = allowed_program_keys(engine, n_epochs)
    used = used_program_keys(engine._step_cache)
    if require_used and not used:
        raise RetraceError(
            "no program-keyed executables were compiled at all — the run "
            "never exercised a mixing step (vacuous invariant)"
        )
    stray = used - allowed
    if stray:
        raise RetraceError(
            f"{len(stray)} executable(s) beyond the pre-enumerated program "
            f"set: {sorted(map(str, stray))[:4]} — a program was built "
            "mid-run that Topology.distinct_programs cannot see"
        )
    return used
