"""Static analysis over the gossip stack: program verifier, collective
linter, recompile sanitizer, kernel budget checker.

Run the whole pipeline with ``python -m repro.analysis --all`` (see
``__main__.py`` and ``analysis/README.md``).  Submodules are imported
lazily so that ``kernels/gossip_update.py`` can pull ``analysis.budget``
without dragging jax-heavy passes in:

  * ``analysis.invariants``  — mixing-program/IR invariants (pass 1)
  * ``analysis.collectives`` — HLO collective-deadlock linter (pass 2)
  * ``analysis.recompile``   — retrace/compile sanitizer (pass 3)
  * ``analysis.budget``      — Pallas kernel budget checker (pass 4)

Only the shared report vocabulary is eager.
"""
from repro.analysis.report import (
    AnalysisViolation,
    BudgetViolation,
    CollectiveViolation,
    Finding,
    InvariantViolation,
    PassReport,
    RetraceError,
    run_pass,
)

__all__ = [
    "AnalysisViolation",
    "InvariantViolation",
    "CollectiveViolation",
    "RetraceError",
    "BudgetViolation",
    "Finding",
    "PassReport",
    "run_pass",
]
