"""``python -m repro.analysis`` — the static-analysis pipeline CLI.

Runs the five passes over every program the benchmarked topology matrix
can emit (ring / star / one-peer-exp / random-matching × fault-free,
transient, permanent-crash, preemption, deadline, join and spare-rank
realizations):

  --invariants   mixing-program IR verifier (stochasticity, bijective
                 permute tables, ghost-rank identity, fusion round
                 conservation, bucket-layout coverage)
  --collectives  HLO collective-deadlock linter (signature consistency
                 across co-executable realizations, all-gather ban,
                 dispatch-window AST lint of the engine sources)
  --recompile    zero-mid-run-recompile sanitizer (live engine run under
                 ``assert_no_retrace`` after warm-up + executable-set
                 pre-enumeration)
  --budget       Pallas kernel SMEM/VMEM budget checker
  --telemetry    telemetry-schema pass: a 2-node smoke run streams every
                 record kind through the schema validator, the rendered
                 summary is checked, and the telemetry-on executable set
                 must equal the telemetry-off one (the recorder is
                 provably free)

``--all`` (the CI entry point) runs everything.  Exit status 1 when any
pass reports findings.
"""
from __future__ import annotations

import argparse
import os
import sys


def _setup_env() -> None:
    """Host-device + platform env, BEFORE jax is imported anywhere."""
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8"
        ).strip()


N = 8
TOPOS = ("d_ring", "d_star", "d_one_peer_exp", "d_random_matching")


def _fault_variants():
    """(label, builder) for every fault realization family at n=N.

    Builders (not instances) so each subject constructs its own seeded
    model — ``verify_topology`` mutates nothing, but crash models fold
    into ``distinct_programs`` and must not leak between topologies.
    """
    from repro.core.faults import make_fault_model as mk

    return [
        ("fault-free", lambda: None),
        ("dropout", lambda: mk("dropout", N, rate=0.3, seed=3)),
        ("link", lambda: mk("link", N, rate=0.3, seed=4)),
        ("crash", lambda: mk("crash", N, rate=0.5, seed=1, down_steps=6)),
        ("concurrent", lambda: mk("concurrent", N, rate=0.7, seed=1, k=2)),
        ("preempt", lambda: mk("preempt", N, rate=0.6, seed=2, drain_steps=3)),
        ("deadline", lambda: mk("deadline", N, rate=0.4, seed=5)),
        ("join", lambda: mk("join", N, join_steps=(4,))),
        ("spares", lambda: mk("dropout", N, rate=0.3, seed=6, spare_ranks=2)),
    ]


def run_invariants():
    from repro.analysis.invariants import verify_bucket_layout, verify_topology
    from repro.analysis.report import run_pass
    from repro.core.buckets import BucketLayout
    from repro.core.dsgd import make_topology

    subjects = []
    for topo_name in TOPOS:
        for fault_label, build in _fault_variants():
            def thunk(topo_name=topo_name, build=build):
                topo = make_topology(topo_name, N, fault_model=build())
                verify_topology(topo, n_epochs=2, fault_steps=24)

            subjects.append((f"{topo_name} × {fault_label}", thunk))
    # representative bucket layouts: multi-leaf, leaf-straddling, exact-fit,
    # single-bucket and empty-tree edges
    for label, sizes, elems in [
        ("layout multi-leaf", (3072, 1024, 7), 512),
        ("layout straddle", (1000, 24, 1000), 256),
        ("layout exact", (512, 512), 512),
        ("layout single", (5,), 1 << 20),
        ("layout empty", (), 512),
    ]:
        subjects.append(
            (label, lambda s=sizes, e=elems: verify_bucket_layout(
                BucketLayout(s, e), sizes=s))
        )
    return run_pass("invariants", subjects)


def run_collectives():
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.analysis.collectives import (
        assert_signatures_consistent,
        collective_signature,
        lint_engine_sources,
        lint_no_forbidden,
    )
    from repro.analysis.report import run_pass
    from repro.core.dsgd import make_topology

    mesh = compat.make_mesh((N,), ("gossip",))
    x = np.arange(N * 4, dtype=np.float32).reshape(N, 4)
    alive = np.ones((N,), np.float32)

    subjects = []
    seen = set()
    for topo_name in TOPOS:
        topo = make_topology(topo_name, N)
        for _, prog in topo.distinct_programs(2):
            if prog.cache_key in seen:
                continue
            seen.add(prog.cache_key)

            def thunk(prog=prog):
                jb = jax.jit(compat.shard_map(
                    lambda v: prog.apply_shard(v, "gossip"),
                    mesh=mesh, in_specs=P("gossip"), out_specs=P("gossip"),
                ))
                jm = jax.jit(compat.shard_map(
                    lambda v, a: prog.apply_shard_masked(v, "gossip", a),
                    mesh=mesh, in_specs=(P("gossip"), P()),
                    out_specs=P("gossip"),
                ))
                if prog.permute_tables() is not None:
                    # colorable programs: masking must not change the
                    # permute schedule, and neither realization may
                    # all-gather on the hot path
                    assert_signatures_consistent({
                        "apply_shard": collective_signature(jb, x),
                        "apply_shard_masked": collective_signature(jm, x, alive),
                    })
                    lint_no_forbidden(jb, x)
                    lint_no_forbidden(jm, x, alive)
                else:
                    # dense/fused fallback: just compile both realizations
                    collective_signature(jb, x)
                    collective_signature(jm, x, alive)

            subjects.append((f"{topo_name}:{prog.name}", thunk))

    report = run_pass("collectives", subjects)
    # AST lint over the engines' dispatch modules
    report.checked += 1
    report.findings.extend(lint_engine_sources())
    return report


def run_recompile():
    import jax
    import jax.numpy as jnp

    from repro.analysis.recompile import (
        assert_executables_preenumerated,
        assert_no_retrace,
    )
    from repro.analysis.report import run_pass
    from repro.core.dsgd import make_topology
    from repro.core.faults import make_fault_model
    from repro.core.simulator import DecentralizedSimulator
    from repro.optim.sgd import sgd

    def _quad_loss(p, b):
        return jnp.mean((b - p["w"]) ** 2)

    def drive(topo_name, fault_model, warm_steps, guard_steps=8):
        topo = make_topology(topo_name, N, fault_model=fault_model)
        sim = DecentralizedSimulator(_quad_loss, sgd(momentum=0.9), topo)
        state = sim.init({"w": jnp.zeros(4)})

        def step(state, t):
            b = jax.random.normal(jax.random.PRNGKey(t), (N, 2, 4))
            state, *_ = sim.train_step(state, b, 0.05)
            return state

        for t in range(warm_steps):
            state = step(state, t)
        with assert_no_retrace(f"{topo_name} steps {warm_steps}..+{guard_steps}"):
            for t in range(warm_steps, warm_steps + guard_steps):
                state = step(state, t)
        assert_executables_preenumerated(sim, n_epochs=2)

    # deterministic fault horizons: crash onset/rejoin derive from the seed,
    # so warm-up provably covers every (program, faulty) combination and the
    # guarded window can demand 0 traces / 0 compiles
    crash = make_fault_model("crash", N, rate=0.5, seed=1, down_steps=4)
    crash_warm = (crash.rejoin_step or 0) + 2 * N
    subjects = [
        ("d_ring fault-free", lambda: drive("d_ring", None, 4)),
        ("d_one_peer_exp fault-free",
         lambda: drive("d_one_peer_exp", None, 8)),
        ("d_ring crash+rejoin", lambda: drive(
            "d_ring",
            make_fault_model("crash", N, rate=0.5, seed=1, down_steps=4),
            crash_warm,
        )),
    ]
    return run_pass("recompile", subjects)


def run_budget():
    from repro.analysis.budget import check_kernel_budget, verify_program_budget
    from repro.analysis.report import run_pass
    from repro.core.dsgd import make_topology

    subjects = []
    seen = set()
    for topo_name in TOPOS:
        topo = make_topology(topo_name, N)
        for _, prog in topo.distinct_programs(2):
            if prog.cache_key in seen:
                continue
            seen.add(prog.cache_key)
            for mode, kw in [("compiled", {}),
                             ("interpret", {"block": 1 << 20, "interpret": True})]:
                subjects.append((
                    f"{topo_name}:{prog.name} [{mode}]",
                    lambda p=prog, kw=kw: verify_program_budget(p, **kw),
                ))
    # the raw dispatch-signature check at the documented defaults
    subjects.append(
        ("defaults deg≤8", lambda: [
            check_kernel_budget(d, 1024) for d in range(9)])
    )
    return run_pass("budget", subjects)


def run_telemetry():
    import jax
    import jax.numpy as jnp

    from repro.analysis.report import run_pass
    from repro.core.dsgd import make_topology
    from repro.core.simulator import DecentralizedSimulator
    from repro.optim.sgd import sgd
    from repro.telemetry import MemorySink, MetricsRecorder
    from repro.telemetry.schema import SchemaError, validate_record
    from repro.telemetry.summarize import render_summary, summarize

    def _quad_loss(p, b):
        return jnp.mean((b - p["w"]) ** 2)

    def _drive(telemetry=None, n=2, steps=6):
        topo = make_topology("d_ring", n)
        sim = DecentralizedSimulator(
            _quad_loss, sgd(momentum=0.9), topo, telemetry=telemetry,
            collect_norms=True,
        )
        state = sim.init({"w": jnp.zeros(4)})
        for t in range(steps):
            b = jax.random.normal(jax.random.PRNGKey(t), (n, 2, 4))
            state, *_ = sim.train_step(state, b, 0.05)
        return sim

    def smoke():
        sink = MemorySink()
        rec = MetricsRecorder(
            sinks=[sink], metrics_every=1, record_spans=True
        )
        rec.manifest({"engine": "simulator", "n": 2})
        _drive(telemetry=rec)
        for r in sink.records:
            validate_record(r)
        kinds = {r["kind"] for r in sink.records}
        missing = {"manifest", "counter", "gauge", "span", "variance"} - kinds
        assert not missing, f"smoke run missing record kinds: {missing}"
        out = render_summary(summarize([dict(r) for r in sink.records]))
        assert "comm MiB" in out and "per-layer variance" in out

    def parity():
        off = _drive()
        on = _drive(telemetry=MetricsRecorder(
            sinks=[MemorySink()], metrics_every=1, record_spans=True
        ))
        k_off = sorted(map(str, off._step_cache))
        k_on = sorted(map(str, on._step_cache))
        assert k_on == k_off, (
            f"telemetry changed the executable set: "
            f"{len(k_off)} -> {len(k_on)}"
        )

    def rejects():
        for bad in (
            {"kind": "nope"},
            {"kind": "counter", "step": 0, "name": "x", "inc": 1},
            {"kind": "gauge", "step": 0, "name": "xi", "value": 1.0,
             "extra": 2},
        ):
            try:
                validate_record(bad)
            except SchemaError:
                continue
            raise AssertionError(f"schema accepted malformed record {bad!r}")

    subjects = [
        ("2-node smoke run + stream validation", smoke),
        ("executable-set parity on/off", parity),
        ("malformed records rejected", rejects),
    ]
    return run_pass("telemetry-schema", subjects)


PASSES = {
    "invariants": run_invariants,
    "collectives": run_collectives,
    "recompile": run_recompile,
    "budget": run_budget,
    "telemetry": run_telemetry,
}


def main(argv=None) -> int:
    _setup_env()
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static-analysis passes over the gossip stack",
    )
    ap.add_argument("--all", action="store_true", help="run every pass")
    for name in PASSES:
        ap.add_argument(f"--{name}", action="store_true")
    args = ap.parse_args(argv)

    selected = [n for n in PASSES if getattr(args, n)]
    if args.all or not selected:
        selected = list(PASSES)

    failed = False
    for name in selected:
        report = PASSES[name]()
        print(report.summary())
        for f in report.findings:
            print(f"  {f}")
        failed = failed or not report.ok
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
