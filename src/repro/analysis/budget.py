"""Pass 4 — the Pallas kernel budget checker.

``kernels/gossip_update.py`` documents a per-grid-cell memory layout
(see ``kernels/README.md``): the scalar hyperparams and the per-node
weight/fault rows live in SMEM, the parameter/gradient/momentum tiles and
the ``(1, deg, block)`` neighbor stack in VMEM.  Those budgets are real
hardware limits on TPU (~16 MiB VMEM per core; SMEM rows must stay tiny
scalars), and nothing previously checked them — a high-degree program or
an oversized ``block`` would sail through tracing and fail (or silently
spill) at the worst possible time.  This pass validates the layout
arithmetic BEFORE dispatch:

  * SMEM per cell: ``8 B`` hyper scalars + 2 rows × ``4·(deg+1) B``
    (weights + fault) — bounded by ``SMEM_BUDGET_BYTES``.
  * VMEM per cell: ``(deg + 5)·4·block`` bytes with momentum
    (θ/g/m tiles + deg neighbor tiles + θ'/m' outs), ``(deg + 3)·4·block``
    without — bounded by ``VMEM_BUDGET_BYTES``, compiled mode only: the
    interpreter's 2^20 default block is a host-level loop where the tile
    bound is correctness-irrelevant.
  * compiled blocks should be lane-aligned (multiples of 128); the
    dispatch path pads to a block multiple, so misalignment is a
    performance bug surfaced by the CLI, not a hard failure.

``check_kernel_budget`` is called (lru-cached per signature) by every
fused dispatch entry point in ``gossip_update.py``.
"""
from __future__ import annotations

from functools import lru_cache

from repro.analysis.report import BudgetViolation

__all__ = [
    "SMEM_BUDGET_BYTES",
    "VMEM_BUDGET_BYTES",
    "LANE",
    "kernel_cell_cost",
    "check_kernel_budget",
    "verify_program_budget",
]

# Documented budgets (kernels/README.md).  SMEM on TPU is O(KiB) of scalar
# memory per core; the kernel keeps two (deg+1,) f32 rows + 2 scalars
# there.  VMEM is ~16 MiB/core; leave headroom for double-buffering.
SMEM_BUDGET_BYTES = 4 << 10
VMEM_BUDGET_BYTES = 16 << 20
LANE = 128  # f32 lane width of a TPU vreg tile row
_HYPER_BYTES = 8  # [lr, beta] f32 scalars


def kernel_cell_cost(deg: int, block: int, *, has_momentum: bool = True) -> dict:
    """SMEM/VMEM bytes one (node, block) grid cell of the fused kernel
    holds resident, per the documented BlockSpec layout."""
    smem = _HYPER_BYTES + 2 * 4 * (deg + 1)  # weights row + fault row
    tiles = (3 if has_momentum else 2) + deg + (2 if has_momentum else 1)
    vmem = tiles * 4 * block
    return {"smem_bytes": smem, "vmem_bytes": vmem, "vmem_tiles": tiles}


@lru_cache(maxsize=256)
def check_kernel_budget(deg: int, block: int, *, interpret: bool = False,
                        has_momentum: bool = True) -> dict:
    """Validate one kernel dispatch signature against the budgets.

    Raises ``BudgetViolation`` on a hard violation; returns the cell cost
    (plus an ``aligned`` flag) otherwise.  Cached per signature so the
    hot dispatch path pays one dict lookup.
    """
    if deg < 0:
        raise BudgetViolation(f"negative program degree {deg}")
    if block < 1:
        raise BudgetViolation(f"non-positive kernel block {block}")
    cost = kernel_cell_cost(deg, block, has_momentum=has_momentum)
    if cost["smem_bytes"] > SMEM_BUDGET_BYTES:
        raise BudgetViolation(
            f"SMEM rows for deg={deg} need {cost['smem_bytes']} B/cell "
            f"(> {SMEM_BUDGET_BYTES} B budget) — the per-node weight/fault "
            "rows no longer fit scalar memory; split the program into "
            "fewer rounds per dispatch"
        )
    if not interpret and cost["vmem_bytes"] > VMEM_BUDGET_BYTES:
        raise BudgetViolation(
            f"VMEM tile set for deg={deg}, block={block} needs "
            f"{cost['vmem_bytes']} B/cell ({cost['vmem_tiles']} tiles × 4·"
            f"{block} B) > {VMEM_BUDGET_BYTES} B budget — shrink the block "
            "or the neighbor degree before dispatch"
        )
    cost["aligned"] = bool(interpret or block % LANE == 0)
    return cost


def verify_program_budget(program, *, block: int | None = None,
                          interpret: bool = False,
                          has_momentum: bool = True) -> dict | None:
    """Budget-check the kernel signature ``program`` would dispatch with.

    Programs without permute tables (dense/fused) never reach the Pallas
    kernel — returns ``None`` for those.  ``block=None`` uses the
    compiled-mode default tile.
    """
    tables = program.permute_tables()
    if tables is None:
        return None
    srcs, weights = tables
    n, deg = srcs.shape
    if weights.shape != (n, deg + 1):
        raise BudgetViolation(
            f"program {program.name!r}: weight table {weights.shape} does "
            f"not match the ({n}, {deg + 1}) SMEM row layout"
        )
    if block is None:
        block = 1024  # _auto_block compiled default
    return check_kernel_budget(
        deg, block, interpret=interpret, has_momentum=has_momentum
    )
