import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

This is how the distribution config is proven coherent without hardware:
512 placeholder host devices build the production meshes; every step
function must ``.lower().compile()`` and report its memory/cost analysis
and collective schedule.  Results stream into a JSON artifact consumed by
``launch/roofline.py`` and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      [--arch all] [--shape all] [--mesh single,multi] \
      [--topology d_ada] [--mixing ppermute] [--out dryrun_results.json]
"""

import argparse
import json
import re
import time
import traceback


_COLL_RE = re.compile(
    r"(\w[\w\.\-]*)\s*=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b"
)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|u32|s16|u16|s8|u8|pred)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}
_DTYPE_BYTES.update({f"f8{suf}": 1 for suf in ("e4m3fn", "e5m2", "e4m3", "e4m3b11fnuz")})


def _type_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-op-kind (count, result bytes, est. wire bytes/device)."""
    stats: dict[str, dict] = {}
    for m in _COLL_RE.finditer(hlo_text):
        _, type_str, kind = m.groups()
        b = _type_bytes(type_str)
        # wire-byte model per device: all-reduce ring = 2N; gather/scatter/
        # permute/alltoall move ~their result/input once.
        wire = 2 * b if kind == "all-reduce" else b
        s = stats.setdefault(kind, {"count": 0, "result_bytes": 0, "wire_bytes": 0})
        s["count"] += 1
        s["result_bytes"] += b
        s["wire_bytes"] += wire
    stats["total_wire_bytes"] = sum(
        v["wire_bytes"] for k, v in stats.items() if isinstance(v, dict)
    )
    return stats


def _apply_overrides(cfg, override: str):
    """--override "remat=False,capacity_factor=2.0" -> dataclasses.replace."""
    import dataclasses

    if not override:
        return cfg
    kw = {}
    for item in override.split(","):
        k, v = item.split("=", 1)
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            kw[k] = v.lower() in ("1", "true", "yes")
        elif isinstance(cur, int):
            kw[k] = int(v)
        elif isinstance(cur, float):
            kw[k] = float(v)
        elif cur is None and v.isdigit():
            kw[k] = int(v)
        else:
            kw[k] = v
    return dataclasses.replace(cfg, **kw)


def run_one(arch: str, shape_name: str, mesh_kind: str, topology: str, mixing: str,
            override: str = "", tag: str = "") -> dict:
    import jax

    from repro.configs import get_config
    from repro.configs.base import SHAPES
    from repro.core.dsgd import make_topology
    from repro.launch.mesh import gossip_axes_for, gossip_size, make_production_mesh
    from repro.launch.serve import ServeEngine
    from repro.launch.train import SPMDTrainer
    from repro.optim.sgd import sgd

    cfg = _apply_overrides(get_config(arch), override)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "kind": shape.kind,
        "mixing": mixing,
    }
    if override:
        rec["override"] = override
    if tag:
        rec["tag"] = tag
    t0 = time.time()

    if shape.kind == "train":
        gx = gossip_axes_for(cfg.name, mesh)
        g = gossip_size(mesh, gx)
        topo = make_topology(
            topology if g > 1 else "d_ring", max(g, 2) if g == 1 else g
        )
        if g == 1:
            topo = make_topology("d_ring", 1)
        trainer = SPMDTrainer(
            cfg, mesh, topo, sgd(momentum=0.9), mixing=mixing,
        )
        rec["gossip_axes"] = list(gx)
        rec["gossip_nodes"] = g
        rec["topology"] = topo.name
        graph = topo.graph_at(0)
        rec["graph"] = graph.describe() if graph else "none"
        lowered = trainer.lower_step(shape)
    else:
        engine = ServeEngine(cfg, mesh)
        if shape.kind == "prefill":
            lowered = engine.lower_prefill(shape)
        else:
            lowered = engine.lower_decode(shape)
            rec["window"] = engine.decode_window(shape)

    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
    }
    from repro.compat import cost_analysis as _cost_analysis

    cost = _cost_analysis(compiled)
    rec["cost"] = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
    }
    hlo_text = compiled.as_text()
    rec["collectives"] = collective_stats(hlo_text)
    # loop-aware accounting (cost_analysis counts while bodies once; scans
    # over layers/KV-chunks would otherwise undercount by the trip count)
    from repro.launch.hlo_analysis import analyze_hlo

    rec["hlo"] = analyze_hlo(hlo_text)
    return rec


def main() -> None:
    import jax

    from repro.configs import ARCH_NAMES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--topology", default="d_ada")
    ap.add_argument("--mixing", default="ppermute", choices=["ppermute", "dense"])
    ap.add_argument("--override", default="", help="cfg field overrides k=v,k=v (perf hillclimbs)")
    ap.add_argument("--tag", default="", help="label stored in the record")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument(
        "--isolate",
        action="store_true",
        help="run every (arch, shape, mesh) combo in its own subprocess — "
        "XLA compile memory for ~80 large modules does not fit one process",
    )
    args = ap.parse_args()

    if args.isolate:
        import subprocess
        import sys

        from repro.configs import ARCH_NAMES as _AN
        from repro.configs.base import SHAPES as _SH

        archs = list(_AN) if args.arch == "all" else args.arch.split(",")
        shapes = list(_SH) if args.shape == "all" else args.shape.split(",")
        meshes = args.mesh.split(",")
        for arch in archs:
            for shape in shapes:
                for mesh_kind in meshes:
                    cmd = [
                        sys.executable, "-m", "repro.launch.dryrun",
                        "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                        "--topology", args.topology, "--mixing", args.mixing,
                        "--out", args.out, "--skip-existing",
                    ] + (["--override", args.override] if args.override else []) \
                      + (["--tag", args.tag] if args.tag else [])
                    r = subprocess.run(cmd)
                    if r.returncode not in (0, 1):
                        print(
                            f"[DIED] {arch} × {shape} × {mesh_kind}: "
                            f"rc={r.returncode} (likely OOM)",
                            flush=True,
                        )
        return

    from repro.configs.base import SHAPES

    archs = list(ARCH_NAMES) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")

    results = []
    if args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {
        (r["arch"], r["shape"], r["mesh"], r.get("tag", ""))
        for r in results
        if "error" not in r
    }

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                key = (arch, shape, mesh_kind, args.tag)
                if key in done:
                    continue
                tag = f"{arch} × {shape} × {mesh_kind}"
                try:
                    rec = run_one(
                        arch, shape, mesh_kind, args.topology, args.mixing,
                        args.override, args.tag,
                    )
                    coll = rec["collectives"].get("total_wire_bytes", 0)
                    print(
                        f"[OK]   {tag}: compile {rec['compile_s']}s  "
                        f"flops/dev {rec['cost']['flops']:.3e}  "
                        f"coll {coll/1e6:.1f} MB/dev",
                        flush=True,
                    )
                except Exception as e:
                    n_fail += 1
                    rec = {
                        "arch": arch, "shape": shape, "mesh": mesh_kind,
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                    print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:200]}", flush=True)
                results = [
                    r for r in results
                    if (r["arch"], r["shape"], r["mesh"], r.get("tag", "")) != key
                ]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                # one process compiles up to 80 large modules: drop executables
                # and tracing caches between combos or host RAM accumulates.
                jax.clear_caches()
                import gc

                gc.collect()
    print(f"\n{len(results)} records, {n_fail} failures -> {args.out}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
