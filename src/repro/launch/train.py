"""SPMD decentralized training engine (the production path).

The train step is a ``shard_map`` manual over the *gossip axes* only; the
``model`` axis stays a GSPMD auto axis, so tensor/expert parallelism inside
a node is driven purely by the parameter in_shardings.  Global state is the
gossip-stacked tree (leaves ``(G, ...)`` sharded over the gossip axes);
inside the body each node sees its own replica.

Mixing interprets the same compiled ``GossipProgram`` as the simulator
oracle (``core/schedule.py``): one ``jax.lax.ppermute`` per compiled
permute, the all-reduce fast path for the complete graph, and the
paper-faithful dense all-gather realization with ``mixing="dense"`` (the
program's GatherRow op).  There is no per-engine mixing dispatch — both
engines call ``GossipProgram.apply``.

Per iteration (paper §2.1 order):
  1. local forward/backward (optionally grad-accumulated over microbatches)
  2. C_complete: ``pmean`` gradients over the gossip axes (all-reduce)
     D_*:        local optimizer update, then gossip parameter averaging
  3. optional DBench probe: per-leaf L2 norms *before* mixing

Time-varying topologies (Ada, one-peer exponential, random-matching pools)
compile one executable per distinct ``GossipProgram`` — a handful per run,
enumerable up front via ``Topology.distinct_programs`` — each at its first
use, and switch cached executables at (epoch, step) boundaries thereafter:
graph adaptation costs zero recompiles beyond that bounded set and zero
host sync.

Closed-loop Ada (``--consensus-target``): before a probe step the trainer
computes the consensus distance Ξ_t over the gossip-stacked global state
(one jitted reduction, ``core/consensus.py``) and feeds it to the
topology's ``ConsensusController``; the measured ratio Ξ_t/Ξ_0 — not the
epoch law — steps the schedule down its pre-enumerated ladder, so the
bounded-executable-set invariant holds unchanged.

jax-version note: partial-manual shard_map needs the modern manual-axes API
(``repro/compat.py``).  On old jax (0.4.37 in this container) the trainer
transparently switches to the *stacked* GSPMD realization — vmap over the
gossip axis + the program's stacked interpreter, whose rolls XLA lowers to
collective-permutes on the sharded axis — numerically identical and proven
against the simulator oracle.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

try:  # jax-version shim (PR 1); degrade gracefully to modern-API-only
    from repro import compat as _compat
except ImportError:  # pragma: no cover
    _compat = None

from repro.checkpoint.ckpt import validate_run_config as _validate_run_config
from repro.core import dbench
from repro.core.dsgd import Topology
from repro.core.schedule import (
    GossipProgram, _flat_axis_index, compile_graph, dense_program,
)
from repro.launch import sharding as shd
from repro.launch.mesh import gossip_axes_for, gossip_size
from repro.models import transformer as tfm
from repro.models.common import abstract_params, spec_tree
from repro.optim.sgd import Optimizer

PyTree = Any

__all__ = ["SPMDTrainer", "TrainState"]


def _set_mesh(mesh):
    if _compat is not None:
        return _compat.set_mesh(mesh)
    return jax.set_mesh(mesh)


def _has_manual_axes() -> bool:
    if _compat is not None:
        return _compat.HAS_MANUAL_AXES_API
    return hasattr(jax, "shard_map")


def _shard_map(f, *, mesh, in_specs, out_specs, axis_names):
    if _compat is not None:
        return _compat.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=axis_names, check_vma=False,
        )
    return jax.shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        axis_names=axis_names, check_vma=False,
    )


@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt_state: PyTree
    step: int = 0


class _LazyStep:
    """Defers the jit/shard_map build until concrete batch shapes arrive."""

    def __init__(self, build):
        self._build = build
        self._fn = None

    def __call__(self, params, opt_state, batch, lr, *fault):
        if self._fn is None:
            self._fn = self._build(batch)
        return self._fn(params, opt_state, batch, lr, *fault)

    def lower(self, params, opt_state, batch, lr, *fault):
        return self._build(batch).lower(params, opt_state, batch, lr, *fault)


class SPMDTrainer:
    """Builds and runs the sharded train step for one (arch × mesh × topology)."""

    def __init__(
        self,
        cfg,
        mesh: jax.sharding.Mesh,
        topology: Topology,
        optimizer: Optimizer,
        *,
        loss_fn: Optional[Callable] = None,
        accum_steps: int = 1,
        collect_norms: bool = False,
        mixing: str = "ppermute",  # ppermute (compiled program) | dense
        mix_every: int = 1,
        mix_rounds: int = 1,
        hub_balance: bool = False,
        fused_apply: bool = False,
        donate: bool = True,
        bucket_mb: Optional[float] = None,
        debug_no_retrace: bool = False,
        telemetry=None,
    ):
        """mix_every: gossip once every H optimizer steps (local-SGD ×
        decentralized; beyond-paper — the limit of the paper's Obs. 5 that
        late-stage connectivity is nearly free to drop).  The non-mixing
        step compiles separately, so the H−1 local steps carry zero gossip
        collectives.

        mix_rounds: fuse H consecutive schedule steps into each gossip
        round — ONE cached executable runs all H rounds back-to-back
        (``GossipProgram.fuse``), so e.g. a full one-peer exponential cycle
        is a single dispatch instead of H.

        hub_balance: with ``mix_rounds > 1`` on a static multi-matching
        program, rotate its edge-colored matchings across the H rounds
        (``hub_balanced_rounds``) so hot vertices (the star hub) stop
        sending in every round of every step.

        fused_apply: run optimizer update + gossip averaging as one fused
        Pallas pass (``kernels/gossip_update``) whenever the step's program
        is all-PPermute (circulant, matching, edge-colored); programs with
        AllReduce/GatherRow ops and non-mixing steps keep the interpreter
        path.  Requires plain momentum-SGD (the kernel re-implements the
        update); the dense-interpreter oracle remains the correctness bar.

        bucket_mb: overlap-scheduled gossip — run each mixing step as a
        chain of per-bucket update+gossip dispatches over a
        ``core/buckets.BucketLayout`` partition of the flattened parameter
        vector instead of one monolithic tail (bucket i's permutes carry
        no data dependency on bucket i+1's compute, so the dispatches
        pipeline), folding each bucket's Ξ² partial into its pass so
        fault-free closed-loop probes skip the standalone probe
        executable.  Composes with ``fused_apply`` (the kernel runs per
        bucket), ``mix_rounds`` (every stage of the fused round runs
        inside the same per-bucket dispatch), and fault masks (runtime
        operands — executables stay one per (program, bucket width), never
        buckets × faults).  SGD family + ``mix_order="post"`` only;
        active in the stacked GSPMD realization (the shard_map realization
        keeps the monolithic step — its per-bucket schedule lives in
        ``GossipProgram.apply_shard_bucketed`` for manual-axes meshes).

        Fault injection rides on the topology (``topology.fault_model``):
        the trainer draws the same seeded realization stream as the
        simulator, gates straggling/dead nodes' local updates, degrades the
        mixing weights with runtime masks (transient faults reuse the
        fault-free executable count; permanent crashes select from the
        pre-enumerated degraded program set), rejoins recovered nodes from
        their neighbors' average, and re-arms the consensus controller on
        membership changes.
        """
        if mixing not in ("ppermute", "dense"):
            raise ValueError(f"mixing must be 'ppermute'|'dense', got {mixing!r}")
        self.cfg = cfg
        self.mesh = mesh
        self.topology = topology
        self.optimizer = optimizer
        self.accum_steps = accum_steps
        self.collect_norms = collect_norms
        self.mixing = mixing
        self.mix_every = max(int(mix_every), 1)
        self.mix_rounds = max(int(mix_rounds), 1)
        self.hub_balance = bool(hub_balance)
        self.fault_model = topology.fault_model
        if self.fault_model is not None and self.fault_model.elastic:
            raise ValueError(
                "elastic (join) fault models grow membership past the mesh's "
                "gossip size; the SPMD trainer's device mesh is fixed — "
                "over-provision the mesh with spare ranks instead "
                "(--spare-ranks / faults.SparePool: joins activate "
                "alive-masked ghost ranks with zero recompiles), or use the "
                "DecentralizedSimulator for true mid-run growth"
            )
        self._last_membership = None
        # unified run telemetry (repro.telemetry): the shared recorder
        # carries the observational wall-clock deadline trace
        # (GossipDeadline runs) — the seeded model drives the masks, the
        # recorder logs MEASURED per-round durations and overruns against
        # the same deadline; enabling timing synchronizes once per step
        # (block on the loss), which the trace documents.  Sink-attached
        # recorders additionally stream counters/gauges/events/variance.
        from repro.telemetry import MetricsRecorder

        self.telemetry = (
            telemetry if telemetry is not None else MetricsRecorder()
        )
        self.telemetry.configure(
            deadline_ms=getattr(self.fault_model, "deadline_ms", None)
        )
        if topology.controller is not None:
            topology.controller.bind_recorder(self.telemetry)
        self._pn_bytes: Optional[int] = None
        self._last_program = None
        self._pending_grads = None
        self.fused_apply = bool(fused_apply)
        if self.fused_apply:
            hyper = optimizer.hyper or {}
            if (
                hyper.get("kind") != "sgd"
                or hyper.get("nesterov")
                or hyper.get("weight_decay")
            ):
                raise ValueError(
                    "fused_apply re-implements the update inside the Pallas "
                    "kernel and supports plain momentum-SGD only; got "
                    f"{optimizer.name}"
                )
            self._fused_beta = float(hyper.get("momentum", 0.0))
        self.bucket_mb = bucket_mb
        if bucket_mb is not None:
            from repro.core.buckets import bucket_eligible_optimizer

            if not bucket_eligible_optimizer(optimizer):
                raise ValueError(
                    "bucket_mb requires an SGD-family optimizer (elementwise "
                    f"update; got {optimizer.name})"
                )
            if topology.centralized:
                raise ValueError("bucket_mb needs a decentralized topology")
            if topology.mix_order != "post":
                raise ValueError(
                    "bucket_mb requires mix_order='post' (pre-mixing must see "
                    "the full tree before the update)"
                )
        self._bucket_layout = None
        self._folded_sq = None
        self._folded_for_step = -1
        self.donate = donate
        self.gossip_axes = gossip_axes_for(cfg.name, mesh)
        self.g = gossip_size(mesh, self.gossip_axes)
        if topology.n_nodes != self.g:
            raise ValueError(
                f"topology has {topology.n_nodes} nodes but mesh gossip axes "
                f"{self.gossip_axes} give {self.g}"
            )
        # Partial-manual shard_map (manual gossip × auto model) needs the
        # modern manual-axes API; otherwise run the stacked GSPMD engine.
        self.use_shard_map = self.g > 1 and _has_manual_axes()
        tp = mesh.shape.get("model", 1)
        self.defs = tfm.model_defs(cfg, tp_size=tp)
        self.loss_fn = loss_fn or (lambda p, b: tfm.loss_fn(p, cfg, b))
        self._step_cache: dict[Any, Any] = {}
        # debug mode (repro.analysis.recompile): a warm cached executable
        # invoked again must never trace/compile
        self.debug_no_retrace = bool(debug_no_retrace)
        self._was_warm = False
        self._build_shardings()

    # -- telemetry views -------------------------------------------------------
    # round_ms / deadline_overruns were per-engine lists before the shared
    # recorder existed; they stay as thin views for backward compatibility.
    @property
    def round_ms(self) -> list:
        return self.telemetry.round_ms

    @property
    def deadline_overruns(self) -> int:
        return self.telemetry.deadline_overruns

    @property
    def _deadline_ms(self):
        return self.telemetry.deadline_ms

    def _per_node_bytes(self, params: PyTree) -> int:
        """Per-node parameter bytes P for comm billing (stacked leaves
        carry the gossip axis first)."""
        if self._pn_bytes is None:
            self._pn_bytes = sum(
                int(np.prod(x.shape[1:])) * jnp.dtype(x.dtype).itemsize
                for x in jax.tree.leaves(params)
            )
        return self._pn_bytes

    def _bill_comm(self, program, params: PyTree, step: int, fr) -> None:
        """Bill one mixing-program application at dispatch time (bytes on
        the wire + permute count) — the same accounting
        ``benchmarks/ada.py::_total_comm`` replays offline."""
        if program is None or not self.telemetry.active:
            return
        alive = link = None
        if fr is not None:
            alive = np.asarray(fr.alive, np.float64)
            link = fr.link_up
        self.telemetry.comm(
            program, self._per_node_bytes(params), step=step,
            alive=alive, link_up=link,
        )

    def _retrace_guard(self, warm: bool, label: str):
        """``debug_no_retrace`` guard around a warm cached-executable call
        (see ``DecentralizedSimulator._retrace_guard``)."""
        if not (self.debug_no_retrace and warm):
            import contextlib

            return contextlib.nullcontext()
        from repro.analysis.recompile import assert_no_retrace

        return assert_no_retrace(label)

    # -- mixing program -------------------------------------------------------
    def _one_program(self, step: int, epoch: int) -> Optional[GossipProgram]:
        graph = self.topology.graph_at(epoch, step)
        if graph is None:
            return None
        if self.mixing == "dense":
            return dense_program(graph)
        return compile_graph(graph)

    def _program_at(self, step: int, epoch: int) -> Optional[GossipProgram]:
        if self.mix_rounds <= 1:
            return self._one_program(step, epoch)
        progs = [
            self._one_program(step * self.mix_rounds + r, epoch)
            for r in range(self.mix_rounds)
        ]
        if any(p is None for p in progs):
            return None
        if self.hub_balance:
            from repro.core.schedule import maybe_hub_balanced

            balanced = maybe_hub_balanced(progs, self.mix_rounds)
            if balanced is not None:
                return balanced
        return GossipProgram.fuse(progs)

    def precompile_programs(self, n_epochs: int = 1) -> list[GossipProgram]:
        """Enumerate every distinct program a run will rotate through.

        This compiles the mixing *programs* (the IR), not the XLA
        executables — each step executable is jitted once at its first use
        and cached by program key; this method bounds and reports that set.
        """
        if self.topology.centralized:
            return []
        progs = []
        seen = set()
        ctl = self.topology.controller
        for (e, s), _ in self.topology.distinct_programs(n_epochs):
            if ctl is not None:
                # Closed-loop keys are (rung, phase): pin the rung so this
                # trainer's own transforms (dense / mix_rounds fusion) see
                # the program the step cache will be keyed on.
                with ctl.pinned(e):
                    p = self._program_at(s, 0)
            else:
                p = self._program_at(s, e)
            if p is not None and p.cache_key not in seen:
                seen.add(p.cache_key)
                progs.append(p)
        if self.fault_model is not None:
            # permanent crashes select among degraded variants of the
            # trainer's own (possibly fused/dense) programs — enumerate
            # them here so they too compile at first use, never beyond.
            from repro.core.faults import fold_degraded_programs

            progs += [
                d for _, d in fold_degraded_programs(progs, self.fault_model)
            ]
        return progs

    # -- shardings -----------------------------------------------------------
    def _build_shardings(self):
        stacked = self.g > 1
        p_abs = abstract_params(self.defs)
        p_specs = spec_tree(self.defs)
        o_abs = jax.eval_shape(self.optimizer.init, p_abs)
        o_specs = self.optimizer.state_specs(p_specs)
        if stacked:
            p_abs = shd.stack_abstract(p_abs, self.g)
            o_abs = shd.stack_abstract(o_abs, self.g)
        kw = dict(stacked=stacked, fsdp=not stacked)
        self.param_shardings = shd.param_shardings(
            p_abs, p_specs, self.mesh, self.gossip_axes, **kw
        )
        self.opt_shardings = shd.param_shardings(
            o_abs, o_specs, self.mesh, self.gossip_axes, **kw
        )
        self.abstract_state = (p_abs, o_abs)

    def batch_shardings(self, batch_like: PyTree) -> PyTree:
        return jax.tree.map(
            lambda l: shd.batch_sharding(
                self.mesh, self.gossip_axes, np.ndim(l) if not hasattr(l, "shape") else len(l.shape),
                stacked=self.g > 1,
            ),
            batch_like,
        )

    # -- state init ------------------------------------------------------------
    def init_state(self, key: jax.Array) -> TrainState:
        """Identical replicas on every node (paper §2.2)."""
        tp = self.mesh.shape.get("model", 1)

        def _init(k):
            p = tfm.init_model(self.cfg, k, tp_size=tp)
            o = self.optimizer.init(p)
            if self.g > 1:
                p, o = jax.tree.map(
                    lambda x: jnp.broadcast_to(x[None], (self.g,) + x.shape), (p, o)
                )
            return p, o

        with _set_mesh(self.mesh):
            p, o = jax.jit(
                _init, out_shardings=(self.param_shardings, self.opt_shardings)
            )(key)
        return TrainState(p, o, 0)

    # -- per-node grads (shared by both realizations) ----------------------------
    def _grads_of(self, params, batch):
        accum = self.accum_steps
        if accum == 1:
            return jax.value_and_grad(self.loss_fn)(params, batch)
        micro = jax.tree.map(
            lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]), batch
        )

        def acc_body(carry, mb):
            l, g = jax.value_and_grad(self.loss_fn)(params, mb)
            return (
                carry[0] + l / accum,
                jax.tree.map(lambda a, b: a + b / accum, carry[1], g),
            ), None

        zero = (
            jnp.zeros((), jnp.float32),
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        )
        (loss, grads), _ = jax.lax.scan(acc_body, zero, micro)
        return loss, grads

    # -- fused kernel eligibility ---------------------------------------------
    def _fused_split(self, program: Optional[GossipProgram]):
        """(kernel_stage, interpreter_stages) when the fused Pallas apply can
        run this program, else None.

        The kernel handles one all-PPermute round (circulant offsets,
        matchings, edge-colored graphs).  A ``mix_rounds`` FusedProgram
        composes: the kernel executes update + round 1, the interpreter the
        remaining rounds — still one executable.  Not eligible: programs
        with AllReduce/GatherRow first ops, non-mixing steps, and
        ``mix_order="pre"`` multi-round fusions (there the descent must
        follow ALL rounds, which the one-round kernel cannot express).
        """
        from repro.core.schedule import FusedProgram

        if (
            not self.fused_apply
            or program is None
            or self.topology.centralized
        ):
            return None
        if isinstance(program, FusedProgram):
            if self.topology.mix_order != "post":
                return None
            first, rest = program.stages[0], program.stages[1:]
        else:
            first, rest = program, ()
        if first.permute_tables() is None:
            return None
        return first, rest

    def _use_fused(self, program: Optional[GossipProgram]) -> bool:
        return self._fused_split(program) is not None

    # -- the node-level step (shard_map realization) ------------------------------
    def _node_step(self, program: Optional[GossipProgram], faulty: bool = False):
        topo = self.topology
        opt = self.optimizer
        axes = self.gossip_axes
        fused = self._fused_split(program) if self.g > 1 else None

        def node_step(params_st, opt_st, batch_st, lr, fault=None):
            squeeze = self.g > 1
            params = jax.tree.map(lambda x: x[0], params_st) if squeeze else params_st
            opt_state = jax.tree.map(lambda x: x[0], opt_st) if squeeze else opt_st
            batch = jax.tree.map(lambda x: x[0], batch_st) if squeeze else batch_st

            loss, grads = self._grads_of(params, batch)
            norms = (
                dbench.param_l2_norms(params)
                if self.collect_norms
                else jnp.zeros((0,), jnp.float32)
            )

            def _mix(tree):
                if fault is None:
                    return program.apply_shard(tree, axes)
                return program.apply_shard_masked(
                    tree, axes, fault["alive"], link_up=fault["link"]
                )

            if topo.centralized and self.g > 1:
                grads = jax.tree.map(lambda g: jax.lax.pmean(g, axes), grads)
            if fused:
                from repro.kernels.gossip_update import fused_apply_shard

                first, rest = fused
                new_p, new_o = fused_apply_shard(
                    first, params, grads, opt_state, axes,
                    lr=lr, beta=self._fused_beta, fault=fault,
                    mix_order=topo.mix_order,
                )
                for stage in rest:
                    if fault is None:
                        new_p = stage.apply_shard(new_p, axes)
                    else:
                        new_p = stage.apply_shard_masked(
                            new_p, axes, fault["alive"], link_up=fault["link"]
                        )
            else:
                if topo.mix_order == "pre" and program is not None and self.g > 1:
                    params = _mix(params)
                new_p, new_o = opt.update(grads, opt_state, params, lr)
                if fault is not None:
                    # stragglers/dead skip their local update (this node's
                    # flag selected from the replicated mask)
                    u = fault["update"][_flat_axis_index(axes)]
                    gate = lambda nw, od: jnp.where(u > 0, nw, od)
                    new_p = jax.tree.map(gate, new_p, params)
                    new_o = jax.tree.map(gate, new_o, opt_state)
                if topo.mix_order == "post" and program is not None and self.g > 1:
                    new_p = _mix(new_p)

            if squeeze:
                new_p = jax.tree.map(lambda x: x[None], new_p)
                new_o = jax.tree.map(lambda x: x[None], new_o)
                loss = loss[None]
                norms = norms[None]
            return new_p, new_o, loss, norms

        if faulty:
            return node_step
        return lambda p, o, b, lr: node_step(p, o, b, lr)

    # -- the stacked step (GSPMD realization; old-jax fallback) -------------------
    def _stacked_step(self, program: Optional[GossipProgram], faulty: bool = False):
        """vmap over the gossip axis + the program's stacked interpreter.

        Numerically identical to the shard_map realization; on a mesh whose
        gossip axes shard the leading dim, XLA lowers the program's rolls to
        collective-permutes (and the GatherRow einsum to an all-gather).
        """
        topo = self.topology
        opt = self.optimizer
        fused = self._fused_split(program)

        def stacked_step(params, opt_state, batch, lr, fault=None):
            loss, grads = jax.vmap(self._grads_of)(params, batch)
            norms = (
                jax.vmap(dbench.param_l2_norms)(params)
                if self.collect_norms
                else jnp.zeros((self.g, 0), jnp.float32)
            )
            if topo.centralized:
                grads = jax.tree.map(
                    lambda g: jnp.broadcast_to(
                        g.mean(axis=0, keepdims=True), g.shape
                    ),
                    grads,
                )

            def _mix(tree):
                if fault is None:
                    return program.apply_stacked(tree)
                return program.apply_masked(
                    tree, fault["alive"], link_up=fault["link"]
                )

            if fused:
                from repro.kernels.gossip_update import fused_apply_stacked

                first, rest = fused
                new_p, new_o = fused_apply_stacked(
                    first, params, grads, opt_state,
                    lr=lr, beta=self._fused_beta, fault=fault,
                    mix_order=topo.mix_order,
                )
                for stage in rest:
                    if fault is None:
                        new_p = stage.apply_stacked(new_p)
                    else:
                        new_p = stage.apply_masked(
                            new_p, fault["alive"], link_up=fault["link"]
                        )
                return new_p, new_o, loss, norms
            if topo.mix_order == "pre" and program is not None:
                params = _mix(params)
            new_p, new_o = jax.vmap(opt.update, in_axes=(0, 0, 0, None))(
                grads, opt_state, params, lr
            )
            if fault is not None:
                u = fault["update"]

                def _gate(nw, od):
                    ucol = u.reshape((self.g,) + (1,) * (nw.ndim - 1))
                    return jnp.where(ucol > 0, nw, od)

                new_p = jax.tree.map(_gate, new_p, params)
                new_o = jax.tree.map(_gate, new_o, opt_state)
            if topo.mix_order == "post" and program is not None:
                new_p = _mix(new_p)
            return new_p, new_o, loss, norms

        if faulty:
            return stacked_step
        return lambda p, o, b, lr: stacked_step(p, o, b, lr)

    # -- bucketed, overlap-scheduled path (stacked realization) ---------------
    @property
    def _bucketed(self) -> bool:
        return (
            self.bucket_mb is not None
            and self.g > 1
            and not self.use_shard_map
        )

    def _bucket_grads_fn(self, batch: PyTree):
        """The jitted backward: (loss, grads, norms) — the compute the
        per-bucket mixing dispatches pipeline behind."""
        key = "__bucket_grads__"
        if key not in self._step_cache:
            gvec = NamedSharding(self.mesh, P(self.gossip_axes))

            def gn(params, batch):
                loss, grads = jax.vmap(self._grads_of)(params, batch)
                norms = (
                    jax.vmap(dbench.param_l2_norms)(params)
                    if self.collect_norms
                    else jnp.zeros((self.g, 0), jnp.float32)
                )
                return loss, grads, norms

            self._step_cache[key] = jax.jit(
                gn,
                in_shardings=(
                    self.param_shardings,
                    jax.tree.map(
                        lambda x: shd.batch_sharding(
                            self.mesh, self.gossip_axes, len(x.shape),
                            stacked=True,
                        ),
                        batch,
                    ),
                ),
                # grads mirror the parameter tree leaf-for-leaf
                out_shardings=(gvec, self.param_shardings, gvec),
            )
        return self._step_cache[key]

    def _bucket_fn(self, program, width: int, has_m: bool, faulty: bool):
        """One bucket width's jitted update+mix dispatch, cached per
        (program, width): all full buckets share one executable, the tail
        adds at most a second; fault masks ride as runtime operands."""
        key = ("__bucket__", program.cache_key, width, has_m, faulty)
        if key not in self._step_cache:
            from repro.core.buckets import build_bucket_step

            kernel_split = (
                self._fused_split(program) if self.fused_apply else None
            )
            fn = build_bucket_step(
                program,
                hyper=self.optimizer.hyper,
                has_momentum=has_m,
                faulty=faulty,
                kernel_split=kernel_split,
            )
            lead2 = NamedSharding(self.mesh, P(self.gossip_axes, None))
            gvec = NamedSharding(self.mesh, P(self.gossip_axes))
            rep = NamedSharding(self.mesh, P())
            ins = (
                [lead2, lead2, rep, gvec]
                if not has_m
                else [lead2, lead2, lead2, rep, gvec]
            )
            if faulty:
                ins.append({
                    "update": rep, "alive": rep,
                    "link": rep if self.fault_model.has_link_faults else None,
                })
            outs = (lead2, lead2, gvec) if has_m else (lead2, gvec)
            self._step_cache[key] = jax.jit(
                fn,
                in_shardings=tuple(ins),
                out_shardings=outs,
                donate_argnums=((0, 1) if has_m else (0,)) if self.donate else (),
            )
        return self._step_cache[key]

    def _bucket_split_fn(self, state, grads, has_m: bool):
        """Jitted bucket-view builder: canonical (model-sharded) trees in,
        (G, w) bucket matrices out.  One executable (not one per leaf):
        the model-axis gathers the reshapes imply stay INSIDE it, so they
        are ordered by its data dependencies — loose eager reshapes would
        each be their own collective-bearing dispatch, outside the token
        chain (see ``_bucketed_step``), and could interleave differently
        across devices and deadlock."""
        key = ("__bucket_split__", has_m)
        if key not in self._step_cache:
            layout = self._bucket_layout
            lead2 = NamedSharding(self.mesh, P(self.gossip_axes, None))

            def split3(params, opt, g):
                return (
                    layout.split_stacked(params),
                    layout.split_stacked(opt) if has_m else [],
                    layout.split_stacked(g),
                )

            nb = layout.num_buckets
            self._step_cache[key] = jax.jit(
                split3,
                in_shardings=(
                    self.param_shardings,
                    self.opt_shardings if has_m else (),
                    self.param_shardings,
                ),
                out_shardings=(
                    [lead2] * nb, [lead2] * nb if has_m else [], [lead2] * nb
                ),
            )
        return self._step_cache[key]

    def _bucket_merge_fn(self, state, has_m: bool):
        """Jitted inverse: bucket matrices back into canonically-sharded
        trees.  Consumes the Ξ² token, so it is ordered after the last
        bucket dispatch; passes it through for the probe fold."""
        key = ("__bucket_merge__", has_m)
        if key not in self._step_cache:
            layout = self._bucket_layout
            lead2 = NamedSharding(self.mesh, P(self.gossip_axes, None))
            gvec = NamedSharding(self.mesh, P(self.gossip_axes))
            p_tmpl = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state.params
            )
            o_tmpl = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                state.opt_state,
            )
            nb = layout.num_buckets

            def merge3(ts, ms, tok):
                p = layout.merge_stacked(ts, p_tmpl)
                o = layout.merge_stacked(ms, o_tmpl) if has_m else ()
                return p, o, tok

            self._step_cache[key] = jax.jit(
                merge3,
                in_shardings=(
                    [lead2] * nb, [lead2] * nb if has_m else [], gvec
                ),
                out_shardings=(
                    self.param_shardings,
                    self.opt_shardings if has_m else (),
                    gvec,
                ),
            )
        return self._step_cache[key]

    def _bucketed_step(self, state, batch, lr, program, fault):
        """One iteration as a pipelined chain of per-bucket dispatches.

        The backward dispatch runs first; a jitted split carves the
        canonical trees into (G, w) bucket matrices; then each bucket's
        update + all its gossip rounds + its Ξ² partial launches as its
        own executable; a jitted merge re-places the canonical trees.
        The (G,) Ξ² accumulator token is the only cross-bucket operand:
        it pins a consistent execution order across devices (independent
        collective-bearing executables can otherwise start in different
        per-device orders and deadlock at the permute rendezvous), while
        the (G, w) payloads stay independent, so the runtime overlaps
        bucket i's collective-permutes with bucket i+1's compute instead
        of serializing communication behind one monolithic tail.  The
        dispatch window is bounded (``MAX_INFLIGHT_BUCKETS``): before
        launching a new bucket the host blocks on the token of the one
        leaving the window, so fine bucket sizes cannot queue hundreds
        of collective-bearing launches at once.
        """
        from repro.core.buckets import MAX_INFLIGHT_BUCKETS, BucketLayout

        if self._bucket_layout is None:
            self._bucket_layout = BucketLayout.for_stacked(
                state.params, self.bucket_mb
            )
        layout = self._bucket_layout
        with _set_mesh(self.mesh):
            loss, grads, norms = self._bucket_grads_fn(batch)(
                state.params, batch
            )
            # the bucketed path is the one place grads materialize outside
            # the fused step executable — stash them for the grad-norm
            # gauge (host work deferred to the post-step metrics emission)
            self._pending_grads = (
                grads if self.telemetry.due(state.step) else None
            )
            has_m = state.opt_state != ()
            t_mats, m_mats, g_mats = self._bucket_split_fn(state, grads, has_m)(
                state.params, state.opt_state, grads
            )
            lr32 = jnp.float32(lr)
            gvec = NamedSharding(self.mesh, P(self.gossip_axes))
            tok = jax.device_put(jnp.zeros((self.g,), jnp.float32), gvec)
            out_t, out_m = [], []
            window: deque = deque()
            for b, w in enumerate(layout.widths):
                tb = self.telemetry.span_start()
                if len(window) >= MAX_INFLIGHT_BUCKETS:
                    jax.block_until_ready(window.popleft())
                fn = self._bucket_fn(program, w, has_m, fault is not None)
                args = (
                    (t_mats[b], m_mats[b], g_mats[b], lr32, tok)
                    if has_m
                    else (t_mats[b], g_mats[b], lr32, tok)
                )
                if fault is not None:
                    args = args + (fault,)
                res = fn(*args)
                if has_m:
                    t2, m2, tok = res
                    out_m.append(m2)
                else:
                    t2, tok = res
                out_t.append(t2)
                window.append(tok)
                self.telemetry.bucket_span(tb, step=state.step, index=b)
            new_params, new_opt, tok = self._bucket_merge_fn(state, has_m)(
                out_t, out_m, tok
            )
            if not has_m:
                new_opt = state.opt_state
        if fault is None:
            self._folded_sq = tok
            self._folded_for_step = state.step + 1
        return new_params, new_opt, loss, norms

    # -- jitted step per program ----------------------------------------------
    def step_fn(self, epoch: int = 0, batch_abstract: Optional[PyTree] = None,
                *, step: int = 0, mix: bool = True, program_alive=None):
        """``program_alive``: permanent-crash membership — selects the
        pre-enumerated degraded program.  A topology with a fault model
        compiles the fault-aware signature (one extra runtime-mask arg):
        transient realizations change mask values only, so the cached-
        executable count matches the fault-free run."""
        program = self._program_at(step, epoch) if mix else None
        if not mix and self.topology.centralized:
            raise ValueError("mix_every > 1 is a decentralized-only feature")
        if program is not None and program_alive is not None:
            program = program.degrade(program_alive)
        faulty = (
            self.fault_model is not None
            and self.g > 1
            and not self.topology.centralized
        )
        key = None if program is None else program.cache_key
        if faulty:
            key = (key, "faulty")
        self._last_program = program  # comm billing reuses this resolution
        self._was_warm = key in self._step_cache
        if key in self._step_cache:
            return self._step_cache[key]

        gspec = P(self.gossip_axes) if self.gossip_axes else P()
        if self.g == 1:
            fn = jax.jit(
                self._node_step(program, faulty=faulty),
                donate_argnums=(0, 1) if self.donate else (),
            )
            self._step_cache[key] = fn
            return fn

        lead = lambda nd: P(self.gossip_axes, *([None] * nd))
        in_specs = (
            jax.tree.map(lambda l: lead(len(l.shape) - 1), self.abstract_state[0]),
            jax.tree.map(lambda l: lead(len(l.shape) - 1), self.abstract_state[1]),
        )

        def shardings_for(batch_tree):
            base = (
                self.param_shardings,
                self.opt_shardings,
                jax.tree.map(
                    lambda x: shd.batch_sharding(
                        self.mesh, self.gossip_axes, len(x.shape), stacked=True
                    ),
                    batch_tree,
                ),
                NamedSharding(self.mesh, P()),
            )
            if faulty:  # the runtime-mask pytree is replicated
                rep = NamedSharding(self.mesh, P())
                base = base + (
                    {"update": rep, "alive": rep,
                     "link": rep if self.fault_model.has_link_faults else None},
                )
            return base

        if self.use_shard_map:
            node_step = self._node_step(program, faulty=faulty)

            def build(batch_tree):
                batch_specs = jax.tree.map(
                    lambda x: lead(len(x.shape) - 1), batch_tree
                )
                arg_specs = (in_specs[0], in_specs[1], batch_specs, P())
                if faulty:
                    arg_specs = arg_specs + (P(),)
                mapped = _shard_map(
                    node_step,
                    mesh=self.mesh,
                    in_specs=arg_specs,
                    out_specs=(in_specs[0], in_specs[1], gspec, gspec),
                    axis_names=set(self.gossip_axes),
                )
                return jax.jit(
                    mapped,
                    in_shardings=shardings_for(batch_tree),
                    out_shardings=(
                        self.param_shardings,
                        self.opt_shardings,
                        NamedSharding(self.mesh, gspec),
                        NamedSharding(self.mesh, gspec),
                    ),
                    donate_argnums=(0, 1) if self.donate else (),
                )

        else:
            stacked_step = self._stacked_step(program, faulty=faulty)

            def build(batch_tree):
                return jax.jit(
                    stacked_step,
                    in_shardings=shardings_for(batch_tree),
                    out_shardings=(
                        self.param_shardings,
                        self.opt_shardings,
                        NamedSharding(self.mesh, gspec),
                        NamedSharding(self.mesh, gspec),
                    ),
                    donate_argnums=(0, 1) if self.donate else (),
                )

        fn = _LazyStep(build)
        self._step_cache[key] = fn
        return fn

    # -- public API ------------------------------------------------------------------
    def _finish_round(self, loss, norms, t_start, *, step: int, mix: bool,
                      lr: float) -> None:
        """Shared post-step telemetry (the former per-engine
        ``_record_round``): closes the ``round`` span — blocking on the
        loss so the measured duration covers the whole dispatched round,
        with deadline-overrun attribution in the recorder — and emits the
        loss/lr/variance/grad-norm sample at the metrics cadence.  Purely
        observational; the averaging masks stay seeded."""
        tel = self.telemetry
        if t_start is not None:
            jax.block_until_ready(loss)
            tel.round_end(t_start, step=step, mix=mix)
        if tel.due(step):
            tel.step_metrics(
                step, loss=loss, lr=lr,
                norms=norms if self.collect_norms else None,
                grads=self._pending_grads,
            )
            self._pending_grads = None

    def train_step(self, state: TrainState, batch: PyTree, lr: float, *, epoch: int = 0):
        tel = self.telemetry
        t_start = tel.round_start()
        ctl = self.topology.controller
        fr = None
        if self.fault_model is not None and self.g > 1:
            from repro.core.faults import (
                adopt_neighbor_average, drain_handoff, rejoin_neighbors,
                track_membership,
            )

            fr = self.fault_model.at(state.step)
            for node in fr.rejoin:
                nbrs = rejoin_neighbors(
                    self.topology, fr, node, step=state.step, epoch=epoch,
                    mix_every=self.mix_every,
                )
                if tel.active:
                    tel.event("rejoin", state.step, data={"node": int(node)})
                with _set_mesh(self.mesh):
                    state = TrainState(
                        adopt_neighbor_average(state.params, node, nbrs),
                        adopt_neighbor_average(state.opt_state, node, nbrs),
                        state.step,
                    )
            for node in fr.depart:
                # clean preemption departure: exact mean-preserving handoff
                # to the neighborhood before the node's row goes dead
                nbrs = rejoin_neighbors(
                    self.topology, fr, node, step=state.step, epoch=epoch,
                    mix_every=self.mix_every,
                )
                if tel.active:
                    tel.event("depart", state.step, data={"node": int(node)})
                with _set_mesh(self.mesh):
                    state = TrainState(
                        drain_handoff(state.params, node, nbrs, fr.alive),
                        drain_handoff(state.opt_state, node, nbrs, fr.alive),
                        state.step,
                    )
            prev_membership = self._last_membership
            self._last_membership = track_membership(
                self._last_membership, fr, ctl, state.step
            )
            if (
                tel.active
                and prev_membership is not None
                and self._last_membership != prev_membership
            ):
                tel.event(
                    "membership", state.step,
                    data={"alive": [bool(b) for b in self._last_membership]},
                )
        if ctl is not None and self.g > 1 and ctl.should_probe(state.step):
            with _set_mesh(self.mesh):
                if fr is not None:
                    from repro.core.consensus import consensus_distance_masked_jit

                    # membership mask, NOT the raw alive mask: a float drain
                    # boost must not weight the draining node in the probe
                    xi = consensus_distance_masked_jit(
                        state.params,
                        jnp.asarray(np.asarray(fr.alive) != 0, jnp.float32),
                    )
                elif self._folded_for_step == state.step:
                    # folded probe: the last bucketed mixing step already
                    # accumulated each bucket's Ξ² partial in its own
                    # dispatch — only the final √mean runs, on the host
                    from repro.core.buckets import xi_from_folded_sq

                    xi = xi_from_folded_sq(self._folded_sq)
                else:
                    from repro.core.consensus import consensus_distance_jit

                    xi = consensus_distance_jit(state.params)
            if tel.active:
                tel.gauge("xi", float(xi), step=state.step)
            ctl.observe(float(xi), state.step)
        mix = (state.step + 1) % self.mix_every == 0
        # Time-varying schedules advance per *gossip round*, not per raw
        # step: with mix_every=H only every H-th step mixes, and indexing by
        # raw step would alias a period-p family to the single phase
        # H-1 mod p whenever p | H (e.g. one-peer n=16 with H=4 would gossip
        # hop 8 forever, splitting the network into isolated pairs).
        # the *selection* mask: for composed concurrent crashes it stays
        # all-ones (base program + runtime masks), so the degraded-program
        # branch — and any extra executable — is never taken
        sel = fr.selection_mask() if fr is not None else None
        palive = sel if sel is not None and not sel.all() else None
        if self._bucketed and mix and not self.topology.centralized:
            program = self._program_at(state.step // self.mix_every, epoch)
            if program is not None and palive is not None:
                program = program.degrade(palive)
            if program is not None:
                from repro.core.faults import realization_arrays

                self._bill_comm(program, state.params, state.step, fr)
                fault = realization_arrays(fr) if fr is not None else None
                p, o, loss, norms = self._bucketed_step(
                    state, batch, lr, program, fault
                )
                self._finish_round(
                    loss, norms, t_start, step=state.step, mix=True, lr=lr
                )
                return TrainState(p, o, state.step + 1), loss, norms
        fn = self.step_fn(
            epoch, step=state.step // self.mix_every,
            mix=mix or self.topology.centralized,
            program_alive=palive,
        )
        if mix and self.g > 1 and not self.topology.centralized:
            self._bill_comm(self._last_program, state.params, state.step, fr)
        args = (state.params, state.opt_state, batch, jnp.float32(lr))
        if fr is not None:
            from repro.core.faults import realization_arrays

            args = args + (realization_arrays(fr),)
        # a warm _LazyStep that has not built yet still traces legitimately
        warm = self._was_warm and (
            not isinstance(fn, _LazyStep) or fn._fn is not None
        )
        with _set_mesh(self.mesh), self._retrace_guard(
            warm, f"spmd step {state.step}"
        ):
            p, o, loss, norms = fn(*args)
        self._finish_round(loss, norms, t_start, step=state.step, mix=mix, lr=lr)
        return TrainState(p, o, state.step + 1), loss, norms

    # -- crash-consistent resume -------------------------------------------------
    def snapshot_extra(self) -> dict:
        """Engine run state a crash-consistent checkpoint must carry beyond
        (params, opt_state): membership tracking (else the first
        post-resume membership change skips its controller re-arm) and the
        consensus controller's phase/rung/log state.  Fault realizations
        themselves are pure fn(seed, step) and need no persisting —
        replaying from the checkpoint step regenerates them bit-exactly.

        ``run_config`` records the load-bearing launch configuration
        (topology name, gossip size, bucket layout) so a mismatched
        ``--resume`` fails fast at restore with a clear error instead of
        surfacing as a shape/tree mismatch mid-run."""
        d: dict = {
            "run_config": {
                "topology": self.topology.name,
                "n": int(self.g),
                "bucket_mb": (
                    None if self.bucket_mb is None else float(self.bucket_mb)
                ),
            },
            "last_membership": (
                None if self._last_membership is None
                else [bool(b) for b in self._last_membership]
            ),
        }
        ctl = self.topology.controller
        if ctl is not None:
            d["controller"] = ctl.state_dict()
        d["telemetry"] = self.telemetry.state_dict()
        return d

    def restore_extra(self, d: dict) -> None:
        """Inverse of ``snapshot_extra`` on a freshly-built trainer.

        Validates the checkpoint's recorded ``run_config`` against this
        trainer's configuration first (fail-fast resume)."""
        rc = d.get("run_config") or {}
        _validate_run_config(
            rc, topology=self.topology.name, n=int(self.g),
            bucket_mb=self.bucket_mb, n_label="mesh gossip size",
        )
        lm = d.get("last_membership")
        self._last_membership = (
            None if lm is None else tuple(bool(b) for b in lm)
        )
        ctl = self.topology.controller
        if ctl is not None and d.get("controller") is not None:
            ctl.load_state_dict(d["controller"])
        if d.get("telemetry") is not None:
            # resumed counters/span totals continue instead of restarting
            self.telemetry.load_state_dict(d["telemetry"])

    def lower_step(self, shape, *, epoch: int = 0, step: int = 0):
        """Abstract lowering for the dry-run: ShapeDtypeStructs only."""
        from repro.configs.base import input_specs

        batch = input_specs(self.cfg, shape, n_nodes=max(self.g, 1))
        if self.g == 1:
            # flat batch for the degenerate placement
            batch = {
                k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()
            }
        fn = self.step_fn(epoch, step=step)
        p_abs, o_abs = self.abstract_state
        lr = jax.ShapeDtypeStruct((), jnp.float32)
        # a fault-model trainer's step takes the runtime-mask pytree too
        fault_abs = ()
        if self.fault_model is not None and self.g > 1:
            fault_abs = ({
                "update": jax.ShapeDtypeStruct((self.g,), jnp.float32),
                "alive": jax.ShapeDtypeStruct((self.g,), jnp.float32),
                "link": (
                    jax.ShapeDtypeStruct((self.g, self.g), jnp.float32)
                    if self.fault_model.has_link_faults
                    else None
                ),
            },)
        with _set_mesh(self.mesh):
            if self.g == 1:
                lowered = jax.jit(
                    self._node_step(self._program_at(step, epoch)),
                    in_shardings=(
                        self.param_shardings,
                        self.opt_shardings,
                        jax.tree.map(
                            lambda x: shd.batch_sharding(
                                self.mesh, (), len(x.shape), stacked=False
                            ),
                            batch,
                        ),
                        NamedSharding(self.mesh, P()),
                    ),
                    out_shardings=(
                        self.param_shardings,
                        self.opt_shardings,
                        NamedSharding(self.mesh, P()),
                        NamedSharding(self.mesh, P()),
                    ),
                ).lower(p_abs, o_abs, batch, lr)
            else:
                lowered = fn.lower(p_abs, o_abs, batch, lr, *fault_abs)
        return lowered


# ---------------------------------------------------------------------------
# CLI launcher:  PYTHONPATH=src python -m repro.launch.train --arch granite-8b
# ---------------------------------------------------------------------------

def main() -> None:
    import argparse
    import time

    ap = argparse.ArgumentParser(description="decentralized training launcher")
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU-scale reduced config (default on CPU)")
    ap.add_argument("--topology", default="d_ada")
    ap.add_argument("--mixing", default="ppermute", choices=["ppermute", "dense"])
    ap.add_argument("--mix-every", type=int, default=1)
    ap.add_argument("--mix-rounds", type=int, default=1,
                    help="fuse H consecutive schedule steps per gossip round "
                         "into one executable (GossipProgram.fuse)")
    ap.add_argument("--hub-balance", action="store_true",
                    help="with --mix-rounds H > 1 on a static multi-matching "
                         "program, rotate the edge-colored matchings across "
                         "the H rounds so hot vertices (star hub) stop "
                         "sending in every round")
    ap.add_argument("--fused-apply", action="store_true",
                    help="run optimizer+gossip as one fused Pallas pass for "
                         "all-PPermute programs (plain momentum-SGD only)")
    ap.add_argument("--bucket-mb", type=float, default=None,
                    help="overlap-scheduled gossip: partition the flattened "
                         "parameter vector into ~this-many-MiB buckets and "
                         "pipeline per-bucket update+permute dispatches "
                         "instead of one monolithic mixing tail (folds the "
                         "consensus probe into the gossip pass; SGD family "
                         "+ post-mixing only)")
    ap.add_argument("--fault-model", default="none",
                    choices=["none", "crash", "concurrent", "preempt",
                             "join", "deadline", "dropout", "link",
                             "straggler"],
                    help="seeded fault injection: permanent single-node "
                         "crash, k-node concurrent crashes, planned "
                         "preemption drain, pre-declared joins ('join' "
                         "needs --spare-ranks on this fixed-mesh trainer), "
                         "per-round gossip deadlines with backoff "
                         "readmission, transient node dropout, Bernoulli "
                         "link failure, or stragglers that skip the local "
                         "update but still mix (core/faults.py)")
    ap.add_argument("--fault-rate", type=float, default=0.1,
                    help="per-step fault probability (crash/concurrent/"
                         "preempt: geometric onset)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="fault realization seed (step-deterministic; both "
                         "engines draw identical realizations)")
    ap.add_argument("--fault-down-steps", type=int, default=None,
                    help="crash/concurrent: steps until a victim rejoins by "
                         "adopting its neighbors' average (elastic "
                         "membership; default: never)")
    ap.add_argument("--fault-k", type=int, default=2,
                    help="concurrent only: number of victims with "
                         "overlapping down windows")
    ap.add_argument("--fault-drain-steps", type=int, default=5,
                    help="preempt only: announced drain window before the "
                         "clean mean-preserving departure")
    ap.add_argument("--fault-enumerate", action="store_true",
                    help="concurrent only: pre-enumerate the realized "
                         "multi-node degraded programs (bounded fast path) "
                         "instead of the composed runtime-mask default")
    ap.add_argument("--fault-join-steps", default="",
                    help="join only: comma-separated steps at which new "
                         "members arrive (with --spare-ranks each join "
                         "activates one spare rank)")
    ap.add_argument("--spare-ranks", type=int, default=0,
                    help="over-provision the gossip mesh with this many "
                         "ghost ranks riding from step 0 as alive-masked "
                         "zero-weight participants: joins/rejoins activate "
                         "a spare with ZERO extra executables "
                         "(faults.SparePool; composes with any "
                         "--fault-model)")
    ap.add_argument("--gossip-deadline-ms", type=float, default=30.0,
                    help="deadline only: per-round gossip deadline; nodes "
                         "whose (seeded) round latency misses it are masked "
                         "out of that round's averaging and fall back to "
                         "their local step")
    ap.add_argument("--deadline-backoff", type=float, default=2.0,
                    help="deadline only: exponential readmission backoff "
                         "base — each consecutive miss benches the node "
                         "for 1, b, b², ... rounds")
    ap.add_argument("--k-floor", default="2",
                    help="Ada decay floor: an int, or 'one_peer' for the "
                         "time-varying one-peer exponential family")
    ap.add_argument("--consensus-target", type=float, default=None,
                    help="close the Ada loop: step the schedule down a rung "
                         "whenever measured consensus distance falls to this "
                         "fraction of its initial value (d_ada only)")
    ap.add_argument("--consensus-every", type=int, default=1,
                    help="consensus-distance probe cadence in steps")
    ap.add_argument("--consensus-spike", type=float, default=None,
                    help="non-monotone ladder: walk the closed-loop "
                         "schedule back UP to a denser rung whenever a "
                         "probed Ξ_t spikes past this multiple of the "
                         "phase's running peak (crash, deadline storm, "
                         "join; ~3.0 is a good start; needs "
                         "--consensus-target)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--steps-per-epoch", type=int, default=10)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--per-node-batch", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--lr-scaling", default="sqrt", choices=["none", "linear", "sqrt"])
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adamw", "lars"])
    ap.add_argument("--mesh", default="2,2", help="data,model (CPU uses host devices)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --ckpt-dir "
                         "(crash-consistent: restores params, optimizer, "
                         "controller phase/rung/logs, and membership "
                         "tracking; fault realizations are pure fn(seed, "
                         "step), so the continued run is bit-identical to "
                         "an uninterrupted one)")
    ap.add_argument("--telemetry", default="",
                    help="stream structured run telemetry (JSONL) to this "
                         "path: per-step spans, comm-bytes counters, "
                         "loss/xi/grad-norm gauges, streamed DBench "
                         "variance, and controller/membership/checkpoint "
                         "events; inspect with "
                         "python -m repro.telemetry summarize PATH "
                         "(with --resume the file is appended, and "
                         "counters continue from the checkpoint)")
    ap.add_argument("--metrics-every", type=int, default=10,
                    help="gauge/variance emission cadence in steps "
                         "(with --telemetry; spans and counters are "
                         "per-step)")
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.core.dsgd import make_topology
    from repro.data import SyntheticLM
    from repro.launch.mesh import make_mesh
    from repro.optim.schedules import lr_scale
    from repro.optim.sgd import get_optimizer

    shape = tuple(int(x) for x in args.mesh.split(","))
    if len(jax.devices()) < shape[0] * shape[1]:
        raise SystemExit(
            f"mesh {shape} needs {shape[0]*shape[1]} devices but only "
            f"{len(jax.devices())} present — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={shape[0]*shape[1]}"
        )
    mesh = make_mesh(shape, ("data", "model"))
    cfg = get_config(args.arch + ("-reduced" if args.reduced or jax.default_backend() == "cpu" else ""))
    import dataclasses

    cfg = dataclasses.replace(cfg, name=args.arch)  # keep gossip placement
    g = shape[0]
    if args.k_floor == "one_peer":
        k_floor = "one_peer"
    else:
        try:
            k_floor = int(args.k_floor)
        except ValueError:
            raise SystemExit(
                f"--k-floor must be an integer or 'one_peer', got {args.k_floor!r}"
            )
    from repro.core.faults import make_fault_model

    join_steps = (
        tuple(int(x) for x in args.fault_join_steps.split(",") if x.strip())
        or None
    )
    fault_model = make_fault_model(
        args.fault_model, g, rate=args.fault_rate, seed=args.fault_seed,
        down_steps=args.fault_down_steps, k=args.fault_k,
        drain_steps=args.fault_drain_steps, join_steps=join_steps,
        enumerate_programs=args.fault_enumerate,
        spare_ranks=args.spare_ranks,
        deadline_ms=args.gossip_deadline_ms,
        deadline_backoff=args.deadline_backoff,
    )
    topo = make_topology(
        args.topology, g, k_floor=k_floor,
        consensus_target=args.consensus_target,
        consensus_spike=args.consensus_spike,
        consensus_probe_every=args.consensus_every,
        fault_model=fault_model,
    )
    recorder = None
    if args.telemetry:
        from repro.telemetry import JsonlSink, MetricsRecorder

        recorder = MetricsRecorder(
            sinks=[JsonlSink(args.telemetry, append=args.resume)],
            metrics_every=args.metrics_every, record_spans=True,
        )
    trainer = SPMDTrainer(
        cfg, mesh, topo, get_optimizer(args.optimizer), collect_norms=True,
        mixing=args.mixing, mix_every=args.mix_every,
        mix_rounds=args.mix_rounds, hub_balance=args.hub_balance,
        fused_apply=args.fused_apply, donate=False,
        bucket_mb=args.bucket_mb, telemetry=recorder,
    )
    if recorder is not None:
        run = {
            "engine": "spmd",
            "config": {k: v for k, v in sorted(vars(args).items())},
            "topology": topo.describe(),
            "mesh": {str(k): int(v) for k, v in dict(mesh.shape).items()},
            "seed": 0,
            "resumed": bool(args.resume),
        }
        try:  # provenance only — absent git must not block a run
            import subprocess

            rev = subprocess.run(
                ["git", "rev-parse", "HEAD"], capture_output=True,
                text=True, timeout=5,
            )
            if rev.returncode == 0:
                run["git"] = rev.stdout.strip()
        except Exception:
            pass
        recorder.manifest(run)
    # report the apply path the step will ACTUALLY take: fused_apply falls
    # back to the interpreter for non-PPermute programs (complete, dense)
    apply_mode = "interpreter"
    if args.fused_apply and trainer._use_fused(trainer._program_at(0, 0)):
        apply_mode = "fused-pallas"
    elif args.fused_apply:
        apply_mode = "interpreter (program not fused-eligible)"
    if trainer._bucketed:
        apply_mode += f" | bucketed {args.bucket_mb}MiB"
    print(topo.describe(), "| mesh", dict(mesh.shape), "| mixing", args.mixing,
          "| engine", "shard_map" if trainer.use_shard_map else "stacked",
          "| rounds", args.mix_rounds, "| apply", apply_mode)
    n_progs = len(trainer.precompile_programs(args.steps // args.steps_per_epoch + 1))
    print(f"{n_progs} distinct mixing program(s) over the run")
    state = trainer.init_state(jax.random.PRNGKey(0))
    start_step = 0
    if args.resume:
        if not args.ckpt_dir:
            raise SystemExit("--resume requires --ckpt-dir")
        from repro.checkpoint import load_checkpoint, load_checkpoint_extra

        restored, start_step = load_checkpoint(
            args.ckpt_dir, {"p": state.params, "o": state.opt_state}
        )
        trainer.restore_extra(load_checkpoint_extra(args.ckpt_dir, start_step) or {})
        state = TrainState(restored["p"], restored["o"], start_step)
        trainer.telemetry.event(
            "checkpoint_restore", int(start_step), data={"dir": args.ckpt_dir}
        )
        print(f"resumed from {args.ckpt_dir} at step {start_step}")
    src = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, seed=0)
    scale = lr_scale(
        args.lr_scaling, global_batch=g * args.per_node_batch,
        base_batch=max(g * args.per_node_batch, 1), graph_degree=topo.degree_at(0),
    )
    t0 = time.time()
    for t in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in src.stacked(g, t, args.per_node_batch).items()}
        epoch = t // args.steps_per_epoch
        state, loss, norms = trainer.train_step(state, batch, args.lr * scale, epoch=epoch)
        if t % 5 == 0 or t == args.steps - 1:
            print(f"step {t:4d} k={topo.degree_at(epoch, t)} loss={float(loss.mean()):.4f} "
                  f"spread={float(loss.max() - loss.min()):.4f}")
        if args.ckpt_dir and args.ckpt_every and (t + 1) % args.ckpt_every == 0:
            from repro.checkpoint import save_checkpoint

            save_checkpoint(
                args.ckpt_dir, t + 1,
                {"p": state.params, "o": state.opt_state},
                extra=trainer.snapshot_extra(),
            )
            trainer.telemetry.event(
                "checkpoint_save", t + 1, data={"dir": args.ckpt_dir}
            )
    print(f"{args.steps} steps in {time.time()-t0:.1f}s")
    if trainer.round_ms:
        ms = np.asarray(trainer.round_ms)
        line = (f"round trace: median {np.median(ms):.1f}ms "
                f"p95 {np.percentile(ms, 95):.1f}ms")
        if trainer._deadline_ms is not None:
            line += (f" | measured overruns "
                     f"{trainer.deadline_overruns}/{len(ms)} "
                     f"(deadline {trainer._deadline_ms}ms; masks stay seeded)")
        print(line)
    if topo.controller is not None:
        ctl = topo.controller
        rungs = " -> ".join(str(ctl.ladder[r]) for _, r in [(0, 0)] + ctl.transitions)
        print(
            f"consensus controller: xi0={ctl.xi0} rungs {rungs} "
            f"handoff_step={ctl.handoff_step}"
        )
    if args.telemetry:
        trainer.telemetry.close()
        print(f"telemetry: {args.telemetry} "
              f"(python -m repro.telemetry summarize {args.telemetry})")


if __name__ == "__main__":
    main()
