"""SPMD decentralized training engine (the production path).

The train step is a ``jax.shard_map`` manual over the *gossip axes* only;
the ``model`` axis stays a GSPMD auto axis, so tensor/expert parallelism
inside a node is driven purely by the parameter in_shardings.  Global state
is the gossip-stacked tree (leaves ``(G, ...)`` sharded over the gossip
axes); inside the body each node sees its own replica.

Per iteration (paper §2.1 order):
  1. local forward/backward (optionally grad-accumulated over microbatches)
  2. C_complete: ``pmean`` gradients over the gossip axes (all-reduce)
     D_*:        local optimizer update, then gossip parameter averaging
                 (``mix_ppermute`` schedule, or the paper-faithful dense
                 all-gather mixing with ``mixing="dense"``)
  3. optional DBench probe: per-leaf L2 norms *before* mixing

Ada is realized by compiling one executable per distinct coordination
number (a handful per run — see ``AdaSchedule.distinct_graphs``) and
switching executables at epoch boundaries: graph adaptation costs zero
mid-step recompiles and zero host sync.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import dbench
from repro.core.dsgd import Topology
from repro.core.graphs import CommGraph
from repro.core.mixing import mix_ppermute
from repro.launch import sharding as shd
from repro.launch.mesh import gossip_axes_for, gossip_size
from repro.models import transformer as tfm
from repro.models.common import abstract_params, spec_tree
from repro.optim.sgd import Optimizer

PyTree = Any

__all__ = ["SPMDTrainer", "TrainState"]


@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt_state: PyTree
    step: int = 0


def _mix_dense_allgather(new_p: PyTree, graph: CommGraph, axes) -> PyTree:
    """Paper-faithful dense mixing: gather all replicas, multiply by W-row.

    Costs an all-gather of the full parameter tree over the gossip axes —
    kept as the *faithful baseline* for §Perf (the paper mixes with a dense
    adjacency matrix; sparsity-aware schedules are our optimization).
    """
    w = jnp.asarray(graph.mixing_matrix(), jnp.float32)
    idx = jax.lax.axis_index(axes)
    row = jax.lax.dynamic_slice_in_dim(w, idx, 1, 0)[0]  # (G,)

    def _mix(x):
        g = jax.lax.all_gather(x.astype(jnp.float32), axes, axis=0, tiled=False)
        return jnp.einsum("g...,g->...", g, row).astype(x.dtype)

    return jax.tree.map(_mix, new_p)


class SPMDTrainer:
    """Builds and runs the sharded train step for one (arch × mesh × topology)."""

    def __init__(
        self,
        cfg,
        mesh: jax.sharding.Mesh,
        topology: Topology,
        optimizer: Optimizer,
        *,
        loss_fn: Optional[Callable] = None,
        accum_steps: int = 1,
        collect_norms: bool = False,
        mixing: str = "ppermute",  # ppermute | dense
        mix_every: int = 1,
        donate: bool = True,
    ):
        """mix_every: gossip once every H optimizer steps (local-SGD ×
        decentralized; beyond-paper — the limit of the paper's Obs. 5 that
        late-stage connectivity is nearly free to drop).  The non-mixing
        step compiles separately, so the H−1 local steps carry zero gossip
        collectives."""
        self.cfg = cfg
        self.mesh = mesh
        self.topology = topology
        self.optimizer = optimizer
        self.accum_steps = accum_steps
        self.collect_norms = collect_norms
        self.mixing = mixing
        self.mix_every = max(int(mix_every), 1)
        self.donate = donate
        self.gossip_axes = gossip_axes_for(cfg.name, mesh)
        self.g = gossip_size(mesh, self.gossip_axes)
        if topology.n_nodes != self.g:
            raise ValueError(
                f"topology has {topology.n_nodes} nodes but mesh gossip axes "
                f"{self.gossip_axes} give {self.g}"
            )
        tp = mesh.shape.get("model", 1)
        self.defs = tfm.model_defs(cfg, tp_size=tp)
        self.loss_fn = loss_fn or (lambda p, b: tfm.loss_fn(p, cfg, b))
        self._step_cache: dict[Any, Any] = {}
        self._build_shardings()

    # -- shardings -----------------------------------------------------------
    def _build_shardings(self):
        stacked = self.g > 1
        p_abs = abstract_params(self.defs)
        p_specs = spec_tree(self.defs)
        o_abs = jax.eval_shape(self.optimizer.init, p_abs)
        o_specs = self.optimizer.state_specs(p_specs)
        if stacked:
            p_abs = shd.stack_abstract(p_abs, self.g)
            o_abs = shd.stack_abstract(o_abs, self.g)
        kw = dict(stacked=stacked, fsdp=not stacked)
        self.param_shardings = shd.param_shardings(
            p_abs, p_specs, self.mesh, self.gossip_axes, **kw
        )
        self.opt_shardings = shd.param_shardings(
            o_abs, o_specs, self.mesh, self.gossip_axes, **kw
        )
        self.abstract_state = (p_abs, o_abs)

    def batch_shardings(self, batch_like: PyTree) -> PyTree:
        return jax.tree.map(
            lambda l: shd.batch_sharding(
                self.mesh, self.gossip_axes, np.ndim(l) if not hasattr(l, "shape") else len(l.shape),
                stacked=self.g > 1,
            ),
            batch_like,
        )

    # -- state init ------------------------------------------------------------
    def init_state(self, key: jax.Array) -> TrainState:
        """Identical replicas on every node (paper §2.2)."""
        tp = self.mesh.shape.get("model", 1)

        def _init(k):
            p = tfm.init_model(self.cfg, k, tp_size=tp)
            o = self.optimizer.init(p)
            if self.g > 1:
                p, o = jax.tree.map(
                    lambda x: jnp.broadcast_to(x[None], (self.g,) + x.shape), (p, o)
                )
            return p, o

        with jax.set_mesh(self.mesh):
            p, o = jax.jit(
                _init, out_shardings=(self.param_shardings, self.opt_shardings)
            )(key)
        return TrainState(p, o, 0)

    # -- the node-level step -----------------------------------------------------
    def _node_step(self, graph: Optional[CommGraph]):
        topo = self.topology
        opt = self.optimizer
        accum = self.accum_steps
        axes = self.gossip_axes

        def grads_of(params, batch):
            if accum == 1:
                return jax.value_and_grad(self.loss_fn)(params, batch)
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]), batch
            )

            def acc_body(carry, mb):
                l, g = jax.value_and_grad(self.loss_fn)(params, mb)
                return (
                    carry[0] + l / accum,
                    jax.tree.map(lambda a, b: a + b / accum, carry[1], g),
                ), None

            zero = (
                jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            )
            (loss, grads), _ = jax.lax.scan(acc_body, zero, micro)
            return loss, grads

        def node_step(params_st, opt_st, batch_st, lr):
            squeeze = self.g > 1
            params = jax.tree.map(lambda x: x[0], params_st) if squeeze else params_st
            opt_state = jax.tree.map(lambda x: x[0], opt_st) if squeeze else opt_st
            batch = jax.tree.map(lambda x: x[0], batch_st) if squeeze else batch_st

            loss, grads = grads_of(params, batch)
            norms = (
                dbench.param_l2_norms(params)
                if self.collect_norms
                else jnp.zeros((0,), jnp.float32)
            )

            if topo.centralized and self.g > 1:
                grads = jax.tree.map(lambda g: jax.lax.pmean(g, axes), grads)
            if topo.mix_order == "pre" and graph is not None and self.g > 1:
                params = self._mix(params, graph)
            new_p, new_o = opt.update(grads, opt_state, params, lr)
            if topo.mix_order == "post" and graph is not None and self.g > 1:
                new_p = self._mix(new_p, graph)

            if squeeze:
                new_p = jax.tree.map(lambda x: x[None], new_p)
                new_o = jax.tree.map(lambda x: x[None], new_o)
                loss = loss[None]
                norms = norms[None]
            return new_p, new_o, loss, norms

        return node_step

    def _mix(self, params, graph):
        if self.mixing == "dense":
            return _mix_dense_allgather(params, graph, self.gossip_axes)
        return mix_ppermute(params, graph, self.gossip_axes)

    # -- jitted step per graph ------------------------------------------------------
    def step_fn(self, epoch: int = 0, batch_abstract: Optional[PyTree] = None,
                *, mix: bool = True):
        graph = self.topology.graph_at(epoch) if mix else None
        if not mix and self.topology.centralized:
            raise ValueError("mix_every > 1 is a decentralized-only feature")
        key = None if graph is None else (graph.name, graph.offsets)
        if key in self._step_cache:
            return self._step_cache[key]

        node_step = self._node_step(graph)
        gspec = P(self.gossip_axes) if self.gossip_axes else P()
        if self.g == 1:
            fn = jax.jit(node_step, donate_argnums=(0, 1) if self.donate else ())
            self._step_cache[key] = fn
            return fn
        lead = lambda nd: P(self.gossip_axes, *([None] * nd))
        in_specs = (
            jax.tree.map(lambda l: lead(len(l.shape) - 1), self.abstract_state[0]),
            jax.tree.map(lambda l: lead(len(l.shape) - 1), self.abstract_state[1]),
        )

        def build(batch_tree):
            batch_specs = jax.tree.map(
                lambda x: lead(len(x.shape) - 1), batch_tree
            )
            mapped = jax.shard_map(
                node_step,
                mesh=self.mesh,
                in_specs=(in_specs[0], in_specs[1], batch_specs, P()),
                out_specs=(in_specs[0], in_specs[1], gspec, gspec),
                axis_names=set(self.gossip_axes),
                check_vma=False,
            )
            return jax.jit(
                mapped,
                in_shardings=(
                    self.param_shardings,
                    self.opt_shardings,
                    jax.tree.map(
                        lambda x: shd.batch_sharding(
                            self.mesh, self.gossip_axes, len(x.shape), stacked=True
                        ),
                        batch_tree,
                    ),
                    NamedSharding(self.mesh, P()),
                ),
                out_shardings=(
                    self.param_shardings,
                    self.opt_shardings,
                    NamedSharding(self.mesh, gspec),
                    NamedSharding(self.mesh, gspec),
                ),
                donate_argnums=(0, 1) if self.donate else (),
            )

        class _LazyStep:
            def __init__(self, build_):
                self._build = build_
                self._fn = None

            def __call__(self, params, opt_state, batch, lr):
                if self._fn is None:
                    self._fn = self._build(batch)
                return self._fn(params, opt_state, batch, lr)

            def lower(self, params, opt_state, batch, lr):
                return self._build(batch).lower(params, opt_state, batch, lr)

        step = _LazyStep(build)
        self._step_cache[key] = step
        return step

    # -- public API ------------------------------------------------------------------
    def train_step(self, state: TrainState, batch: PyTree, lr: float, *, epoch: int = 0):
        mix = (state.step + 1) % self.mix_every == 0
        fn = self.step_fn(epoch, mix=mix or self.topology.centralized)
        with jax.set_mesh(self.mesh):
            p, o, loss, norms = fn(
                state.params, state.opt_state, batch, jnp.float32(lr)
            )
        return TrainState(p, o, state.step + 1), loss, norms

    def lower_step(self, shape, *, epoch: int = 0):
        """Abstract lowering for the dry-run: ShapeDtypeStructs only."""
        from repro.configs.base import input_specs

        batch = input_specs(self.cfg, shape, n_nodes=max(self.g, 1))
        if self.g == 1:
            # flat batch for the degenerate placement
            batch = {
                k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()
            }
        fn = self.step_fn(epoch)
        p_abs, o_abs = self.abstract_state
        lr = jax.ShapeDtypeStruct((), jnp.float32)
        with jax.set_mesh(self.mesh):
            if self.g == 1:
                lowered = jax.jit(
                    self._node_step(self.topology.graph_at(epoch)),
                    in_shardings=(
                        self.param_shardings,
                        self.opt_shardings,
                        jax.tree.map(
                            lambda x: shd.batch_sharding(
                                self.mesh, (), len(x.shape), stacked=False
                            ),
                            batch,
                        ),
                        NamedSharding(self.mesh, P()),
                    ),
                    out_shardings=(
                        self.param_shardings,
                        self.opt_shardings,
                        NamedSharding(self.mesh, P()),
                        NamedSharding(self.mesh, P()),
                    ),
                ).lower(p_abs, o_abs, batch, lr)
            else:
                lowered = fn.lower(p_abs, o_abs, batch, lr)
        return lowered


# ---------------------------------------------------------------------------
# CLI launcher:  PYTHONPATH=src python -m repro.launch.train --arch granite-8b
# ---------------------------------------------------------------------------

def main() -> None:
    import argparse
    import time

    ap = argparse.ArgumentParser(description="decentralized training launcher")
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU-scale reduced config (default on CPU)")
    ap.add_argument("--topology", default="d_ada")
    ap.add_argument("--mixing", default="ppermute", choices=["ppermute", "dense"])
    ap.add_argument("--mix-every", type=int, default=1)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--steps-per-epoch", type=int, default=10)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--per-node-batch", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--lr-scaling", default="sqrt", choices=["none", "linear", "sqrt"])
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adamw", "lars"])
    ap.add_argument("--mesh", default="2,2", help="data,model (CPU uses host devices)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.core.dsgd import make_topology
    from repro.data import SyntheticLM
    from repro.launch.mesh import make_mesh
    from repro.optim.schedules import lr_scale
    from repro.optim.sgd import get_optimizer

    shape = tuple(int(x) for x in args.mesh.split(","))
    if len(jax.devices()) < shape[0] * shape[1]:
        raise SystemExit(
            f"mesh {shape} needs {shape[0]*shape[1]} devices but only "
            f"{len(jax.devices())} present — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={shape[0]*shape[1]}"
        )
    mesh = make_mesh(shape, ("data", "model"))
    cfg = get_config(args.arch + ("-reduced" if args.reduced or jax.default_backend() == "cpu" else ""))
    import dataclasses

    cfg = dataclasses.replace(cfg, name=args.arch)  # keep gossip placement
    g = shape[0]
    topo = make_topology(args.topology, g)
    trainer = SPMDTrainer(
        cfg, mesh, topo, get_optimizer(args.optimizer), collect_norms=True,
        mixing=args.mixing, mix_every=args.mix_every, donate=False,
    )
    print(topo.describe(), "| mesh", dict(mesh.shape), "| mixing", args.mixing)
    state = trainer.init_state(jax.random.PRNGKey(0))
    src = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, seed=0)
    scale = lr_scale(
        args.lr_scaling, global_batch=g * args.per_node_batch,
        base_batch=max(g * args.per_node_batch, 1), graph_degree=topo.degree_at(0),
    )
    t0 = time.time()
    for t in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in src.stacked(g, t, args.per_node_batch).items()}
        epoch = t // args.steps_per_epoch
        state, loss, norms = trainer.train_step(state, batch, args.lr * scale, epoch=epoch)
        if t % 5 == 0 or t == args.steps - 1:
            print(f"step {t:4d} k={topo.degree_at(epoch)} loss={float(loss.mean()):.4f} "
                  f"spread={float(loss.max() - loss.min()):.4f}")
        if args.ckpt_dir and args.ckpt_every and (t + 1) % args.ckpt_every == 0:
            from repro.checkpoint import save_checkpoint

            save_checkpoint(args.ckpt_dir, t + 1, {"p": state.params, "o": state.opt_state})
    print(f"{args.steps} steps in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
