"""Serving: prefill and decode step builders (per-replica, no gossip).

Serving is a single-replica workload: weights are sharded over the ``model``
axis only (replicated across data/pod axes); the request batch is sharded
over the non-model axes.  Decode states get explicit per-family shardings:

  kv cache   (L, B, slots, KV, Dh): batch over data axes; KV heads over
             ``model`` when divisible, else slots over ``model``.
  rwkv state (L, B, H, N, N): heads over ``model``.
  mamba      (..., B, H, P, N): heads over ``model``; conv tail d_inner over
             ``model``.

``long_500k`` (B = 1) cannot shard the batch: the cache slot dim takes the
combined (data, model) axes instead and full-attention archs run their
sliding-window ring cache (``cfg.sliding_window``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

try:  # jax-version shim (PR 1); degrade gracefully to the modern API
    from repro import compat as _compat
except ImportError:  # pragma: no cover
    _compat = None

from repro.configs.base import ArchConfig, InputShape
from repro.launch import sharding as shd
from repro.models import transformer as tfm
from repro.models.common import abstract_params, spec_tree

PyTree = Any

__all__ = ["ServeEngine", "DEFAULT_WINDOW"]

DEFAULT_WINDOW = 8192  # sliding window for full-attention archs on long_500k


def _divides(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


class ServeEngine:
    """Builds sharded prefill/decode steps for one (arch × mesh)."""

    def __init__(self, cfg: ArchConfig, mesh: jax.sharding.Mesh):
        self.cfg = cfg
        self.mesh = mesh
        self.data_axes = tuple(a for a in mesh.axis_names if a != "model")
        self.tp = mesh.shape.get("model", 1)
        self.defs = tfm.model_defs(cfg, tp_size=self.tp)
        self.param_shardings = shd.param_shardings(
            abstract_params(self.defs),
            spec_tree(self.defs),
            mesh,
            (),
            stacked=False,
            fsdp=False,
        )

    # -- sharding helpers -------------------------------------------------------
    def _batch_axes(self, b: int):
        size = int(np.prod([self.mesh.shape[a] for a in self.data_axes])) if self.data_axes else 1
        return self.data_axes if _divides(b, size) else None

    def _state_shardings(self, state_abs: PyTree, b: int) -> PyTree:
        batch_ax = self._batch_axes(b)
        model = "model"
        msize = self.tp
        combined = (
            tuple(self.data_axes) + ("model",) if batch_ax is None else None
        )
        csize = int(np.prod([self.mesh.shape[a] for a in self.mesh.axis_names]))

        def rule(leaf):
            shape = leaf.shape
            nd = len(shape)
            spec = [None] * nd
            # find the batch dim: the first dim equal to b after leading stack dims
            bdim = next(
                (i for i, s in enumerate(shape) if s == b and i <= 2), None
            )
            if bdim is not None and batch_ax is not None:
                spec[bdim] = batch_ax
            # shard one more dim over `model` (prefer head-like dims right of batch)
            start = (bdim + 1) if bdim is not None else 0
            cands = [i for i in range(start, nd) if spec[i] is None]
            # prefer later, smaller "head" dims over the huge slot dim when both work
            for i in sorted(cands, key=lambda i: (shape[i] > 1024, -i)):
                if _divides(shape[i], msize):
                    spec[i] = model
                    break
            # long-context B=1: put the combined axes on the big slot dim
            if batch_ax is None and combined:
                for i in cands:
                    if spec[i] is None and shape[i] >= csize and _divides(shape[i], csize):
                        if model in spec:
                            spec[spec.index(model)] = None
                        spec[i] = combined
                        break
            return NamedSharding(self.mesh, P(*spec))

        return jax.tree.map(rule, state_abs)

    # -- prefill -----------------------------------------------------------------
    def prefill_fn(self):
        # reference attention materializes (B, H, S, S) — never at 32k.
        # an explicit chunked-family override (e.g. chunked_skip) is honored.
        cfg = (
            self.cfg
            if self.cfg.attn_impl.startswith("chunked")
            else dataclasses.replace(self.cfg, attn_impl="chunked")
        )

        def fn(params, tokens, patch_embeds=None):
            return tfm.prefill(params, cfg, tokens, patch_embeds=patch_embeds)

        return fn

    def lower_prefill(self, shape: InputShape):
        from repro.configs.base import input_specs

        batch = input_specs(self.cfg, shape)
        b = shape.global_batch
        batch_ax = self._batch_axes(b)
        bspec = lambda nd: NamedSharding(self.mesh, P(batch_ax, *([None] * (nd - 1))))
        in_sh = jax.tree.map(lambda l: bspec(len(l.shape)), batch)
        fn = self.prefill_fn()
        args = (batch["tokens"],)
        in_shardings = (self.param_shardings, in_sh["tokens"])
        if "patch_embeds" in batch:
            args += (batch["patch_embeds"],)
            in_shardings += (in_sh["patch_embeds"],)
        with (_compat.set_mesh(self.mesh) if _compat is not None else jax.set_mesh(self.mesh)):
            return jax.jit(fn, in_shardings=in_shardings).lower(
                abstract_params(self.defs), *args
            )

    # -- decode ---------------------------------------------------------------------
    def decode_window(self, shape: InputShape) -> Optional[int]:
        """Sliding window if this arch needs one at this context length."""
        if self.cfg.family in ("ssm",):
            return None
        if shape.seq_len > 100_000:
            return self.cfg.sliding_window or DEFAULT_WINDOW
        return None

    def decode_fn(self, window: Optional[int]):
        cfg = self.cfg

        def fn(params, tokens, pos, state):
            return tfm.decode_step(params, cfg, tokens, pos, state, window=window)

        return fn

    def abstract_decode_state(self, shape: InputShape):
        window = self.decode_window(shape)
        return (
            jax.eval_shape(
                lambda: tfm.init_decode_state(
                    self.cfg, shape.global_batch, shape.seq_len, window=window,
                    tp_size=self.tp,
                )
            ),
            window,
        )

    def lower_decode(self, shape: InputShape):
        from repro.configs.base import input_specs

        state_abs, window = self.abstract_decode_state(shape)
        state_sh = self._state_shardings(state_abs, shape.global_batch)
        batch = input_specs(self.cfg, shape)
        batch_ax = self._batch_axes(shape.global_batch)
        tok_sh = NamedSharding(self.mesh, P(batch_ax, None))
        pos_sh = NamedSharding(self.mesh, P())
        fn = self.decode_fn(window)
        with (_compat.set_mesh(self.mesh) if _compat is not None else jax.set_mesh(self.mesh)):
            return jax.jit(
                fn,
                in_shardings=(self.param_shardings, tok_sh, pos_sh, state_sh),
                donate_argnums=(3,),
            ).lower(
                abstract_params(self.defs), batch["tokens"], batch["pos"], state_abs
            )

    # -- concrete serving loop (CPU-scale demo) ---------------------------------------
    def generate(
        self,
        params,
        prompts: jax.Array,
        n_new: int,
        *,
        patch_embeds=None,
        max_len: Optional[int] = None,
        temperature: float = 0.0,
        key: Optional[jax.Array] = None,
    ):
        """Batched greedy/sampled generation (runs on any mesh incl. CPU)."""
        cfg = self.cfg
        b, s0 = prompts.shape
        n_patches = cfg.n_patches if (cfg.input_kind == "vlm" and patch_embeds is not None) else 0
        max_len = max_len or (s0 + n_patches + n_new)
        logits, _ = tfm.prefill(params, cfg, prompts, patch_embeds=patch_embeds)
        # re-run prefill into a right-sized cache by decoding from scratch is
        # wasteful; instead allocate the full cache and replay the prompt.
        state = tfm.init_decode_state(cfg, b, max_len)
        pos = jnp.int32(0)
        last = None
        step = jax.jit(
            lambda p, t, ps, st: tfm.decode_step(p, cfg, t, ps, st)
        )
        if n_patches:
            # feed patch positions as a pseudo-prompt is out of scope for the
            # demo loop: VLM generation starts after text-only replay.
            pass
        for t in range(s0):
            last, state = step(params, prompts[:, t : t + 1], pos, state)
            pos = pos + 1
        out = []
        tok = None
        for i in range(n_new):
            if temperature > 0.0 and key is not None:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, last / temperature)[:, None]
            else:
                tok = jnp.argmax(last, axis=-1)[:, None]
            out.append(tok)
            last, state = step(params, tok, pos, state)
            pos = pos + 1
        return jnp.concatenate(out, axis=1)
