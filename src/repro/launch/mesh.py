"""Production meshes and gossip-axis placement.

single-pod : (16, 16)    ("data", "model")           — 256 chips (one v5e pod)
multi-pod  : (2, 16, 16) ("pod", "data", "model")    — 512 chips (2 pods)

The *gossip axes* enumerate decentralized nodes; the remaining axes shard
each node's replica (TP/EP over "model"; FSDP over "data" for the pod-level
placement).  Everything is a function — importing this module never touches
jax device state.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax

try:  # jax-version shim (PR 1); degrade gracefully to the modern API
    from repro import compat as _compat
except ImportError:  # pragma: no cover
    _compat = None

__all__ = [
    "make_production_mesh",
    "make_mesh",
    "gossip_axes_for",
    "gossip_size",
]


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> jax.sharding.Mesh:
    if _compat is not None:
        return _compat.make_mesh(shape, axes)
    return jax.make_mesh(
        tuple(shape),
        tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def gossip_axes_for(arch_name: str, mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Which mesh axes enumerate gossip nodes for an architecture.

    Default: every non-"model" axis is a gossip axis (node = one TP group).
    1T-scale MoE (kimi-k2): a replica needs the whole pod (FSDP x EP), so
    gossip runs across pods only — () on a single pod (degenerate G=1,
    decentralization scale-inapplicable; DESIGN.md §4), ("pod",) multi-pod.
    """
    names = tuple(mesh.axis_names)
    if arch_name.startswith("kimi-k2"):
        return ("pod",) if "pod" in names else ()
    return tuple(a for a in names if a != "model")


def gossip_size(mesh: jax.sharding.Mesh, gossip_axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in gossip_axes) if gossip_axes else 1
