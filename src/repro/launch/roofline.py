"""Roofline analysis over dry-run artifacts (TPU v5e targets).

Three terms per (arch × shape × mesh), all *seconds per step, per device*:

  compute    = HLO_FLOPs_per_device / peak_FLOPs        (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
  collective = wire_bytes_per_device / ICI_bw           (~50 GB/s per link)

``cost_analysis()`` of a compiled SPMD module describes one partition's
program, so its flops/bytes are already per-device.  Wire bytes come from
the HLO collective parse in ``dryrun.py`` (all-reduce counted 2× for the
ring schedule).

MODEL_FLOPS uses the 6·N_active·D rule (D = tokens processed per device per
step; decode: D = batch/device, one token each; the backward pass is counted
by the standard 3× multiplier for training).  The ratio
MODEL_FLOPS / HLO_FLOPs shows how much compiled compute is "useful"
(remat/capacity-factor/padding waste pushes it below 1; reference-attention
quadratic terms push HLO above the 6ND rule at long context).

Usage:
  PYTHONPATH=src python -m repro.launch.roofline --in dryrun_results.json [--md]
"""
from __future__ import annotations

import argparse
import json
import math

from repro.configs import get_config
from repro.configs.base import SHAPES, ArchConfig

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s / link

__all__ = ["active_params", "roofline_terms", "analyze"]


def _attn_params_per_layer(cfg: ArchConfig) -> int:
    dh = cfg.head_dim
    return cfg.d_model * dh * (2 * cfg.n_heads + 2 * cfg.n_kv)


def _ffn_params_per_layer(cfg: ArchConfig, active: bool) -> int:
    if not cfg.n_experts:
        return 3 * cfg.d_model * cfg.d_ff
    e = cfg.top_k if active else cfg.n_experts
    per_expert = 3 * cfg.d_model * cfg.d_ff
    shared = cfg.n_shared_experts * 3 * cfg.d_model * cfg.d_ff
    router = cfg.d_model * cfg.n_experts
    return e * per_expert + shared + router


def active_params(cfg: ArchConfig, *, total: bool = False) -> int:
    """N (dense) or N_active (MoE) excluding embeddings (standard 6ND rule)."""
    n = 0
    if cfg.family == "ssm":
        d, f = cfg.d_model, cfg.d_ff
        per_layer = 5 * d * d + 2 * d * 64 + (d * f + f * d + d * d)
        n = cfg.n_layers * per_layer
    elif cfg.family == "hybrid":
        group = cfg.attn_every
        n_groups, tail = divmod(cfg.n_layers, group)
        d_inner = 2 * cfg.d_model
        mamba = (
            2 * cfg.d_model * d_inner        # z, x proj
            + 2 * cfg.d_model * cfg.ssm_state
            + cfg.d_model * (d_inner // 64)
            + d_inner * cfg.d_model          # out proj
        )
        n_mamba = n_groups * (group - 1) + tail
        attn = _attn_params_per_layer(cfg) + 3 * cfg.d_model * cfg.d_ff
        n = n_mamba * mamba + n_groups * attn if not total else (
            n_mamba * mamba + attn  # weights are shared: stored once
        )
    else:
        per_layer = _attn_params_per_layer(cfg) + _ffn_params_per_layer(
            cfg, active=not total
        )
        n = cfg.n_layers * per_layer
    return n


def _mixer_flops_per_token(cfg: ArchConfig, context: float) -> float:
    """Sequence-mixing FLOPs per token at a given average context length.

    Attention: 4·H·Dh·context per layer (QKᵀ + PV, 2 flops each).
    RWKV wkv: ~8·D·Dh per layer (context-independent state ops).
    Mamba2 SSD: ~8·d_inner·N per layer.
    """
    if cfg.family == "ssm":
        dh = cfg.d_model // max(cfg.n_heads or cfg.d_model // 64, 1)
        return cfg.n_layers * 8.0 * cfg.d_model * dh
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
        n_mamba = cfg.n_layers - n_attn
        attn = n_attn * 4.0 * cfg.n_heads * cfg.head_dim * context
        mamba = n_mamba * 8.0 * (2 * cfg.d_model) * cfg.ssm_state
        return attn + mamba
    if cfg.n_heads == 0:
        return 0.0
    return cfg.n_layers * 4.0 * cfg.n_heads * cfg.head_dim * context


def model_flops_per_device(cfg: ArchConfig, shape, mesh_shape: dict, gossip_nodes: int) -> float:
    """6·N_active·D rule + sequence-mixing term, D = tokens per device."""
    n_dev = math.prod(mesh_shape.values())
    n_act = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        # causal: average context = S/2; backward = 2x forward
        mix = tokens * _mixer_flops_per_token(cfg, shape.seq_len / 2) * 3.0
        return (6.0 * n_act * tokens + mix) / n_dev
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mix = tokens * _mixer_flops_per_token(cfg, shape.seq_len / 2)
        return (2.0 * n_act * tokens + mix) / n_dev
    # decode: 1 token per sequence against a seq_len-deep context (window-
    # limited for the sliding-window archs on long_500k)
    ctx = shape.seq_len
    if shape.seq_len > 100_000 and cfg.family not in ("ssm",):
        ctx = min(ctx, cfg.sliding_window or 8192)
    mix = shape.global_batch * _mixer_flops_per_token(cfg, ctx)
    return (2.0 * n_act * shape.global_batch + mix) / n_dev


def roofline_terms(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    hlo = rec.get("hlo")
    if hlo:  # loop-aware accounting (scan bodies × trip counts)
        flops = hlo["dot_flops"]
        bytes_acc = hlo["traffic_bytes"]
        wire = hlo["total_wire_bytes"]
    else:  # legacy records: cost_analysis counts while bodies once
        flops = rec["cost"]["flops"]
        bytes_acc = rec["cost"]["bytes_accessed"]
        wire = rec.get("collectives", {}).get("total_wire_bytes", 0)
    t_c = flops / PEAK_FLOPS
    t_m = bytes_acc / HBM_BW
    t_x = wire / ICI_BW
    dominant = max(
        (("compute", t_c), ("memory", t_m), ("collective", t_x)), key=lambda kv: kv[1]
    )[0]
    mflops = model_flops_per_device(
        cfg, shape, rec["mesh_shape"], rec.get("gossip_nodes", 1)
    )
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "dominant": dominant,
        "model_flops": mflops,
        "useful_ratio": (mflops / flops) if flops else 0.0,
        "bound_s": max(t_c, t_m, t_x),
    }


def analyze(path: str) -> list[dict]:
    with open(path) as f:
        records = json.load(f)
    out = []
    for rec in records:
        if "error" in rec:
            out.append({**rec, "roofline": None})
            continue
        out.append({**rec, "roofline": roofline_terms(rec)})
    return out


def to_markdown(rows: list[dict]) -> str:
    hdr = (
        "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
        "| dominant | useful FLOP ratio |\n|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        if r.get("tag"):
            continue  # hillclimb variants live in §Perf, not the baseline table
        rf = r.get("roofline")
        if rf is None:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | ERROR | — |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['compute_s']*1e3:.2f} | {rf['memory_s']*1e3:.2f} "
            f"| {rf['collective_s']*1e3:.2f} | **{rf['dominant']}** "
            f"| {rf['useful_ratio']:.2f} |"
        )
    return hdr + "\n".join(lines)


def _fmt_bytes(b: float) -> str:
    if b >= 1e9:
        return f"{b/2**30:.2f} GiB"
    if b >= 1e6:
        return f"{b/2**20:.1f} MiB"
    return f"{b/2**10:.0f} KiB"


def dryrun_markdown(rows: list[dict], mesh: str) -> str:
    """§Dry-run table: per-device memory + collective schedule."""
    out = [
        "| arch | shape | gossip | compile (s) | args/dev | temp/dev "
        "| collective schedule (loop-aware, per device/step) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        if r.get("mesh") != mesh or r.get("roofline") is None or r.get("tag"):
            continue
        m = r["memory"]
        h = r.get("hlo", {})
        colls = ", ".join(
            f"{k}×{v}" for k, v in sorted(h.get("coll_counts", {}).items())
        ) or "—"
        wire = _fmt_bytes(h.get("total_wire_bytes", 0))
        gossip = r.get("graph", "—") if r.get("kind") == "train" else "serving"
        out.append(
            f"| {r['arch']} | {r['shape']} | {gossip.split('(')[0]} "
            f"| {r.get('compile_s', 0)} | {_fmt_bytes(m['argument_bytes'])} "
            f"| {_fmt_bytes(m['temp_bytes'])} | {colls} = {wire} |"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="dryrun_results.json")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--dryrun-md", metavar="MESH", help="emit §Dry-run table for a mesh")
    args = ap.parse_args()
    rows = analyze(args.inp)
    if args.dryrun_md:
        print(dryrun_markdown(rows, args.dryrun_md))
        return
    if args.md:
        print(to_markdown(rows))
        return
    for r in rows:
        rf = r.get("roofline")
        tag = f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s}"
        if rf is None:
            print(f"{tag} ERROR: {r.get('error', '?')[:80]}")
        else:
            print(
                f"{tag} C={rf['compute_s']*1e3:8.2f}ms M={rf['memory_s']*1e3:8.2f}ms "
                f"X={rf['collective_s']*1e3:8.2f}ms -> {rf['dominant']:10s} "
                f"useful={rf['useful_ratio']:.2f}"
            )


if __name__ == "__main__":
    main()
