"""Validate dry-run records against the analytic FLOP model; list outliers.

A record is suspect when its loop-aware ``hlo.dot_flops`` is far below the
6·N_active·D model (trip counts not applied — e.g. records written by a
stale worker) or zero where compute must exist.  Prints suspect
(arch, shape, mesh) triples; ``--fix`` deletes them from the artifact so a
``--skip-existing`` re-run regenerates exactly those.

  PYTHONPATH=src python -m repro.launch.validate_dryrun --in dryrun_results_v2.json [--fix]
"""
from __future__ import annotations

import argparse
import json

from repro.configs.base import SHAPES
from repro.launch.roofline import model_flops_per_device
from repro.configs import get_config


def is_suspect(rec: dict) -> str | None:
    if "error" in rec:
        return "error"
    hlo = rec.get("hlo")
    if not hlo:
        return "no-hlo"
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    mflops = model_flops_per_device(
        cfg, shape, rec["mesh_shape"], rec.get("gossip_nodes", 1)
    )
    dot = hlo.get("dot_flops", 0.0)
    if dot <= 0:
        return "zero-dot-flops"
    # allow [0.3, 6]x of analytic: remat adds ~1.33x, attention quadratic adds
    # more at long context, capacity factors ~1.25x; a missing layer-loop
    # multiplier shows up as ~L-fold (>= 20x) deficit.
    ratio = dot / mflops
    if ratio < 0.3:
        return f"dot/model={ratio:.3f} (trip counts likely missing)"
    if ratio > 8.0:
        return f"dot/model={ratio:.1f} (double counting?)"
    return None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="dryrun_results_v2.json")
    ap.add_argument("--fix", action="store_true")
    args = ap.parse_args()
    with open(args.inp) as f:
        records = json.load(f)
    keep, bad = [], []
    for r in records:
        why = is_suspect(r)
        if why:
            bad.append((r["arch"], r["shape"], r["mesh"], why))
        else:
            keep.append(r)
    for arch, shape, mesh, why in bad:
        print(f"SUSPECT {arch:24s} {shape:12s} {mesh:6s} {why}")
    print(f"{len(keep)} ok, {len(bad)} suspect")
    if args.fix and bad:
        with open(args.inp, "w") as f:
            json.dump(keep, f, indent=1)
        print(f"removed {len(bad)} records from {args.inp}")


if __name__ == "__main__":
    main()
