"""Logical partition specs → mesh shardings.

Model modules declare per-weight logical specs (entries: None | "model",
see ``models/common.ParamDef``).  This module materializes them for a
concrete mesh and gossip placement:

  * gossip placement (G > 1): every leaf gains a leading stacked-replica dim
    sharded over the gossip axes: P(gossip_axes, *logical).
  * degenerate placement (G == 1, e.g. kimi-k2 on one pod): no stacking;
    instead remaining non-model axes FSDP-shard the largest divisible
    unsharded dim of each leaf.

Divisibility is always validated against the mesh — a spec that does not
divide falls back to replication on that dim (never a compile error).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

PyTree = Any

__all__ = [
    "leaf_sharding",
    "param_shardings",
    "stack_abstract",
    "batch_sharding",
    "tree_size_bytes",
]


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def _validated_spec(shape, spec_entries, mesh) -> list:
    out = []
    for dim, ax in zip(shape, spec_entries):
        if ax is not None and dim % _axis_size(mesh, ax) == 0:
            out.append(ax)
        else:
            out.append(None)
    return out


def leaf_sharding(
    shape: tuple[int, ...],
    logical: tuple[Optional[str], ...],
    mesh: jax.sharding.Mesh,
    gossip_axes: tuple[str, ...],
    *,
    stacked: bool,
    fsdp: bool = False,
) -> NamedSharding:
    """Sharding for one (possibly gossip-stacked) weight leaf."""
    if stacked:
        entries = _validated_spec(shape[1:], logical, mesh)
        return NamedSharding(mesh, P(gossip_axes, *entries))
    entries = _validated_spec(shape, logical, mesh)
    if fsdp and any(e not in (None, "model") for e in entries):
        fsdp = False  # leaf already uses a data/pod axis explicitly
    if fsdp:
        fsdp_axes = tuple(a for a in mesh.axis_names if a != "model")
        for cand in (fsdp_axes, fsdp_axes[-1:] if fsdp_axes else ()):
            size = _axis_size(mesh, cand) if cand else 1
            if not cand:
                continue
            # shard the largest still-unsharded divisible dim
            dims = sorted(
                (d for d in range(len(shape)) if entries[d] is None),
                key=lambda d: -shape[d],
            )
            for d in dims:
                if shape[d] % size == 0:
                    entries[d] = cand
                    break
            else:
                continue
            break
    return NamedSharding(mesh, P(*entries))


def param_shardings(
    abstract: PyTree,
    logical_specs: PyTree,
    mesh: jax.sharding.Mesh,
    gossip_axes: tuple[str, ...],
    *,
    stacked: bool,
    fsdp: bool = False,
) -> PyTree:
    """Shardings for a whole (possibly stacked) abstract param tree.

    ``logical_specs`` mirrors the *unstacked* tree; when ``stacked`` the
    abstract leaves carry the extra leading G dim.
    """
    return jax.tree.map(
        lambda leaf, spec: leaf_sharding(
            leaf.shape, spec, mesh, gossip_axes, stacked=stacked, fsdp=fsdp
        ),
        abstract,
        logical_specs,
    )


def stack_abstract(abstract: PyTree, g: int) -> PyTree:
    """Prepend the gossip-replica dim to an abstract tree."""
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((g,) + tuple(l.shape), l.dtype), abstract
    )


def batch_sharding(
    mesh: jax.sharding.Mesh,
    gossip_axes: tuple[str, ...],
    ndim: int,
    *,
    stacked: bool,
) -> NamedSharding:
    """Training batches: (G, b, ...) with G over gossip axes (stacked), or
    (B, ...) with B over all non-model axes (G == 1)."""
    if stacked:
        return NamedSharding(mesh, P(gossip_axes, *([None] * (ndim - 1))))
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    return NamedSharding(mesh, P(data_axes if data_axes else None, *([None] * (ndim - 1))))


def tree_size_bytes(tree: PyTree) -> int:
    return sum(
        int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
        for l in jax.tree.leaves(tree)
    )
