"""Loop-aware HLO cost extraction.

XLA's ``compiled.cost_analysis()`` visits a ``while`` body **once**, so any
module with ``lax.scan`` (layers, KV chunks, grad accumulation) under-counts
flops/bytes/collectives by the trip count.  This module re-derives costs from
the compiled HLO text with loop multipliers applied:

  * computations are parsed into op lists (result type, operand refs, attrs)
  * ``while`` ops carry ``backend_config={"known_trip_count":{"n":N}}`` —
    the body/condition computation costs are scaled by N (nested loops
    multiply)
  * collective wire bytes: result bytes per op (all-reduce counted 2× for
    the ring schedule), summed loop-aware
  * HBM traffic estimate: for every materializing top-level op (fusion, dot,
    copy, convolution, custom-call, collectives), reads = operand bytes,
    writes = result bytes — post-fusion HLO top-level ops are kernel
    launches, so this approximates actual memory movement
  * dot FLOPs: 2 · |result| · contraction-size, loop-aware

This powers the §Roofline terms; the raw (once-counted) ``cost_analysis``
numbers are kept in the dry-run artifact for comparison.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict

__all__ = [
    "analyze_hlo",
    "collective_counts",
    "assert_no_all_gather",
    "CollectiveReport",
    "COLLECTIVE_KINDS",
]

COLLECTIVE_KINDS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_ZERO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "iota", "after-all", "add-dependency", "broadcast", "reshape",
    "partition-id", "replica-id",
}

_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8\w*|s64|s32|u32|s16|u16|s8|u8|pred|u64)\[([\d,]*)\]"
)
_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    # 8-bit floats: XLA prints a family of names (f8e4m3, f8e4m3fn,
    # f8e5m2, f8e4m3b11fnuz, ...) — _SHAPE_RE matches them as f8\w*, and
    # _dtype_width resolves any unlisted variant to 1 byte by bit-width.
    "f8e4m3": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "f8e4m3fnuz": 1, "f8e5m2fnuz": 1, "f8e3m4": 1,
}
_WIDTH_RE = re.compile(r"^[a-z]+?(\d+)")


def _dtype_width(dt: str) -> int:
    """Byte width of an HLO dtype token, with a bit-width fallback.

    Anything _SHAPE_RE can match but the table misses (new f8 variants,
    future narrow types) derives its width from the leading digit group of
    the name — f8e8m0 → 1, s4 → 1 (sub-byte rounds up) — instead of the
    old silent ``.get(dt, 4)`` that billed every unknown dtype 4 bytes.
    """
    w = _DTYPE_BYTES.get(dt)
    if w is not None:
        return w
    m = _WIDTH_RE.match(dt)
    if m:
        return max(1, int(m.group(1)) // 8)
    return 4
# result type is matched lazily up to the first "kind(" token: tuple types
# contain parens and /*index=N*/ comments, so anything stricter misparses
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _type_bytes(type_str: str):
    """(total bytes, first-shape dims) of an HLO type string."""
    total = 0
    first_dims = None
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        dl = [int(d) for d in dims.split(",")] if dims else []
        n = 1
        for d in dl:
            n *= d
        total += n * _dtype_width(dt)
        if first_dims is None:
            first_dims = dl
    return total, (first_dims or [])


def _dtype_nbytes(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    return _dtype_width(m.group(1)) if m else 4


class _Op:
    __slots__ = ("name", "kind", "rbytes", "rdims", "operands", "attrs", "rtype")

    def __init__(self, name, kind, rtype, operands, attrs):
        self.name = name
        self.kind = kind
        self.rtype = rtype
        self.rbytes, self.rdims = _type_bytes(rtype)
        self.operands = operands
        self.attrs = attrs


def _parse_computations(text: str) -> dict[str, list[_Op]]:
    comps: dict[str, list[_Op]] = {}
    cur = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_START.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rtype, kind, rest = m.groups()
        # operands: %refs before the closing paren of the op call; attrs after
        depth, i = 1, 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str, attrs = rest[:i], rest[i + 1 :]
        operands = _OPERAND_RE.findall(operand_str)
        comps[cur].append(_Op(name, kind, rtype, operands, attrs))
    return comps


def _hlo_text_of(fn_or_hlo, *args) -> str:
    """Compiled HLO text of a (jitted) callable on ``args``, or pass through
    an already-extracted HLO string."""
    if isinstance(fn_or_hlo, str):
        return fn_or_hlo
    lowered = fn_or_hlo.lower(*args)
    return lowered.compile().as_text()


class CollectiveReport(dict):
    """Structured collective inventory of one compiled executable.

    A dict subclass — ``report["collective-permute"]``, ``.get``, equality
    with plain count dicts, all pre-existing callers keep working — that
    additionally carries ``op_names``: kind → tuple of the *static* HLO op
    names of that kind (one entry per op in the module text; the dict
    values stay the loop-aware dynamic counts, so a permute inside a
    trip-8 while shows count 8 but one op name).  Consumed by the
    ``repro.analysis.collectives`` deadlock linter.
    """

    def __init__(self, counts=None, op_names=None, wire_bytes=None):
        super().__init__(counts or {})
        self.op_names: dict[str, tuple[str, ...]] = {
            k: tuple(v) for k, v in (op_names or {}).items()
        }
        self.wire_bytes: dict[str, int] = dict(wire_bytes or {})

    @property
    def total(self) -> int:
        return int(sum(self.values()))

    def offending(self, forbid) -> dict[str, tuple[str, ...]]:
        """kind → op names for every forbidden kind present (count > 0)."""
        return {
            k: self.op_names.get(k, ())
            for k in self
            if k in forbid and self[k]
        }

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"CollectiveReport({dict(self)!r}, op_names={self.op_names!r})"


def collective_counts(fn_or_hlo, *args) -> "CollectiveReport":
    """Loop-aware collective-op counts of a compiled function's HLO.

    Returns a ``CollectiveReport`` (dict-compatible: kind → count) whose
    ``op_names`` attribute lists the offending HLO op names per kind.
    """
    info = analyze_hlo(_hlo_text_of(fn_or_hlo, *args))
    return CollectiveReport(
        info.get("coll_counts", {}),
        op_names=info.get("coll_ops", {}),
        wire_bytes=info.get("wire_bytes", {}),
    )


def assert_no_all_gather(fn_or_hlo, *args, forbid=("all-gather",)) -> "CollectiveReport":
    """Assert the compiled HLO carries none of the ``forbid`` collectives.

    The acceptance bar for the sparse mixing compiler: a colorable graph
    (circulant, matching, edge-colored star/irregular) must lower to
    collective-permutes only — any all-gather means the dense GatherRow
    fallback leaked back onto the hot path.  Accepts a jitted callable plus
    its example args (lowered and compiled here) or a raw HLO string.
    Returns the full ``CollectiveReport`` for further assertions.
    """
    report = collective_counts(fn_or_hlo, *args)
    bad = {k: v for k, v in report.items() if k in forbid and v}
    if bad:
        names = report.offending(forbid)
        raise AssertionError(
            f"forbidden collectives in lowered HLO: {bad} "
            f"(ops: {names}, all counts: {dict(report)})"
        )
    return report


def analyze_hlo(text: str) -> dict:
    comps = _parse_computations(text)
    # map op name -> op for operand/shape lookup (types live at def sites)
    op_index: dict[str, "_Op"] = {}
    for ops in comps.values():
        for op in ops:
            op_index[op.name] = op
    def_bytes = {k: v.rbytes for k, v in op_index.items()}

    # fusion-called computations must not be traversed (their ops are fused)
    fused_comps: set[str] = set()
    for ops in comps.values():
        for op in ops:
            if op.kind == "fusion":
                for c in _CALLS_RE.findall(op.attrs):
                    fused_comps.add(c)

    def comp_cost(cname: str, seen: tuple) -> dict:
        """Loop-aware cost of one computation (recursive, multiplier-free)."""
        out = {
            "wire": defaultdict(float),
            "traffic": 0.0,
            "dot_flops": 0.0,
            "coll_count": defaultdict(float),
            "coll_ops": defaultdict(list),
        }
        if cname in seen or cname not in comps:
            return out

        def merge_sub(sub, mult=1):
            for k in ("traffic", "dot_flops"):
                out[k] += mult * sub[k]
            for k, v in sub["wire"].items():
                out["wire"][k] += mult * v
            for k, v in sub["coll_count"].items():
                out["coll_count"][k] += mult * v
            # op names are static module text — never loop-multiplied
            for k, v in sub["coll_ops"].items():
                out["coll_ops"][k].extend(v)

        for op in comps[cname]:
            if op.kind == "while":
                n = 1
                tm = _TRIP_RE.search(op.attrs)
                if tm:
                    n = int(tm.group(1))
                for sub_re in (_BODY_RE, _COND_RE):
                    sm = sub_re.search(op.attrs)
                    if sm:
                        merge_sub(comp_cost(sm.group(1), seen + (cname,)), n)
                continue
            if op.kind in ("conditional",):
                branches = _BRANCHES_RE.search(op.attrs)
                names = (
                    _OPERAND_RE.findall(branches.group(1)) if branches else []
                ) or _CALLS_RE.findall(op.attrs)
                for cn in names:
                    merge_sub(comp_cost(cn, seen + (cname,)))
                continue
            if op.kind == "call":
                for cn in _CALLS_RE.findall(op.attrs):
                    if cn in fused_comps:
                        continue
                    merge_sub(comp_cost(cn, seen + (cname,)))
                continue

            if op.kind in COLLECTIVE_KINDS or op.kind.rstrip("-start") in COLLECTIVE_KINDS:
                kind = op.kind[:-6] if op.kind.endswith("-start") else op.kind
                if kind.endswith("-done"):
                    continue
                wire = 2 * op.rbytes if kind == "all-reduce" else op.rbytes
                out["wire"][kind] += wire
                out["coll_count"][kind] += 1
                out["coll_ops"][kind].append(op.name)
                out["traffic"] += op.rbytes + sum(
                    def_bytes.get(o, 0) for o in op.operands
                )
                continue

            if op.kind == "dot":
                # flops = 2 * |result| * contraction size
                res_elems = 1
                for d in op.rdims:
                    res_elems *= d
                csize = 1
                cm = _CDIMS_RE.search(op.attrs)
                if cm and op.operands:
                    lhs_bytes = def_bytes.get(op.operands[0], 0)
                    # recover lhs dims from its def line is indirect; use the
                    # contracting size via bytes ratio when possible
                    cdims = [int(x) for x in cm.group(1).split(",") if x]
                    lhs_op = op_index.get(op.operands[0])
                    if lhs_op is not None:
                        for d in cdims:
                            if d < len(lhs_op.rdims):
                                csize *= lhs_op.rdims[d]
                out["dot_flops"] += 2.0 * res_elems * csize
                out["traffic"] += op.rbytes + sum(
                    def_bytes.get(o, 0) for o in op.operands
                )
                continue

            if op.kind in _ZERO_TRAFFIC:
                continue
            # materializing op (fusion, copy, custom-call, scatter, sort, ...)
            out["traffic"] += op.rbytes + sum(def_bytes.get(o, 0) for o in op.operands)
            if op.kind == "fusion":
                # dots inside loop fusions: count their flops too
                for cn in _CALLS_RE.findall(op.attrs):
                    sub = comps.get(cn, [])
                    for sop in sub:
                        if sop.kind == "dot":
                            res_elems = 1
                            for d in sop.rdims:
                                res_elems *= d
                            csize = 1
                            cm = _CDIMS_RE.search(sop.attrs)
                            lhs_op = op_index.get(sop.operands[0]) if sop.operands else None
                            if cm and lhs_op is not None:
                                for d in [int(x) for x in cm.group(1).split(",") if x]:
                                    if d < len(lhs_op.rdims):
                                        csize *= lhs_op.rdims[d]
                            out["dot_flops"] += 2.0 * res_elems * csize
        return out

    # entry = last computation defined (HLO prints ENTRY last) or the one
    # named like the module; detect via "ENTRY" marker
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None or entry not in comps:
        entry = list(comps)[-1] if comps else None
    if entry is None:
        return {"wire_bytes": {}, "total_wire_bytes": 0, "traffic_bytes": 0.0, "dot_flops": 0.0}

    cost = comp_cost(entry, ())
    return {
        "wire_bytes": {k: int(v) for k, v in cost["wire"].items()},
        "coll_counts": {k: int(v) for k, v in cost["coll_count"].items()},
        "coll_ops": {k: tuple(v) for k, v in cost["coll_ops"].items()},
        "total_wire_bytes": int(sum(cost["wire"].values())),
        "traffic_bytes": float(cost["traffic"]),
        "dot_flops": float(cost["dot_flops"]),
    }


