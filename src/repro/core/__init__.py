"""Core: the paper's contribution — decentralized data parallelism.

graphs     communication graphs (circulant fast path + general edge graphs:
           ring/torus/ring-lattice/exponential/complete, one-peer
           exponential, random matchings, star, from_adjacency)
schedule   the mixing-program IR: compile any graph into a GossipProgram
           with dense / stacked / shard_map interpreters
mixing     thin façade over the IR (dense / shift / ppermute wrappers)
ada        Ada adaptive ring-lattice schedule (Algorithm 1, + one-peer floor)
dsgd       topology registry (epoch- and step-granular program schedules)
dbench     white-box variance instrumentation (gini et al., rank analysis)
simulator  vmap-based paper-faithful multi-node engine (CPU oracle)
"""
from repro.core.ada import AdaSchedule, default_k0
from repro.core.dsgd import TOPOLOGIES, Topology, make_topology
from repro.core.graphs import (
    CirculantGraph, CommGraph, Complete, EdgeGraph, Exponential, Ring,
    RingLattice, Star, Torus, from_adjacency, make_graph,
    one_peer_exponential, random_matching, spectral_gap,
)
from repro.core.schedule import (
    GossipProgram, compile_graph, dense_program, identity_program,
)
from repro.core.simulator import DecentralizedSimulator, SimState
