"""Core: the paper's contribution — decentralized data parallelism.

graphs     communication graphs (ring/torus/ring-lattice/exponential/complete)
mixing     dense / circulant-shift / ppermute gossip realizations
ada        Ada adaptive ring-lattice schedule (Algorithm 1)
dsgd       topology registry for the five SGD implementations (+ Ada)
dbench     white-box variance instrumentation (gini et al., rank analysis)
simulator  vmap-based paper-faithful multi-node engine (CPU oracle)
"""
from repro.core.ada import AdaSchedule, default_k0
from repro.core.dsgd import TOPOLOGIES, Topology, make_topology
from repro.core.graphs import (
    CommGraph, Complete, Exponential, Ring, RingLattice, Torus, make_graph,
    spectral_gap,
)
from repro.core.simulator import DecentralizedSimulator, SimState
