"""Mixing-program IR: compile any communication graph into a gossip program.

A ``GossipProgram`` is a small list of primitive communication ops that
realizes one mixing step  θ ← W θ  for an n-node gossip graph:

  * ``PPermute(perm, weight[, offset])`` — every node receives one weighted
    neighbor buffer along a permutation (a single collective-permute on the
    wire).  ``offset`` marks the circulant special case (perm is the shift
    ``i ← i+d``), which the stacked interpreter realizes as one ``jnp.roll``.
  * ``AllReduce()``                      — uniform average over all nodes
    (ring all-reduce; the complete-graph fast path).
  * ``GatherRow(w)``                     — dense fallback: gather all
    replicas, contract with this node's row of W.  Exact for *any* W; costs
    an all-gather (kept for the paper-faithful dense baseline and irregular
    graphs with no sparse decomposition).

Program semantics (all interpreters agree to float32 accumulation):

    out = self_weight ⊙ x + Σ_op op(x)

with ``self_weight`` a scalar or per-node vector (irregular graphs weight
their own replica differently per node).

Three interpreters share the single compiled program:

  * ``apply_dense``   — dense mixing-matrix einsum over the stacked replica
                        axis.  The paper-faithful oracle.
  * ``apply_stacked`` — rolls/gathers over the stacked axis (vmap engine;
                        under jit on a sharded axis XLA lowers each roll to
                        collective-permutes).
  * ``apply_shard``   — explicit collectives inside ``shard_map`` (SPMD
                        production engine): one ``jax.lax.ppermute`` per
                        ``PPermute``, ``pmean`` for ``AllReduce``,
                        all-gather + row contraction for ``GatherRow``.

``compile_graph`` picks the cheapest faithful realization:
circulant graph → one PPermute per offset; complete graph → AllReduce;
any other ``EdgeGraph`` (matchings, the star, arbitrary irregular graphs) →
an **edge-colored permute program**: the edge set is partitioned into
≤ Δ+1 matchings (Vizing's theorem, constructive Misra–Gries coloring with
a greedy fast path), each matching becomes one per-node-weighted PPermute,
and the diagonal of W rides in ``self_weight``.  The decomposition is
verified against W exactly at compile time; only if it cannot reproduce W
does the compiler fall back to the ``GatherRow`` dense all-gather.  A star
at n = 1008 therefore moves O(Δ) buffers per step instead of the O(n·P)
all-gather.

Multi-step fusion: ``GossipProgram.fuse`` composes H consecutive programs
(e.g. a full one-peer exponential cycle) into one ``FusedProgram`` whose
interpreters run all H rounds inside a single jitted executable — H
dispatches become one, and engines cache it under one key.

Programs are frozen/hashable: both engines key their compiled-executable
caches on the program, so time-varying topologies rotate through a bounded
executable set — one XLA compile per distinct program at its first use and
zero recompiles thereafter (``Topology.distinct_programs`` enumerates the
set up front).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Any, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

try:  # jax-version shim (PR 1); degrade gracefully when absent
    from repro import compat as _compat
except ImportError:  # pragma: no cover
    _compat = None

PyTree = Any

__all__ = [
    "PPermute",
    "AllReduce",
    "GatherRow",
    "GossipProgram",
    "FusedProgram",
    "compile_graph",
    "degraded_matrix",
    "dense_program",
    "edge_coloring",
    "hub_balanced_rounds",
    "identity_program",
    "maybe_hub_balanced",
    "permutation_for_offset",
    "program_comm_bytes",
    "program_max_node_bytes",
]


# ---------------------------------------------------------------------------
# Primitive ops
# ---------------------------------------------------------------------------

def permutation_for_offset(n: int, d: int) -> tuple[tuple[int, int], ...]:
    """ppermute pairs so that node i receives from node (i + d) % n."""
    return tuple(((i + d) % n, i) for i in range(n))


@dataclasses.dataclass(frozen=True)
class PPermute:
    """Receive one weighted buffer along a permutation.

    perm: (src, dst) pairs; a dst absent from the list receives zeros.
    weight: scalar, or per-dst-node tuple of length n (applied at receiver).
    offset: when the perm is the circulant shift ``dst ← dst + offset``,
      the stacked interpreter uses one ``jnp.roll`` instead of a gather.
    """

    perm: tuple[tuple[int, int], ...]
    weight: Union[float, tuple[float, ...]]
    offset: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class AllReduce:
    """Uniform average over all nodes (contributes J/n to W)."""


@dataclasses.dataclass(frozen=True)
class GatherRow:
    """Dense fallback: all-gather replicas, contract with this node's W row.

    w: the full n×n mixing matrix (including the diagonal) as nested tuples.
    """

    w: tuple[tuple[float, ...], ...]


Op = Union[PPermute, AllReduce, GatherRow]


# ---------------------------------------------------------------------------
# The program
# ---------------------------------------------------------------------------

def _weight_column(weight, n: int) -> np.ndarray:
    if isinstance(weight, tuple):
        return np.asarray(weight, dtype=np.float64)
    return np.full(n, float(weight), dtype=np.float64)


def degraded_matrix(w, alive, link_up=None) -> np.ndarray:
    """The fault-degraded mixing matrix W' (the dense oracle, float64).

    Every off-diagonal entry whose edge is down — either endpoint not in
    ``alive``, or the link itself masked by ``link_up`` — is zeroed and its
    mass moved onto the *receiver's* diagonal, so W' stays row-stochastic
    for any W, symmetric when W and the masks are symmetric (and therefore
    doubly stochastic when W is).  A node that loses every edge — dead, or
    isolated by link failures — self-averages: its row becomes identity and
    its parameters are untouched by the mixing step.

    This single rule is the semantic shared by ``GossipProgram.degrade``
    (the pre-enumerated permanent-crash program transform), the runtime
    masked interpreters (``apply_masked`` / ``apply_shard_masked``), and
    the in-kernel renormalization of the fused Pallas apply: all three
    realize exactly this matrix for the same masks.

    Two consequences the elastic-membership subsystem relies on:

    *Composition.*  Degrading only zeroes off-diagonal entries and moves
    their mass to the receiver diagonal, so degrading by mask A and then
    runtime-masking by mask B realizes exactly ``degraded_matrix(W, A∩B)``
    — a k-node concurrent crash composes runtime masks over the existing
    single-node-out programs and needs NO multi-node-out enumeration.

    *Float masks.*  The formula is linear in ``alive``: a value b > 1 at
    node d scales every edge weight touching d by b (the excess subtracted
    from the receiver's diagonal).  A symmetric float mask keeps W'
    symmetric and row sums at 1, so W' stays doubly stochastic and the
    global mean is preserved — the mean-preserving preemption drain
    (``faults.Preemption``) up-weights a departing node exactly this way.
    Nonnegativity bounds the boost: node d's diagonal needs
    ``w_dd >= (b-1) * sum_j w_dj``.

    *Ghost ranks.*  A rank masked dead from step 0 (``faults.SparePool``'s
    spare, alive = 0 throughout) degrades to the exact identity row AND
    column: it is an inert fixed point of the mixing and the alive block
    stays doubly stochastic.  Over-provisioning a mesh with such ghosts is
    therefore free in the mixing math, and *activating* one — flipping its
    mask to 1 at an elastic join — is just a different runtime realization
    of the same W: no re-formation, no new programs.
    """
    w = np.asarray(w, dtype=np.float64)
    n = w.shape[0]
    alive = np.asarray(alive, dtype=np.float64).reshape(n)
    em = np.outer(alive, alive)
    if link_up is not None:
        em = em * np.asarray(link_up, dtype=np.float64)
    off = w * em
    np.fill_diagonal(off, 0.0)
    return off + np.diag(1.0 - off.sum(axis=1))


def _flat_axis_index(axis_names):
    """Node index along (possibly multiple) manual mesh axes."""
    if isinstance(axis_names, str):
        return jax.lax.axis_index(axis_names)
    idx = jnp.zeros((), jnp.int32)
    for a in axis_names:
        size = (
            _compat.axis_size(a)
            if _compat is not None
            else jax.lax.psum(jnp.ones((), jnp.int32), a)
        )
        idx = idx * size + jax.lax.axis_index(a)
    return idx


@dataclasses.dataclass(frozen=True)
class GossipProgram:
    """A compiled mixing schedule: out = self_weight ⊙ x + Σ_op op(x)."""

    name: str
    n: int
    ops: tuple[Op, ...]
    self_weight: Union[float, tuple[float, ...]] = 0.0

    # -- views ---------------------------------------------------------------
    @property
    def cache_key(self):
        """Cheap hashable identity for per-executable step caches.

        Computed once per program: dict lookups must not re-hash the op
        tuple every training step (a GatherRow at n=1008 holds ~1M floats).
        The sha256 digest of the canonical repr makes collisions across
        distinct programs practically impossible.
        """
        key = self.__dict__.get("_cache_key")
        if key is None:
            import hashlib

            digest = hashlib.sha256(
                repr((self.n, self.ops, self.self_weight)).encode()
            ).hexdigest()[:32]
            key = (self.name, self.n, digest)
            object.__setattr__(self, "_cache_key", key)
        return key

    @property
    def is_identity(self) -> bool:
        return not self.ops

    @property
    def num_collectives(self) -> int:
        return len(self.ops)

    def matrix(self) -> np.ndarray:
        """The dense (n, n) mixing matrix W this program realizes (float64)."""
        return _program_matrix(self)

    def describe(self) -> str:
        kinds = [type(op).__name__ for op in self.ops]
        return f"{self.name}(n={self.n}, ops=[{', '.join(kinds)}])"

    def permute_tables(self):
        """Dense per-node tables for an all-PPermute program, or ``None``.

        Returns ``(srcs, weights)`` with ``srcs`` an (n, deg) int32 array —
        ``srcs[i, k]`` is the node whose buffer node i receives in permute
        round k (itself when i idles that round) — and ``weights`` an
        (n, deg+1) float32 array ``[self, w_1 .. w_deg]`` whose masked
        entries are 0.  This is the layout the fused Pallas kernel consumes:
        each node's weight row is one (deg+1,) SMEM vector.
        """
        if not self.ops or not all(isinstance(op, PPermute) for op in self.ops):
            return None
        n, deg = self.n, len(self.ops)
        srcs = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, deg))
        weights = np.zeros((n, deg + 1), dtype=np.float32)
        weights[:, 0] = _weight_column(self.self_weight, n)
        for k, op in enumerate(self.ops):
            wv = _weight_column(op.weight, n)
            for s, d in op.perm:
                srcs[d, k] = s
                weights[d, k + 1] = wv[d]
        return srcs, weights

    def degrade(self, alive) -> "GossipProgram":
        """The program for the surviving membership ``alive`` ((n,) bools).

        Removes every permute pair with a dead endpoint and renormalizes by
        moving the dropped weight onto the receiver's self weight, so the
        result realizes exactly ``degraded_matrix(self.matrix(), alive)``:
        still row-stochastic, symmetric when the base is, dead/isolated
        nodes self-averaging.  Programs with non-permute ops (AllReduce /
        GatherRow) fall back to one GatherRow of the degraded dense matrix.

        This is the *permanent-crash* path: each alive-set yields one new
        (cached, hashable) program, pre-enumerated by
        ``Topology.distinct_programs`` so crashes never recompile mid-run.
        Transient faults instead keep the base program and feed runtime
        masks to ``apply_masked`` — same matrix, zero new executables.
        """
        alive_t = tuple(bool(a) for a in np.asarray(alive).reshape(-1))
        if len(alive_t) != self.n:
            raise ValueError(f"alive mask has {len(alive_t)} entries, n={self.n}")
        if all(alive_t):
            return self
        return _degrade_cached(self, alive_t)

    # -- runtime-masked interpreters (transient faults; no new executables) --
    def _masked_tables(self, alive, link_up):
        """(srcs const, per-node effective weight rows) under runtime masks.

        ``alive`` is an (n,) runtime array, ``link_up`` an optional (n, n)
        runtime array; the returned weights are traced values, so one
        jitted executable serves every fault realization.  ``alive`` may
        be a *float* mask (see ``degraded_matrix``): values in (0, 1)
        down-weight a node's edges, values > 1 up-weight them (preemption
        drain) — the w0 compensation keeps every row sum at 1 either way.
        """
        tables = self.permute_tables()
        if tables is None:
            return None
        srcs_np, weights_np = tables
        srcs = jnp.asarray(srcs_np)
        w = jnp.asarray(weights_np)
        af = jnp.asarray(alive, jnp.float32).reshape(self.n)
        m = af[srcs] * af[:, None]
        if link_up is not None:
            lm = jnp.asarray(link_up, jnp.float32)
            m = m * lm[jnp.arange(self.n)[:, None], srcs]
        wn = w[:, 1:] * m
        w0 = w[:, 0] + jnp.sum(w[:, 1:] * (1.0 - m), axis=1)
        return srcs_np, jnp.concatenate([w0[:, None], wn], axis=1)

    def _masked_matrix(self, alive, link_up):
        """Runtime degraded matrix (traced) — the dense fallback/oracle."""
        w0 = jnp.asarray(self.matrix(), jnp.float32)
        af = jnp.asarray(alive, jnp.float32).reshape(self.n)
        em = af[:, None] * af[None, :]
        if link_up is not None:
            em = em * jnp.asarray(link_up, jnp.float32)
        off = w0 * em * (1.0 - jnp.eye(self.n, dtype=jnp.float32))
        return off + jnp.diag(1.0 - jnp.sum(off, axis=1))

    def apply_masked(
        self, tree: PyTree, alive, *, link_up=None, engine: str = "stacked"
    ) -> PyTree:
        """One fault-degraded mixing step with *runtime* masks.

        Equivalent to ``self.degrade(alive).apply(...)`` (plus link
        masking) but with the masks as traced inputs: a new fault
        realization changes only array values, never the executable.
        ``engine="dense"`` multiplies by the runtime degraded matrix (the
        oracle); ``engine="stacked"`` uses the masked permute tables when
        the program is all-PPermute and the dense matrix otherwise.
        """
        if engine not in ("dense", "stacked"):
            raise ValueError(f"unknown engine {engine!r}")
        masked = self._masked_tables(alive, link_up)
        if engine == "dense" or masked is None:
            wm = self._masked_matrix(alive, link_up)

            def _mix(x):
                return jnp.einsum(
                    "ij,j...->i...", wm, x.astype(jnp.float32)
                ).astype(x.dtype)

            return jax.tree.map(_mix, tree)
        srcs_np, weights = masked
        n = self.n

        def _col(v, ndim):
            return v.reshape((n,) + (1,) * (ndim - 1))

        def _mix(x):
            xf = x.astype(jnp.float32)
            acc = _col(weights[:, 0], x.ndim) * xf
            for k in range(srcs_np.shape[1]):
                gathered = jnp.take(xf, jnp.asarray(srcs_np[:, k]), axis=0)
                acc = acc + _col(weights[:, k + 1], x.ndim) * gathered
            return acc.astype(x.dtype)

        return jax.tree.map(_mix, tree)

    def apply_shard_masked(self, local: PyTree, axis_names, alive, *, link_up=None):
        """``apply_masked`` on per-node values inside ``shard_map``.

        Dropped edges still traverse the wire (the permute schedule is
        compiled); their weight is zeroed and renormalized onto self at the
        receiver — the transient-fault trade: no recompile, dead-edge bytes
        still move.  Permanent crashes use ``degrade`` to actually remove
        the sends.  Non-permute programs fall back to all-gather + a
        runtime row of the degraded matrix.
        """
        n = self.n
        idx = _flat_axis_index(axis_names)
        masked = self._masked_tables(alive, link_up)
        if masked is None:
            wm = self._masked_matrix(alive, link_up)

            def _mix(x):
                xf = x.astype(jnp.float32)
                row = jax.lax.dynamic_slice_in_dim(wm, idx, 1, 0)[0]
                g = jax.lax.all_gather(xf, axis_names, axis=0, tiled=False)
                return jnp.einsum("g...,g->...", g, row).astype(x.dtype)

            return jax.tree.map(_mix, local)
        _, weights = masked
        wrow = weights[idx]

        def _mix(x):
            xf = x.astype(jnp.float32)
            acc = wrow[0] * xf
            for k, op in enumerate(self.ops):
                y = jax.lax.ppermute(xf, axis_names, list(op.perm))
                acc = acc + wrow[k + 1] * y
            return acc.astype(x.dtype)

        return jax.tree.map(_mix, local)

    @staticmethod
    def fuse(programs: Sequence["GossipProgram"], name: Optional[str] = None):
        """Compose H consecutive mixing steps into one program.

        The result applies ``programs[0]`` first, then ``programs[1]``, …
        (``matrix() == W_H ··· W_1``), and its interpreters run all rounds
        inside one jitted executable — H dispatches become one.  Nested
        fused programs flatten; a single program passes through unchanged.
        """
        stages: list[GossipProgram] = []
        for p in programs:
            if isinstance(p, FusedProgram):
                stages.extend(p.stages)
            else:
                stages.append(p)
        if not stages:
            raise ValueError("fuse needs at least one program")
        if len({p.n for p in stages}) > 1:
            raise ValueError("cannot fuse programs over different node counts")
        if len(stages) == 1:
            return stages[0]
        return FusedProgram(
            name=name or f"fuse[{'+'.join(p.name for p in stages)}]",
            n=stages[0].n,
            ops=tuple(op for p in stages for op in p.ops),
            self_weight=0.0,
            stages=tuple(stages),
        )

    # -- interpreters --------------------------------------------------------
    def apply(
        self,
        tree: PyTree,
        *,
        engine: str = "stacked",
        axis_names=None,
    ) -> PyTree:
        """Run one mixing step.

        engine:
          "dense"   — dense-matrix einsum over leading axis 0 (oracle).
          "stacked" — rolls/gathers over leading axis 0 (vmap engine).
          "shard"   — collectives on per-node values inside shard_map;
                      requires ``axis_names``.
        """
        if engine == "dense":
            return self.apply_dense(tree)
        if engine == "stacked":
            return self.apply_stacked(tree)
        if engine == "shard":
            if axis_names is None:
                raise ValueError("engine='shard' requires axis_names")
            return self.apply_shard(tree, axis_names)
        raise ValueError(f"unknown engine {engine!r}")

    def apply_dense(self, stacked: PyTree) -> PyTree:
        """θ ← W θ via the dense matrix (leading axis 0 = node axis)."""
        if self.is_identity and self.self_weight == 1.0:
            return stacked
        w = jnp.asarray(self.matrix(), jnp.float32)

        def _mix(x):
            return jnp.einsum("ij,j...->i...", w, x.astype(jnp.float32)).astype(
                x.dtype
            )

        return jax.tree.map(_mix, stacked)

    def apply_stacked(self, stacked: PyTree) -> PyTree:
        """Mixing over the stacked node axis via rolls / gathers."""
        if self.is_identity and self.self_weight == 1.0:
            return stacked
        n = self.n
        sw = jnp.asarray(_weight_column(self.self_weight, n), jnp.float32)

        def _col(v, ndim):
            return v.reshape((n,) + (1,) * (ndim - 1))

        def _mix(x):
            xf = x.astype(jnp.float32)
            acc = _col(sw, x.ndim) * xf
            for op in self.ops:
                if isinstance(op, PPermute):
                    wv = jnp.asarray(_weight_column(op.weight, n), jnp.float32)
                    if op.offset is not None:
                        # node i receives from (i + d) % n: roll by -d
                        acc = acc + _col(wv, x.ndim) * jnp.roll(
                            xf, -op.offset, axis=0
                        )
                    else:
                        src = np.full(n, 0, dtype=np.int32)
                        mask = np.zeros(n, dtype=np.float32)
                        for s, d in op.perm:
                            src[d] = s
                            mask[d] = 1.0
                        gathered = jnp.take(xf, jnp.asarray(src), axis=0)
                        acc = acc + _col(wv * jnp.asarray(mask), x.ndim) * gathered
                elif isinstance(op, AllReduce):
                    acc = acc + jnp.mean(xf, axis=0, keepdims=True)
                else:  # GatherRow
                    wm = jnp.asarray(op.w, jnp.float32)
                    acc = acc + jnp.einsum("ij,j...->i...", wm, xf)
            return acc.astype(x.dtype)

        return jax.tree.map(_mix, stacked)

    def apply_shard(self, local: PyTree, axis_names) -> PyTree:
        """Mixing on per-node values inside shard_map (one collective/op)."""
        if self.is_identity and self.self_weight == 1.0:
            return local
        n = self.n
        per_node_sw = isinstance(self.self_weight, tuple)
        per_node = per_node_sw or any(
            isinstance(op, PPermute) and isinstance(op.weight, tuple)
            for op in self.ops
        )
        idx = _flat_axis_index(axis_names) if per_node else None

        def _scalar_here(weight):
            if isinstance(weight, tuple):
                return jnp.asarray(weight, jnp.float32)[idx]
            return jnp.float32(weight)

        def _mix(x):
            xf = x.astype(jnp.float32)
            acc = _scalar_here(self.self_weight) * xf
            for op in self.ops:
                if isinstance(op, PPermute):
                    y = jax.lax.ppermute(xf, axis_names, list(op.perm))
                    acc = acc + _scalar_here(op.weight) * y
                elif isinstance(op, AllReduce):
                    acc = acc + jax.lax.pmean(xf, axis_names)
                else:  # GatherRow
                    wm = jnp.asarray(op.w, jnp.float32)
                    row = jax.lax.dynamic_slice_in_dim(
                        wm, _flat_axis_index(axis_names), 1, 0
                    )[0]
                    g = jax.lax.all_gather(xf, axis_names, axis=0, tiled=False)
                    acc = acc + jnp.einsum("g...,g->...", g, row)
            return acc.astype(x.dtype)

        return jax.tree.map(_mix, local)

    # -- bucketed interpreters (overlap-scheduled gossip) --------------------
    # Each bucket's mixing runs as its own dispatch over a contiguous slice
    # of the flattened tree (``core.buckets.BucketLayout``): bucket i's
    # collectives carry NO data dependency on bucket j's compute, so the
    # engines pipeline per-bucket update+mix dispatches instead of one
    # monolithic tail barrier.  These delegate to the per-bucket matrix
    # applies, so ``FusedProgram`` inherits them (its overridden
    # ``apply_stacked``/``apply_masked`` run every stage inside the SAME
    # per-bucket dispatch — fusion composes with bucketing).

    def apply_stacked_bucketed(self, stacked: PyTree, layout) -> PyTree:
        """``apply_stacked`` split into one dispatch per layout bucket."""
        if self.is_identity and self.self_weight == 1.0:
            return stacked
        mats = layout.split_stacked(stacked)
        return layout.merge_stacked(
            [self.apply_stacked(m) for m in mats], stacked
        )

    def apply_masked_bucketed(
        self, stacked: PyTree, alive, *, link_up=None, layout
    ) -> PyTree:
        """``apply_masked`` per bucket — masks stay runtime operands, so the
        executable set is still one per (program, bucket width)."""
        mats = layout.split_stacked(stacked)
        return layout.merge_stacked(
            [self.apply_masked(m, alive, link_up=link_up) for m in mats],
            stacked,
        )

    def apply_shard_bucketed(self, local: PyTree, axis_names, layout) -> PyTree:
        """``apply_shard`` as one ppermute chain per bucket: the collectives
        for bucket i commute with bucket j's compute in the schedule."""
        if self.is_identity and self.self_weight == 1.0:
            return local
        vecs = layout.split_local(local)
        return layout.merge_local(
            [self.apply_shard(v, axis_names) for v in vecs], local
        )

    def apply_shard_masked_bucketed(
        self, local: PyTree, axis_names, alive, *, link_up=None, layout
    ) -> PyTree:
        vecs = layout.split_local(local)
        return layout.merge_local(
            [
                self.apply_shard_masked(v, axis_names, alive, link_up=link_up)
                for v in vecs
            ],
            local,
        )


@lru_cache(maxsize=512)
def _degrade_cached(program: GossipProgram, alive: tuple) -> GossipProgram:
    n = program.n
    dead = [i for i, a in enumerate(alive) if not a]
    name = f"{program.name}!dead[{','.join(map(str, dead))}]"
    if not all(isinstance(op, PPermute) for op in program.ops):
        # AllReduce / GatherRow programs: one dense row of the degraded W.
        return GossipProgram(
            name=name,
            n=n,
            ops=(GatherRow(_matrix_to_tuple(
                degraded_matrix(program.matrix(), alive)
            )),),
            self_weight=0.0,
        )
    self_w = _weight_column(program.self_weight, n).copy()
    ops = []
    for op in program.ops:
        wv = _weight_column(op.weight, n)
        perm, weight = [], np.zeros(n)
        for s, d in op.perm:
            if alive[s] and alive[d]:
                perm.append((s, d))
                weight[d] = wv[d]
            elif alive[d]:
                self_w[d] += wv[d]  # receiver renormalizes the lost edge
        if perm:
            ops.append(
                PPermute(tuple(perm), tuple(float(v) for v in weight))
            )
    for i in dead:
        self_w[i] = 1.0  # dead nodes self-average: params frozen
    return GossipProgram(
        name=name,
        n=n,
        ops=tuple(ops),
        self_weight=tuple(float(v) for v in self_w),
    )


@lru_cache(maxsize=512)
def _program_matrix(program: GossipProgram) -> np.ndarray:
    n = program.n
    w = np.diag(_weight_column(program.self_weight, n))
    for op in program.ops:
        if isinstance(op, PPermute):
            wv = _weight_column(op.weight, n)
            for s, d in op.perm:
                w[d, s] += wv[d]
        elif isinstance(op, AllReduce):
            w += np.ones((n, n)) / n
        else:  # GatherRow
            w += np.asarray(op.w, dtype=np.float64)
    return w


@dataclasses.dataclass(frozen=True)
class FusedProgram(GossipProgram):
    """H mixing rounds compiled into one executable (``GossipProgram.fuse``).

    Semantics are *sequential*: ``out = W_H ··· W_1 x`` where stage i
    realizes W_i.  ``ops`` holds the concatenated stage ops so collective
    counts and the comm-cost model sum naturally; the interpreters ignore
    it and fold over ``stages`` instead (one jit of an apply method runs
    every round in a single dispatch — that is the fusion win for
    time-varying one-peer schedules).
    """

    stages: tuple[GossipProgram, ...] = ()

    @property
    def cache_key(self):
        key = self.__dict__.get("_cache_key")
        if key is None:
            key = ("fused",) + tuple(p.cache_key for p in self.stages)
            object.__setattr__(self, "_cache_key", key)
        return key

    @property
    def is_identity(self) -> bool:
        return all(p.is_identity and p.self_weight == 1.0 for p in self.stages)

    def matrix(self) -> np.ndarray:
        w = np.eye(self.n)
        for p in self.stages:
            w = p.matrix() @ w
        return w

    def describe(self) -> str:
        inner = ", ".join(p.describe() for p in self.stages)
        return f"{self.name}(n={self.n}, stages=[{inner}])"

    def permute_tables(self):
        """Fused programs mix sequentially; the single-round kernel tables
        do not apply (each stage has its own — use ``stages[i]``)."""
        return None

    def degrade(self, alive) -> "GossipProgram":
        """Stage-wise degrade: each round renormalizes independently (NOT a
        mask of the product matrix — faults apply to every wire round)."""
        alive_t = tuple(bool(a) for a in np.asarray(alive).reshape(-1))
        if all(alive_t):
            return self
        return GossipProgram.fuse(
            [p.degrade(alive_t) for p in self.stages],
            name=f"{self.name}!dead[{','.join(str(i) for i, a in enumerate(alive_t) if not a)}]",
        )

    def apply_masked(self, tree, alive, *, link_up=None, engine="stacked"):
        for p in self.stages:
            tree = p.apply_masked(tree, alive, link_up=link_up, engine=engine)
        return tree

    def apply_shard_masked(self, local, axis_names, alive, *, link_up=None):
        for p in self.stages:
            local = p.apply_shard_masked(local, axis_names, alive, link_up=link_up)
        return local

    def apply_dense(self, stacked: PyTree) -> PyTree:
        """One einsum with the *product* matrix — the fused dense oracle."""
        if self.is_identity:
            return stacked
        w = jnp.asarray(self.matrix(), jnp.float32)

        def _mix(x):
            return jnp.einsum("ij,j...->i...", w, x.astype(jnp.float32)).astype(
                x.dtype
            )

        return jax.tree.map(_mix, stacked)

    def apply_stacked(self, stacked: PyTree) -> PyTree:
        for p in self.stages:
            stacked = p.apply_stacked(stacked)
        return stacked

    def apply_shard(self, local: PyTree, axis_names) -> PyTree:
        for p in self.stages:
            local = p.apply_shard(local, axis_names)
        return local


# ---------------------------------------------------------------------------
# Hub-balanced round scheduling
# ---------------------------------------------------------------------------

def hub_balanced_rounds(
    program: GossipProgram, rounds: int, name: Optional[str] = None
) -> GossipProgram:
    """Distribute a program's permute rounds across ``rounds`` fused steps.

    A static edge-colored program applies all C matchings every step, so a
    hot vertex (the star hub, degree Δ) sends Δ·P bytes per step even
    though the mean is ~2P.  This scheduler round-robins the C matchings
    over ``rounds`` stage programs — stage h applies matchings
    ``ops[h::rounds]`` and soaks the unapplied neighbor mass into its self
    weight, so every stage is row-stochastic (symmetric/doubly stochastic
    when the base is) and each matching runs exactly once per cycle.  The
    hub's *per-step peak* send volume drops from Δ·P to ⌈Δ/rounds⌉·P.

    The cycle's product matrix is not W^rounds — it is a time-varying
    schedule over the same edge set (each edge averaged once per cycle at
    its base weight), trading per-cycle mixing strength for a ``rounds``×
    lower peak link load.  Mean preservation and consensus contraction are
    kept (pinned by tests); use via ``mix_rounds`` + ``hub_balance`` on the
    engines.
    """
    rounds = int(rounds)
    if rounds <= 1:
        return program
    if not all(isinstance(op, PPermute) for op in program.ops):
        raise ValueError(
            f"hub_balanced_rounds needs an all-PPermute program, got "
            f"{program.describe()}"
        )
    if len(program.ops) <= 1:
        return program
    n = program.n
    base_self = _weight_column(program.self_weight, n)
    cols = [_weight_column(op.weight, n) for op in program.ops]
    # receiver-side mass per op: only perm-participating dsts carry weight
    masks = []
    for op in program.ops:
        m = np.zeros(n)
        for _, d in op.perm:
            m[d] = 1.0
        masks.append(m)
    stages = []
    for h in range(rounds):
        picked = list(range(h, len(program.ops), rounds))
        sw = base_self.copy()
        for k, (wv, m) in enumerate(zip(cols, masks)):
            if k not in picked:
                sw += wv * m  # unapplied matchings self-average this step
        stages.append(
            GossipProgram(
                name=f"{program.name}@round{h}",
                n=n,
                ops=tuple(program.ops[k] for k in picked),
                self_weight=tuple(float(v) for v in sw),
            )
        )
    return GossipProgram.fuse(
        stages, name=name or f"hub_balanced[{program.name}/H{rounds}]"
    )


def maybe_hub_balanced(progs: Sequence[GossipProgram], rounds: int):
    """The shared eligibility rule for hub-balancing a fused gossip round.

    Reschedules ONLY when the ``rounds`` fused steps are one *static*
    multi-matching permute program repeated — time-varying families keep
    their own rotation, single-matching and non-permute programs have
    nothing to rotate.  Both the Topology and the SPMD trainer route
    through this helper so the engines always hub-balance the same
    programs (their shared-schedule invariant).  Returns the rescheduled
    program, or ``None`` when plain fusion should apply.
    """
    if (
        rounds > 1
        and len({p.cache_key for p in progs}) == 1
        and progs[0].permute_tables() is not None
        and len(progs[0].ops) > 1
    ):
        return hub_balanced_rounds(progs[0], rounds)
    return None


# ---------------------------------------------------------------------------
# Edge coloring: decompose an arbitrary edge set into <= Δ+1 matchings
# ---------------------------------------------------------------------------

def _greedy_coloring(n: int, edges, ncolors: int):
    """Smallest-free-color greedy pass.  O(E·Δ); may need up to 2Δ-1 colors,
    but is exact (Δ or Δ+1) on stars, matchings, paths and most sparse
    graphs — the hot compile path.  Returns None when it exceeds ncolors."""
    used = [set() for _ in range(n)]
    color: dict[tuple[int, int], int] = {}
    for i, j in edges:
        taken = used[i] | used[j]
        c = next((c for c in range(ncolors) if c not in taken), None)
        if c is None:
            return None
        color[(i, j)] = c
        used[i].add(c)
        used[j].add(c)
    return color


def _misra_gries_coloring(n: int, edges, ncolors: int):
    """Misra & Gries (1992) constructive Vizing coloring: always <= Δ+1
    colors on a simple graph.  O(E·Δ²) worst case — only invoked when the
    greedy pass overflows, which small irregular graphs occasionally do."""
    adj = [dict() for _ in range(n)]   # adj[u][v] = color of edge (u, v)
    # color -> multiplicity at each node: a plain set would corrupt during
    # path inversion / fan rotation, where a color transiently sits on two
    # edges of one node and a set-discard would lose the surviving copy
    used = [dict() for _ in range(n)]

    def _add(u, c):
        used[u][c] = used[u].get(c, 0) + 1

    def _rm(u, c):
        k = used[u][c] - 1
        if k:
            used[u][c] = k
        else:
            del used[u][c]

    def free(u):
        return next(c for c in range(ncolors) if c not in used[u])

    def set_color(u, v, c):
        adj[u][v] = c
        adj[v][u] = c
        _add(u, c)
        _add(v, c)

    def unset(u, v):
        c = adj[u].pop(v)
        adj[v].pop(u)
        _rm(u, c)
        _rm(v, c)

    def invert_cd_path(u, c, d):
        """Flip colors along the maximal c/d-alternating path through u."""
        prev, cur, want = None, u, d
        while True:
            nxt = next(
                (w for w, cc in adj[cur].items() if cc == want and w != prev),
                None,
            )
            if nxt is None:
                return
            unset(cur, nxt)
            set_color(cur, nxt, c if want == d else d)
            prev, cur = cur, nxt
            want = c if want == d else d

    for u, v in edges:
        # maximal fan of u: F[0] = v; color(u, F[i]) is free on F[i-1]
        fan, in_fan = [v], {v}
        grown = True
        while grown:
            grown = False
            for w, c in adj[u].items():
                if w not in in_fan and c not in used[fan[-1]]:
                    fan.append(w)
                    in_fan.add(w)
                    grown = True
                    break
        c, d = free(u), free(fan[-1])
        invert_cd_path(u, c, d)
        # the inversion may shrink the usable fan: take the shortest prefix
        # that is still a fan and whose tip has d free, then rotate it
        w_idx = None
        for i, w in enumerate(fan):
            if i > 0 and adj[u][fan[i]] in used[fan[i - 1]]:
                break
            if d not in used[w]:
                w_idx = i
                break
        if w_idx is None:  # pragma: no cover - MG invariant guarantees a w
            return None
        # rotate fan[0..w_idx]: (u, F[i]) takes the color of (u, F[i+1]);
        # unset every involved edge first so multiplicities stay exact
        old = [adj[u].get(fan[i]) for i in range(w_idx + 1)]
        for i in range(w_idx + 1):
            if fan[i] in adj[u]:
                unset(u, fan[i])
        for i in range(w_idx):
            set_color(u, fan[i], old[i + 1])
        set_color(u, fan[w_idx], d)

    return {(i, j): adj[i][j] for i, j in edges}


def edge_coloring(
    n: int, edges: Sequence[tuple[int, int]]
) -> list[list[tuple[int, int]]]:
    """Partition an undirected edge set into <= Δ+1 matchings.

    Greedy first (covers stars/matchings/sparse graphs with Δ or Δ+1 colors
    in O(E·Δ)); when greedy overflows the Δ+1 palette, the Misra–Gries
    constructive Vizing pass guarantees Δ+1.  Every returned color class is
    a matching; together they cover each edge exactly once, so a mixing
    matrix W decomposes exactly into one per-node-weighted PPermute per
    class plus its diagonal.
    """
    edges = [tuple(sorted(e)) for e in edges]
    if not edges:
        return []
    deg = [0] * n
    for i, j in edges:
        deg[i] += 1
        deg[j] += 1
    ncolors = max(deg) + 1
    color = _greedy_coloring(n, edges, ncolors)
    if color is None:
        color = _misra_gries_coloring(n, edges, ncolors)
    if color is None:  # pragma: no cover - MG always succeeds on simple graphs
        color = _greedy_coloring(n, edges, 2 * max(deg))
    classes: dict[int, list[tuple[int, int]]] = {}
    for e, c in color.items():
        classes.setdefault(c, []).append(e)
    return [sorted(classes[c]) for c in sorted(classes)]


# ---------------------------------------------------------------------------
# Compilation
# ---------------------------------------------------------------------------

def identity_program(n: int, name: str = "identity") -> GossipProgram:
    return GossipProgram(name=name, n=n, ops=(), self_weight=1.0)


def _matrix_to_tuple(w: np.ndarray) -> tuple[tuple[float, ...], ...]:
    return tuple(tuple(float(v) for v in row) for row in np.asarray(w))


@lru_cache(maxsize=512)
def dense_program(graph) -> GossipProgram:
    """The paper-faithful dense realization: one GatherRow of the full W.

    Costs an all-gather of the parameter tree — kept as the faithful
    baseline (``mixing="dense"``); ``compile_graph`` is the optimized path.
    Cached: callers look this up every training step, and building the
    n×n tuple (plus the cache_key digest) is O(n²) host work.
    """
    w = graph.mixing_matrix()
    return GossipProgram(
        name=f"dense:{graph.name}",
        n=graph.n,
        ops=(GatherRow(_matrix_to_tuple(w)),),
        self_weight=0.0,
    )


def compile_graph(graph_or_sequence):
    """Compile a graph (or a sequence of graphs) into GossipProgram(s).

    A single ``CommGraph`` yields one program; a sequence (time-varying
    topology: one graph per step/phase) yields a tuple of programs, one per
    element — the rotation schedule the engines iterate through.
    """
    if isinstance(graph_or_sequence, (list, tuple)):
        return tuple(_compile_one(g) for g in graph_or_sequence)
    return _compile_one(graph_or_sequence)


@lru_cache(maxsize=512)
def _compile_one(graph) -> GossipProgram:
    # Local import: graphs.py ↔ schedule.py would otherwise cycle.
    from repro.core.graphs import CirculantGraph, EdgeGraph

    n = graph.n
    if graph.degree == 0 or n <= 1:
        return identity_program(n, name=graph.name)

    if isinstance(graph, CirculantGraph):
        if graph.name == "complete" and graph.degree == n - 1:
            # Uniform complete graph: W = J/n == one ring all-reduce.
            return GossipProgram(
                name=graph.name, n=n, ops=(AllReduce(),), self_weight=0.0
            )
        ops = tuple(
            PPermute(permutation_for_offset(n, d), wd, offset=d)
            for d, wd in graph.weighted_offsets()
        )
        return GossipProgram(
            name=graph.name, n=n, ops=ops, self_weight=graph.self_weight
        )

    if isinstance(graph, EdgeGraph):
        w = graph.mixing_matrix()
        # Edge-colored sparse decomposition: <= Δ+1 per-node-weighted
        # permute rounds (matchings are the 1-color special case).  Every
        # off-diagonal W entry lands in exactly one matching, the diagonal
        # rides in self_weight — exact for any symmetric weight scheme.
        ops = []
        for matching in edge_coloring(n, graph.edges):
            perm = []
            weight = np.zeros(n)
            for i, j in matching:
                perm += [(i, j), (j, i)]
                weight[j] = w[j, i]
                weight[i] = w[i, j]
            ops.append(
                PPermute(
                    tuple(sorted(perm, key=lambda p: p[1])),
                    tuple(float(v) for v in weight),
                )
            )
        program = GossipProgram(
            name=graph.name,
            n=n,
            ops=tuple(ops),
            self_weight=tuple(float(v) for v in np.diag(w)),
        )
        if np.allclose(program.matrix(), w, rtol=0.0, atol=1e-12):
            return program
        # Exactness check failed (cannot happen for a proper coloring of a
        # simple graph; kept as the safety net): dense fallback.
        return GossipProgram(  # pragma: no cover
            name=graph.name,
            n=n,
            ops=(GatherRow(_matrix_to_tuple(w)),),
            self_weight=0.0,
        )

    raise TypeError(f"cannot compile {type(graph).__name__} into a GossipProgram")


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

def _live_pairs(op: PPermute, n: int, alive=None, link_up=None):
    """The (src, dst) pairs that actually move bytes under this permute.

    A pair moves nothing when its receiver weight is zero (a degraded
    program keeps renormalized zero entries out of ``perm``, but masked /
    hand-built programs may carry them) or when a fault mask kills either
    endpoint or the link — dead edges must not be billed (at high fault
    rates they dominate a naive ``len(perm)`` count).
    """
    wv = _weight_column(op.weight, n)
    pairs = []
    for s, d in op.perm:
        if wv[d] == 0.0:
            continue
        if alive is not None and not (alive[s] and alive[d]):
            continue
        if link_up is not None and not link_up[s][d]:
            continue
        pairs.append((s, d))
    return pairs


def program_comm_bytes(
    program: GossipProgram, param_bytes: int, *, alive=None, link_up=None
) -> int:
    """Mean bytes each node sends per mixing step under this program.

    A partial permute (an edge-colored matching round) only moves buffers
    on its participating source→dest links, so it costs ``P · pairs/n``
    per node on average — an edge-colored star totals ~2P per node versus
    the (n-1)·P ring all-gather of ``GatherRow``.  ``alive`` / ``link_up``
    bill a fault realization by its surviving edges only (the ``GatherRow``
    all-gather still moves every replica regardless of masks).
    """
    total = 0.0
    n = program.n
    alive_l = None if alive is None else [bool(a) for a in np.asarray(alive)]
    link_l = None if link_up is None else np.asarray(link_up).tolist()
    for op in program.ops:
        if isinstance(op, PPermute):
            total += param_bytes * (len(_live_pairs(op, n, alive_l, link_l)) / n)
        elif isinstance(op, AllReduce):
            total += 2 * param_bytes * (n - 1) / n
        else:  # GatherRow: ring all-gather — each node forwards P to n-1 peers
            total += param_bytes * (n - 1)
    return int(total)


def program_max_node_bytes(
    program: GossipProgram, param_bytes: int, *, alive=None, link_up=None
) -> int:
    """Bytes the busiest node sends per mixing step (the latency-critical
    figure: a star hub participates in every matching round, so its send
    volume is Δ·P even though the mean is ~2P — ``hub_balanced_rounds``
    exists to cap exactly this number)."""
    n = program.n
    sends = np.zeros(n)
    alive_l = None if alive is None else [bool(a) for a in np.asarray(alive)]
    link_l = None if link_up is None else np.asarray(link_up).tolist()
    for op in program.ops:
        if isinstance(op, PPermute):
            for s, _ in _live_pairs(op, n, alive_l, link_l):
                sends[s] += param_bytes
        elif isinstance(op, AllReduce):
            sends += 2 * param_bytes * (n - 1) / n
        else:  # GatherRow
            sends += param_bytes * (n - 1)
    return int(sends.max()) if n else 0
