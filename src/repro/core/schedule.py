"""Mixing-program IR: compile any communication graph into a gossip program.

A ``GossipProgram`` is a small list of primitive communication ops that
realizes one mixing step  θ ← W θ  for an n-node gossip graph:

  * ``PPermute(perm, weight[, offset])`` — every node receives one weighted
    neighbor buffer along a permutation (a single collective-permute on the
    wire).  ``offset`` marks the circulant special case (perm is the shift
    ``i ← i+d``), which the stacked interpreter realizes as one ``jnp.roll``.
  * ``AllReduce()``                      — uniform average over all nodes
    (ring all-reduce; the complete-graph fast path).
  * ``GatherRow(w)``                     — dense fallback: gather all
    replicas, contract with this node's row of W.  Exact for *any* W; costs
    an all-gather (kept for the paper-faithful dense baseline and irregular
    graphs with no sparse decomposition).

Program semantics (all interpreters agree to float32 accumulation):

    out = self_weight ⊙ x + Σ_op op(x)

with ``self_weight`` a scalar or per-node vector (irregular graphs weight
their own replica differently per node).

Three interpreters share the single compiled program:

  * ``apply_dense``   — dense mixing-matrix einsum over the stacked replica
                        axis.  The paper-faithful oracle.
  * ``apply_stacked`` — rolls/gathers over the stacked axis (vmap engine;
                        under jit on a sharded axis XLA lowers each roll to
                        collective-permutes).
  * ``apply_shard``   — explicit collectives inside ``shard_map`` (SPMD
                        production engine): one ``jax.lax.ppermute`` per
                        ``PPermute``, ``pmean`` for ``AllReduce``,
                        all-gather + row contraction for ``GatherRow``.

``compile_graph`` picks the cheapest faithful realization:
circulant graph → one PPermute per offset; complete graph → AllReduce;
matching (degree ≤ 1, e.g. one-peer / random pairwise averaging) → a single
PPermute with per-node weights; anything else → GatherRow.

Programs are frozen/hashable: both engines key their compiled-executable
caches on the program, so time-varying topologies rotate through a bounded
executable set — one XLA compile per distinct program at its first use and
zero recompiles thereafter (``Topology.distinct_programs`` enumerates the
set up front).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Any, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

try:  # jax-version shim (PR 1); degrade gracefully when absent
    from repro import compat as _compat
except ImportError:  # pragma: no cover
    _compat = None

PyTree = Any

__all__ = [
    "PPermute",
    "AllReduce",
    "GatherRow",
    "GossipProgram",
    "compile_graph",
    "dense_program",
    "identity_program",
    "permutation_for_offset",
    "program_comm_bytes",
]


# ---------------------------------------------------------------------------
# Primitive ops
# ---------------------------------------------------------------------------

def permutation_for_offset(n: int, d: int) -> tuple[tuple[int, int], ...]:
    """ppermute pairs so that node i receives from node (i + d) % n."""
    return tuple(((i + d) % n, i) for i in range(n))


@dataclasses.dataclass(frozen=True)
class PPermute:
    """Receive one weighted buffer along a permutation.

    perm: (src, dst) pairs; a dst absent from the list receives zeros.
    weight: scalar, or per-dst-node tuple of length n (applied at receiver).
    offset: when the perm is the circulant shift ``dst ← dst + offset``,
      the stacked interpreter uses one ``jnp.roll`` instead of a gather.
    """

    perm: tuple[tuple[int, int], ...]
    weight: Union[float, tuple[float, ...]]
    offset: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class AllReduce:
    """Uniform average over all nodes (contributes J/n to W)."""


@dataclasses.dataclass(frozen=True)
class GatherRow:
    """Dense fallback: all-gather replicas, contract with this node's W row.

    w: the full n×n mixing matrix (including the diagonal) as nested tuples.
    """

    w: tuple[tuple[float, ...], ...]


Op = Union[PPermute, AllReduce, GatherRow]


# ---------------------------------------------------------------------------
# The program
# ---------------------------------------------------------------------------

def _weight_column(weight, n: int) -> np.ndarray:
    if isinstance(weight, tuple):
        return np.asarray(weight, dtype=np.float64)
    return np.full(n, float(weight), dtype=np.float64)


def _flat_axis_index(axis_names):
    """Node index along (possibly multiple) manual mesh axes."""
    if isinstance(axis_names, str):
        return jax.lax.axis_index(axis_names)
    idx = jnp.zeros((), jnp.int32)
    for a in axis_names:
        size = (
            _compat.axis_size(a)
            if _compat is not None
            else jax.lax.psum(jnp.ones((), jnp.int32), a)
        )
        idx = idx * size + jax.lax.axis_index(a)
    return idx


@dataclasses.dataclass(frozen=True)
class GossipProgram:
    """A compiled mixing schedule: out = self_weight ⊙ x + Σ_op op(x)."""

    name: str
    n: int
    ops: tuple[Op, ...]
    self_weight: Union[float, tuple[float, ...]] = 0.0

    # -- views ---------------------------------------------------------------
    @property
    def cache_key(self):
        """Cheap hashable identity for per-executable step caches.

        Computed once per program: dict lookups must not re-hash the op
        tuple every training step (a GatherRow at n=1008 holds ~1M floats).
        The sha256 digest of the canonical repr makes collisions across
        distinct programs practically impossible.
        """
        key = self.__dict__.get("_cache_key")
        if key is None:
            import hashlib

            digest = hashlib.sha256(
                repr((self.n, self.ops, self.self_weight)).encode()
            ).hexdigest()[:32]
            key = (self.name, self.n, digest)
            object.__setattr__(self, "_cache_key", key)
        return key

    @property
    def is_identity(self) -> bool:
        return not self.ops

    @property
    def num_collectives(self) -> int:
        return len(self.ops)

    def matrix(self) -> np.ndarray:
        """The dense (n, n) mixing matrix W this program realizes (float64)."""
        return _program_matrix(self)

    def describe(self) -> str:
        kinds = [type(op).__name__ for op in self.ops]
        return f"{self.name}(n={self.n}, ops=[{', '.join(kinds)}])"

    # -- interpreters --------------------------------------------------------
    def apply(
        self,
        tree: PyTree,
        *,
        engine: str = "stacked",
        axis_names=None,
    ) -> PyTree:
        """Run one mixing step.

        engine:
          "dense"   — dense-matrix einsum over leading axis 0 (oracle).
          "stacked" — rolls/gathers over leading axis 0 (vmap engine).
          "shard"   — collectives on per-node values inside shard_map;
                      requires ``axis_names``.
        """
        if engine == "dense":
            return self.apply_dense(tree)
        if engine == "stacked":
            return self.apply_stacked(tree)
        if engine == "shard":
            if axis_names is None:
                raise ValueError("engine='shard' requires axis_names")
            return self.apply_shard(tree, axis_names)
        raise ValueError(f"unknown engine {engine!r}")

    def apply_dense(self, stacked: PyTree) -> PyTree:
        """θ ← W θ via the dense matrix (leading axis 0 = node axis)."""
        if self.is_identity and self.self_weight == 1.0:
            return stacked
        w = jnp.asarray(self.matrix(), jnp.float32)

        def _mix(x):
            return jnp.einsum("ij,j...->i...", w, x.astype(jnp.float32)).astype(
                x.dtype
            )

        return jax.tree.map(_mix, stacked)

    def apply_stacked(self, stacked: PyTree) -> PyTree:
        """Mixing over the stacked node axis via rolls / gathers."""
        if self.is_identity and self.self_weight == 1.0:
            return stacked
        n = self.n
        sw = jnp.asarray(_weight_column(self.self_weight, n), jnp.float32)

        def _col(v, ndim):
            return v.reshape((n,) + (1,) * (ndim - 1))

        def _mix(x):
            xf = x.astype(jnp.float32)
            acc = _col(sw, x.ndim) * xf
            for op in self.ops:
                if isinstance(op, PPermute):
                    wv = jnp.asarray(_weight_column(op.weight, n), jnp.float32)
                    if op.offset is not None:
                        # node i receives from (i + d) % n: roll by -d
                        acc = acc + _col(wv, x.ndim) * jnp.roll(
                            xf, -op.offset, axis=0
                        )
                    else:
                        src = np.full(n, 0, dtype=np.int32)
                        mask = np.zeros(n, dtype=np.float32)
                        for s, d in op.perm:
                            src[d] = s
                            mask[d] = 1.0
                        gathered = jnp.take(xf, jnp.asarray(src), axis=0)
                        acc = acc + _col(wv * jnp.asarray(mask), x.ndim) * gathered
                elif isinstance(op, AllReduce):
                    acc = acc + jnp.mean(xf, axis=0, keepdims=True)
                else:  # GatherRow
                    wm = jnp.asarray(op.w, jnp.float32)
                    acc = acc + jnp.einsum("ij,j...->i...", wm, xf)
            return acc.astype(x.dtype)

        return jax.tree.map(_mix, stacked)

    def apply_shard(self, local: PyTree, axis_names) -> PyTree:
        """Mixing on per-node values inside shard_map (one collective/op)."""
        if self.is_identity and self.self_weight == 1.0:
            return local
        n = self.n
        per_node_sw = isinstance(self.self_weight, tuple)
        per_node = per_node_sw or any(
            isinstance(op, PPermute) and isinstance(op.weight, tuple)
            for op in self.ops
        )
        idx = _flat_axis_index(axis_names) if per_node else None

        def _scalar_here(weight):
            if isinstance(weight, tuple):
                return jnp.asarray(weight, jnp.float32)[idx]
            return jnp.float32(weight)

        def _mix(x):
            xf = x.astype(jnp.float32)
            acc = _scalar_here(self.self_weight) * xf
            for op in self.ops:
                if isinstance(op, PPermute):
                    y = jax.lax.ppermute(xf, axis_names, list(op.perm))
                    acc = acc + _scalar_here(op.weight) * y
                elif isinstance(op, AllReduce):
                    acc = acc + jax.lax.pmean(xf, axis_names)
                else:  # GatherRow
                    wm = jnp.asarray(op.w, jnp.float32)
                    row = jax.lax.dynamic_slice_in_dim(
                        wm, _flat_axis_index(axis_names), 1, 0
                    )[0]
                    g = jax.lax.all_gather(xf, axis_names, axis=0, tiled=False)
                    acc = acc + jnp.einsum("g...,g->...", g, row)
            return acc.astype(x.dtype)

        return jax.tree.map(_mix, local)


@lru_cache(maxsize=512)
def _program_matrix(program: GossipProgram) -> np.ndarray:
    n = program.n
    w = np.diag(_weight_column(program.self_weight, n))
    for op in program.ops:
        if isinstance(op, PPermute):
            wv = _weight_column(op.weight, n)
            for s, d in op.perm:
                w[d, s] += wv[d]
        elif isinstance(op, AllReduce):
            w += np.ones((n, n)) / n
        else:  # GatherRow
            w += np.asarray(op.w, dtype=np.float64)
    return w


# ---------------------------------------------------------------------------
# Compilation
# ---------------------------------------------------------------------------

def identity_program(n: int, name: str = "identity") -> GossipProgram:
    return GossipProgram(name=name, n=n, ops=(), self_weight=1.0)


def _matrix_to_tuple(w: np.ndarray) -> tuple[tuple[float, ...], ...]:
    return tuple(tuple(float(v) for v in row) for row in np.asarray(w))


@lru_cache(maxsize=512)
def dense_program(graph) -> GossipProgram:
    """The paper-faithful dense realization: one GatherRow of the full W.

    Costs an all-gather of the parameter tree — kept as the faithful
    baseline (``mixing="dense"``); ``compile_graph`` is the optimized path.
    Cached: callers look this up every training step, and building the
    n×n tuple (plus the cache_key digest) is O(n²) host work.
    """
    w = graph.mixing_matrix()
    return GossipProgram(
        name=f"dense:{graph.name}",
        n=graph.n,
        ops=(GatherRow(_matrix_to_tuple(w)),),
        self_weight=0.0,
    )


def compile_graph(graph_or_sequence):
    """Compile a graph (or a sequence of graphs) into GossipProgram(s).

    A single ``CommGraph`` yields one program; a sequence (time-varying
    topology: one graph per step/phase) yields a tuple of programs, one per
    element — the rotation schedule the engines iterate through.
    """
    if isinstance(graph_or_sequence, (list, tuple)):
        return tuple(_compile_one(g) for g in graph_or_sequence)
    return _compile_one(graph_or_sequence)


@lru_cache(maxsize=512)
def _compile_one(graph) -> GossipProgram:
    # Local import: graphs.py ↔ schedule.py would otherwise cycle.
    from repro.core.graphs import CirculantGraph, EdgeGraph

    n = graph.n
    if graph.degree == 0 or n <= 1:
        return identity_program(n, name=graph.name)

    if isinstance(graph, CirculantGraph):
        if graph.name == "complete" and graph.degree == n - 1:
            # Uniform complete graph: W = J/n == one ring all-reduce.
            return GossipProgram(
                name=graph.name, n=n, ops=(AllReduce(),), self_weight=0.0
            )
        ops = tuple(
            PPermute(permutation_for_offset(n, d), wd, offset=d)
            for d, wd in graph.weighted_offsets()
        )
        return GossipProgram(
            name=graph.name, n=n, ops=ops, self_weight=graph.self_weight
        )

    if isinstance(graph, EdgeGraph):
        w = graph.mixing_matrix()
        degrees = graph.degrees
        if max(degrees) <= 1:
            # A (partial) matching: one permute with per-node weights.
            perm = []
            weight = np.zeros(n)
            for i, j in graph.edges:
                perm += [(i, j), (j, i)]
                weight[j] = w[j, i]
                weight[i] = w[i, j]
            return GossipProgram(
                name=graph.name,
                n=n,
                ops=(
                    PPermute(
                        tuple(sorted(perm, key=lambda p: p[1])),
                        tuple(float(v) for v in weight),
                    ),
                ),
                self_weight=tuple(float(v) for v in np.diag(w)),
            )
        # Irregular graph with no sparse decomposition (yet): dense fallback.
        return GossipProgram(
            name=graph.name,
            n=n,
            ops=(GatherRow(_matrix_to_tuple(w)),),
            self_weight=0.0,
        )

    raise TypeError(f"cannot compile {type(graph).__name__} into a GossipProgram")


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

def program_comm_bytes(program: GossipProgram, param_bytes: int) -> int:
    """Bytes each node sends per mixing step under this program."""
    total = 0.0
    n = program.n
    for op in program.ops:
        if isinstance(op, PPermute):
            total += param_bytes
        elif isinstance(op, AllReduce):
            total += 2 * param_bytes * (n - 1) / n
        else:  # GatherRow: ring all-gather — each node forwards P to n-1 peers
            total += param_bytes * (n - 1)
    return int(total)
