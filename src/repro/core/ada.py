"""Ada — adaptive ring-lattice scheduling (paper §4, Algorithm 1).

Ada starts training on a highly-connected ring lattice (coordination number
``k0``) and linearly decays the coordination number per epoch:

    k(epoch) = max(k0 - int(gamma_k * epoch), 2)          (Algorithm 1, l.2)

so the communication graph evolves from (near-)complete to a sparse ring,
capturing the paper's Observation 5: high connectivity helps early, sparse
graphs are free later.

Paper defaults (Table 4):
    ResNet20 / DenseNet100 / LSTM @ 96 GPUs : k0 = 10,  gamma_k = 0.02
    ResNet50 @ 1008 GPUs                    : k0 = 112, gamma_k = 1

The paper's heuristic initialization (Table 2) is k0 = max(#GPUs // 9, 2);
``default_k0`` implements it.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import numpy as np

from repro.core.graphs import CommGraph, RingLattice

__all__ = ["AdaSchedule", "default_k0"]


def default_k0(n_nodes: int) -> int:
    """Paper Table 2 heuristic: k(ours) = max(#GPUs // 9, 2)."""
    return max(n_nodes // 9, 2)


@dataclasses.dataclass(frozen=True)
class AdaSchedule:
    """Maps epoch -> ring-lattice communication graph (Algorithm 1)."""

    n_nodes: int
    k0: int
    gamma_k: float = 0.02
    k_floor: int = 2  # Algorithm 1 line 2 (the §4.1 prose floors at 1)

    @classmethod
    def auto(cls, n_nodes: int, gamma_k: float = 0.02) -> "AdaSchedule":
        return cls(n_nodes=n_nodes, k0=default_k0(n_nodes), gamma_k=gamma_k)

    def k_at(self, epoch: int) -> int:
        """Coordination number at an epoch (0-indexed)."""
        k = self.k0 - int(self.gamma_k * epoch)
        # A node cannot have more neighbors than n-1.
        return int(np.clip(k, self.k_floor, max(self.n_nodes - 1, 1)))

    def graph_at(self, epoch: int) -> CommGraph:
        return _lattice(self.n_nodes, self.k_at(epoch))

    def mixing_matrix_at(self, epoch: int) -> np.ndarray:
        """Dense W per Algorithm 1 lines 3-8 (uniform 1/(k+1) weights)."""
        return self.graph_at(epoch).mixing_matrix()

    def distinct_graphs(self, n_epochs: int) -> list[tuple[int, CommGraph]]:
        """(first_epoch, graph) for each distinct k over a run.

        The SPMD engine compiles one train-step executable per distinct k;
        this enumerates them up front (a handful — k is integer-valued and
        monotone), so graph adaptation costs no mid-run recompiles.
        """
        out: list[tuple[int, CommGraph]] = []
        last_k = None
        for e in range(n_epochs):
            k = self.k_at(e)
            if k != last_k:
                out.append((e, self.graph_at(e)))
                last_k = k
        return out


@lru_cache(maxsize=256)
def _lattice(n: int, k: int) -> CommGraph:
    return RingLattice(n, k)
