"""Ada — adaptive ring-lattice scheduling (paper §4, Algorithm 1).

Ada starts training on a highly-connected ring lattice (coordination number
``k0``) and linearly decays the coordination number per epoch:

    k(epoch) = max(k0 - int(gamma_k * epoch), 2)          (Algorithm 1, l.2)

so the communication graph evolves from (near-)complete to a sparse ring,
capturing the paper's Observation 5: high connectivity helps early, sparse
graphs are free later.

Beyond-paper extension (``k_floor="one_peer"``): instead of stopping at the
k=2 ring, Ada can decay onto the *one-peer time-varying exponential* family
(arXiv:2410.11998) — degree 1 per step, cycling hop 2^m per step — the
cheapest per-step gossip that still mixes like an expander over a cycle.
The schedule then becomes step-granular; ``graph_at(epoch, step)`` /
``distinct_programs`` expose it, and both engines cache one executable per
distinct ``GossipProgram`` (a handful per run, compiled at first use).

Closed-loop variant (``core/consensus.py``): this module's schedule is the
*open-loop* time law.  Passing ``consensus_target=`` to ``make_topology``
wraps the same schedule in a ``ConsensusController`` that walks the ladder
``k0, k0-1, …, 2[, one_peer]`` on a measured trigger instead — each probe
compares the on-device consensus distance Ξ_t = √(1/n Σ_i ‖x_i - x̄‖²)
(arXiv:2102.04828) against ``target · Ξ_0`` and steps down one rung when it
crosses, so both the k-decay *and* the one-peer handoff epoch come from the
run's own variance signal, not the γ·epoch constant.  The controller can
only select among the ladder's pre-enumerated programs, preserving the
zero-mid-run-recompiles invariant.

Paper defaults (Table 4):
    ResNet20 / DenseNet100 / LSTM @ 96 GPUs : k0 = 10,  gamma_k = 0.02
    ResNet50 @ 1008 GPUs                    : k0 = 112, gamma_k = 1

The paper's heuristic initialization (Table 2) is k0 = max(#GPUs // 9, 2);
``default_k0`` implements it.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Union

import numpy as np

from repro.core.graphs import (
    CommGraph, RingLattice, one_peer_exponential, one_peer_period,
)

__all__ = ["AdaSchedule", "default_k0"]


def default_k0(n_nodes: int) -> int:
    """Paper Table 2 heuristic: k(ours) = max(#GPUs // 9, 2)."""
    return max(n_nodes // 9, 2)


@dataclasses.dataclass(frozen=True)
class AdaSchedule:
    """Maps (epoch, step) -> communication graph (Algorithm 1 + extension).

    k_floor: the decay floor.  An int (paper: 2) keeps the final graph a
      static ring lattice; the string ``"one_peer"`` hands off to the
      time-varying one-peer exponential family once the lattice would
      decay below k=2.
    """

    n_nodes: int
    k0: int
    gamma_k: float = 0.02
    k_floor: Union[int, str] = 2  # Algorithm 1 line 2, or "one_peer"

    @classmethod
    def auto(cls, n_nodes: int, gamma_k: float = 0.02) -> "AdaSchedule":
        return cls(n_nodes=n_nodes, k0=default_k0(n_nodes), gamma_k=gamma_k)

    # -- schedule ------------------------------------------------------------
    def _k_raw(self, epoch: int) -> int:
        return self.k0 - int(self.gamma_k * epoch)

    def one_peer_at(self, epoch: int) -> bool:
        """True once the schedule has handed off to the one-peer family."""
        return self.k_floor == "one_peer" and self._k_raw(epoch) < 2

    def k_at(self, epoch: int) -> int:
        """Coordination number at an epoch (0-indexed); 1 in one-peer mode."""
        if self.one_peer_at(epoch):
            return 1
        floor = 2 if self.k_floor == "one_peer" else int(self.k_floor)
        # A node cannot have more neighbors than n-1.
        return int(np.clip(self._k_raw(epoch), floor, max(self.n_nodes - 1, 1)))

    def graph_at(self, epoch: int, step: int = 0) -> CommGraph:
        if self.one_peer_at(epoch):
            return one_peer_exponential(self.n_nodes, step)
        return _lattice(self.n_nodes, self.k_at(epoch))

    def mixing_matrix_at(self, epoch: int, step: int = 0) -> np.ndarray:
        """Dense W per Algorithm 1 lines 3-8 (uniform 1/(k+1) weights)."""
        return self.graph_at(epoch, step).mixing_matrix()

    def period_at(self, epoch: int) -> int:
        """Steps before the graph repeats within an epoch (1 when static)."""
        return one_peer_period(self.n_nodes) if self.one_peer_at(epoch) else 1

    # -- up-front enumeration (zero mid-run recompiles) ----------------------
    def distinct_graphs(self, n_epochs: int) -> list[tuple[int, CommGraph]]:
        """(first_epoch, graph) for each distinct k over a run.

        For ``k_floor="one_peer"`` the one-peer phase contributes its step-0
        graph only; use ``distinct_programs`` for the full step-granular set.
        """
        out: list[tuple[int, CommGraph]] = []
        last_k = None
        for e in range(n_epochs):
            k = self.k_at(e)
            if k != last_k:
                out.append((e, self.graph_at(e)))
                last_k = k
        return out

    def distinct_programs(
        self, n_epochs: int
    ) -> list[tuple[tuple[int, int], "object"]]:
        """((first_epoch, step_phase), GossipProgram) for every distinct
        compiled mixing program over a run — the executables an engine needs.

        Delegates to ``Topology.distinct_programs`` (the single enumeration
        implementation).
        """
        from repro.core.dsgd import Topology

        topo = Topology(name="d_ada", n_nodes=self.n_nodes, ada=self)
        return topo.distinct_programs(n_epochs)


@lru_cache(maxsize=256)
def _lattice(n: int, k: int) -> CommGraph:
    return RingLattice(n, k)
