"""Bucketed gossip execution: partition the parameter vector, pipeline it.

All gossip used to run as ONE monolithic dispatch over the full flattened
parameter vector, with the Ξ_t consensus probe as a separate tiny dispatch
— so communication serialized entirely behind compute on the hot path.
This module supplies the two pieces that break that tail barrier
("From Promise to Practice", arXiv:2410.11998; decent-dp's
``param_as_bucket_view`` / ``bucket_size_in_mb``):

``BucketLayout``
    A *deterministic, size-targeted* partition of the flattened parameter
    pytree into contiguous buckets of ~``bucket_mb`` MiB (float32
    accounting, so the layout is dtype- and value-independent).  Buckets
    may cross leaf boundaries; a segment table maps each bucket to its
    ``(leaf, start, stop)`` slices, and ``split_*`` / ``merge_*`` views
    round-trip exactly.  Both engines (the vmap simulator and the SPMD
    trainer's stacked realization) build the SAME layout from abstract
    leaf shapes, so a checkpoint moved between engines buckets identically.

``build_bucket_step``
    The per-bucket executor: one jitted dispatch that runs bucket *b*'s
    plain-SGD update AND its gossip mixing rounds (interpreter or fused
    Pallas kernel), plus this bucket's partial Ξ_t sum, accumulated into
    a tiny (n,) token threaded bucket-to-bucket.  Bucket *i*'s (n, w)
    parameter/gradient payload carries NO dependency on bucket *i−1*'s
    output — only the token does — so the engines issue all B dispatches
    back-to-back, the token pins a consistent cross-device execution
    order (required: independent collective-bearing executables can
    otherwise start in different per-device orders and deadlock at the
    permute rendezvous), and the runtime pipelines the payload work.  On a TPU mesh the
    same structure overlaps bucket *i*'s PPermutes with bucket *i+1*'s
    update; on the 2-CPU XLA box it lands as dispatch pipelining plus
    cache blocking (each bucket's update output is still cache-hot when
    its mixing pass reads it — the monolithic step streams the full
    multi-MB vector through memory once per pass instead).

Executable accounting: every full bucket has the same width, so jax's
shape-keyed jit cache compiles ONE executable per (program, width) — at
most two per program (full width + tail) regardless of bucket count, and
fault masks stay runtime operands, so executables scale with distinct
programs, not with buckets × faults.

The Ξ_t probe fold: each bucket's dispatch returns the per-node partial
sum  Σ_{c ∈ bucket} (x_ic − x̄_c)²  over its POST-MIX values.  Summing the
partials over buckets equals ``consensus_sq_stacked`` of the new params
exactly (the consensus distance decomposes per coordinate), so the engine
caches the folded (n,) vector and the next probe takes a host-side √mean
instead of dispatching the standalone probe executable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = [
    "BucketLayout",
    "MAX_INFLIGHT_BUCKETS",
    "build_bucket_step",
    "bucket_eligible_optimizer",
    "xi_from_folded_sq",
]

_F32_BYTES = 4  # layout accounting is dtype-independent by design

# Dispatch-window depth for the per-bucket pipeline.  The Ξ² token chain
# orders bucket executables per device, but XLA's CPU runtime matches
# cross-module collectives at a global rendezvous, and queueing hundreds
# of collective-bearing launches at once can strand a rank there (7 of 8
# waiting at a permute while the scheduler never runs the 8th) even with
# the token chain in place.  Both engines therefore block on the token of
# the bucket leaving the window before dispatching a new one: at most
# this many bucket launches are in flight — plenty to overlap bucket i's
# permutes with bucket i+1's compute — and the host sync is on a tiny
# (n,) f32 vector, so the payload transfers stay asynchronous.  This also
# bounds staging memory to window × bucket bytes per node.
MAX_INFLIGHT_BUCKETS = 4


def _leaf_sizes_stacked(tree: PyTree) -> tuple[int, ...]:
    """Per-node flat element count of each leaf (leading axis = node axis)."""
    sizes = []
    for leaf in jax.tree.leaves(tree):
        shape = leaf.shape
        size = 1
        for d in shape[1:]:
            size *= int(d)
        sizes.append(size)
    return tuple(sizes)


def _leaf_sizes_local(tree: PyTree) -> tuple[int, ...]:
    sizes = []
    for leaf in jax.tree.leaves(tree):
        size = 1
        for d in leaf.shape:
            size *= int(d)
        sizes.append(size)
    return tuple(sizes)


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Deterministic size-targeted partition of a flattened parameter tree.

    ``sizes`` is the per-node flat element count of each leaf in tree
    order; ``bucket_elems`` the target elements per bucket.  The partition
    is contiguous equal-width slices of the concatenated [0, P) vector —
    every bucket but the last has exactly ``bucket_elems`` elements, so
    the jit shape cache shares one executable across all full buckets.
    Build via ``for_stacked`` / ``for_local`` (works on concrete arrays
    and ``ShapeDtypeStruct`` trees alike — only shapes are read).
    """

    sizes: tuple[int, ...]
    bucket_elems: int

    def __post_init__(self):
        if self.bucket_elems < 1:
            raise ValueError(f"bucket_elems must be >= 1, got {self.bucket_elems}")
        if any(s < 0 for s in self.sizes):
            raise ValueError(f"negative leaf size in {self.sizes}")

    # -- constructors --------------------------------------------------------
    @staticmethod
    def elems_for_mb(bucket_mb: float) -> int:
        """Target elements per bucket for a MiB budget (float32 accounting)."""
        return max(1, int(float(bucket_mb) * (1 << 20)) // _F32_BYTES)

    @classmethod
    def for_stacked(cls, tree: PyTree, bucket_mb: float) -> "BucketLayout":
        """Layout for trees whose leaves carry a leading (n, ...) node axis."""
        return cls(_leaf_sizes_stacked(tree), cls.elems_for_mb(bucket_mb))

    @classmethod
    def for_local(cls, tree: PyTree, bucket_mb: float) -> "BucketLayout":
        """Layout for one node's (un-stacked) parameter tree."""
        return cls(_leaf_sizes_local(tree), cls.elems_for_mb(bucket_mb))

    # -- derived views -------------------------------------------------------
    @property
    def total(self) -> int:
        return sum(self.sizes)

    @property
    def num_buckets(self) -> int:
        p = self.total
        if p == 0:
            return 1
        return -(-p // self.bucket_elems)

    @property
    def bounds(self) -> tuple[int, ...]:
        """Bucket boundaries 0 = b_0 < b_1 < ... < b_B = P."""
        cached = self.__dict__.get("_bounds")
        if cached is None:
            p = self.total
            cuts = list(range(0, p, self.bucket_elems)) + [p]
            if len(cuts) == 1:  # empty tree: one empty bucket
                cuts = [0, 0]
            cached = tuple(cuts)
            object.__setattr__(self, "_bounds", cached)
        return cached

    @property
    def widths(self) -> tuple[int, ...]:
        b = self.bounds
        return tuple(b[i + 1] - b[i] for i in range(len(b) - 1))

    @property
    def segments(self) -> tuple[tuple[tuple[int, int, int], ...], ...]:
        """Per bucket: ``(leaf_index, start, stop)`` slices in leaf-local flat
        coordinates.  Buckets freely cross leaf boundaries."""
        cached = self.__dict__.get("_segments")
        if cached is None:
            starts = []  # global offset of each leaf
            off = 0
            for s in self.sizes:
                starts.append(off)
                off += s
            out = []
            b = self.bounds
            for k in range(len(b) - 1):
                lo, hi = b[k], b[k + 1]
                segs = []
                for li, (s0, sz) in enumerate(zip(starts, self.sizes)):
                    s, e = max(lo, s0), min(hi, s0 + sz)
                    if e > s:
                        segs.append((li, s - s0, e - s0))
                out.append(tuple(segs))
            cached = tuple(out)
            object.__setattr__(self, "_segments", cached)
        return cached

    def describe(self) -> str:
        return (
            f"BucketLayout(P={self.total}, target={self.bucket_elems}, "
            f"buckets={self.num_buckets}, widths={self.widths})"
        )

    # -- stacked (n, ...) views ----------------------------------------------
    def _check(self, sizes) -> None:
        if tuple(sizes) != self.sizes:
            raise ValueError(
                f"tree leaf sizes {tuple(sizes)} do not match layout {self.sizes}"
            )

    def split_stacked(self, tree: PyTree) -> list[jax.Array]:
        """Bucket matrices [(n, w_0), (n, w_1), ...] of the stacked tree."""
        leaves = jax.tree.leaves(tree)
        self._check(_leaf_sizes_stacked(tree))
        n = leaves[0].shape[0]
        flat = [x.reshape(n, -1) for x in leaves]
        out = []
        for segs in self.segments:
            parts = [flat[li][:, s:e] for li, s, e in segs]
            if not parts:
                out.append(jnp.zeros((n, 0), jnp.float32))
            elif len(parts) == 1:
                out.append(parts[0])
            else:
                out.append(jnp.concatenate(parts, axis=1))
        return out

    def merge_stacked(self, mats: Sequence[jax.Array], tree_like: PyTree) -> PyTree:
        """Inverse of ``split_stacked``: bucket matrices back into the tree."""
        leaves = jax.tree.leaves(tree_like)
        self._check(_leaf_sizes_stacked(tree_like))
        pieces: list[list[jax.Array]] = [[] for _ in leaves]
        for mat, segs in zip(mats, self.segments):
            off = 0
            for li, s, e in segs:
                pieces[li].append(mat[:, off:off + (e - s)])
                off += e - s
        out = []
        for leaf, ps in zip(leaves, pieces):
            if not ps:  # zero-size leaf
                n = mats[0].shape[0] if mats else leaf.shape[0]
                flat = jnp.zeros((n, 0), jnp.float32)
            elif len(ps) == 1:
                flat = ps[0]
            else:
                flat = jnp.concatenate(ps, axis=1)
            out.append(flat.reshape(leaf.shape).astype(leaf.dtype))
        return jax.tree.unflatten(jax.tree.structure(tree_like), out)

    # -- local (per-node, inside shard_map) views ------------------------------
    def split_local(self, tree: PyTree) -> list[jax.Array]:
        """Bucket vectors [(w_0,), (w_1,), ...] of one node's tree."""
        leaves = jax.tree.leaves(tree)
        self._check(_leaf_sizes_local(tree))
        flat = [x.reshape(-1) for x in leaves]
        out = []
        for segs in self.segments:
            parts = [flat[li][s:e] for li, s, e in segs]
            if not parts:
                out.append(jnp.zeros((0,), jnp.float32))
            elif len(parts) == 1:
                out.append(parts[0])
            else:
                out.append(jnp.concatenate(parts))
        return out

    def merge_local(self, vecs: Sequence[jax.Array], tree_like: PyTree) -> PyTree:
        leaves = jax.tree.leaves(tree_like)
        self._check(_leaf_sizes_local(tree_like))
        pieces: list[list[jax.Array]] = [[] for _ in leaves]
        for vec, segs in zip(vecs, self.segments):
            off = 0
            for li, s, e in segs:
                pieces[li].append(vec[off:off + (e - s)])
                off += e - s
        out = []
        for leaf, ps in zip(leaves, pieces):
            if not ps:
                flat = jnp.zeros((0,), jnp.float32)
            elif len(ps) == 1:
                flat = ps[0]
            else:
                flat = jnp.concatenate(ps)
            out.append(flat.reshape(leaf.shape).astype(leaf.dtype))
        return jax.tree.unflatten(jax.tree.structure(tree_like), out)


# ---------------------------------------------------------------------------
# The per-bucket executor (shared by both engines)
# ---------------------------------------------------------------------------

def bucket_eligible_optimizer(optimizer) -> bool:
    """Can this optimizer's update be re-run independently per bucket?

    True for the SGD family: the update is elementwise (momentum state
    mirrors the params leaf-for-leaf, so it buckets identically, and
    weight decay / Nesterov stay elementwise too).  AdamW (global step
    counter in its state tree) and LARS (per-*layer* trust ratios that a
    bucket boundary would corrupt) keep the monolithic path.
    """
    hyper = optimizer.hyper or {}
    return hyper.get("kind") == "sgd"


def xi_from_folded_sq(folded_sq) -> float:
    """Host-side Ξ_t from the accumulated per-node partial sums (final √)."""
    import numpy as np

    sq = np.asarray(folded_sq)
    return float(np.sqrt(np.mean(sq))) if sq.size else 0.0


def _bucket_partial_sq(out_mat: jax.Array) -> jax.Array:
    """This bucket's per-node partial Σ_c (x_ic - x̄_c)² — (n,) float32.

    Summed over buckets this equals ``consensus_sq_stacked`` of the merged
    tree exactly: the consensus distance decomposes per coordinate.
    """
    xf = out_mat.astype(jnp.float32)
    d = xf - xf.mean(axis=0, keepdims=True)
    return jnp.sum(d * d, axis=1)


def build_bucket_step(
    program,
    *,
    hyper: dict,
    has_momentum: bool,
    mix_order: str = "post",
    faulty: bool = False,
    kernel_split=None,
):
    """Build the jittable per-bucket dispatch: SGD update + mixing rounds.

    The returned function operates on one bucket's (n, w) matrices::

        fn(theta_b, mom_b, grad_b, lr, tok[, fault]) -> (theta_b', mom_b', tok')

    (``mom_b`` / ``mom_b'`` omitted when ``has_momentum`` is False).
    ``tok`` is the running (n,) Ξ² accumulator: ``tok' = tok + partial_b``
    where ``partial_b`` is this bucket's per-node post-mix Σ(x−x̄)².  It is
    deliberately threaded bucket-to-bucket even though the payload slices
    are independent: the tiny (n,) dependency pins a CONSISTENT execution
    order across devices (independent executables that each contain
    collectives may otherwise start in different orders on different
    devices and deadlock at the permute rendezvous — observed on the XLA
    CPU client), while the (n, w) parameter/gradient payloads still carry
    no cross-bucket dependency, so runtimes with per-op dependency
    tracking overlap bucket *i*'s permutes with bucket *i+1*'s update.
    The last bucket's ``tok'`` is the full folded Ξ² vector — the probe
    fold costs zero extra dispatches.  ``fault`` is the engines'
    runtime-mask pytree (``realization_arrays``): update gating and edge
    renormalization ride as runtime values, so every realization reuses
    the one executable.

    ``kernel_split=(first, rest)`` routes the update + first mixing round
    through the fused Pallas kernel (``fused_bucket_update`` — the bucket
    boundary is the kernel's outer dispatch unit) and the remaining fused
    stages through the interpreter; ``None`` runs all-interpreter.  The
    kernel path supports plain momentum-SGD only (the fused-apply gate);
    the interpreter path additionally handles weight decay and Nesterov.

    Only ``mix_order="post"`` buckets: with "pre" mixing the engines keep
    the monolithic step (descent must follow the full-tree mix there, so
    there is nothing to pipeline behind).
    """
    if mix_order != "post":
        raise ValueError("bucketed execution requires mix_order='post'")
    if hyper.get("kind") != "sgd":
        raise ValueError(
            f"bucketed execution supports the SGD family only, got {hyper!r}"
        )
    beta = float(hyper.get("momentum", 0.0))
    wd = float(hyper.get("weight_decay", 0.0) or 0.0)
    nesterov = bool(hyper.get("nesterov", False))
    if kernel_split is not None and (wd or nesterov):
        raise ValueError("the fused kernel path supports plain momentum-SGD only")

    def _mix(mat, fault):
        if faulty:
            return program.apply_masked(
                mat, fault["alive"], link_up=fault.get("link")
            )
        return program.apply_stacked(mat)

    def _update(theta, mom, grad, lr, fault):
        """Elementwise SGD on one bucket matrix; returns (theta*, mom')."""
        t32 = theta.astype(jnp.float32)
        g32 = grad.astype(jnp.float32)
        if wd:
            g32 = g32 + wd * t32
        if beta == 0.0:
            step_v, new_m = g32, mom
        else:
            new_m = beta * mom + g32
            step_v = g32 + beta * new_m if nesterov else new_m
        t_new = t32 - jnp.asarray(lr, jnp.float32) * step_v
        if faulty:
            # stragglers/dead skip their local update entirely
            u = fault["update"].astype(jnp.float32)[:, None]
            t_new = jnp.where(u > 0, t_new, t32)
            if beta != 0.0:
                new_m = jnp.where(u > 0, new_m, mom)
        return t_new.astype(theta.dtype), new_m

    def _kernel_round(theta, mom, grad, lr, fault):
        from repro.kernels.gossip_update import fused_bucket_update

        first, rest = kernel_split
        t_new, m_new = fused_bucket_update(
            first, theta, grad, mom,
            lr=lr, beta=beta, fault=fault, mix_order="post",
        )
        for stage in rest:
            t_new = (
                stage.apply_masked(
                    t_new, fault["alive"], link_up=fault.get("link")
                )
                if faulty
                else stage.apply_stacked(t_new)
            )
        return t_new, m_new

    def bucket_step(theta_b, mom_b, grad_b, lr, tok, fault=None):
        if kernel_split is not None:
            mixed, m_new = _kernel_round(theta_b, mom_b, grad_b, lr, fault)
        else:
            theta_star, m_new = _update(theta_b, mom_b, grad_b, lr, fault)
            mixed = _mix(theta_star, fault)
        tok_out = tok.astype(jnp.float32) + _bucket_partial_sq(mixed)
        return mixed, m_new, tok_out

    if has_momentum:
        if faulty:
            return bucket_step
        return lambda t, m, g, lr, tok: bucket_step(t, m, g, lr, tok)

    # momentum-free signature: no state matrix in or out
    def bucket_step_nomom(theta_b, grad_b, lr, tok, fault=None):
        zeros = jnp.zeros(theta_b.shape, jnp.float32)
        mixed, _, tok_out = bucket_step(theta_b, zeros, grad_b, lr, tok, fault)
        return mixed, tok_out

    if faulty:
        return bucket_step_nomom
    return lambda t, g, lr, tok: bucket_step_nomom(t, g, lr, tok)
