"""Communication graphs for decentralized data-parallel training.

Implements the five representative graphs of the paper (Table 1 / Figure 1):
ring, torus, ring lattice, exponential, complete — plus the Ada adaptive
ring-lattice (Algorithm 1).

Every graph here is *circulant* on the flattened node index (ring,
ring-lattice, exponential) or grid-circulant (torus).  A circulant gossip
matrix is fully described by a set of (offset, weight) pairs:

    W[i, j] = weight(d)   where  d = (j - i) mod n  is a registered offset

which lets the SPMD engine realize one mixing step as a sum of
``jax.lax.ppermute`` collectives (one per offset) instead of a dense n×n
matrix product — see ``core/mixing.py``.

Weights follow Algorithm 1 of the paper: uniform ``1/(deg+1)`` over the
closed neighborhood (self included), which makes W row-stochastic.  For
undirected graphs W is symmetric (doubly stochastic).  The directed
exponential graph is row-stochastic only, as in the paper.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

__all__ = [
    "CommGraph",
    "Ring",
    "Torus",
    "RingLattice",
    "Exponential",
    "Complete",
    "make_graph",
    "spectral_gap",
]


@dataclasses.dataclass(frozen=True)
class CommGraph:
    """A communication graph over ``n`` gossip nodes.

    Attributes:
      name: human-readable graph name.
      n: number of nodes.
      offsets: circulant offsets ``d`` (mod n); node ``i`` receives from
        node ``(i + d) % n`` for every ``d`` in ``offsets``.  ``0`` (self)
        is implicit and never listed.
      self_weight / neighbor_weight: mixing weights (uniform per Alg. 1).
      directed: whether the edge set is symmetric.
    """

    name: str
    n: int
    offsets: tuple[int, ...]
    directed: bool = False

    def __post_init__(self):
        if self.n < 1:
            raise ValueError(f"graph needs >=1 node, got n={self.n}")
        offs = tuple(sorted({d % self.n for d in self.offsets} - {0}))
        object.__setattr__(self, "offsets", offs)

    # -- basic characteristics (Table 1) ------------------------------------
    @property
    def degree(self) -> int:
        """Number of in-neighbors per node (excluding self)."""
        return len(self.offsets)

    @property
    def num_edges(self) -> int:
        """Directed edge count (undirected edges counted once)."""
        e = self.n * self.degree
        return e if self.directed else e // 2

    @property
    def self_weight(self) -> float:
        return 1.0 / (self.degree + 1)

    @property
    def neighbor_weight(self) -> float:
        return 1.0 / (self.degree + 1)

    @property
    def is_symmetric(self) -> bool:
        offs = set(self.offsets)
        return all((-d) % self.n in offs for d in offs)

    # -- matrix / schedule views --------------------------------------------
    def mixing_matrix(self, weights: str = "uniform") -> np.ndarray:
        """Dense row-stochastic mixing matrix W (float64).

        weights:
          "uniform"    — 1/(deg+1) everywhere (paper Algorithm 1).
          "metropolis" — Metropolis–Hastings: W_ij = 1/(1+max(deg_i, deg_j)),
            W_ii = 1 − Σ_j W_ij.  Doubly stochastic for *any* undirected
            graph (beyond-paper; coincides with uniform on the regular
            graphs used here, but correct for irregular topologies too).
        """
        w = np.zeros((self.n, self.n), dtype=np.float64)
        if weights == "metropolis":
            if self.directed:
                raise ValueError("metropolis weights need an undirected graph")
            deg = np.full(self.n, self.degree, dtype=np.float64)
            for i in range(self.n):
                for d in self.offsets:
                    j = (i + d) % self.n
                    w[i, j] += 1.0 / (1.0 + max(deg[i], deg[j]))
            np.fill_diagonal(w, 0.0)
            np.fill_diagonal(w, 1.0 - w.sum(axis=1))
            return w
        if weights != "uniform":
            raise ValueError(f"unknown weight scheme {weights!r}")
        np.fill_diagonal(w, self.self_weight)
        for i in range(self.n):
            for d in self.offsets:
                w[i, (i + d) % self.n] += self.neighbor_weight
        return w

    def weighted_offsets(self) -> list[tuple[int, float]]:
        """(offset, weight) pairs excluding self — drives shift/ppermute mixing."""
        return [(d, self.neighbor_weight) for d in self.offsets]

    def neighbors(self, i: int) -> list[int]:
        return [(i + d) % self.n for d in self.offsets]

    def comm_bytes_per_node(self, param_bytes: int) -> int:
        """Bytes each node sends per mixing step (the paper's cost argument)."""
        return self.degree * param_bytes

    def describe(self) -> str:
        return (
            f"{self.name}(n={self.n}, degree={self.degree}, "
            f"edges={self.num_edges}, directed={self.directed})"
        )


# ---------------------------------------------------------------------------
# The five representative graphs (paper Figure 1 / Table 1)
# ---------------------------------------------------------------------------

def Ring(n: int) -> CommGraph:
    """Ring: 2 neighbors (±1 hop). Degenerates gracefully for tiny n."""
    if n <= 1:
        return CommGraph("ring", n, ())
    if n == 2:
        return CommGraph("ring", n, (1,))
    return CommGraph("ring", n, (1, n - 1))


def Torus(n: int, grid: tuple[int, int] | None = None) -> CommGraph:
    """2-D torus: 4 neighbors (±1 on each grid dimension).

    The node index is flattened row-major over ``grid=(a, b)`` with
    ``a*b == n``; a torus row/column wrap becomes a circulant offset of the
    flattened index (±1 and ±b), so torus mixing is still a circulant
    schedule.  If ``grid`` is not given we pick the most-square factorization.
    """
    if n <= 4:
        return dataclasses.replace(Ring(n), name="torus")
    if grid is None:
        a = int(math.isqrt(n))
        while n % a:
            a -= 1
        grid = (a, n // a)
    a, b = grid
    if a * b != n:
        raise ValueError(f"torus grid {grid} does not tile n={n}")
    if a == 1 or b == 1:
        return dataclasses.replace(Ring(n), name="torus")
    # Row neighbors: ±1 within a row of length b. Wrapping i -> i±1 inside the
    # row is offset ±1 except at row borders; a true row-ring is NOT circulant
    # in the flat index unless we use offset ±1 with the convention that the
    # flat ring visits nodes in row-major "boustrophedon"... Keep it exact:
    # offsets ±1 (flat ring through all nodes) and ±b (column ring).  This is
    # the standard "twisted torus" embedding used on real interconnects; it
    # has exactly 4 neighbors per node and 2n edges like the paper's torus.
    offs = {1, n - 1, b % n, (n - b) % n}
    return CommGraph("torus", n, tuple(offs))


def RingLattice(n: int, k: int) -> CommGraph:
    """Ring lattice per Algorithm 1: neighbors j ∈ [-k//2, k//2], j != 0.

    ``k`` is the *total neighbor count* (coordination number as used by
    Algorithm 1, where the mixing weight is 1/(k+1)).  NOTE: the paper's §4.1
    prose describes 2k neighbors for coordination number k; Algorithm 1 (which
    we follow) uses k neighbors, k//2 hops on each side.
    """
    if n <= 1:
        return CommGraph(f"ring_lattice(k={k})", n, ())
    k = max(int(k), 1)
    half = max(k // 2, 1)
    half = min(half, (n - 1) // 2 if n > 2 else 1)
    offs: set[int] = set()
    for j in range(1, half + 1):
        offs.add(j % n)
        offs.add((n - j) % n)
    offs.discard(0)
    return CommGraph(f"ring_lattice(k={k})", n, tuple(sorted(offs)))


def Exponential(n: int) -> CommGraph:
    """Directed exponential (expander) graph: neighbors (i + 2^m) % n.

    m = 0, 1, ..., floor(log2(n-1)); degree = floor(log2(n-1)) + 1.
    """
    if n <= 1:
        return CommGraph("exponential", n, (), directed=True)
    mmax = int(math.floor(math.log2(n - 1))) if n > 2 else 0
    offs = {pow(2, m) % n for m in range(mmax + 1)}
    offs.discard(0)
    return CommGraph("exponential", n, tuple(sorted(offs)), directed=True)


def Complete(n: int) -> CommGraph:
    """Complete graph: every node averages with every other node."""
    return CommGraph("complete", n, tuple(range(1, n)))


_FACTORIES = {
    "ring": lambda n, **kw: Ring(n),
    "torus": lambda n, **kw: Torus(n, grid=kw.get("grid")),
    "ring_lattice": lambda n, **kw: RingLattice(n, kw.get("k", 2)),
    "exponential": lambda n, **kw: Exponential(n),
    "complete": lambda n, **kw: Complete(n),
}


def make_graph(kind: str, n: int, **kwargs) -> CommGraph:
    """Factory: ``make_graph("ring_lattice", 96, k=10)``."""
    try:
        return _FACTORIES[kind](n, **kwargs)
    except KeyError:
        raise ValueError(
            f"unknown graph kind {kind!r}; one of {sorted(_FACTORIES)}"
        ) from None


def spectral_gap(graph_or_matrix) -> float:
    """1 - |lambda_2(W)|: the consensus rate of a mixing matrix.

    Larger gap = faster information spreading (complete: gap = 1).
    """
    w = (
        graph_or_matrix.mixing_matrix()
        if isinstance(graph_or_matrix, CommGraph)
        else np.asarray(graph_or_matrix, dtype=np.float64)
    )
    if w.shape[0] == 1:
        return 1.0
    eig = np.linalg.eigvals(w)
    mags = np.sort(np.abs(eig))[::-1]
    return float(1.0 - mags[1])
