"""Communication graphs for decentralized data-parallel training.

Implements the five representative graphs of the paper (Table 1 / Figure 1)
— ring, torus, ring lattice, exponential, complete — plus beyond-paper
families from related work: the time-varying one-peer exponential graph
(arXiv:2410.11998), seeded random matchings (pairwise averaging), the star,
and arbitrary graphs via ``from_adjacency``.

Graphs are *descriptions only*.  How a graph's mixing step  θ ← W θ  is
executed is decided by compiling it into a ``GossipProgram``
(``core/schedule.py``), the IR both training engines interpret.  Two graph
classes split the old monolithic ``CommGraph``:

  * ``CirculantGraph`` — the fast path.  W is circulant on the flattened
    node index: fully described by (offset, multiplicity) pairs with
    ``W[i, (i+d) % n] = mult_d / (deg + 1)``.  Compiles to exactly one
    collective-permute per offset (complete graph → one all-reduce), and
    its spectral gap is the DFT of the weight vector (exact at n = 1008).
  * ``EdgeGraph``      — the general path: an explicit undirected edge set
    with per-node degrees and Metropolis–Hastings weights
    ``W_ij = 1/(1 + max(deg_i, deg_j))`` (doubly stochastic for *any*
    undirected graph).  The compiler edge-colors the edge set into ≤ Δ+1
    matchings (Vizing / Misra–Gries), one per-node-weighted permute each —
    a matching is the 1-color special case, and the star costs O(Δ)
    permute rounds instead of the dense gather-row all-gather.

Weights on circulant graphs follow Algorithm 1 of the paper: uniform
``1/(deg+1)`` over the closed neighborhood (self included; multi-edges —
e.g. the 2×b torus column wrap — count with multiplicity), making W
row-stochastic, and symmetric (doubly stochastic) for undirected graphs.
The directed exponential graph is row-stochastic only, as in the paper;
one-peer graphs are permutations and therefore doubly stochastic even
though directed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

__all__ = [
    "CommGraph",
    "CirculantGraph",
    "EdgeGraph",
    "Ring",
    "Torus",
    "RingLattice",
    "Exponential",
    "Complete",
    "Star",
    "OnePeerExponential",
    "one_peer_exponential",
    "random_matching",
    "from_adjacency",
    "make_graph",
    "spectral_gap",
]


class CommGraph:
    """Base interface of a communication graph over ``n`` gossip nodes.

    Concrete classes: ``CirculantGraph`` (offset-structured fast path) and
    ``EdgeGraph`` (explicit adjacency).  Shared surface: ``n``, ``name``,
    ``degree``, ``num_edges``, ``directed``, ``is_symmetric``,
    ``mixing_matrix()``, ``neighbors(i)``, ``describe()``.
    """

    name: str
    n: int
    directed: bool

    # concrete classes provide: degree, num_edges, is_symmetric,
    # mixing_matrix(), neighbors(i)

    def comm_bytes_per_node(self, param_bytes: int) -> int:
        """Bytes each node sends per mixing step (the paper's cost argument)."""
        return self.degree * param_bytes

    def program(self):
        """Compile this graph into its ``GossipProgram`` (cached)."""
        from repro.core.schedule import compile_graph

        return compile_graph(self)

    def describe(self) -> str:
        return (
            f"{self.name}(n={self.n}, degree={self.degree}, "
            f"edges={self.num_edges}, directed={self.directed})"
        )


# ---------------------------------------------------------------------------
# Circulant fast path
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CirculantGraph(CommGraph):
    """A circulant graph: node ``i`` receives from ``(i + d) % n`` per offset.

    Attributes:
      name: human-readable graph name.
      n: number of nodes.
      offsets: distinct circulant offsets ``d`` (mod n, 0 excluded).
      mult: per-offset edge multiplicity (parallel edges, e.g. the 2×b torus
        column wrap where +b and −b coincide).  Defaults to all-ones.
      directed: whether the offset set is closed under negation.
    """

    name: str
    n: int
    offsets: tuple[int, ...]
    directed: bool = False
    mult: Optional[tuple[int, ...]] = None

    def __post_init__(self):
        if self.n < 1:
            raise ValueError(f"graph needs >=1 node, got n={self.n}")
        mult = self.mult or (1,) * len(self.offsets)
        if len(mult) != len(self.offsets):
            raise ValueError("mult must align with offsets")
        merged: dict[int, int] = {}
        for d, m in zip(self.offsets, mult):
            d = d % self.n
            if d == 0:
                continue
            merged[d] = merged.get(d, 0) + m
        offs = tuple(sorted(merged))
        object.__setattr__(self, "offsets", offs)
        object.__setattr__(self, "mult", tuple(merged[d] for d in offs))

    # -- basic characteristics (Table 1) ------------------------------------
    @property
    def degree(self) -> int:
        """In-degree per node counting multiplicity (paper Table 1)."""
        return sum(self.mult)

    @property
    def num_edges(self) -> int:
        """Directed edge count (undirected edges counted once)."""
        e = self.n * self.degree
        return e if self.directed else e // 2

    @property
    def self_weight(self) -> float:
        return 1.0 / (self.degree + 1)

    @property
    def neighbor_weight(self) -> float:
        """Weight per *unit* edge (an offset of multiplicity m gets m×this)."""
        return 1.0 / (self.degree + 1)

    @property
    def is_symmetric(self) -> bool:
        offs = dict(zip(self.offsets, self.mult))
        return all(offs.get((-d) % self.n) == m for d, m in offs.items())

    # -- matrix / schedule views --------------------------------------------
    def mixing_matrix(self, weights: str = "uniform") -> np.ndarray:
        """Dense row-stochastic mixing matrix W (float64).

        weights:
          "uniform"    — 1/(deg+1) per unit edge (paper Algorithm 1).
          "metropolis" — Metropolis–Hastings (coincides with uniform on
            these regular graphs; see ``EdgeGraph`` for the general case).
        """
        if weights == "metropolis":
            if self.directed:
                raise ValueError("metropolis weights need an undirected graph")
            deg = self.degree
            w = np.zeros((self.n, self.n), dtype=np.float64)
            for i in range(self.n):
                for d, m in zip(self.offsets, self.mult):
                    w[i, (i + d) % self.n] += m / (1.0 + deg)
            np.fill_diagonal(w, 1.0 - w.sum(axis=1))
            return w
        if weights != "uniform":
            raise ValueError(f"unknown weight scheme {weights!r}")
        w = np.zeros((self.n, self.n), dtype=np.float64)
        np.fill_diagonal(w, self.self_weight)
        for i in range(self.n):
            for d, m in zip(self.offsets, self.mult):
                w[i, (i + d) % self.n] += m * self.neighbor_weight
        return w

    def weight_vector(self) -> np.ndarray:
        """The circulant generator c with ``W[i, j] = c[(j - i) mod n]``."""
        c = np.zeros(self.n, dtype=np.float64)
        c[0] = self.self_weight
        for d, m in zip(self.offsets, self.mult):
            c[d] += m * self.neighbor_weight
        return c

    def weighted_offsets(self) -> list[tuple[int, float]]:
        """(offset, weight) pairs excluding self — drives permute compilation."""
        return [
            (d, m * self.neighbor_weight) for d, m in zip(self.offsets, self.mult)
        ]

    def neighbors(self, i: int) -> list[int]:
        return [(i + d) % self.n for d in self.offsets]


# ---------------------------------------------------------------------------
# General graphs: explicit adjacency, Metropolis–Hastings weights
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EdgeGraph(CommGraph):
    """An arbitrary undirected graph given by its edge set.

    Attributes:
      name: human-readable graph name.
      n: number of nodes.
      edges: undirected edges as sorted (i, j) pairs, i < j, deduplicated.

    Mixing weights are Metropolis–Hastings by default:
    ``W_ij = 1/(1 + max(deg_i, deg_j))``, ``W_ii = 1 − Σ_j W_ij`` — doubly
    stochastic for any undirected graph, including irregular ones where the
    paper's uniform 1/(deg+1) rule is ill-defined.
    """

    name: str
    n: int
    edges: tuple[tuple[int, int], ...]
    directed: bool = dataclasses.field(default=False, init=False)

    def __post_init__(self):
        if self.n < 1:
            raise ValueError(f"graph needs >=1 node, got n={self.n}")
        seen = set()
        for i, j in self.edges:
            if not (0 <= i < self.n and 0 <= j < self.n):
                raise ValueError(f"edge ({i}, {j}) out of range for n={self.n}")
            if i == j:
                raise ValueError(f"self-loop ({i}, {j}) not allowed")
            seen.add((min(i, j), max(i, j)))
        object.__setattr__(self, "edges", tuple(sorted(seen)))

    @property
    def degrees(self) -> tuple[int, ...]:
        deg = [0] * self.n
        for i, j in self.edges:
            deg[i] += 1
            deg[j] += 1
        return tuple(deg)

    @property
    def degree(self) -> int:
        """Maximum node degree (the per-step collective budget)."""
        return max(self.degrees) if self.edges else 0

    @property
    def num_edges(self) -> int:
        return len(self.edges)

    @property
    def is_symmetric(self) -> bool:
        return True

    def mixing_matrix(self, weights: str = "metropolis") -> np.ndarray:
        """Metropolis–Hastings W (doubly stochastic; the only scheme that is
        well-defined for irregular graphs — the paper's uniform 1/(deg+1)
        rule is not row-stochastic when degrees differ, so it is rejected
        rather than silently substituted)."""
        if weights != "metropolis":
            raise ValueError(
                f"EdgeGraph supports only 'metropolis' weights, got {weights!r}"
            )
        deg = self.degrees
        w = np.zeros((self.n, self.n), dtype=np.float64)
        for i, j in self.edges:
            wij = 1.0 / (1.0 + max(deg[i], deg[j]))
            w[i, j] = wij
            w[j, i] = wij
        np.fill_diagonal(w, 1.0 - w.sum(axis=1))
        return w

    def neighbors(self, i: int) -> list[int]:
        out = []
        for a, b in self.edges:
            if a == i:
                out.append(b)
            elif b == i:
                out.append(a)
        return sorted(out)


# ---------------------------------------------------------------------------
# The five representative graphs (paper Figure 1 / Table 1)
# ---------------------------------------------------------------------------

def Ring(n: int) -> CirculantGraph:
    """Ring: 2 neighbors (±1 hop). Degenerates gracefully for tiny n."""
    if n <= 1:
        return CirculantGraph("ring", n, ())
    if n == 2:
        return CirculantGraph("ring", n, (1,))
    return CirculantGraph("ring", n, (1, n - 1))


def Torus(n: int, grid: tuple[int, int] | None = None) -> CirculantGraph:
    """2-D torus: 4 neighbors (±1 on each grid dimension).

    The node index is flattened row-major over ``grid=(a, b)`` with
    ``a*b == n``; a torus row/column wrap becomes a circulant offset of the
    flattened index (±1 and ±b) — the standard "twisted torus" embedding
    used on real interconnects, 4 neighbors per node and 2n edges like the
    paper's torus.  If ``grid`` is not given we pick the most-square
    factorization.

    For ``a == 2`` the column offsets +b and −b coincide mod n (the column
    ring of length 2 is a double edge); the offset carries multiplicity 2 so
    the graph stays 4-regular with weight 2/5 on that neighbor — *not*
    silently degree-3 with 1/4 weights.
    """
    if n <= 4:
        g = Ring(n)
        return dataclasses.replace(g, name="torus")
    if grid is None:
        a = int(math.isqrt(n))
        while n % a:
            a -= 1
        grid = (a, n // a)
    a, b = grid
    if a * b != n:
        raise ValueError(f"torus grid {grid} does not tile n={n}")
    if a == 1 or b == 1:
        return dataclasses.replace(Ring(n), name="torus")
    offs: dict[int, int] = {}
    for d in (1, n - 1, b % n, (n - b) % n):
        offs[d] = offs.get(d, 0) + 1
    return CirculantGraph(
        "torus", n, tuple(offs), mult=tuple(offs[d] for d in offs)
    )


def RingLattice(n: int, k: int) -> CirculantGraph:
    """Ring lattice per Algorithm 1: neighbors j ∈ [-k//2, k//2], j != 0.

    ``k`` is the *total neighbor count* (coordination number as used by
    Algorithm 1, where the mixing weight is 1/(k+1)).  NOTE: the paper's §4.1
    prose describes 2k neighbors for coordination number k; Algorithm 1 (which
    we follow) uses k neighbors, k//2 hops on each side.
    """
    if n <= 1:
        return CirculantGraph(f"ring_lattice(k={k})", n, ())
    k = max(int(k), 1)
    half = max(k // 2, 1)
    half = min(half, (n - 1) // 2 if n > 2 else 1)
    offs: set[int] = set()
    for j in range(1, half + 1):
        offs.add(j % n)
        offs.add((n - j) % n)
    offs.discard(0)
    return CirculantGraph(f"ring_lattice(k={k})", n, tuple(sorted(offs)))


def Exponential(n: int) -> CirculantGraph:
    """Directed exponential (expander) graph: neighbors (i + 2^m) % n.

    m = 0, 1, ..., floor(log2(n-1)); degree = floor(log2(n-1)) + 1.
    """
    if n <= 1:
        return CirculantGraph("exponential", n, (), directed=True)
    mmax = int(math.floor(math.log2(n - 1))) if n > 2 else 0
    offs = {pow(2, m) % n for m in range(mmax + 1)}
    offs.discard(0)
    return CirculantGraph("exponential", n, tuple(sorted(offs)), directed=True)


def Complete(n: int) -> CirculantGraph:
    """Complete graph: every node averages with every other node."""
    return CirculantGraph("complete", n, tuple(range(1, n)))


# ---------------------------------------------------------------------------
# Beyond-paper families (related work)
# ---------------------------------------------------------------------------

def one_peer_exponential(n: int, step: int = 0) -> CirculantGraph:
    """One-peer time-varying exponential graph (arXiv:2410.11998).

    At step t every node talks to exactly ONE peer at hop 2^(t mod p),
    p = ceil(log2(n)): degree 1 per step, and a full cycle of p steps mixes
    like the dense exponential graph.  W = (I + P)/2 with P a cyclic
    permutation — doubly stochastic despite being directed.
    """
    if n <= 1:
        return CirculantGraph("one_peer_exp[0]", n, (), directed=True)
    p = max(int(math.ceil(math.log2(n))), 1)
    m = step % p
    d = pow(2, m) % n
    if d == 0:
        d = 1 % n
    return CirculantGraph(f"one_peer_exp[{m}]", n, (d,), directed=True)


def one_peer_period(n: int) -> int:
    """Steps in one full one-peer exponential cycle."""
    return max(int(math.ceil(math.log2(n))), 1) if n > 1 else 1


def random_matching(n: int, seed: int = 0, round: int = 0) -> EdgeGraph:
    """Seeded random (near-)perfect matching: pairwise parameter averaging.

    Every node averages with exactly one partner (one node idles when n is
    odd).  Deterministic in (seed, round), so an engine can precompile the
    programs of a fixed pool of rounds and rotate through them.
    """
    rng = np.random.default_rng(np.random.SeedSequence([seed, round]))
    order = rng.permutation(n)
    edges = tuple(
        (int(order[2 * i]), int(order[2 * i + 1])) for i in range(n // 2)
    )
    return EdgeGraph(f"random_matching[s{seed}r{round}]", n, edges)


def Star(n: int) -> EdgeGraph:
    """Star graph: node 0 is the hub; MH weights keep it doubly stochastic."""
    return EdgeGraph("star", n, tuple((0, i) for i in range(1, n)))


def OnePeerExponential(n: int) -> CirculantGraph:
    """Alias for the step-0 one-peer exponential graph (see factory)."""
    return one_peer_exponential(n, 0)


def from_adjacency(adj, name: str = "custom") -> EdgeGraph:
    """Build an ``EdgeGraph`` from an adjacency matrix or an edge list.

    ``adj``: an (n, n) 0/1 symmetric ``np.ndarray`` adjacency matrix, or any
    other iterable of (i, j) pairs (``n`` inferred from the maximum index).
    The type disambiguates: a plain list of pairs is ALWAYS an edge list —
    wrap a nested-list matrix in ``np.asarray`` to use the matrix form
    (otherwise a 2-edge list would be indistinguishable from a 2×2 matrix).
    """
    if isinstance(adj, np.ndarray):
        arr = adj
        if arr.ndim != 2 or arr.shape[0] != arr.shape[1]:
            raise ValueError(
                f"adjacency matrix must be square 2-D, got shape {arr.shape}"
            )
        if not np.array_equal(arr, arr.T):
            raise ValueError("adjacency matrix must be symmetric (undirected)")
        n = arr.shape[0]
        edges = tuple(
            (int(i), int(j))
            for i in range(n)
            for j in range(i + 1, n)
            if arr[i, j]
        )
        return EdgeGraph(name, n, edges)
    pairs = [(int(i), int(j)) for i, j in adj]
    n = max((max(i, j) for i, j in pairs), default=-1) + 1
    return EdgeGraph(name, n, tuple(pairs))


_FACTORIES = {
    "ring": lambda n, **kw: Ring(n),
    "torus": lambda n, **kw: Torus(n, grid=kw.get("grid")),
    "ring_lattice": lambda n, **kw: RingLattice(n, kw.get("k", 2)),
    "exponential": lambda n, **kw: Exponential(n),
    "complete": lambda n, **kw: Complete(n),
    "star": lambda n, **kw: Star(n),
    "one_peer_exponential": lambda n, **kw: one_peer_exponential(
        n, kw.get("step", 0)
    ),
    "random_matching": lambda n, **kw: random_matching(
        n, kw.get("seed", 0), kw.get("round", 0)
    ),
    "from_adjacency": lambda n, **kw: from_adjacency(
        kw["adjacency"], kw.get("name", "custom")
    )
    if "adjacency" in kw
    else _missing_adjacency(),
}


def _missing_adjacency():
    raise ValueError("graph kind 'from_adjacency' requires adjacency=")


def make_graph(kind: str, n: int, **kwargs) -> CommGraph:
    """Factory: ``make_graph("ring_lattice", 96, k=10)``."""
    try:
        factory = _FACTORIES[kind]
    except KeyError:
        # narrow: only the registry lookup — a KeyError raised *inside* a
        # factory must not be misreported as an unknown kind
        raise ValueError(
            f"unknown graph kind {kind!r}; one of {sorted(_FACTORIES)}"
        ) from None
    return factory(n, **kwargs)


def spectral_gap(graph_or_matrix) -> float:
    """1 - |lambda_2(W)|: the consensus rate of a mixing matrix.

    Larger gap = faster information spreading (complete: gap = 1).

    Circulant graphs use the exact O(n log n) fast path: a circulant W is
    diagonalized by the DFT, so its eigenvalues are the DFT of the weight
    vector — exact gaps at n = 1008 and beyond, no dense eigendecomposition.
    """
    if isinstance(graph_or_matrix, CirculantGraph):
        if graph_or_matrix.n == 1:
            return 1.0
        eig = np.fft.fft(graph_or_matrix.weight_vector())
        mags = np.sort(np.abs(eig))[::-1]
        return float(1.0 - mags[1])
    w = (
        graph_or_matrix.mixing_matrix()
        if isinstance(graph_or_matrix, CommGraph)
        else np.asarray(graph_or_matrix, dtype=np.float64)
    )
    if w.shape[0] == 1:
        return 1.0
    eig = np.linalg.eigvals(w)
    mags = np.sort(np.abs(eig))[::-1]
    return float(1.0 - mags[1])
