"""DBench — white-box variance instrumentation (paper §3).

DBench profiles a (de)centralized run by collecting, per training iteration,
the L2 norm of every parameter tensor on every replica *before* the mixing
step, then summarizing the cross-replica dispersion of those norms with four
metrics (paper §3.3):

  * gini coefficient
  * index of dispersion        (variance / mean)
  * coefficient of variation   (std / mean)
  * quartile coefficient of dispersion  ((Q3 - Q1) / (Q3 + Q1))

and integrating across parameters via rank analysis (paper Figure 5).

The in-step collection is a cheap per-node reduction (one scalar per leaf);
metric math runs host-side on (n_nodes,)-vectors.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = [
    "param_l2_norms",
    "gini",
    "index_of_dispersion",
    "coefficient_of_variation",
    "quartile_coefficient",
    "variance_report",
    "rank_analysis",
    "DBenchRecorder",
]


# ---------------------------------------------------------------------------
# In-step collection (jit-able)
# ---------------------------------------------------------------------------

def param_l2_norms(params: PyTree) -> jax.Array:
    """Stacked L2 norm per leaf: returns (n_leaves,) float32.

    Used inside the per-node step function (so under vmap/shard_map the
    result gains the node axis automatically).
    """
    leaves = jax.tree.leaves(params)
    return jnp.stack(
        [jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32)))) for x in leaves]
    )


# ---------------------------------------------------------------------------
# Dispersion metrics (host-side, numpy; operate on the replica axis)
# ---------------------------------------------------------------------------

def _as2d(x) -> np.ndarray:
    """-> (n_replicas, n_series) float64."""
    a = np.asarray(x, dtype=np.float64)
    if a.ndim == 1:
        a = a[:, None]
    return a


def gini(x, axis: int = 0) -> np.ndarray:
    """Gini coefficient  Σ_ij |x_i - x_j| / (2 n² μ)  along ``axis``."""
    a = np.moveaxis(np.asarray(x, dtype=np.float64), axis, 0)
    n = a.shape[0]
    diffs = np.abs(a[:, None, ...] - a[None, :, ...]).sum(axis=(0, 1))
    mu = a.mean(axis=0)
    denom = 2.0 * n * n * np.where(mu == 0.0, 1.0, np.abs(mu))
    out = diffs / denom
    return np.where(mu == 0.0, 0.0, out)


def index_of_dispersion(x, axis: int = 0) -> np.ndarray:
    a = np.asarray(x, dtype=np.float64)
    mu = a.mean(axis=axis)
    var = a.var(axis=axis)
    return np.where(mu == 0.0, 0.0, var / np.where(mu == 0.0, 1.0, mu))


def coefficient_of_variation(x, axis: int = 0) -> np.ndarray:
    a = np.asarray(x, dtype=np.float64)
    mu = a.mean(axis=axis)
    sd = a.std(axis=axis)
    return np.where(mu == 0.0, 0.0, sd / np.where(mu == 0.0, 1.0, np.abs(mu)))


def quartile_coefficient(x, axis: int = 0) -> np.ndarray:
    a = np.asarray(x, dtype=np.float64)
    q1 = np.percentile(a, 25, axis=axis)
    q3 = np.percentile(a, 75, axis=axis)
    s = q3 + q1
    return np.where(s == 0.0, 0.0, (q3 - q1) / np.where(s == 0.0, 1.0, s))


_METRICS = {
    "gini": gini,
    "index_of_dispersion": index_of_dispersion,
    "coefficient_of_variation": coefficient_of_variation,
    "quartile_coefficient": quartile_coefficient,
}


def variance_report(norms: np.ndarray) -> dict[str, np.ndarray]:
    """All four metrics for per-node norms of shape (n_nodes, n_leaves)."""
    a = _as2d(norms)
    return {name: fn(a, axis=0) for name, fn in _METRICS.items()}


# ---------------------------------------------------------------------------
# Rank analysis (paper Figure 5)
# ---------------------------------------------------------------------------

def _average_ranks(a: np.ndarray) -> np.ndarray:
    """1-based ranks along axis 0 with ties averaged (scipy ``rankdata``
    "average" method): equal values share the mean of the positions they
    span, so e.g. an all-equal column ranks every entry (I+1)/2."""
    a = np.asarray(a, dtype=np.float64)
    n = a.shape[0]
    flat = a.reshape(n, -1)
    out = np.empty_like(flat)
    for j in range(flat.shape[1]):
        col = flat[:, j]
        order = np.argsort(col, kind="stable")
        i = 0
        while i < n:
            k = i
            while k + 1 < n and col[order[k + 1]] == col[order[i]]:
                k += 1
            out[order[i : k + 1], j] = 0.5 * (i + k) + 1.0  # mean of i+1..k+1
            i = k + 1
    return out.reshape(a.shape)


def rank_analysis(
    per_impl_metric: Mapping[str, np.ndarray]
) -> dict[str, np.ndarray]:
    """Rank SGD implementations by dispersion at matched iterations.

    Args:
      per_impl_metric: impl name -> (n_iters, n_leaves) metric values
        (e.g. gini) collected at the same iterations for the same model.

    Returns:
      impl name -> (n_iters,) mean rank across leaves (1 = lowest variance,
      len(impls) = highest), the paper's integration device for comparing
      topologies across heterogeneous parameters.  Ties get *average* ranks
      (scipy-style): equal-dispersion implementations tie in the Fig-5 rank
      curves instead of being split by dictionary order.
    """
    names = sorted(per_impl_metric)
    stack = np.stack([np.atleast_2d(per_impl_metric[k]) for k in names])  # (I, T, L)
    ranks = _average_ranks(stack)
    return {k: ranks[i].mean(axis=-1) for i, k in enumerate(names)}


# ---------------------------------------------------------------------------
# Recorder — the DBench profiling log of a run
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DBenchRecorder:
    """Accumulates per-iteration profiling data for one training run."""

    impl: str
    n_nodes: int
    leaf_names: Sequence[str] = ()
    iterations: list[int] = dataclasses.field(default_factory=list)
    losses: list[np.ndarray] = dataclasses.field(default_factory=list)
    norms: list[np.ndarray] = dataclasses.field(default_factory=list)

    def record(self, iteration: int, per_node_loss, per_node_norms) -> None:
        """per_node_loss: (n,), per_node_norms: (n, n_leaves) — pre-mixing."""
        self.iterations.append(int(iteration))
        self.losses.append(np.asarray(per_node_loss, dtype=np.float64))
        self.norms.append(np.asarray(per_node_norms, dtype=np.float64))

    def metric_series(self, metric: str = "gini") -> np.ndarray:
        """(n_iters, n_leaves) dispersion series."""
        fn = _METRICS[metric]
        return np.stack([fn(m, axis=0) for m in self.norms])

    def summary(self) -> dict[str, Any]:
        g = self.metric_series("gini")
        return {
            "impl": self.impl,
            "n_nodes": self.n_nodes,
            "iterations": list(self.iterations),
            "mean_loss": [float(l.mean()) for l in self.losses],
            "loss_spread": [float(l.max() - l.min()) for l in self.losses],
            "mean_gini": g.mean(axis=-1).tolist(),
            "max_gini": g.max(axis=-1).tolist(),
        }
