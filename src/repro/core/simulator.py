"""Paper-faithful multi-node simulator (the DBench engine).

Simulates an n-node (de)centralized data-parallel run on any number of real
devices by carrying a leading *node axis* on every state leaf and vmapping
the per-node computation.  Mixing interprets the same compiled
``GossipProgram`` as the SPMD engine — with the dense-matrix interpreter
(the literal equation of the paper, §2.2) by default, so this engine is the
correctness oracle for the production engine.

One simulator step:
  1. per-node forward/backward on that node's batch shard   (vmap)
  2. centralized  : all-reduce gradients, identical update everywhere
     decentralized: local optimizer update, then θ ← W θ  (mix_order="post")
  3. optional DBench probe: per-node, per-leaf L2 norms *before* mixing

Time-varying topologies (one-peer exponential, random-matching pools, Ada
with ``k_floor="one_peer"``) are step-granular: the step function is cached
per compiled program, so a run compiles each member of a small bounded set
(``Topology.distinct_programs``) once at first use and never recompiles.

Closed-loop Ada (``Topology.controller``): before a probe step the engine
computes the consensus distance Ξ_t on-device (one jitted stacked
reduction, ``core/consensus.py``) and feeds it to the controller, which may
step the schedule down one rung.  The controller only selects among the
pre-enumerated ladder programs, so the cached-executable bound holds
unchanged.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import validate_run_config as _validate_run_config
from repro.core import dbench
from repro.core.dsgd import Topology
from repro.core.faults import (
    admit_node, adopt_neighbor_average, drain_handoff, realization_arrays,
    rejoin_neighbors, track_membership,
)
from repro.core.schedule import GossipProgram
from repro.optim.sgd import Optimizer

PyTree = Any

__all__ = ["SimState", "DecentralizedSimulator"]

_ENGINES = {"dense": "dense", "shift": "stacked", "stacked": "stacked"}


@dataclasses.dataclass
class SimState:
    params: PyTree      # leaves (n_nodes, ...)
    opt_state: PyTree   # leaves (n_nodes, ...)
    step: int = 0

    def node_params(self, i: int) -> PyTree:
        return jax.tree.map(lambda x: x[i], self.params)

    def mean_params(self) -> PyTree:
        """The final model θ = average over all θ_i (paper §2.2)."""
        return jax.tree.map(lambda x: x.mean(axis=0), self.params)


class DecentralizedSimulator:
    """vmap-based engine for centralized/decentralized DNN training."""

    def __init__(
        self,
        loss_fn: Callable[..., jax.Array],
        optimizer: Optimizer,
        topology: Topology,
        *,
        mixing: str = "dense",  # "dense" (paper equation) | "shift" (stacked)
        mix_every: int = 1,
        mix_rounds: int = 1,
        hub_balance: bool = False,
        collect_norms: bool = False,
        has_rng: bool = False,
        shard_nodes: bool = False,
        bucket_mb: Optional[float] = None,
        debug_no_retrace: bool = False,
        telemetry=None,
    ):
        """Args:
          loss_fn: per-node ``loss_fn(params, batch)`` (or with rng as third
            arg when ``has_rng``) returning a scalar.
          optimizer: per-node optimizer (state carried per node).
          topology: which SGD implementation to simulate.  A topology with
            a ``fault_model`` runs the fault-aware step: stragglers/dead
            nodes skip their local update, transient drops degrade the
            mixing matrix via *runtime* masks (one executable per program,
            exactly as many as the fault-free run), permanent crashes
            select the pre-enumerated degraded program, and recovered
            nodes rejoin by adopting their neighbors' average.
          mixing: which ``GossipProgram`` interpreter executes W θ — "dense"
            (paper-faithful matrix product) or "shift" (stacked roll/gather).
          mix_rounds: gossip rounds fused into each mixing step — H
            consecutive schedule steps (e.g. a full one-peer cycle) run as
            ONE cached executable instead of H dispatches.
          hub_balance: with ``mix_rounds > 1`` on a static multi-matching
            program, rotate its edge-colored matchings across the H rounds
            (``hub_balanced_rounds``) to cap hot-vertex peak send volume.
          shard_nodes: virtual-node sharding — partition the leading node
            axis over the host's devices (a 1-D "nodes" mesh using the
            largest device count dividing n), so n = 256–1024 dynamics runs
            fit a small CPU box: each device simulates an n/d block of
            virtual nodes.  A no-op (identical numerics) on one device.
          bucket_mb: overlap-scheduled gossip — partition the flattened
            parameter vector into ~bucket_mb-MiB buckets
            (``core/buckets.BucketLayout``) and run each mixing step as
            one *per-bucket* update+gossip dispatch chain instead of a
            monolithic tail: bucket i's permutes carry no data dependency
            on bucket i+1's compute, so the dispatches pipeline, and each
            bucket's Ξ² partial sum is folded into its pass (closed-loop
            probes on fault-free runs stop paying the standalone probe
            executable).  SGD-family optimizers and ``mix_order="post"``
            only; numerically equivalent to the monolithic path (tested
            ≤ 1e-6 vs the dense oracle).
        """
        if mixing not in _ENGINES:
            raise ValueError(
                f"mixing must be one of {sorted(_ENGINES)}, got {mixing!r}"
            )
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.topology = topology
        self.n = topology.n_nodes
        self.mixing = mixing
        self.mix_every = max(int(mix_every), 1)
        self.mix_rounds = max(int(mix_rounds), 1)
        self.hub_balance = bool(hub_balance)
        self.collect_norms = collect_norms
        self.has_rng = has_rng
        self.fault_model = topology.fault_model
        self._last_membership = None
        # unified run telemetry (repro.telemetry): counters/gauges/spans/
        # events for sink-attached runs, and the observational wall-clock
        # deadline trace for deadline runs — the seeded model drives the
        # masks (determinism + engine equivalence), the recorder just logs
        # measured per-round durations against the deadline.  The default
        # recorder has no sinks and costs nothing on the hot path.
        from repro.telemetry import MetricsRecorder

        self.telemetry = (
            telemetry if telemetry is not None else MetricsRecorder()
        )
        self.telemetry.configure(
            deadline_ms=getattr(self.fault_model, "deadline_ms", None)
        )
        if topology.controller is not None:
            topology.controller.bind_recorder(self.telemetry)
        self._pn_bytes: Optional[int] = None
        self._last_program = None
        self._step_cache: dict[Any, Callable] = {}
        # debug mode (repro.analysis.recompile): invoking a WARM cached
        # executable must never trace/compile — the zero-mid-run-recompile
        # invariant enforced live instead of post-hoc cache counting
        self.debug_no_retrace = bool(debug_no_retrace)
        self._was_warm = False
        self.shard_nodes = bool(shard_nodes)
        self._sharding = (
            self._node_sharding(self.n) if self.shard_nodes else None
        )
        self.bucket_mb = bucket_mb
        if bucket_mb is not None:
            from repro.core.buckets import bucket_eligible_optimizer

            if not bucket_eligible_optimizer(optimizer):
                raise ValueError(
                    "bucket_mb requires an SGD-family optimizer (elementwise "
                    f"update; got {optimizer.name}) — AdamW's global step "
                    "counter and LARS's per-layer norms do not bucket"
                )
            if topology.centralized:
                raise ValueError("bucket_mb needs a decentralized topology")
            if topology.mix_order != "post":
                raise ValueError(
                    "bucket_mb requires mix_order='post' (pre-mixing must "
                    "see the full tree before the update — nothing to "
                    "pipeline behind)"
                )
        self._bucket_layout = None
        # Ξ² fold: per-node partial sums accumulated across the last bucketed
        # mixing step's dispatches; valid for a probe at _folded_for_step
        self._folded_sq = None
        self._folded_for_step = -1
        # grads stashed by the bucketed path for the grad-norm gauge at
        # metrics-due steps (cleared after each emission)
        self._pending_grads = None

    # -- telemetry views -------------------------------------------------------
    # round_ms / deadline_overruns were per-engine lists before the shared
    # recorder existed; they stay as thin views for backward compatibility.
    @property
    def round_ms(self) -> list:
        return self.telemetry.round_ms

    @property
    def deadline_overruns(self) -> int:
        return self.telemetry.deadline_overruns

    @property
    def _deadline_ms(self):
        return self.telemetry.deadline_ms

    def _per_node_bytes(self, params: PyTree) -> int:
        """Per-node parameter bytes P for comm billing (stacked leaves
        carry the node axis first)."""
        if self._pn_bytes is None:
            self._pn_bytes = sum(
                int(np.prod(x.shape[1:])) * x.dtype.itemsize
                for x in jax.tree.leaves(params)
            )
        return self._pn_bytes

    def _bill_comm(self, program, params: PyTree, step: int, fr) -> None:
        """Bill one mixing-program application at dispatch time (bytes on
        the wire + permute count), matching the offline replay accounting
        in ``benchmarks/ada.py::_total_comm``."""
        if program is None or not self.telemetry.active:
            return
        alive = link = None
        if fr is not None:
            alive = np.asarray(fr.alive, np.float64)
            link = fr.link_up
        self.telemetry.comm(
            program, self._per_node_bytes(params), step=step,
            alive=alive, link_up=link,
        )

    @staticmethod
    def _node_sharding(n: int):
        """NamedSharding partitioning the leading node axis over the largest
        device count that divides n (1 device => effectively replicated)."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec

        devs = jax.devices()
        nd = max(d for d in range(1, len(devs) + 1) if n % d == 0)
        mesh = Mesh(np.array(devs[:nd]), ("nodes",))
        return NamedSharding(mesh, PartitionSpec("nodes"))

    def _place(self, tree: PyTree) -> PyTree:
        return (
            tree if self._sharding is None
            else jax.device_put(tree, self._sharding)
        )

    # -- state ----------------------------------------------------------------
    def init(self, params: PyTree) -> SimState:
        """Broadcast one replica to all nodes (paper: identical replicas)."""
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (self.n,) + x.shape), params
        )
        opt0 = self.optimizer.init(params)
        opt = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (self.n,) + x.shape), opt0
        )
        return SimState(
            params=self._place(stacked), opt_state=self._place(opt), step=0
        )

    # -- one training step ------------------------------------------------------
    def _build_step(self, program: Optional[GossipProgram], faulty: bool = False):
        """program: compiled mixing schedule; None => pure local update.

        ``faulty`` builds the fault-aware signature: an extra runtime mask
        pytree (``realization_arrays``) gates per-node updates and degrades
        the mixing weights — mask *values* change per realization, the
        executable never does.
        """
        engine = _ENGINES[self.mixing]

        def _grads(params, batch, rng):
            if self.has_rng:
                rngs = jax.random.split(rng, self.n)
                return jax.vmap(jax.value_and_grad(self.loss_fn))(
                    params, batch, rngs
                )
            return jax.vmap(jax.value_and_grad(self.loss_fn))(params, batch)

        def _norms(params):
            return (
                jax.vmap(dbench.param_l2_norms)(params)
                if self.collect_norms
                else jnp.zeros((self.n, 0), jnp.float32)
            )

        def step(params, opt_state, batch, lr, rng):
            loss, grads = _grads(params, batch, rng)
            norms = _norms(params)

            if self.topology.centralized:
                # C_complete: average gradients globally; replicas stay identical.
                grads = jax.tree.map(
                    lambda g: jnp.broadcast_to(
                        g.mean(axis=0, keepdims=True), g.shape
                    ),
                    grads,
                )
                new_params, new_opt = jax.vmap(
                    self.optimizer.update, in_axes=(0, 0, 0, None)
                )(grads, opt_state, params, lr)
                return new_params, new_opt, loss, norms

            if program is not None and self.topology.mix_order == "pre":
                params = program.apply(params, engine=engine)
            new_params, new_opt = jax.vmap(
                self.optimizer.update, in_axes=(0, 0, 0, None)
            )(grads, opt_state, params, lr)
            if program is not None and self.topology.mix_order == "post":
                new_params = program.apply(new_params, engine=engine)
            return new_params, new_opt, loss, norms

        def fault_step(params, opt_state, batch, lr, rng, fault):
            loss, grads = _grads(params, batch, rng)
            norms = _norms(params)

            def _mix(tree):
                return program.apply_masked(
                    tree, fault["alive"], link_up=fault["link"], engine=engine
                )

            if program is not None and self.topology.mix_order == "pre":
                params = _mix(params)
            new_params, new_opt = jax.vmap(
                self.optimizer.update, in_axes=(0, 0, 0, None)
            )(grads, opt_state, params, lr)
            # stragglers and dead nodes skip their local update entirely
            u = fault["update"]

            def _gate(new, old):
                ucol = u.reshape((self.n,) + (1,) * (new.ndim - 1))
                return jnp.where(ucol > 0, new, old)

            new_params = jax.tree.map(_gate, new_params, params)
            new_opt = jax.tree.map(_gate, new_opt, opt_state)
            if program is not None and self.topology.mix_order == "post":
                new_params = _mix(new_params)
            return new_params, new_opt, loss, norms

        fn = fault_step if faulty else step
        if self._sharding is None:
            return jax.jit(fn)
        # virtual-node sharding: keep every node-axis output partitioned so
        # the state never silently collapses to replicated between steps
        s = self._sharding
        return jax.jit(fn, out_shardings=(s, s, s, s))

    def _resolve_program(self, step: int, epoch: int, program_alive=None):
        """This gossip round's fused program (degraded for a permanent-crash
        membership) — shared by the monolithic and bucketed paths."""
        program = self.topology.fused_program_at(
            step=step, epoch=epoch, rounds=self.mix_rounds,
            hub_balance=self.hub_balance,
        )
        if program is not None and program_alive is not None:
            program = program.degrade(program_alive)
        return program

    def _step_for(self, step: int, epoch: int, mix: bool = True,
                  program_alive=None):
        """The jitted executable for one iteration, cached per program.

        ``program_alive`` (permanent-crash membership) selects the
        pre-enumerated degraded program; a non-None value also selects the
        fault-aware step signature.
        """
        faulty = self.fault_model is not None
        # programless keys carry n: an elastic join changes the node-axis
        # shape the closures trace with, so sizes must not share executables
        if self.topology.centralized:
            key = ("__centralized__", self.n)
            program = None
            faulty = False
        elif not mix:
            key = ("__local__", self.n)
            program = None
        else:
            program = self._resolve_program(step, epoch, program_alive)
            key = (
                program.cache_key if program is not None
                else ("__local__", self.n)
            )
        if faulty:
            key = (key, "faulty")
        self._last_program = program  # comm billing reuses this resolution
        self._was_warm = key in self._step_cache
        if key not in self._step_cache:
            self._step_cache[key] = self._build_step(program, faulty=faulty)
        return self._step_cache[key]

    def _retrace_guard(self, warm: bool, label: str):
        """``debug_no_retrace`` guard around a cached-executable call: a
        warm executable invoked again must not fire a trace/compile event
        (``repro.analysis.recompile``).  Guards ONLY the call itself —
        eager membership-event work (admit/adopt/drain) legitimately runs
        outside jit and must not trip the sanitizer."""
        if not (self.debug_no_retrace and warm):
            import contextlib

            return contextlib.nullcontext()
        from repro.analysis.recompile import assert_no_retrace

        return assert_no_retrace(label)

    # -- bucketed, overlap-scheduled path -----------------------------------
    def _grads_fn(self):
        """Jitted (loss, grads, norms) — the compute the bucketed mixing
        dispatches pipeline behind."""
        key = ("__grads__", self.n)
        if key not in self._step_cache:

            def gn(params, batch, rng):
                if self.has_rng:
                    rngs = jax.random.split(rng, self.n)
                    loss, grads = jax.vmap(jax.value_and_grad(self.loss_fn))(
                        params, batch, rngs
                    )
                else:
                    loss, grads = jax.vmap(jax.value_and_grad(self.loss_fn))(
                        params, batch
                    )
                norms = (
                    jax.vmap(dbench.param_l2_norms)(params)
                    if self.collect_norms
                    else jnp.zeros((self.n, 0), jnp.float32)
                )
                return loss, grads, norms

            if self._sharding is None:
                self._step_cache[key] = jax.jit(gn)
            else:
                s = self._sharding
                self._step_cache[key] = jax.jit(gn, out_shardings=(s, s, s))
        return self._step_cache[key]

    def _bucket_fn(self, program, width: int, has_m: bool, faulty: bool):
        """One bucket width's jitted update+mix dispatch, cached per
        (program, width): all full buckets share one executable, the tail
        adds at most a second — fault masks are runtime operands, so
        executables scale with distinct programs, never buckets × faults."""
        key = ("__bucket__", program.cache_key, width, has_m, faulty)
        self._was_warm = key in self._step_cache
        if key not in self._step_cache:
            from repro.core.buckets import build_bucket_step

            fn = build_bucket_step(
                program,
                hyper=self.optimizer.hyper,
                has_momentum=has_m,
                faulty=faulty,
            )
            if self._sharding is None:
                self._step_cache[key] = jax.jit(fn)
            else:
                s = self._sharding
                outs = (s, s, s) if has_m else (s, s)
                self._step_cache[key] = jax.jit(fn, out_shardings=outs)
        return self._step_cache[key]

    def _bucketed_step(self, state, batch, lr, rng, program, fault):
        """One iteration as B independent per-bucket dispatches.

        The grads dispatch runs first; then each bucket's update+mix+Ξ²
        launches as its own executable over that bucket's slices.  The
        (n,) Ξ² accumulator token is the ONLY cross-bucket dependency —
        it pins a consistent execution order (collective-bearing
        executables deadlock if devices start them in different orders)
        while the (n, w) payloads stay independent, so the runtime
        pipelines bucket i's permutes behind bucket i+1's update (the
        monolithic step is one tail barrier instead).  On a fault-free
        step the final token is cached for the next Ξ_t probe.  The
        dispatch window is bounded (``MAX_INFLIGHT_BUCKETS``): before
        launching a new bucket the host blocks on the token of the one
        leaving the window, so fine bucket sizes cannot queue hundreds
        of collective-bearing launches at once.
        """
        from repro.core.buckets import MAX_INFLIGHT_BUCKETS, BucketLayout

        if self._bucket_layout is None:
            # per-node leaf sizes only — elastic joins change n, not the
            # layout, and jit re-traces per node-axis shape on its own
            self._bucket_layout = BucketLayout.for_stacked(
                state.params, self.bucket_mb
            )
        layout = self._bucket_layout
        loss, grads, norms = self._grads_fn()(state.params, batch, rng)
        # the bucketed path is the one place grads materialize outside the
        # fused step executable — stash them for the grad-norm gauge (host
        # work deferred to the post-step metrics emission, after the loss
        # sync, so the bucket dispatch chain is not delayed)
        self._pending_grads = (
            grads if self.telemetry.due(state.step) else None
        )
        has_m = state.opt_state != ()
        t_mats = layout.split_stacked(state.params)
        g_mats = layout.split_stacked(grads)
        m_mats = layout.split_stacked(state.opt_state) if has_m else None
        lr32 = jnp.float32(lr)
        n = jax.tree.leaves(state.params)[0].shape[0]
        tok = self._place(jnp.zeros((n,), jnp.float32))
        out_t, out_m = [], []
        window: deque = deque()
        for b, w in enumerate(layout.widths):
            tb = self.telemetry.span_start()
            if len(window) >= MAX_INFLIGHT_BUCKETS:
                jax.block_until_ready(window.popleft())
            fn = self._bucket_fn(program, w, has_m, fault is not None)
            args = (
                (t_mats[b], m_mats[b], g_mats[b], lr32, tok)
                if has_m
                else (t_mats[b], g_mats[b], lr32, tok)
            )
            if fault is not None:
                args = args + (fault,)
            with self._retrace_guard(self._was_warm, f"bucket {b}"):
                res = fn(*args)
            if has_m:
                t2, m2, tok = res
                out_m.append(m2)
            else:
                t2, tok = res
            out_t.append(t2)
            window.append(tok)
            self.telemetry.bucket_span(tb, step=state.step, index=b)
        new_params = self._place(layout.merge_stacked(out_t, state.params))
        new_opt = (
            self._place(layout.merge_stacked(out_m, state.opt_state))
            if has_m
            else state.opt_state
        )
        if fault is None:
            self._folded_sq = tok
            self._folded_for_step = state.step + 1
        return new_params, new_opt, loss, norms

    def train_step(
        self,
        state: SimState,
        batch: PyTree,
        lr: float,
        *,
        epoch: int = 0,
        rng: Optional[jax.Array] = None,
    ) -> tuple[SimState, jax.Array, jax.Array]:
        """Run one iteration.

        Args:
          batch: leaves with leading (n_nodes, per_node_batch, ...) dims.
        Returns:
          (new_state, per_node_loss (n,), per_node_norms (n, n_leaves)).
        """
        tel = self.telemetry
        t_start = tel.round_start()
        fr = None
        if self.fault_model is not None:
            fr = self.fault_model.at(state.step)
            if fr.joins:
                # elastic growth: resize the family, then admit the newcomers
                if tel.active:
                    tel.event("join", state.step,
                              data={"nodes": sorted(int(j) for j in fr.joins)})
                state = self._admit(state, fr, epoch)
            for node in fr.rejoin:
                # elastic re-entry: adopt the alive neighbors' average
                nbrs = rejoin_neighbors(
                    self.topology, fr, node, step=state.step, epoch=epoch,
                    mix_every=self.mix_every,
                )
                if tel.active:
                    tel.event("rejoin", state.step, data={"node": int(node)})
                state = SimState(
                    adopt_neighbor_average(state.params, node, nbrs),
                    adopt_neighbor_average(state.opt_state, node, nbrs),
                    state.step,
                )
            for node in fr.depart:
                # clean preemption departure: exact mean-preserving handoff
                # to the neighborhood before the node's row goes dead
                nbrs = rejoin_neighbors(
                    self.topology, fr, node, step=state.step, epoch=epoch,
                    mix_every=self.mix_every,
                )
                if tel.active:
                    tel.event("depart", state.step, data={"node": int(node)})
                state = SimState(
                    drain_handoff(state.params, node, nbrs, fr.alive),
                    drain_handoff(state.opt_state, node, nbrs, fr.alive),
                    state.step,
                )
        ctl = self.topology.controller
        if self.fault_model is not None:
            prev_membership = self._last_membership
            self._last_membership = track_membership(
                self._last_membership, fr, ctl, state.step
            )
            if (
                tel.active
                and prev_membership is not None
                and self._last_membership != prev_membership
            ):
                tel.event(
                    "membership", state.step,
                    data={"alive": [bool(b) for b in self._last_membership]},
                )
        if ctl is not None and ctl.should_probe(state.step):
            if fr is not None:
                from repro.core.consensus import consensus_distance_masked_jit

                # membership mask, NOT the raw alive mask: a float drain
                # boost must not weight the draining node in the probe
                xi = consensus_distance_masked_jit(
                    state.params,
                    jnp.asarray(np.asarray(fr.alive) != 0, jnp.float32),
                )
            elif self._folded_for_step == state.step:
                # folded probe: the last bucketed mixing step already
                # accumulated each bucket's Ξ² partial sum in its own
                # dispatch — only the final √mean runs, on the host
                from repro.core.buckets import xi_from_folded_sq

                xi = xi_from_folded_sq(self._folded_sq)
            else:
                from repro.core.consensus import consensus_distance_jit

                xi = consensus_distance_jit(state.params)
            if tel.active:
                tel.gauge("xi", float(xi), step=state.step)
            ctl.observe(float(xi), state.step)
        mix = (state.step + 1) % self.mix_every == 0
        # index time-varying schedules by gossip round (see SPMDTrainer):
        # raw-step indexing under mix_every=H would alias period-p families
        # to a single phase whenever p divides H.
        sel = fr.selection_mask() if fr is not None else None
        palive = sel if sel is not None and not sel.all() else None
        if rng is None:
            rng = jax.random.PRNGKey(0)
        if (
            self.bucket_mb is not None
            and mix
            and not self.topology.centralized
        ):
            program = self._resolve_program(
                state.step // self.mix_every, epoch, palive
            )
            if program is not None:
                self._bill_comm(program, state.params, state.step, fr)
                fault = realization_arrays(fr) if fr is not None else None
                p, o, loss, norms = self._bucketed_step(
                    state, batch, lr, rng, program, fault
                )
                self._finish_round(
                    loss, norms, t_start, step=state.step, mix=True, lr=lr
                )
                return SimState(p, o, state.step + 1), loss, norms
        fn = self._step_for(
            state.step // self.mix_every, epoch, mix=mix, program_alive=palive
        )
        if mix and not self.topology.centralized:
            self._bill_comm(self._last_program, state.params, state.step, fr)
        args = (state.params, state.opt_state, batch, jnp.float32(lr), rng)
        if fr is not None and not self.topology.centralized:
            args = args + (realization_arrays(fr),)
        with self._retrace_guard(self._was_warm, f"sim step {state.step}"):
            p, o, loss, norms = fn(*args)
        self._finish_round(loss, norms, t_start, step=state.step, mix=mix, lr=lr)
        return SimState(p, o, state.step + 1), loss, norms

    def _finish_round(self, loss, norms, t_start, *, step: int, mix: bool,
                      lr: float) -> None:
        """Shared post-step telemetry (the former per-engine
        ``_record_round``): closes the ``round`` span — blocking on the
        loss so the measured duration covers the whole dispatched round,
        with deadline-overrun attribution in the recorder — and emits the
        loss/lr/variance/grad-norm sample at the metrics cadence.  Purely
        observational; the averaging masks stay seeded."""
        tel = self.telemetry
        if t_start is not None:
            jax.block_until_ready(loss)
            tel.round_end(t_start, step=step, mix=mix)
        if tel.due(step):
            tel.step_metrics(
                step, loss=loss, lr=lr,
                norms=norms if self.collect_norms else None,
                grads=self._pending_grads,
            )
            self._pending_grads = None

    # -- elastic growth ----------------------------------------------------------
    def _admit(self, state: SimState, fr, epoch: int) -> SimState:
        """Grow membership to ``len(fr.program_alive)``: re-derive the
        topology family at the new n (``Topology.resized``; the fresh
        controller adopts the old run state) and append one state row per
        joining node seeded with its neighborhood average."""
        m = len(fr.program_alive)
        old_ctl = self.topology.controller
        topo = self.topology.resized(m)
        if topo.controller is not None and old_ctl is not None:
            topo.controller.adopt(old_ctl)
        if topo.controller is not None:
            # the rebuilt controller keeps routing events into the run's
            # recorder (same stream across the membership change)
            topo.controller.bind_recorder(self.telemetry)
        self.topology = topo
        self.n = m
        if self.shard_nodes:
            self._sharding = self._node_sharding(m)
        params, opt = state.params, state.opt_state
        rows = len(fr.program_alive) - len(fr.joins)
        for node in sorted(fr.joins):
            # same-step multi-joins admit in index order; a later joiner is
            # not yet a row, so drop it from an earlier joiner's average
            nbrs = [
                i for i in rejoin_neighbors(
                    topo, fr, node, step=state.step, epoch=epoch,
                    mix_every=self.mix_every,
                )
                if i < rows
            ]
            params = admit_node(params, nbrs)
            opt = admit_node(opt, nbrs)
            rows += 1
        return SimState(self._place(params), self._place(opt), state.step)

    # -- crash-consistent resume -------------------------------------------------
    def snapshot_extra(self) -> dict:
        """Engine run state a crash-consistent checkpoint must carry beyond
        (params, opt_state): the membership tracking (else the first
        post-resume membership change skips its controller re-arm) and the
        controller's phase/rung/log state.  JSON-serializable.

        ``run_config`` records the load-bearing launch configuration
        (topology name, bucket layout) so a mismatched ``--resume`` fails
        fast at restore.  ``n`` stays OUTSIDE run_config: elastic joins
        legitimately grow it mid-run, and restore resizes to match."""
        d: dict = {
            "run_config": {
                "topology": self.topology.name,
                "bucket_mb": (
                    None if self.bucket_mb is None else float(self.bucket_mb)
                ),
            },
            "n": int(self.n),
            "last_membership": (
                None if self._last_membership is None
                else [bool(b) for b in self._last_membership]
            ),
        }
        ctl = self.topology.controller
        if ctl is not None:
            d["controller"] = ctl.state_dict()
        d["telemetry"] = self.telemetry.state_dict()
        return d

    def restore_extra(self, d: dict) -> None:
        """Inverse of ``snapshot_extra`` on a freshly-built engine.

        Validates the checkpoint's recorded ``run_config`` (topology and
        bucket layout; NOT n — elastic resumes resize) fail-fast first."""
        _validate_run_config(
            d.get("run_config") or {}, topology=self.topology.name,
            bucket_mb=self.bucket_mb,
        )
        n = int(d.get("n", self.n))
        if n != self.n:
            # elastic resume: the run had already grown past the initial n
            self.topology = self.topology.resized(n)
            self.n = n
            if self.shard_nodes:
                self._sharding = self._node_sharding(n)
        lm = d.get("last_membership")
        self._last_membership = (
            None if lm is None else tuple(bool(b) for b in lm)
        )
        ctl = self.topology.controller
        if ctl is not None and d.get("controller") is not None:
            ctl.load_state_dict(d["controller"])
        if d.get("telemetry") is not None:
            # resumed counters/span totals continue instead of restarting
            self.telemetry.load_state_dict(d["telemetry"])

    # -- full run helper ---------------------------------------------------------
    def run(
        self,
        params0: PyTree,
        batches: Iterator[PyTree],
        *,
        n_steps: int,
        lr_schedule: Callable[[float], float],
        steps_per_epoch: int = 1,
        record_every: int = 1,
        recorder: Optional[dbench.DBenchRecorder] = None,
        eval_fn: Optional[Callable[[PyTree], float]] = None,
        eval_every: int = 0,
        rng: Optional[jax.Array] = None,
    ) -> tuple[SimState, dict]:
        state = self.init(params0)
        rng = jax.random.PRNGKey(17) if rng is None else rng
        history = {"step": [], "loss": [], "eval_step": [], "eval": []}
        for t in range(n_steps):
            epoch = t // steps_per_epoch
            rng, sub = jax.random.split(rng)
            batch = next(batches)
            state, loss, norms = self.train_step(
                state, batch, lr_schedule(t), epoch=epoch, rng=sub
            )
            if t % record_every == 0:
                history["step"].append(t)
                history["loss"].append(float(jnp.mean(loss)))
                if recorder is not None:
                    recorder.record(t, np.asarray(loss), np.asarray(norms))
            if eval_fn is not None and eval_every and (t + 1) % eval_every == 0:
                history["eval_step"].append(t + 1)
                history["eval"].append(float(eval_fn(state.mean_params())))
        return state, history
