"""Topology registry for (de)centralized SGD (paper §3.1.2).

The five benchmarked SGD implementations, plus Ada:

  c_complete      centralized: all-reduce *gradients* (PyTorch-DDP analogue)
  d_complete      decentralized: average *parameters* over the complete graph
  d_ring          decentralized, ring
  d_torus         decentralized, torus
  d_exponential   decentralized, directed exponential graph
  d_ring_lattice  decentralized, static ring lattice (coordination number k)
  d_ada           decentralized, Ada adaptive ring lattice (Algorithm 1)

A ``Topology`` answers one question per epoch: *which mixing graph is in
force* (``None`` for the centralized implementation, which mixes gradients
globally instead).  The engines (``core/simulator.py`` for vmap-on-CPU,
``launch/train.py`` for shard_map-on-mesh) consume it.

Update order (paper §2.1, Lian et al. 2017 equivalence):
  ``post``: local SGD update, then gossip-average parameters (default)
  ``pre`` : gossip-average parameters, then local SGD update
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.ada import AdaSchedule, default_k0
from repro.core.graphs import CommGraph, make_graph

__all__ = ["Topology", "make_topology", "TOPOLOGIES"]

TOPOLOGIES = (
    "c_complete",
    "d_complete",
    "d_ring",
    "d_torus",
    "d_exponential",
    "d_ring_lattice",
    "d_ada",
)


@dataclasses.dataclass(frozen=True)
class Topology:
    """A (possibly epoch-varying) communication topology."""

    name: str
    n_nodes: int
    centralized: bool = False
    static_graph: Optional[CommGraph] = None
    ada: Optional[AdaSchedule] = None
    mix_order: str = "post"  # "post" | "pre"

    def graph_at(self, epoch: int = 0) -> Optional[CommGraph]:
        """The parameter-mixing graph at an epoch; None => centralized."""
        if self.centralized:
            return None
        if self.ada is not None:
            return self.ada.graph_at(epoch)
        return self.static_graph

    @property
    def adaptive(self) -> bool:
        return self.ada is not None

    def degree_at(self, epoch: int = 0) -> int:
        g = self.graph_at(epoch)
        return self.n_nodes - 1 if g is None else g.degree

    def describe(self) -> str:
        if self.centralized:
            return f"{self.name}: centralized all-reduce over {self.n_nodes} nodes"
        if self.ada is not None:
            return (
                f"{self.name}: Ada ring-lattice k0={self.ada.k0} "
                f"gamma_k={self.ada.gamma_k} over {self.n_nodes} nodes"
            )
        return f"{self.name}: static {self.static_graph.describe()}"


def make_topology(
    name: str,
    n_nodes: int,
    *,
    k: int | None = None,
    k0: int | None = None,
    gamma_k: float = 0.02,
    mix_order: str = "post",
    torus_grid: tuple[int, int] | None = None,
) -> Topology:
    """Build one of the benchmarked topologies.

    Args:
      name: one of ``TOPOLOGIES``.
      n_nodes: gossip node count (the training scale).
      k: coordination number for ``d_ring_lattice``.
      k0, gamma_k: Ada hyperparameters (default k0: paper's max(n//9, 2)).
    """
    if mix_order not in ("post", "pre"):
        raise ValueError(f"mix_order must be 'post'|'pre', got {mix_order!r}")
    base = dict(name=name, n_nodes=n_nodes, mix_order=mix_order)
    if name == "c_complete":
        return Topology(centralized=True, **base)
    if name == "d_complete":
        return Topology(static_graph=make_graph("complete", n_nodes), **base)
    if name == "d_ring":
        return Topology(static_graph=make_graph("ring", n_nodes), **base)
    if name == "d_torus":
        return Topology(
            static_graph=make_graph("torus", n_nodes, grid=torus_grid), **base
        )
    if name == "d_exponential":
        return Topology(static_graph=make_graph("exponential", n_nodes), **base)
    if name == "d_ring_lattice":
        if k is None:
            raise ValueError("d_ring_lattice requires k")
        return Topology(static_graph=make_graph("ring_lattice", n_nodes, k=k), **base)
    if name == "d_ada":
        sched = AdaSchedule(
            n_nodes=n_nodes,
            k0=k0 if k0 is not None else default_k0(n_nodes),
            gamma_k=gamma_k,
        )
        return Topology(ada=sched, **base)
    raise ValueError(f"unknown topology {name!r}; one of {TOPOLOGIES}")
