"""Topology registry for (de)centralized SGD (paper §3.1.2).

The five benchmarked SGD implementations, Ada, and the beyond-paper
time-varying families:

  c_complete        centralized: all-reduce *gradients* (PyTorch-DDP analogue)
  d_complete        decentralized: average *parameters* over the complete graph
  d_ring            decentralized, ring
  d_torus           decentralized, torus
  d_exponential     decentralized, directed exponential graph
  d_ring_lattice    decentralized, static ring lattice (coordination number k)
  d_ada             decentralized, Ada adaptive ring lattice (Algorithm 1);
                    ``k_floor="one_peer"`` decays onto the one-peer family;
                    ``consensus_target=`` closes the loop — measured
                    consensus distance (core/consensus.py) drives the decay
                    and the handoff instead of the epoch law
  d_one_peer_exp    decentralized, one-peer time-varying exponential
                    (degree 1 per step, arXiv:2410.11998)
  d_random_matching decentralized, seeded random pairwise averaging rotating
                    through a precompiled pool of matchings
  d_star            decentralized, star graph (MH weights)
  d_custom          decentralized, arbitrary undirected graph
                    (``adjacency=`` matrix or edge list)

A ``Topology`` answers one question per (epoch, step): *which compiled
mixing program is in force* (``program_at``; ``None`` for the centralized
implementation, which mixes gradients globally instead).  Time-varying
topologies rotate through a small program set that ``distinct_programs``
enumerates up front; the engines cache one executable per program (compiled
at its first use), so graph adaptation never recompiles.  The engines
(``core/simulator.py`` for vmap-on-CPU, ``launch/train.py`` for
shard_map-on-mesh) both interpret the same ``GossipProgram`` IR.

Update order (paper §2.1, Lian et al. 2017 equivalence):
  ``post``: local SGD update, then gossip-average parameters (default)
  ``pre`` : gossip-average parameters, then local SGD update
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.core.ada import AdaSchedule, default_k0
from repro.core.consensus import ConsensusController
from repro.core.faults import FaultModel
from repro.core.graphs import (
    CommGraph, make_graph, one_peer_exponential, one_peer_period,
    random_matching,
)
from repro.core.schedule import (
    GossipProgram, compile_graph, maybe_hub_balanced,
)

__all__ = [
    "Topology",
    "GraphSequence",
    "OnePeerSequence",
    "MatchingSequence",
    "make_topology",
    "TOPOLOGIES",
]

TOPOLOGIES = (
    "c_complete",
    "d_complete",
    "d_ring",
    "d_torus",
    "d_exponential",
    "d_ring_lattice",
    "d_ada",
    "d_one_peer_exp",
    "d_random_matching",
    "d_star",
    "d_custom",
)


# ---------------------------------------------------------------------------
# Step-varying graph sequences
# ---------------------------------------------------------------------------

class GraphSequence:
    """A periodic step-indexed family of graphs (time-varying topology)."""

    n: int

    def period_steps(self) -> int:  # pragma: no cover - interface
        raise NotImplementedError

    def graph_at(self, step: int) -> CommGraph:  # pragma: no cover
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class OnePeerSequence(GraphSequence):
    """One-peer exponential: hop 2^(t mod p), degree 1 per step."""

    n: int

    def period_steps(self) -> int:
        return one_peer_period(self.n)

    def graph_at(self, step: int) -> CommGraph:
        return one_peer_exponential(self.n, step)


@dataclasses.dataclass(frozen=True)
class MatchingSequence(GraphSequence):
    """Random pairwise averaging rotating through ``pool`` seeded matchings.

    The pool bounds the number of compiled executables (randomized-but-
    precompilable): step t uses matching ``(seed, t mod pool)``.
    """

    n: int
    seed: int = 0
    pool: int = 8

    def period_steps(self) -> int:
        return max(int(self.pool), 1)

    def graph_at(self, step: int) -> CommGraph:
        return random_matching(self.n, self.seed, step % self.period_steps())


# ---------------------------------------------------------------------------
# Topology
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Topology:
    """A (possibly epoch- and step-varying) communication topology."""

    name: str
    n_nodes: int
    centralized: bool = False
    static_graph: Optional[CommGraph] = None
    ada: Optional[AdaSchedule] = None
    sequence: Optional[GraphSequence] = None
    controller: Optional[ConsensusController] = None
    fault_model: Optional[FaultModel] = None
    mix_order: str = "post"  # "post" | "pre"
    # (name, kwargs) recipe recorded by make_topology so the SAME family can
    # be re-derived at a different n (elastic joins); excluded from equality
    # because it duplicates the constructed fields.
    spec: Any = dataclasses.field(default=None, compare=False)

    def graph_at(self, epoch: int = 0, step: int = 0) -> Optional[CommGraph]:
        """The parameter-mixing graph in force; None => centralized.

        With a ``controller`` (closed-loop Ada) the graph follows the
        controller's *current rung* — the measured consensus-distance
        signal, fed by the engines via ``controller.observe``, selects it
        instead of the open-loop epoch law.
        """
        if self.centralized:
            return None
        if self.controller is not None:
            return self.controller.graph_at(epoch, step)
        if self.sequence is not None:
            return self.sequence.graph_at(step)
        if self.ada is not None:
            return self.ada.graph_at(epoch, step)
        return self.static_graph

    def program_at(self, *, step: int = 0, epoch: int = 0) -> Optional[GossipProgram]:
        """The compiled mixing program in force; None => centralized.

        Keyword-only: ``graph_at`` takes (epoch, step) in the opposite
        order, so positional use would silently pick the wrong program.
        """
        g = self.graph_at(epoch, step)
        return None if g is None else compile_graph(g)

    def fused_program_at(
        self, *, step: int = 0, epoch: int = 0, rounds: int = 1,
        hub_balance: bool = False,
    ) -> Optional[GossipProgram]:
        """The program for gossip round ``step`` when every round applies
        ``rounds`` consecutive schedule steps fused into ONE executable
        (``GossipProgram.fuse``) — H dispatches collapse to one, and a
        time-varying family advances its phase by ``rounds`` per round.

        ``hub_balance``: when the fused rounds are one *static* multi-round
        permute program repeated (the star, lattices), reschedule its
        matchings round-robin across the H steps (``hub_balanced_rounds``)
        so a hot vertex no longer sends in every round of every step —
        time-varying families keep their own rotation.
        """
        if rounds <= 1:
            return self.program_at(step=step, epoch=epoch)
        progs = [
            self.program_at(step=step * rounds + r, epoch=epoch)
            for r in range(rounds)
        ]
        if any(p is None for p in progs):
            return None
        if hub_balance:
            balanced = maybe_hub_balanced(progs, rounds)
            if balanced is not None:
                return balanced
        return GossipProgram.fuse(progs)

    def period_at(self, epoch: int = 0) -> int:
        """Steps before the program repeats within an epoch (1 = static)."""
        if self.controller is not None:
            return self.controller.period_steps()
        if self.sequence is not None:
            return self.sequence.period_steps()
        if self.ada is not None:
            return self.ada.period_at(epoch)
        return 1

    def distinct_programs(
        self, n_epochs: int = 1
    ) -> list[tuple[tuple[int, int], GossipProgram]]:
        """((first_epoch, step_phase), program) for every distinct compiled
        program over a run — the bounded executable set an engine caches.

        Generalizes ``AdaSchedule.distinct_graphs`` to step-granular and
        randomized-with-pool topologies.  For a closed-loop controller the
        first key component is the *rung* index instead of an epoch: the
        measured signal decides when each rung activates, but the set it
        can select from is the controller's ladder, pinned rung by rung
        here — closed-loop adaptation compiles nothing beyond this set.

        With a permanent-crash ``fault_model`` the set additionally folds
        in each base program's degraded variant per membership mask the
        model can realize (``FaultModel.program_masks`` — the single-node-
        out set): a crash then *selects* among pre-enumerated programs
        exactly like a schedule transition, and zero mid-run recompiles
        still holds under faults.
        """
        if self.centralized:
            return []
        out: list[tuple[tuple[int, int], GossipProgram]] = []
        seen = set()
        if self.controller is not None:
            for r in range(len(self.controller.ladder)):
                with self.controller.pinned(r):
                    for s in range(self.period_at(0)):
                        prog = self.program_at(step=s, epoch=0)
                        if prog is not None and prog.cache_key not in seen:
                            seen.add(prog.cache_key)
                            out.append(((r, s), prog))
        else:
            for e in range(max(int(n_epochs), 1)):
                for s in range(self.period_at(e)):
                    prog = self.program_at(step=s, epoch=e)
                    if prog is not None and prog.cache_key not in seen:
                        seen.add(prog.cache_key)
                        out.append(((e, s), prog))
        if self.fault_model is not None:
            from repro.core.faults import fold_degraded_programs

            key_of = {p.cache_key: k for k, p in out}
            for base_p, deg in fold_degraded_programs(
                [p for _, p in out], self.fault_model
            ):
                out.append((key_of[base_p.cache_key], deg))
            if self.fault_model.elastic:
                # pre-declared growth schedule: fold in the family at every
                # size the joins can reach, so a mid-run join *selects* a
                # pre-enumerated program instead of recompiling.  The
                # resized topology drops the fault model (its masks are
                # sized for the initial n; elastic models realize all-ones
                # membership at grown sizes anyway) to avoid re-entering
                # this fold per size.
                for m in self.fault_model.membership_sizes():
                    if m == self.n_nodes:
                        continue
                    grown = dataclasses.replace(
                        self.resized(m), fault_model=None
                    )
                    for gk, p in grown.distinct_programs(n_epochs):
                        if p.cache_key not in seen:
                            seen.add(p.cache_key)
                            out.append((gk, p))
        return out

    def resized(self, n_new: int) -> "Topology":
        """Re-derive this topology family at a different node count.

        Elastic joins grow membership past the initial n; the graph family
        (ring, one-peer exponential, Ada ladder, ...) is parameterized by n
        throughout, so a membership change re-derives the SAME family at
        the new size from the ``spec`` recipe ``make_topology`` recorded —
        it does not mutate graphs in place.  The fault model is carried
        over (elastic models are size-aware); the controller is rebuilt for
        the new n and should ``adopt`` the old one's run state.
        """
        if self.spec is None:
            raise ValueError(
                "topology has no spec recipe (hand-constructed?); build via "
                "make_topology to support elastic resizing"
            )
        name, kwargs = self.spec
        if name == "d_custom":
            raise ValueError(
                "d_custom has no size-parameterized family to re-derive; "
                "elastic membership needs a named topology"
            )
        return make_topology(
            name, int(n_new), fault_model=self.fault_model, **kwargs
        )

    @property
    def adaptive(self) -> bool:
        return self.ada is not None

    @property
    def closed_loop(self) -> bool:
        """Is the schedule driven by measured consensus distance?"""
        return self.controller is not None

    @property
    def time_varying(self) -> bool:
        """Does the graph (possibly) change within an epoch?  True for any
        closed-loop controller: rung transitions fire at measured steps,
        not epoch boundaries, regardless of the ladder's floor."""
        if self.controller is not None:
            return True
        if self.sequence is not None:
            return self.sequence.period_steps() > 1
        return self.ada is not None and self.ada.k_floor == "one_peer"

    def degree_at(self, epoch: int = 0, step: int = 0) -> int:
        g = self.graph_at(epoch, step)
        return self.n_nodes - 1 if g is None else g.degree

    def describe(self) -> str:
        suffix = (
            f" [faults: {self.fault_model.describe()}]"
            if self.fault_model is not None
            else ""
        )
        return self._describe_base() + suffix

    def _describe_base(self) -> str:
        if self.centralized:
            return f"{self.name}: centralized all-reduce over {self.n_nodes} nodes"
        if self.controller is not None:
            return (
                f"{self.name}: closed-loop Ada ({self.controller.describe()}) "
                f"over {self.n_nodes} nodes"
            )
        if self.ada is not None:
            return (
                f"{self.name}: Ada ring-lattice k0={self.ada.k0} "
                f"gamma_k={self.ada.gamma_k} k_floor={self.ada.k_floor} "
                f"over {self.n_nodes} nodes"
            )
        if self.sequence is not None:
            return (
                f"{self.name}: time-varying "
                f"{type(self.sequence).__name__} (period "
                f"{self.sequence.period_steps()}) over {self.n_nodes} nodes"
            )
        return f"{self.name}: static {self.static_graph.describe()}"


def make_topology(
    name: str,
    n_nodes: int,
    *,
    k: int | None = None,
    k0: int | None = None,
    gamma_k: float | None = None,
    k_floor: int | str = 2,
    seed: int = 0,
    pool: int = 8,
    mix_order: str = "post",
    torus_grid: tuple[int, int] | None = None,
    adjacency: Any = None,
    consensus_target: float | None = None,
    consensus_probe_every: int = 1,
    consensus_spike: float | None = None,
    fault_model: FaultModel | None = None,
) -> Topology:
    """Build one of the benchmarked topologies.

    Args:
      name: one of ``TOPOLOGIES``.
      n_nodes: gossip node count (the training scale).
      k: coordination number for ``d_ring_lattice``.
      k0, gamma_k, k_floor: Ada hyperparameters (default k0: paper's
        max(n//9, 2), default gamma_k: the paper's 0.02; k_floor="one_peer"
        decays onto the one-peer family).  gamma_k is the open-loop time
        law and is rejected together with consensus_target.
      seed, pool: ``d_random_matching`` randomness and precompiled-pool size.
      consensus_target: ``d_ada`` only — close the loop: drive the k-decay
        and one-peer handoff from the measured consensus-distance ratio
        Ξ_t/Ξ_0 crossing this target (arXiv:2102.04828) instead of the
        open-loop epoch law.  ``consensus_probe_every`` sets the probe
        cadence in training steps.  ``consensus_spike`` (a ratio > 1) makes
        the ladder non-monotone: a Ξ_t spike at or past ``spike`` × the
        phase peak (crash, deadline storm, join) re-densifies one rung.
      fault_model: seeded fault injection (``core/faults.make_fault_model``)
        both engines consume identically; decentralized only — the
        centralized all-reduce has no per-node degradation semantics.
    """
    if mix_order not in ("post", "pre"):
        raise ValueError(f"mix_order must be 'post'|'pre', got {mix_order!r}")
    if consensus_target is not None and name != "d_ada":
        raise ValueError(
            f"consensus_target is a d_ada (closed-loop Ada) option; got {name!r}"
        )
    if consensus_spike is not None and consensus_target is None:
        raise ValueError(
            "consensus_spike re-densifies the closed loop and requires "
            "consensus_target"
        )
    if fault_model is not None:
        if name == "c_complete":
            raise ValueError("fault injection is decentralized-only")
        if fault_model.n != n_nodes and not fault_model.elastic:
            # elastic models are size-aware: a resized() topology at a
            # grown membership keeps the original model (n = initial size)
            raise ValueError(
                f"fault model covers {fault_model.n} nodes but n_nodes={n_nodes}"
            )
    base = dict(
        name=name, n_nodes=n_nodes, mix_order=mix_order, fault_model=fault_model,
        # the resize recipe: everything size-independent; torus_grid and
        # adjacency are size-specific and are re-derived (or rejected) at
        # the new n instead
        spec=(name, dict(
            k=k, k0=k0, gamma_k=gamma_k, k_floor=k_floor, seed=seed,
            pool=pool, mix_order=mix_order, consensus_target=consensus_target,
            consensus_probe_every=consensus_probe_every,
            consensus_spike=consensus_spike,
        )),
    )
    if name == "c_complete":
        return Topology(centralized=True, **base)
    if name == "d_complete":
        return Topology(static_graph=make_graph("complete", n_nodes), **base)
    if name == "d_ring":
        return Topology(static_graph=make_graph("ring", n_nodes), **base)
    if name == "d_torus":
        return Topology(
            static_graph=make_graph("torus", n_nodes, grid=torus_grid), **base
        )
    if name == "d_exponential":
        return Topology(static_graph=make_graph("exponential", n_nodes), **base)
    if name == "d_ring_lattice":
        if k is None:
            raise ValueError("d_ring_lattice requires k")
        return Topology(static_graph=make_graph("ring_lattice", n_nodes, k=k), **base)
    if name == "d_ada":
        if consensus_target is not None and gamma_k is not None:
            # the controller never consults the time law: a gamma_k sweep
            # with the closed loop on would silently report duplicates
            raise ValueError(
                "gamma_k is the open-loop time law and is unused with "
                "consensus_target; pass one or the other"
            )
        sched = AdaSchedule(
            n_nodes=n_nodes,
            k0=k0 if k0 is not None else default_k0(n_nodes),
            gamma_k=0.02 if gamma_k is None else gamma_k,
            k_floor=k_floor,
        )
        ctl = (
            ConsensusController(
                schedule=sched,
                target=consensus_target,
                probe_every=consensus_probe_every,
                spike=consensus_spike,
            )
            if consensus_target is not None
            else None
        )
        return Topology(ada=sched, controller=ctl, **base)
    if name == "d_one_peer_exp":
        return Topology(sequence=OnePeerSequence(n_nodes), **base)
    if name == "d_random_matching":
        return Topology(
            sequence=MatchingSequence(n_nodes, seed=seed, pool=pool), **base
        )
    if name == "d_star":
        return Topology(static_graph=make_graph("star", n_nodes), **base)
    if name == "d_custom":
        if adjacency is None:
            raise ValueError("d_custom requires adjacency")
        g = make_graph("from_adjacency", n_nodes, adjacency=adjacency)
        if g.n != n_nodes:
            # edge lists infer n from the max index; a mismatch would make
            # the mixing program and the replica axis silently disagree
            raise ValueError(
                f"adjacency describes {g.n} nodes but n_nodes={n_nodes}; "
                "pass an (n, n) matrix to include trailing isolated nodes"
            )
        return Topology(static_graph=g, **base)
    raise ValueError(f"unknown topology {name!r}; one of {TOPOLOGIES}")
