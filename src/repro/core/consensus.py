"""Consensus distance + the closed-loop Ada controller (arXiv:2102.04828).

Consensus Control for Decentralized Deep Learning shows the right control
signal for adapting decentralized training is the *consensus distance*

    Ξ_t = sqrt( 1/n · Σ_i ‖x_i - x̄‖² ),    x̄ = 1/n Σ_i x_i,

the RMS disagreement between replicas and their average.  This module
computes it on-device and uses it to close Ada's scheduling loop.  Each
probe reduces the whole parameter tree to one scalar per node (mirroring
``dbench.param_l2_norms``), but computing x̄ itself costs one pmean of the
parameter tree — O(P) on the wire per probe, about one one-peer gossip
step — so probes are *not* free: ``probe_every`` sets the cadence, and the
comm accounting in ``benchmarks/ada.py`` bills them.

On-device realizations (both engines):

  * ``consensus_sq_stacked`` / ``consensus_distance_stacked`` — for trees
    whose leaves carry a leading (n, ...) node axis (the simulator state and
    the SPMD trainer's gossip-stacked global state).  One mean over the node
    axis per leaf, then a per-node squared-distance reduction.
  * ``consensus_sq_shard`` / ``consensus_distance_shard`` — for per-node
    values inside ``shard_map``: ``pmean`` produces x̄, a local reduction
    produces ‖x_i - x̄‖², and a second ``pmean`` averages it over nodes.

``ConsensusController`` replaces Ada's open-loop time law
``k(epoch) = k0 - int(γ·epoch)`` (and the hard-coded k<2 one-peer handoff)
with a measured trigger: every time the probed ratio ``Ξ_t / Ξ_0`` falls to
the ``target``, the schedule steps down one rung of the pre-enumerated
ladder ``k0, k0-1, …, 2[, one_peer]``.  The paper's Observation 5 (high
connectivity helps early, sparse graphs are free later) becomes a
measurement: the graph sparsifies exactly when the replicas agree tightly
enough to afford it.

The bounded-executable-set invariant is preserved by construction: the
controller only ever *selects among* the ladder's rungs, and every rung's
mixing programs are enumerable up front (``Topology.distinct_programs``
pins each rung in turn), so closed-loop graph adaptation still costs zero
mid-run recompiles.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ada import AdaSchedule
from repro.core.graphs import (
    CommGraph, RingLattice, one_peer_exponential, one_peer_period,
)

PyTree = Any

__all__ = [
    "consensus_sq_stacked",
    "consensus_distance_stacked",
    "consensus_distance_jit",
    "consensus_distance_masked",
    "consensus_distance_masked_jit",
    "consensus_sq_shard",
    "consensus_distance_shard",
    "ConsensusController",
]


# ---------------------------------------------------------------------------
# On-device consensus distance (jit-able)
# ---------------------------------------------------------------------------

def consensus_sq_stacked(stacked: PyTree) -> jax.Array:
    """Per-node squared consensus distance ‖x_i - x̄‖² — returns (n,) float32.

    ``stacked``: a pytree whose leaves carry a leading node axis (n, ...) —
    the simulator state and the SPMD trainer's gossip-stacked global state.
    Accumulates in float32 across every leaf (the full parameter vector).
    """
    leaves = jax.tree.leaves(stacked)
    if not leaves:
        raise ValueError("consensus distance of an empty pytree")
    total = None
    for x in leaves:
        xf = x.astype(jnp.float32)
        d = xf - xf.mean(axis=0, keepdims=True)
        sq = jnp.sum(jnp.square(d), axis=tuple(range(1, d.ndim)))
        total = sq if total is None else total + sq
    return total


def consensus_distance_stacked(stacked: PyTree) -> jax.Array:
    """Ξ = sqrt(1/n Σ_i ‖x_i - x̄‖²) over the leading node axis (scalar)."""
    return jnp.sqrt(jnp.mean(consensus_sq_stacked(stacked)))


# The probe both engines call every `probe_every` steps: one shared jitted
# entry point (jax caches traces per shape), so neither engine carries its
# own lazy-init state.
consensus_distance_jit = jax.jit(consensus_distance_stacked)


def consensus_distance_masked(stacked: PyTree, alive) -> jax.Array:
    """Ξ over the *alive* nodes only: sqrt(1/|A| Σ_{i∈A} ‖x_i - x̄_A‖²).

    Under faults a dead node's frozen replica is not part of the training
    population; including it would hold Ξ artificially high and freeze the
    controller's ladder.  ``alive`` is a runtime (n,) mask, so one
    executable serves every realization (shape-keyed jit like the unmasked
    probe).  With every node alive this equals ``consensus_distance_stacked``.
    """
    leaves = jax.tree.leaves(stacked)
    if not leaves:
        raise ValueError("consensus distance of an empty pytree")
    af = jnp.asarray(alive, jnp.float32)
    count = jnp.maximum(jnp.sum(af), 1.0)
    total = None
    for x in leaves:
        xf = x.astype(jnp.float32)
        acol = af.reshape((af.shape[0],) + (1,) * (xf.ndim - 1))
        mean = jnp.sum(xf * acol, axis=0, keepdims=True) / count
        d = (xf - mean) * acol
        sq = jnp.sum(jnp.square(d), axis=tuple(range(1, d.ndim)))
        total = sq if total is None else total + sq
    return jnp.sqrt(jnp.sum(total) / count)


consensus_distance_masked_jit = jax.jit(consensus_distance_masked)


def consensus_sq_shard(local: PyTree, axis_names) -> jax.Array:
    """This node's ‖x_i - x̄‖² inside ``shard_map`` (one pmean; scalar)."""
    leaves = jax.tree.leaves(local)
    if not leaves:
        raise ValueError("consensus distance of an empty pytree")
    total = jnp.zeros((), jnp.float32)
    for x in leaves:
        xf = x.astype(jnp.float32)
        mean = jax.lax.pmean(xf, axis_names)
        total = total + jnp.sum(jnp.square(xf - mean))
    return total


def consensus_distance_shard(local: PyTree, axis_names) -> jax.Array:
    """Ξ inside ``shard_map``: the same scalar on every node (two pmeans)."""
    return jnp.sqrt(
        jax.lax.pmean(consensus_sq_shard(local, axis_names), axis_names)
    )


# ---------------------------------------------------------------------------
# The closed-loop controller
# ---------------------------------------------------------------------------

Rung = Union[int, str]  # a coordination number, or the terminal "one_peer"


@dataclasses.dataclass(eq=False)
class ConsensusController:
    """Consensus-distance-triggered Ada scheduling (closed loop).

    Wraps an ``AdaSchedule`` and replaces its time law with a measured
    trigger.  The controller walks a fixed ladder of rungs

        k0, k0-1, …, floor[, "one_peer"]

    (``floor`` = the schedule's integer ``k_floor``, 2 in the paper;
    ``"one_peer"`` appended when ``k_floor == "one_peer"``; graph-identical
    k's — RingLattice uses k//2 hops, so odd k equals k-1 — collapse to one
    rung so every transition actually sparsifies).  Each probe calls
    ``observe(Ξ_t, step)``:

      * Ξ_0 is the *phase reference*: the peak consensus distance observed
        on the current rung (replicas start identical, so zero probes are
        skipped; early probes rise while momentum spins up and the peak
        tracks them — 2102.04828 likewise re-anchors its reference per
        phase);
      * whenever Ξ_t ≤ target · Ξ_0 the schedule steps down exactly one
        rung and the reference re-arms on the sparser graph (sparsifying
        raises Ξ back up — the loop self-regulates), and the one-peer
        handoff happens when — and only when — the measured ratio crosses
        the target on the last lattice rung, not at the open-loop ``k < 2``
        constant.

    By default the rung walk is monotone (never re-densifies).  Passing
    ``spike`` (a ratio > 1) makes the ladder NON-monotone: a measured Ξ_t
    at or above ``spike`` × the phase's running peak — a crash, a deadline
    storm, a join landing — walks the ladder back UP one rung to a denser
    graph (logged as a ``"redensify"`` event and a transition), because a
    disagreement spike is exactly when the run needs MORE connectivity,
    not the sparser graph the stale monotone walk would keep.  The spike
    reference survives ``rearm`` (a membership event clears the trigger
    reference Ξ_0 *before* the spiked probe arrives — the spike must still
    compare against the pre-fault level); after a re-densify the phase
    re-seeds at the spiked level, so a single event moves at most one rung
    and the loop cannot thrash.  Either way the walk is bounded by the
    ladder, so the executable set an engine needs is exactly the ladder's
    programs — ``Topology.distinct_programs`` enumerates them by pinning
    each rung in turn (``pinned``), and engines cache one executable per
    program as for open-loop Ada: re-densification only ever *re-selects*
    an already-enumerated denser rung.

    Mutable by design (training-run state); ``reset()`` re-arms it for a
    fresh run, ``rung_at(step)`` replays the realized schedule afterwards
    (the comm-volume accounting in ``benchmarks/ada.py`` uses this).
    """

    schedule: AdaSchedule
    target: float = 0.5      # trigger ratio Ξ_t / Ξ_0 (2102.04828's fraction)
    probe_every: int = 1     # probe cadence in raw training steps
    spike: Optional[float] = None  # Ξ_t / peak ratio that re-densifies (>1)

    # -- run state (mutated by observe) -------------------------------------
    xi0: Optional[float] = None
    rung: int = 0
    transitions: list = dataclasses.field(default_factory=list)  # [(step, rung)]
    trace: list = dataclasses.field(default_factory=list)  # [(step, xi, rung)]
    events: list = dataclasses.field(default_factory=list)  # [(step, reason)]

    def __post_init__(self):
        if not (0.0 < self.target < 1.0):
            raise ValueError(f"target must be in (0, 1), got {self.target}")
        if self.spike is not None and not float(self.spike) > 1.0:
            raise ValueError(
                f"spike is a re-densify ratio and must be > 1, got {self.spike}"
            )
        self.probe_every = max(int(self.probe_every), 1)
        # the re-densify reference: the current phase's peak Ξ, persisted
        # through rearm() (unlike xi0) so a membership event cannot hide
        # the very spike it causes from the spike trigger
        self._spike_ref: Optional[float] = None
        # run-telemetry recorder (engines bind theirs): transitions and
        # rearm/redensify reasons route through it so both engines share
        # one event stream with identical coalescing semantics
        self._recorder = None
        n = self.schedule.n_nodes
        floor = (
            2
            if self.schedule.k_floor == "one_peer"
            else max(int(self.schedule.k_floor), 2)
        )
        start = int(np.clip(self.schedule.k0, floor, max(n - 1, floor)))
        # Dedup graph-identical rungs: RingLattice uses k//2 hops per side,
        # so k and k-1 compile to the SAME graph for odd k.  Keeping both
        # would waste a full trigger crossing (and a duplicate executable)
        # on a transition that changes nothing — keep one rung per distinct
        # graph, labeled by the sparser k (honoring the floor).
        ladder: list[Rung] = []
        prev_sig = None
        for k in range(start, floor - 1, -1):
            g = RingLattice(n, k)
            sig = (g.offsets, g.mult)
            if ladder and sig == prev_sig:
                ladder[-1] = k
            else:
                ladder.append(k)
            prev_sig = sig
        if self.schedule.k_floor == "one_peer":
            ladder.append("one_peer")
        self._ladder: tuple[Rung, ...] = tuple(ladder)

    # -- the ladder ----------------------------------------------------------
    @property
    def ladder(self) -> tuple[Rung, ...]:
        """The pre-enumerated rungs the controller may select among."""
        return self._ladder

    @property
    def current(self) -> Rung:
        return self._ladder[self.rung]

    @property
    def one_peer_active(self) -> bool:
        return self.current == "one_peer"

    @property
    def handoff_step(self) -> Optional[int]:
        """Step at which the one-peer handoff fired (None before it does)."""
        for step, rung in self.transitions:
            if self._ladder[rung] == "one_peer":
                return step
        return None

    # -- probing -------------------------------------------------------------
    def should_probe(self, step: int) -> bool:
        return step % self.probe_every == 0

    def observe(self, xi: float, step: int) -> bool:
        """Feed one measured Ξ_t; returns True iff the schedule stepped down.

        Ξ_0 is the running peak of the current phase: the first
        strictly-positive finite observation (after init or after a
        transition) seeds it, later larger observations raise it.  A
        transition fires iff ``xi <= target * Ξ_0`` with a sparser rung
        available; firing re-arms the reference for the new phase.  At most
        one rung step per observation.

        With ``spike`` set the walk is non-monotone: before anything else,
        ``xi >= spike * peak`` (the phase peak persisted through ``rearm``)
        with a denser rung available walks the ladder UP one rung, logs a
        ``"redensify"`` event, and re-seeds the phase at the spiked level
        — so the same event cannot fire twice, and once Ξ recovers below
        ``target`` × the spiked reference the normal trigger re-sparsifies
        (the loop heals the spike, then resumes the walk).
        """
        xi = float(xi)
        if (
            self.spike is not None
            and self.rung > 0
            and math.isfinite(xi)
            and self._spike_ref is not None
            and xi >= float(self.spike) * self._spike_ref
        ):
            self.rung -= 1
            self.transitions.append((int(step), self.rung))
            self._emit_transition(step)
            self._log_event(step, "redensify")
            # re-seed the phase on the denser rung at the spiked level:
            # both references restart, so this spike is consumed
            self.xi0 = None
            self._spike_ref = None
            self.trace.append((int(step), xi, self.rung))
            return False
        if xi > 0.0 and math.isfinite(xi):
            self._spike_ref = (
                xi if self._spike_ref is None else max(self._spike_ref, xi)
            )
        if self.xi0 is None:
            if xi > 0.0 and math.isfinite(xi):
                self.xi0 = xi
            self.trace.append((int(step), xi, self.rung))
            return False
        if math.isfinite(xi):
            self.xi0 = max(self.xi0, xi)
        fired = (
            math.isfinite(xi)
            and xi <= self.target * self.xi0
            and self.rung < len(self._ladder) - 1
        )
        if fired:
            self.rung += 1
            self.transitions.append((int(step), self.rung))
            self._emit_transition(step)
            self.xi0 = None  # re-arm the phase reference on the new rung
            self._spike_ref = None  # sparser graphs run hotter: new baseline
        self.trace.append((int(step), xi, self.rung))
        return fired

    def rearm(self, step: int, reason: str = "fault") -> None:
        """Re-arm the per-phase peak Ξ_0 on a membership event.

        A crash or rejoin spikes the measured consensus distance (a dead
        node's replica freezes; a rejoining node re-enters off-average).
        Without re-arming, the stale pre-fault Ξ_0 makes the post-fault
        ratio Ξ_t/Ξ_0 look tighter than it is and ratchets the ladder down
        exactly when the run needs MORE connectivity.  Re-arming keeps the
        rung and restarts the phase reference: the next probes re-seed and
        peak-track Ξ_0 on the degraded membership, so the trigger compares
        like with like.  Recorded in ``events`` for replay/diagnostics.

        Simultaneous membership events in ONE step — a k-node concurrent
        crash, a departure landing on a join — coalesce into a single
        re-arm and a single log entry: re-arming is idempotent within a
        step (Ξ_0 is already cleared), and k duplicate entries would make
        the event log overstate distinct membership phases k-fold.
        Distinct same-step reasons merge into one ``"a+b"`` entry.

        The spike reference deliberately SURVIVES re-arming: the membership
        event fires before the spiked probe it causes, so clearing it here
        would blind the ``spike`` re-densify trigger to exactly the spikes
        it exists for.
        """
        self.xi0 = None
        self._log_event(step, reason)

    def bind_recorder(self, recorder) -> None:
        """Attach the run's :class:`repro.telemetry.MetricsRecorder`: every
        transition/rearm/redensify log entry is mirrored as a telemetry
        event.  Both engines bind at construction (and the simulator
        re-binds after an elastic ``_admit`` rebuilds the controller), so
        the event stream — coalescing included — is engine-independent."""
        self._recorder = recorder

    def _emit_transition(self, step: int) -> None:
        if self._recorder is not None:
            self._recorder.event(
                "transition", int(step),
                data={"rung": int(self.rung), "k": str(self.current)},
            )

    def _log_event(self, step: int, reason: str) -> None:
        """Append to ``events``, coalescing same-step reasons into "a+b".

        The merge itself is the shared implementation in
        ``repro.telemetry.coalesce_into``; when the coalesced entry
        changes, the merged reason is re-emitted as a ``controller``
        telemetry event (consumers keep the last emission per step)."""
        from repro.telemetry import coalesce_into

        merged = coalesce_into(self.events, int(step), str(reason))
        if merged is not None and self._recorder is not None:
            self._recorder.event(
                "controller", int(step), data={"reason": merged}
            )

    # -- resume / adoption ----------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-serializable run state (for crash-consistent resume)."""
        return {
            "xi0": self.xi0,
            "spike_ref": self._spike_ref,
            "rung": int(self.rung),
            "transitions": [[int(s), int(r)] for s, r in self.transitions],
            "trace": [[int(s), float(x), int(r)] for s, x, r in self.trace],
            "events": [[int(s), str(r)] for s, r in self.events],
        }

    def load_state_dict(self, d: dict) -> None:
        """Restore ``state_dict`` output — resumed runs continue the same
        phase reference, rung walk, and logs as the uninterrupted run."""
        self.xi0 = None if d.get("xi0") is None else float(d["xi0"])
        self._spike_ref = (
            None if d.get("spike_ref") is None else float(d["spike_ref"])
        )
        self.rung = min(int(d["rung"]), len(self._ladder) - 1)
        self.transitions[:] = [(int(s), int(r)) for s, r in d["transitions"]]
        self.trace[:] = [
            (int(s), float(x), int(r)) for s, x, r in d["trace"]
        ]
        self.events[:] = [(int(s), str(r)) for s, r in d["events"]]

    def adopt(self, other: "ConsensusController") -> None:
        """Continue another controller's run state on THIS ladder.

        Used at an elastic join: the topology re-derives its graph family
        at the new n, which rebuilds the controller with a new ladder; the
        fresh instance adopts the old run state (rung clamped to the new
        ladder, history carried over) so the schedule position and logs
        survive the membership change.  The caller's next
        ``track_membership`` re-arms the phase reference for the grown
        population.
        """
        self.load_state_dict(other.state_dict())

    def reset(self) -> None:
        """Re-arm for a fresh run (clears Ξ_0, rung, and the trace)."""
        self.xi0 = None
        self._spike_ref = None
        self.rung = 0
        self.transitions.clear()
        self.trace.clear()
        self.events.clear()

    # -- schedule interface (what Topology delegates to) ----------------------
    def graph_at(self, epoch: int = 0, step: int = 0) -> CommGraph:
        """The graph the *current* rung selects (epoch is ignored: the
        measured signal, not wall-clock epochs, drives the schedule)."""
        cur = self.current
        if cur == "one_peer":
            return one_peer_exponential(self.schedule.n_nodes, step)
        return RingLattice(self.schedule.n_nodes, int(cur))

    def period_steps(self) -> int:
        """Steps before the current rung's graph repeats (1 = static)."""
        if self.one_peer_active:
            return one_peer_period(self.schedule.n_nodes)
        return 1

    @contextlib.contextmanager
    def pinned(self, rung: int):
        """Temporarily force a rung — used to enumerate the bounded program
        set (``Topology.distinct_programs``) and to replay a recorded run
        for comm accounting, without disturbing the live run state."""
        if not 0 <= rung < len(self._ladder):
            raise ValueError(f"rung {rung} outside ladder of {len(self._ladder)}")
        old = self.rung
        self.rung = rung
        try:
            yield self
        finally:
            self.rung = old

    def rung_at(self, step: int) -> int:
        """The rung in force at ``step``, replayed from the transition log
        (a transition observed at step s governs step s onward)."""
        rung = 0
        for s, r in self.transitions:
            if s <= step:
                rung = r
            else:
                break
        return rung

    def describe(self) -> str:
        ks = ",".join(str(r) for r in self._ladder)
        sp = "" if self.spike is None else f", spike={self.spike}"
        return (
            f"ConsensusController(target={self.target}, "
            f"probe_every={self.probe_every}{sp}, ladder=[{ks}])"
        )
