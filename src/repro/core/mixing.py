"""Mixing (gossip averaging) — thin façade over the program IR.

One mixing step  θ ← W θ  is executed by compiling the graph into a
``GossipProgram`` (``core/schedule.py``) and running one of its three
interpreters.  This module keeps the historical function-level API as
wrappers over that single code path:

  * ``mix_dense``    — dense mixing-matrix einsum over a stacked replica
                       axis.  Bit-faithful to the paper's equations; the
                       correctness oracle (costs an all-gather at scale).
  * ``mix_shift``    — the program's *stacked* interpreter: Σ_d w_d ·
                       roll/gather over the stacked axis.  Under jit on a
                       sharded axis XLA lowers each roll to
                       collective-permutes.
  * ``mix_ppermute`` — the program's *shard* interpreter inside
                       ``shard_map``: one ``jax.lax.ppermute`` per PPermute
                       op, all-reduce fast path for the complete graph.
                       The production (beyond-paper-optimized) path.

All three are tested for equivalence on every registered topology
(tests/test_mixing.py, tests/test_schedule.py).  New call sites should use
``graph.program().apply(...)`` / ``Topology.program_at(...)`` directly;
these wrappers exist for the benchmark suite and backwards compatibility.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graphs import CommGraph
from repro.core.schedule import (
    GossipProgram, compile_graph, permutation_for_offset, program_comm_bytes,
)

PyTree = Any

__all__ = [
    "mix_dense",
    "mix_shift",
    "mix_ppermute",
    "permutation_for_offset",
    "mixing_comm_bytes",
]


def _tree_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def mixing_comm_bytes(graph: CommGraph, params: PyTree) -> int:
    """Bytes sent per node per mixing step (analytic model).

    Derived from the compiled program: permutes move P each, the complete
    graph lowers to a ring all-reduce (2·P·(n-1)/n per node, not (n-1)·P).
    """
    return program_comm_bytes(compile_graph(graph), _tree_bytes(params))


# ---------------------------------------------------------------------------
# Dense (paper-faithful reference)
# ---------------------------------------------------------------------------

def mix_dense(stacked: PyTree, w: jax.Array | np.ndarray) -> PyTree:
    """θ ← W θ with a dense (n, n) mixing matrix over leading axis 0."""
    w = jnp.asarray(w)

    def _mix(x):
        return jnp.einsum(
            "ij,j...->i...", w.astype(jnp.float32), x.astype(jnp.float32)
        ).astype(x.dtype)

    return jax.tree.map(_mix, stacked)


# ---------------------------------------------------------------------------
# Circulant shift / gather (jit-friendly; stacked interpreter)
# ---------------------------------------------------------------------------

def mix_shift(stacked: PyTree, graph: CommGraph) -> PyTree:
    """θ_i ← w_self·θ_i + Σ_d w_d·θ_{(i+d) mod n} over the stacked axis."""
    return compile_graph(graph).apply_stacked(stacked)


# ---------------------------------------------------------------------------
# Explicit collective schedule (production path, inside shard_map)
# ---------------------------------------------------------------------------

def mix_ppermute(
    local: PyTree,
    graph: CommGraph,
    axis_names: str | Sequence[str],
    *,
    complete_as_allreduce: bool = True,
) -> PyTree:
    """One gossip step for per-node values inside ``shard_map``.

    Args:
      local: this node's (post-update) parameter pytree.
      graph: the communication graph; ``graph.n`` must equal the total size
        of ``axis_names``.
      axis_names: the manual mesh axis (or tuple of axes) enumerating nodes.
      complete_as_allreduce: lower the complete graph as ``pmean`` (ring
        all-reduce, 2P(n-1)/n bytes) instead of n-1 permutes.
    """
    program = compile_graph(graph)
    if not complete_as_allreduce and graph.name == "complete":
        # n-1 explicit permutes (benchmark baseline; never the default)
        from repro.core.schedule import PPermute

        program = GossipProgram(
            name="complete_unrolled",
            n=graph.n,
            ops=tuple(
                PPermute(permutation_for_offset(graph.n, d), wd, offset=d)
                for d, wd in graph.weighted_offsets()
            ),
            self_weight=graph.self_weight,
        )
    return program.apply_shard(local, axis_names)
