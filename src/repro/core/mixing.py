"""Mixing (gossip averaging) implementations.

Three equivalent realizations of one mixing step  θ ← W θ :

  * ``mix_dense``    — dense mixing-matrix einsum over a stacked replica axis.
                       Bit-faithful to the paper's equations; used by the CPU
                       simulator and as the *paper-faithful baseline* in the
                       perf study (costs an all-gather at scale).
  * ``mix_shift``    — Σ_d w_d · roll(θ, d) over the stacked axis.  Exploits
                       the circulant structure; under jit on a sharded axis
                       XLA lowers each roll to collective-permutes.
  * ``mix_ppermute`` — explicit ``jax.lax.ppermute`` schedule inside
                       ``shard_map``; one permute per graph offset, plus the
                       all-reduce fast path for the complete graph.  This is
                       the production (beyond-paper-optimized) path.

All three are tested for equivalence (tests/test_mixing.py).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graphs import CommGraph

PyTree = Any

__all__ = [
    "mix_dense",
    "mix_shift",
    "mix_ppermute",
    "permutation_for_offset",
    "mixing_comm_bytes",
]


def _tree_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def mixing_comm_bytes(graph: CommGraph, params: PyTree) -> int:
    """Bytes sent per node per mixing step (analytic model).

    complete graph is realized as an all-reduce: ring-reduced cost
    2·P·(n-1)/n per node, not (n-1)·P.
    """
    p = _tree_bytes(params)
    if graph.degree == 0:
        return 0
    if graph.name == "complete":
        return int(2 * p * (graph.n - 1) / graph.n)
    return graph.degree * p


# ---------------------------------------------------------------------------
# Dense (paper-faithful reference)
# ---------------------------------------------------------------------------

def mix_dense(stacked: PyTree, w: jax.Array | np.ndarray) -> PyTree:
    """θ ← W θ with a dense (n, n) mixing matrix over leading axis 0."""
    w = jnp.asarray(w)

    def _mix(x):
        return jnp.einsum(
            "ij,j...->i...", w.astype(jnp.float32), x.astype(jnp.float32)
        ).astype(x.dtype)

    return jax.tree.map(_mix, stacked)


# ---------------------------------------------------------------------------
# Circulant shift (jit-friendly, XLA lowers rolls on sharded axes to
# collective-permute)
# ---------------------------------------------------------------------------

def mix_shift(stacked: PyTree, graph: CommGraph) -> PyTree:
    """θ_i ← w_self·θ_i + Σ_d w_d·θ_{(i+d) mod n}   via jnp.roll."""
    if graph.degree == 0:
        return stacked
    pairs = graph.weighted_offsets()
    ws = graph.self_weight

    def _mix(x):
        acc = ws * x.astype(jnp.float32)
        for d, wd in pairs:
            # receive from node (i+d): roll the stacked axis by -d
            acc = acc + wd * jnp.roll(x, -d, axis=0).astype(jnp.float32)
        return acc.astype(x.dtype)

    return jax.tree.map(_mix, stacked)


# ---------------------------------------------------------------------------
# Explicit collective schedule (production path, inside shard_map)
# ---------------------------------------------------------------------------

def permutation_for_offset(n: int, d: int) -> list[tuple[int, int]]:
    """ppermute pairs so that node i receives from node (i + d) % n."""
    return [((i + d) % n, i) for i in range(n)]


def mix_ppermute(
    local: PyTree,
    graph: CommGraph,
    axis_names: str | Sequence[str],
    *,
    complete_as_allreduce: bool = True,
) -> PyTree:
    """One gossip step for per-node values inside ``shard_map``.

    Args:
      local: this node's (post-update) parameter pytree.
      graph: the communication graph; ``graph.n`` must equal the total size
        of ``axis_names``.
      axis_names: the manual mesh axis (or tuple of axes) enumerating nodes.
      complete_as_allreduce: lower the complete graph as ``pmean`` (ring
        all-reduce, 2P(n-1)/n bytes) instead of n-1 permutes.
    """
    if graph.degree == 0:
        return local
    if complete_as_allreduce and graph.name == "complete":
        return jax.tree.map(
            lambda x: jax.lax.pmean(x.astype(jnp.float32), axis_names).astype(x.dtype),
            local,
        )

    n = graph.n
    pairs = graph.weighted_offsets()
    ws = graph.self_weight

    def _mix(x):
        acc = ws * x.astype(jnp.float32)
        for d, wd in pairs:
            perm = permutation_for_offset(n, d)
            acc = acc + wd * jax.lax.ppermute(x, axis_names, perm).astype(jnp.float32)
        return acc.astype(x.dtype)

    return jax.tree.map(_mix, local)
