"""Fault models + elastic membership for decentralized training (resilience).

The paper motivates decentralized learning with production stability, yet
repro runs only ever see pristine graphs.  This module injects the fault
classes production training must survive (arXiv:2410.11998 "from promise to
practice") as *seeded, step-deterministic* models both engines consume
identically: the realization at step t is a pure function of
``(seed, t)`` — the simulator and the SPMD trainer draw the same masks
without any cross-engine communication, so fault runs stay reproducible
and engine-equivalence tests stay exact.

Fault classes (``make_fault_model``):

  * ``crash``     — one permanent node crash: a seeded victim dies at a
    seeded step and stays dead (optionally rejoining after ``down_steps``).
    The engines switch to the pre-enumerated degraded program
    (``GossipProgram.degrade``) — the *single-node-out program set* folded
    into ``Topology.distinct_programs`` — so a crash changes which cached
    executable runs, never compiles a new one mid-run.
  * ``concurrent`` — k >= 2 seeded victims with independent geometric
    onsets, so their down windows OVERLAP.  Default execution is *composed*:
    the realized multi-node dead set rides entirely in the runtime alive
    mask over the base program (``select_alive`` stays all-ones), which by
    the mask-composition identity below realizes exactly the multi-node
    ``degraded_matrix`` — a concurrent-crash run compiles NO more
    executables than the fault-free run.  ``enumerate_programs=True`` is
    the bounded fast path: the <= 2k realized membership masks along the
    crash timeline are pre-enumerated as degraded programs, so dead-edge
    sends actually leave the wire (still zero mid-run recompiles).
  * ``preempt``   — planned preemption drain: a seeded victim announces
    departure ``drain_steps`` before it leaves.  During the drain its edges
    are *up-weighted* by ``boost`` (a float runtime mask — the masked
    interpreters are linear in the mask, so boost > 1 moves extra mass onto
    the draining edges and subtracts it from the receivers' self weight;
    W stays symmetric + doubly stochastic, so the global mean is preserved
    every drain step).  At departure the engines run the exact
    mean-preserving handoff (``drain_handoff``) and the node leaves without
    the Xi_t spike a hard crash causes; afterwards it is a permanent
    single-node-out membership like ``crash``.
  * ``join``      — true mid-run growth (simulator-only): at each
    pre-declared (or seeded) join step membership grows by one node, which
    enters by adopting its neighbors' average (``admit_node``).  The
    topology re-derives its graph family at the new n
    (``Topology.resized``); programs for every pre-declared size are
    enumerable up front, so joins never recompile beyond that set.
  * ``deadline``  — per-round gossip deadline with exponential-backoff
    readmission (arXiv:2506.00961's graceful degradation): each node draws
    a seeded lognormal round latency; a node that misses ``deadline_ms``
    is masked out of THAT round's gossip (neighbors renormalize onto self)
    but keeps its local optimizer step — the round degrades to partial
    participation with a local-step fallback instead of stalling on the
    straggler.  A miss additionally benches the node for 1, 2, 4, …
    rounds (``backoff``), so a persistently slow node is readmitted at
    exponentially growing intervals instead of thrashing the deadline
    every round; an on-time round resets its backoff.  Masks ride the
    runtime fault row — zero extra executables — and ``program_alive``
    stays all-ones: a deadline miss is transient, not a membership event
    (the Ξ_t drift from locally-stepping nodes is what the controller's
    spike re-densification reacts to).  The mask-driving latencies are
    seeded (pure fn(seed, step)), which keeps both engines bit-identical
    and resumes exact; the engines additionally record *measured*
    wall-clock round durations as an observational trace
    (``round_ms`` / ``deadline_overruns``).
  * ``spare``     — over-provisioned spare-rank pool (``SparePool``): the
    gossip mesh is built at ``n = n_active + spares`` and the spare ranks
    ride from step 0 as alive-masked zero-weight *ghosts* — their edges
    carry weight 0, the mass renormalizes onto the active receivers'
    self weight, and the ghost's own row degrades to the identity
    (exactly ``degraded_matrix`` with the ghost mask, so activating a
    spare compiles ZERO extra executables: ``select_alive`` stays
    all-ones and every realization rides the base program's runtime
    fault row).  Wrapping an (inner) ``join`` model maps each
    pre-declared join onto a spare activation: at the join step the spare
    flips alive, adopts its neighbors' average (the ``rejoin`` path ==
    ``admit_node`` semantics without growing any array), and the
    membership-key change re-arms the controller — true elasticity on a
    FIXED device mesh, which is why (unlike ``join``) a spare pool runs
    on the SPMD trainer.  Any non-elastic inner model (deadline,
    preempt, crash, dropout, …) composes: its realization occupies the
    active ranks while the ghosts pad the rest.
  * ``dropout``   — transient node dropout: per-step i.i.d. Bernoulli(rate)
    per node.  A dropped node skips this round's gossip (its row degrades
    to identity, its neighbors renormalize onto self) but still takes its
    local update.  Realized through *runtime masks* — same executable for
    every realization.
  * ``link``      — per-edge Bernoulli(rate) link failure per step,
    symmetric (both directions die together).  Runtime masks.
  * ``straggler`` — per-step Bernoulli(rate) stragglers: the node skips its
    local optimizer update (gradient discarded, momentum untouched) but
    still participates in gossip — the "slow worker" regime.

Mask composition (why ``concurrent`` compiles nothing new): degradation by
an alive mask only zeroes off-diagonal entries and renormalizes onto the
receiver's diagonal, so degrading by mask A and then runtime-masking by
mask B realizes exactly ``degraded_matrix(W, A & B)`` — composition over
disjoint dead sets equals direct multi-node degradation.  The property
test in ``tests/test_elastic.py`` pins this against the dense oracle.

How the masks act (shared by both engines):

  * ``update`` gates the local optimizer step per node (stragglers, dead).
  * ``alive`` + ``link_up`` degrade the mixing matrix at runtime exactly as
    ``schedule.degraded_matrix``: dropped edges renormalize onto the
    receiver's self weight (in-kernel for the fused Pallas apply).
  * ``rejoin`` lists nodes re-entering *this* step: elastic membership —
    a recovered node adopts its alive neighbors' average (params and
    optimizer state) before the step runs, then trains normally.

``ConsensusController`` integration: a membership change spikes the
measured consensus distance; the engines call ``controller.rearm`` so the
per-phase peak Ξ_0 re-arms on the new membership instead of a stale ladder
reference ratcheting the schedule down.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import degraded_matrix  # noqa: F401  (re-export)

PyTree = Any

__all__ = [
    "FAULT_MODELS",
    "ConcurrentCrash",
    "FaultModel",
    "FaultRealization",
    "GossipDeadline",
    "Join",
    "LinkFailure",
    "NoFaults",
    "PermanentCrash",
    "Preemption",
    "SparePool",
    "Straggler",
    "TransientDropout",
    "admit_node",
    "adopt_neighbor_average",
    "degraded_matrix",
    "drain_handoff",
    "fold_degraded_programs",
    "make_fault_model",
    "realization_arrays",
    "rejoin_neighbors",
    "track_membership",
]


@dataclasses.dataclass(frozen=True, eq=False)
class FaultRealization:
    """What the fault model says about ONE training step (numpy, host-side).

    alive:         (n,) — node participates in this step's gossip.  Usually
        bool; float values are *weight multipliers* on the node's edges
        (the masked interpreters are linear in the mask): 0 removes the
        edge, 1 keeps it, and a preemption drain up-weights the departing
        node with values > 1 — still symmetric, so W stays doubly
        stochastic and the mean is preserved.
    update:        (n,) bool — node performs its local optimizer update.
    program_alive: (n,) bool — the slowly-varying TRUE membership (all
        ones except permanent crashes/departures).  Drives
        ``membership_key`` and hence controller re-arming.
    select_alive:  optional (n,) bool — the mask used for degraded-program
        *selection* when it differs from the true membership.  The composed
        concurrent-crash path keeps it all-ones (base program + runtime
        masks realize the multi-node degradation), while ``program_alive``
        still records who is actually dead.  ``None`` => ``program_alive``.
    link_up:       optional (n, n) bool, symmetric — per-link liveness.
    rejoin:        nodes re-entering at this step (adopt neighbor average).
    depart:        nodes leaving cleanly AT this step (after a drain): the
        engines run the mean-preserving ``drain_handoff`` before the step.
    joins:         new node indices entering at this step (elastic growth;
        realization arrays from this step on are sized for the grown n).
    """

    alive: np.ndarray
    update: np.ndarray
    program_alive: np.ndarray
    link_up: Optional[np.ndarray] = None
    rejoin: tuple[int, ...] = ()
    select_alive: Optional[np.ndarray] = None
    depart: tuple[int, ...] = ()
    joins: tuple[int, ...] = ()

    @property
    def faulty(self) -> bool:
        # `alive == 1` (not `.all()`): a float drain boost (alive > 1) must
        # also route through the masked step even though every node is up
        return (
            not (self.alive == 1).all()
            or not self.update.all()
            or (self.link_up is not None and not self.link_up.all())
        )

    def membership_key(self) -> tuple:
        """Hashable TRUE-membership identity (drives controller re-arming).

        Always derived from ``program_alive`` — even when the composed
        concurrent-crash path selects the base program (``select_alive``
        all-ones), a real membership change must still re-arm the
        controller's phase reference.
        """
        return tuple(bool(a) for a in self.program_alive)

    def selection_mask(self) -> np.ndarray:
        """The membership mask engines select the degraded program by."""
        return (
            self.program_alive if self.select_alive is None
            else self.select_alive
        )


def _rng(seed: int, step: int, salt: int = 0) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, salt, step]))


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Base: a seeded, step-deterministic fault process over n nodes."""

    n: int
    rate: float
    seed: int = 0
    name: str = "none"

    def __post_init__(self):
        if self.n < 1:
            raise ValueError(f"fault model needs >=1 node, got n={self.n}")
        if not (0.0 <= self.rate <= 1.0):
            raise ValueError(f"fault rate must be in [0, 1], got {self.rate}")

    def _ones(self) -> np.ndarray:
        return np.ones(self.n, dtype=bool)

    def at(self, step: int) -> FaultRealization:  # pragma: no cover - base
        raise NotImplementedError

    def program_masks(self) -> tuple[tuple[bool, ...], ...]:
        """Every membership mask this model can realize beyond all-alive —
        the alive-sets ``Topology.distinct_programs`` pre-enumerates
        degraded programs for (empty for purely transient models)."""
        return ()

    @property
    def has_link_faults(self) -> bool:
        """Whether realizations may carry a per-edge ``link_up`` mask —
        models that never do skip the (n, n) link operand entirely."""
        return False

    @property
    def elastic(self) -> bool:
        """Whether membership can EXCEED the initial n (mid-run joins).
        Elastic models are simulator-only — a device mesh is fixed."""
        return False

    def describe(self) -> str:
        return f"{self.name}(n={self.n}, rate={self.rate}, seed={self.seed})"


@dataclasses.dataclass(frozen=True)
class NoFaults(FaultModel):
    name: str = "none"

    def at(self, step: int) -> FaultRealization:
        ones = self._ones()
        return FaultRealization(alive=ones, update=ones, program_alive=ones)


@dataclasses.dataclass(frozen=True)
class PermanentCrash(FaultModel):
    """One seeded victim crashes at a seeded step (single-node-out).

    The victim and crash step derive from the seed: the crash step is a
    geometric draw with parameter ``rate`` (expected onset ~1/rate steps).
    ``down_steps`` (elastic membership) brings the victim back after that
    many dead steps — it rejoins by adopting its neighbors' average.
    Exactly one node is ever out at a time, so the degraded-program set the
    engines must cache is bounded by one extra program per base program.
    """

    name: str = "crash"
    down_steps: Optional[int] = None

    def __post_init__(self):
        super().__post_init__()
        if self.down_steps is not None and int(self.down_steps) < 1:
            # 0 would fire a rejoin for a node that never went down
            # (neighbor-average overwrites healthy state); negative values
            # would silently empty the crash window
            raise ValueError(
                f"down_steps must be >= 1, got {self.down_steps}"
            )
        r = _rng(self.seed, 0, salt=101)
        victim = int(r.integers(self.n))
        # first success of a Bernoulli(rate) sequence; rate 0 => never
        crash_step = int(r.geometric(self.rate)) if self.rate > 0 else None
        object.__setattr__(self, "_victim", victim)
        object.__setattr__(self, "_crash_step", crash_step)

    @property
    def victim(self) -> int:
        return self._victim

    @property
    def crash_step(self) -> Optional[int]:
        return self._crash_step

    @property
    def rejoin_step(self) -> Optional[int]:
        if self._crash_step is None or self.down_steps is None:
            return None
        return self._crash_step + int(self.down_steps)

    def at(self, step: int) -> FaultRealization:
        ones = self._ones()
        c, r = self._crash_step, self.rejoin_step
        down = c is not None and c <= step and (r is None or step < r)
        if not down:
            return FaultRealization(
                alive=ones, update=ones, program_alive=ones,
                rejoin=(self._victim,) if (r is not None and step == r) else (),
            )
        alive = ones.copy()
        alive[self._victim] = False
        return FaultRealization(
            alive=alive, update=alive.copy(), program_alive=alive.copy()
        )

    def program_masks(self):
        if self._crash_step is None:
            return ()
        mask = [True] * self.n
        mask[self._victim] = False
        return (tuple(mask),)


@dataclasses.dataclass(frozen=True)
class ConcurrentCrash(FaultModel):
    """k >= 2 seeded victims crash in overlapping windows.

    Each victim gets an independent geometric onset (parameter ``rate``),
    so down windows overlap — including simultaneous same-step crashes
    (the coalesced-rearm case).  ``down_steps`` brings each victim back
    that many steps after its own onset (elastic rejoin, per victim).

    Execution modes:

      * composed (default): ``select_alive`` stays all-ones — the engines
        keep the BASE program and the realized multi-node dead set rides
        the runtime alive mask.  By the mask-composition identity this
        realizes exactly ``degraded_matrix(W, dead-set)``, and the run
        compiles no more executables than the fault-free run (the
        acceptance bar pinned by ``tests/faults_spmd_script.py``).
      * ``enumerate_programs=True``: the bounded enumeration fast path —
        ``program_masks`` walks the crash/rejoin timeline and returns every
        membership mask the model actually realizes (<= 2k distinct, NOT
        the C(n, k) combinatorial set).  Engines then select the exact
        degraded program, so dead-edge sends leave the wire; the masks are
        pre-enumerated, so zero mid-run recompiles still holds.
    """

    name: str = "concurrent"
    k: int = 2
    down_steps: Optional[int] = None
    enumerate_programs: bool = False

    def __post_init__(self):
        super().__post_init__()
        if not 2 <= int(self.k) < self.n:
            raise ValueError(
                f"concurrent crash needs 2 <= k < n, got k={self.k}, n={self.n}"
            )
        if self.down_steps is not None and int(self.down_steps) < 1:
            raise ValueError(f"down_steps must be >= 1, got {self.down_steps}")
        r = _rng(self.seed, 0, salt=105)
        victims = tuple(int(v) for v in r.choice(self.n, int(self.k), False))
        onsets = tuple(
            int(r.geometric(self.rate)) if self.rate > 0 else None
            for _ in victims
        )
        object.__setattr__(self, "_victims", victims)
        object.__setattr__(self, "_onsets", onsets)

    @property
    def victims(self) -> tuple[int, ...]:
        return self._victims

    @property
    def onsets(self) -> tuple[Optional[int], ...]:
        return self._onsets

    def _window(self, i: int) -> tuple[Optional[int], Optional[int]]:
        on = self._onsets[i]
        if on is None:
            return None, None
        off = None if self.down_steps is None else on + int(self.down_steps)
        return on, off

    def at(self, step: int) -> FaultRealization:
        ones = self._ones()
        alive = ones.copy()
        rejoin = []
        for i, v in enumerate(self._victims):
            on, off = self._window(i)
            if on is None:
                continue
            if on <= step and (off is None or step < off):
                alive[v] = False
            elif off is not None and step == off:
                rejoin.append(v)
        return FaultRealization(
            alive=alive,
            update=alive.copy(),
            program_alive=alive.copy(),
            rejoin=tuple(rejoin),
            # composed mode: base program + runtime masks (select stays
            # all-ones); enumeration mode selects the realized membership
            select_alive=None if self.enumerate_programs else ones.copy(),
        )

    def program_masks(self):
        if not self.enumerate_programs:
            return ()  # composed: the dead set rides the runtime mask
        events = sorted(
            {s for i in range(len(self._victims))
             for s in self._window(i) if s is not None}
        )
        masks, seen = [], set()
        for s in events:
            mask = tuple(bool(a) for a in self.at(s).program_alive)
            if not all(mask) and mask not in seen:
                seen.add(mask)
                masks.append(mask)
        return tuple(masks)


@dataclasses.dataclass(frozen=True)
class Preemption(FaultModel):
    """Planned preemption: announce, drain, hand off, leave cleanly.

    A seeded victim is preempted at a seeded step (geometric onset with
    parameter ``rate``) but — unlike a hard crash — it announces departure
    ``drain_steps`` ahead.  During the drain its edges carry a float
    ``boost`` > 1 in the runtime alive mask: the masked interpreters are
    linear in the mask, so every edge touching the victim moves ``boost``×
    its weight while receivers subtract the excess from their self weight.
    The boosted W stays symmetric and doubly stochastic (mean preserved
    every drain step); neighbors absorb the departing replica's state
    faster than the base graph would diffuse it.

    At the departure step the realization carries ``depart=(victim,)`` and
    the engines apply the exact mean-preserving handoff
    (``drain_handoff``): the survivors' post-departure mean equals the
    pre-departure global mean, so Xi_t sees no membership spike — the
    clean-leave contrast to ``crash`` that ``benchmarks/faults.py``'s
    elastic sweep measures.  From then on the victim is a permanent
    single-node-out membership (one pre-enumerated degraded program, as
    for ``crash``).

    The default ``boost=1.5`` keeps every receiver's self weight
    nonnegative for the uniform circulant families and Metropolis–Hastings
    leaf drains (self weight >= 0.5 × boosted incoming mass there); larger
    boosts stay mean-preserving but may push a self weight negative.
    """

    name: str = "preempt"
    drain_steps: int = 5
    boost: float = 1.5

    def __post_init__(self):
        super().__post_init__()
        if int(self.drain_steps) < 1:
            raise ValueError(
                f"drain_steps must be >= 1, got {self.drain_steps}"
            )
        if not float(self.boost) >= 1.0:
            raise ValueError(f"boost must be >= 1, got {self.boost}")
        r = _rng(self.seed, 0, salt=106)
        victim = int(r.integers(self.n))
        announce = int(r.geometric(self.rate)) if self.rate > 0 else None
        object.__setattr__(self, "_victim", victim)
        object.__setattr__(self, "_announce_step", announce)

    @property
    def victim(self) -> int:
        return self._victim

    @property
    def announce_step(self) -> Optional[int]:
        return self._announce_step

    @property
    def depart_step(self) -> Optional[int]:
        if self._announce_step is None:
            return None
        return self._announce_step + int(self.drain_steps)

    def at(self, step: int) -> FaultRealization:
        ones = self._ones()
        a, d = self._announce_step, self.depart_step
        if a is None or step < a:
            return FaultRealization(
                alive=ones, update=ones.copy(), program_alive=ones.copy()
            )
        if step < d:  # draining: still training, edges boosted
            boosted = np.ones(self.n, dtype=np.float64)
            boosted[self._victim] = float(self.boost)
            return FaultRealization(
                alive=boosted, update=ones.copy(), program_alive=ones.copy()
            )
        dead = ones.copy()
        dead[self._victim] = False
        return FaultRealization(
            alive=dead,
            update=dead.copy(),
            program_alive=dead.copy(),
            depart=(self._victim,) if step == d else (),
        )

    def program_masks(self):
        if self._announce_step is None:
            return ()
        mask = [True] * self.n
        mask[self._victim] = False
        return (tuple(mask),)


@dataclasses.dataclass(frozen=True)
class Join(FaultModel):
    """True mid-run growth: membership exceeds the initial n (simulator-only).

    ``join_steps`` pre-declares when each new node enters (one per step
    listed; the new node's index is ``n + i`` for the i-th join).  When not
    given, one seeded geometric onset (parameter ``rate``) is drawn — still
    a pure function of the seed, so both a run and its resume replay the
    same growth.  A joining node enters by adopting its (new) neighbors'
    average (``admit_node``); the engine re-derives the topology at the new
    n via ``Topology.resized`` and the controller re-arms through
    ``track_membership`` (the membership key changes length).

    Programs for every pre-declared size are enumerable up front
    (``Topology.distinct_programs`` folds the growth schedule in), so joins
    compile nothing beyond that bounded set.
    """

    name: str = "join"
    join_steps: Optional[tuple[int, ...]] = None

    def __post_init__(self):
        super().__post_init__()
        js = self.join_steps
        if js is None:
            r = _rng(self.seed, 0, salt=107)
            js = (int(r.geometric(self.rate)),) if self.rate > 0 else ()
        js = tuple(sorted(int(s) for s in js))
        if js and js[0] < 1:
            raise ValueError(f"join steps must be >= 1, got {js}")
        object.__setattr__(self, "join_steps", js)

    @property
    def elastic(self) -> bool:
        return True

    def membership_sizes(self) -> tuple[int, ...]:
        """Every n the run can reach (the pre-declared growth schedule)."""
        return tuple(self.n + i for i in range(len(self.join_steps) + 1))

    def n_at(self, step: int) -> int:
        """Membership size in force AT ``step`` (joins land at their step)."""
        return self.n + sum(1 for s in self.join_steps if s <= step)

    def at(self, step: int) -> FaultRealization:
        m = self.n_at(step)
        ones = np.ones(m, dtype=bool)
        joins = tuple(
            self.n + i for i, s in enumerate(self.join_steps) if s == step
        )
        return FaultRealization(
            alive=ones, update=ones.copy(), program_alive=ones.copy(),
            joins=joins,
        )


@dataclasses.dataclass(frozen=True)
class TransientDropout(FaultModel):
    """Per-step i.i.d. node dropout: skips gossip, keeps the local update."""

    name: str = "dropout"

    def at(self, step: int) -> FaultRealization:
        ones = self._ones()
        drop = _rng(self.seed, step, salt=1).random(self.n) < self.rate
        if drop.all():  # keep at least one node in the round
            drop[int(_rng(self.seed, step, salt=2).integers(self.n))] = False
        return FaultRealization(alive=~drop, update=ones, program_alive=ones)


@dataclasses.dataclass(frozen=True)
class LinkFailure(FaultModel):
    """Per-step i.i.d. symmetric link failures (both directions die)."""

    name: str = "link"

    @property
    def has_link_faults(self) -> bool:
        return True

    def at(self, step: int) -> FaultRealization:
        ones = self._ones()
        u = _rng(self.seed, step, salt=3).random((self.n, self.n))
        up = np.triu(u >= self.rate, k=1)
        link_up = up | up.T
        np.fill_diagonal(link_up, True)
        return FaultRealization(
            alive=ones, update=ones.copy(), program_alive=ones.copy(),
            link_up=link_up,
        )


@dataclasses.dataclass(frozen=True)
class Straggler(FaultModel):
    """Per-step stragglers: skip the local update but still mix."""

    name: str = "straggler"

    def at(self, step: int) -> FaultRealization:
        ones = self._ones()
        slow = _rng(self.seed, step, salt=4).random(self.n) < self.rate
        return FaultRealization(
            alive=ones, update=~slow, program_alive=ones.copy()
        )


@dataclasses.dataclass(frozen=True)
class GossipDeadline(FaultModel):
    """Per-round gossip deadline with exponential-backoff readmission.

    Each (node, step) draws a lognormal round latency
    ``mean_ms · exp(sigma · Z)``; with probability ``rate`` the node
    additionally suffers a straggler spike (``spike_mult``× the draw).  A
    node whose latency exceeds ``deadline_ms`` MISSES the round: it is
    masked out of gossip (``alive = 0`` — its neighbors renormalize onto
    self, its own row degrades to identity) but keeps its local optimizer
    step (``update = 1``) — graceful degradation to partial participation
    with a local-step fallback (arXiv:2506.00961) instead of the whole
    round stalling on the straggler.

    Readmission is under exponential backoff: a fresh miss benches the
    node for ``penalty`` further rounds (masked out, still local-stepping)
    and multiplies the penalty by ``backoff`` (1, 2, 4, … up to
    ``backoff_cap``); an on-time *participated* round resets the penalty
    to 1.  This prevents a persistently slow node from thrashing the
    deadline every round while guaranteeing it is re-probed at growing
    intervals.

    The timeline is a pure function of ``(seed, step)``: it is replayed
    incrementally from step 0 and cached, so out-of-order queries and
    resumed runs see the identical stream (the backoff state machine is
    deterministic given the seeded latency draws).  ``program_alive``
    stays all-ones — a miss is transient, never a membership event — and
    all masks are runtime fault-row values: zero extra executables.

    The seeded latencies stand in for wall-clock measurement so both
    engines and any resume stay bit-identical; the engines separately
    record measured wall-clock round durations (``round_ms``) and count
    overruns against this same ``deadline_ms`` as an observational trace.
    """

    name: str = "deadline"
    deadline_ms: float = 30.0
    mean_ms: float = 20.0
    sigma: float = 0.25
    spike_mult: float = 10.0
    backoff: float = 2.0
    backoff_cap: int = 64

    def __post_init__(self):
        super().__post_init__()
        if not float(self.deadline_ms) > 0.0:
            raise ValueError(f"deadline_ms must be > 0, got {self.deadline_ms}")
        if not 0.0 < float(self.mean_ms):
            raise ValueError(f"mean_ms must be > 0, got {self.mean_ms}")
        if not float(self.backoff) >= 1.0:
            raise ValueError(f"backoff must be >= 1, got {self.backoff}")
        if int(self.backoff_cap) < 1:
            raise ValueError(
                f"backoff_cap must be >= 1, got {self.backoff_cap}"
            )
        # incremental replay cache: _participates[t] is the (n,) bool mask
        # of nodes that made round t; the penalty/suspension state machine
        # advances with it (deterministic given the seeded draws, so two
        # same-seed instances — or a resume — replay the identical stream)
        object.__setattr__(self, "_participates", [])
        object.__setattr__(self, "_penalty", np.ones(self.n))
        object.__setattr__(self, "_suspend", np.zeros(self.n, dtype=np.int64))

    def latency_ms(self, step: int) -> np.ndarray:
        """The seeded per-node round latency draw for ``step`` (ms)."""
        r = _rng(self.seed, step, salt=108)
        base = self.mean_ms * np.exp(self.sigma * r.standard_normal(self.n))
        spiked = r.random(self.n) < self.rate
        return np.where(spiked, base * self.spike_mult, base)

    def _advance_to(self, step: int) -> None:
        while len(self._participates) <= step:
            t = len(self._participates)
            miss = self.latency_ms(t) > self.deadline_ms
            benched = self._suspend > 0
            part = ~(miss | benched)
            self._suspend[benched] -= 1
            # a fresh miss (not already benched) earns a sit-out window of
            # the current penalty, then the penalty grows geometrically
            fresh = miss & ~benched
            self._suspend[fresh] += np.round(self._penalty[fresh]).astype(
                np.int64
            )
            self._penalty[fresh] = np.minimum(
                self._penalty[fresh] * self.backoff, float(self.backoff_cap)
            )
            self._penalty[part] = 1.0  # on-time round: backoff resets
            self._participates.append(part)

    def at(self, step: int) -> FaultRealization:
        self._advance_to(step)
        ones = self._ones()
        return FaultRealization(
            alive=self._participates[step].copy(),
            update=ones,  # local-step fallback: a benched node keeps training
            program_alive=ones.copy(),
        )

    def describe(self) -> str:
        return (
            f"{self.name}(n={self.n}, rate={self.rate}, seed={self.seed}, "
            f"deadline_ms={self.deadline_ms}, backoff={self.backoff})"
        )


@dataclasses.dataclass(frozen=True)
class SparePool(FaultModel):
    """Over-provisioned spare-rank pool: elastic membership on a FIXED mesh.

    ``n`` is the FULL gossip size the mesh (and topology) is built at;
    the last ``spares`` ranks ride from step 0 as alive-masked, zero-weight
    *ghosts*: their ``alive``/``update`` masks are 0, so ``degraded_matrix``
    renormalizes their edge mass onto the active receivers' self weight and
    degrades each ghost's own row to the identity — a zero-weight
    participant whose replica stays frozen at init.  ``select_alive`` is
    ALWAYS all-ones and ``program_masks`` is empty: every realization —
    ghosts, inner faults, activations — rides the base program's runtime
    fault row, so a spare pool compiles exactly as many executables as the
    fault-free run (the invariant ``tests/faults_spmd_script.py`` pins).

    ``inner`` is an optional fault model over the ``n - spares`` initially
    active ranks.  A ``Join`` inner turns pre-declared joins into spare
    ACTIVATIONS: inner join i lands on outer rank ``(n - spares) + i``,
    surfaced through ``rejoin`` — the engines' existing rejoin path adopts
    the spare's state from its alive neighbors' average (``admit_node``
    semantics without growing any array) and the membership-key flip
    re-arms the consensus controller.  Non-elastic inners (deadline,
    preempt, crash, dropout, link, straggler) compose unchanged on the
    active ranks; an inner's own pre-enumerated program masks are
    deliberately dropped — the pool forces the composed runtime-mask
    execution for everything.

    The pool itself is NOT elastic (membership never exceeds ``n``), which
    is exactly why — unlike ``join`` — it runs on the SPMD trainer.
    """

    name: str = "spare"
    spares: int = 1
    inner: Optional[FaultModel] = None

    def __post_init__(self):
        super().__post_init__()
        if not 1 <= int(self.spares) < self.n:
            raise ValueError(
                f"spare pool needs 1 <= spares < n, got spares={self.spares}, "
                f"n={self.n}"
            )
        n0 = self.n - int(self.spares)
        if self.inner is not None:
            if isinstance(self.inner, SparePool):
                raise ValueError("spare pools do not nest")
            if self.inner.n != n0:
                raise ValueError(
                    f"inner fault model covers {self.inner.n} nodes but the "
                    f"pool has {n0} initially-active ranks "
                    f"(n={self.n} - spares={self.spares})"
                )
            if self.inner.elastic:
                js = getattr(self.inner, "join_steps", ())
                if len(js) > int(self.spares):
                    raise ValueError(
                        f"{len(js)} pre-declared joins exceed the "
                        f"{self.spares} spare rank(s)"
                    )

    @property
    def n_active0(self) -> int:
        """Initially-active rank count (the inner model's n)."""
        return self.n - int(self.spares)

    @property
    def has_link_faults(self) -> bool:
        return self.inner is not None and self.inner.has_link_faults

    @property
    def deadline_ms(self) -> Optional[float]:
        """The inner deadline (ms) when wrapping a ``GossipDeadline``."""
        return getattr(self.inner, "deadline_ms", None)

    def activation_steps(self) -> tuple[int, ...]:
        """Steps at which a spare activates (the inner join schedule)."""
        if self.inner is not None and self.inner.elastic:
            return tuple(self.inner.join_steps)
        return ()

    def at(self, step: int) -> FaultRealization:
        n0 = self.n_active0
        if self.inner is None:
            m = n0
            ones = np.ones(m, dtype=bool)
            base = FaultRealization(
                alive=ones, update=ones.copy(), program_alive=ones.copy()
            )
        else:
            base = self.inner.at(step)
            m = len(base.program_alive)  # grows as inner joins land
        base_alive = np.asarray(base.alive)
        alive = np.zeros(self.n, dtype=base_alive.dtype)  # ghosts: 0
        alive[:m] = base_alive
        update = np.zeros(self.n, dtype=bool)  # ghosts: frozen at init
        update[:m] = base.update
        palive = np.zeros(self.n, dtype=bool)  # drives membership_key/rearm
        palive[:m] = base.program_alive
        link = None
        if base.link_up is not None:
            link = np.ones((self.n, self.n), dtype=bool)
            link[:m, :m] = base.link_up
        return FaultRealization(
            alive=alive,
            update=update,
            program_alive=palive,
            link_up=link,
            # inner joins become spare activations at the SAME index: the
            # rejoin path adopts the spare's row from its alive neighbors
            rejoin=tuple(base.rejoin) + tuple(base.joins),
            depart=tuple(base.depart),
            # zero-recompile invariant: the base program + runtime fault
            # row realize every ghost/inner degradation (never select a
            # degraded program, never enumerate one)
            select_alive=np.ones(self.n, dtype=bool),
        )

    def program_masks(self):
        return ()

    def describe(self) -> str:
        inner = "none" if self.inner is None else self.inner.describe()
        return (
            f"{self.name}(n={self.n}, spares={self.spares}, inner={inner})"
        )


FAULT_MODELS = (
    "none", "crash", "concurrent", "preempt", "join", "deadline", "dropout",
    "link", "straggler",
)


def make_fault_model(
    kind: str,
    n: int,
    *,
    rate: float = 0.1,
    seed: int = 0,
    down_steps: Optional[int] = None,
    k: int = 2,
    drain_steps: int = 5,
    boost: float = 1.5,
    join_steps: Optional[tuple[int, ...]] = None,
    enumerate_programs: bool = False,
    spare_ranks: int = 0,
    deadline_ms: float = 30.0,
    deadline_mean_ms: float = 20.0,
    deadline_backoff: float = 2.0,
) -> Optional[FaultModel]:
    """Factory: ``make_fault_model("dropout", 16, rate=0.05, seed=3)``.

    ``kind="none"`` (or rate 0 for transient models) returns ``None`` so
    engines keep their exact fault-free hot path.  Elastic/permanent kinds:
    ``crash`` (one victim; ``down_steps`` rejoins it), ``concurrent``
    (``k`` victims, overlapping windows; ``enumerate_programs`` switches
    from the composed runtime-mask default to the bounded pre-enumerated
    degraded-program fast path), ``preempt`` (``drain_steps`` of ``boost``-
    weighted drain, then a clean mean-preserving departure), ``join``
    (``join_steps`` pre-declared growth; simulator-only unless wrapped in a
    spare pool), and ``deadline`` (per-round gossip deadline ``deadline_ms``
    with latency-spike probability ``rate`` and exponential
    ``deadline_backoff`` readmission).

    ``spare_ranks=S`` wraps ANY kind in a ``SparePool`` over a mesh of
    ``n`` total ranks whose last S ride as alive-masked zero-weight ghosts:
    the inner model is built at ``n - S`` active ranks, and a ``join``
    inner's pre-declared joins become spare *activations* — elastic
    membership that runs on the fixed-mesh SPMD trainer.  With spares a
    pool is always returned (the ghost masks alone make the run faulty)
    even when the inner kind realizes nothing.
    """
    if int(spare_ranks or 0) > 0:
        inner = make_fault_model(
            kind, n - int(spare_ranks), rate=rate, seed=seed,
            down_steps=down_steps, k=k, drain_steps=drain_steps, boost=boost,
            join_steps=join_steps, enumerate_programs=enumerate_programs,
            deadline_ms=deadline_ms, deadline_mean_ms=deadline_mean_ms,
            deadline_backoff=deadline_backoff,
        )
        return SparePool(
            n=n, rate=0.0, seed=seed, spares=int(spare_ranks), inner=inner
        )
    if kind in (None, "none"):
        return None
    if kind == "crash":
        m = PermanentCrash(n=n, rate=rate, seed=seed, down_steps=down_steps)
        # rate 0 => crash_step None: the model can never realize a fault;
        # keep the documented contract that engines stay on the exact
        # fault-free hot path instead of paying the mask plumbing for nothing
        return m if m.crash_step is not None else None
    if kind == "concurrent":
        m = ConcurrentCrash(
            n=n, rate=rate, seed=seed, k=k, down_steps=down_steps,
            enumerate_programs=enumerate_programs,
        )
        return m if any(o is not None for o in m.onsets) else None
    if down_steps is not None:
        raise ValueError(
            "down_steps is a crash/concurrent (permanent-fault) option"
        )
    if kind == "preempt":
        m = Preemption(
            n=n, rate=rate, seed=seed, drain_steps=drain_steps, boost=boost,
        )
        return m if m.announce_step is not None else None
    if kind == "join":
        m = Join(n=n, rate=rate, seed=seed, join_steps=join_steps)
        return m if m.join_steps else None
    if kind == "deadline":
        if rate == 0.0:
            return None
        return GossipDeadline(
            n=n, rate=rate, seed=seed, deadline_ms=deadline_ms,
            mean_ms=deadline_mean_ms, backoff=deadline_backoff,
        )
    if rate == 0.0:
        return None
    if kind == "dropout":
        return TransientDropout(n=n, rate=rate, seed=seed)
    if kind == "link":
        return LinkFailure(n=n, rate=rate, seed=seed)
    if kind == "straggler":
        return Straggler(n=n, rate=rate, seed=seed)
    raise ValueError(f"unknown fault model {kind!r}; one of {FAULT_MODELS}")


def fold_degraded_programs(programs, fault_model: FaultModel):
    """(base, degraded) pairs for every membership mask the model can
    realize over the given base programs, deduped against the bases and
    each other by cache key.

    The single enumeration used by both ``Topology.distinct_programs`` and
    ``SPMDTrainer.precompile_programs`` — crash semantics (e.g. a future
    multi-node mask set) must change in exactly one place or the trainer's
    precompiled set drifts from the Topology's asserted cache bound.
    """
    programs = list(programs)
    seen = {p.cache_key for p in programs}
    out = []
    for mask in fault_model.program_masks():
        for p in programs:
            d = p.degrade(mask)
            if d.cache_key not in seen:
                seen.add(d.cache_key)
                out.append((p, d))
    return out


# ---------------------------------------------------------------------------
# Elastic rejoin
# ---------------------------------------------------------------------------

def rejoin_neighbors(topology, fr: FaultRealization, node: int, *,
                     step: int, epoch: int, mix_every: int = 1) -> list[int]:
    """The alive peers a recovering node averages over: its neighborhood in
    the graph in force at the rejoin step (every alive node for the
    centralized/no-graph case).  Shared by both engines — the rejoin
    semantics must stay in lockstep or the engine-equivalence guarantee
    breaks."""
    graph = topology.graph_at(epoch, step // max(int(mix_every), 1))
    if graph is None:
        return [i for i in range(len(fr.alive)) if fr.alive[i] and i != node]
    return [i for i in graph.neighbors(node) if fr.alive[i] and i != node]


def track_membership(last, fr: FaultRealization, controller, step: int):
    """Fold one step's realization into the engine's membership tracking.

    Returns the new membership key; on a change after the first step it
    re-arms the consensus controller's phase reference (a crash/rejoin
    spikes Ξ — comparing it against the pre-fault peak would ratchet the
    ladder on a stale reference).  Shared by both engines.  This is the
    single per-step re-arm entry point: a k-node concurrent crash changes
    the key ONCE, and ``ConsensusController.rearm`` coalesces any further
    same-step events into one log entry.
    """
    membership = fr.membership_key()
    if membership != last and last is not None and controller is not None:
        controller.rearm(step, reason="membership")
    return membership


def adopt_neighbor_average(stacked: PyTree, node: int, neighbors) -> PyTree:
    """Elastic re-entry: ``node`` adopts the average of ``neighbors``.

    ``stacked`` carries a leading (n, ...) node axis (both engines' global
    state).  The recovered node's stale parameters (and optimizer state)
    are replaced by the mean of its alive neighbors' values — the gossip
    average it would have converged to had it kept mixing; with no alive
    neighbor it keeps its own values.  Rejoins are rare membership events,
    executed eagerly: they never enter the step-executable cache.
    """
    nbrs = [int(i) for i in neighbors]
    if not nbrs:
        return stacked
    idx = jnp.asarray(nbrs)

    def _adopt(x):
        mean = jnp.mean(
            jnp.take(x, idx, axis=0).astype(jnp.float32), axis=0
        ).astype(x.dtype)
        return x.at[node].set(mean)

    return jax.tree.map(_adopt, stacked)


def drain_handoff(stacked: PyTree, node: int, neighbors, alive) -> PyTree:
    """Exact mean-preserving handoff at a drained node's departure step.

    With ``n_surv`` survivors and ``m`` neighbors of the departing node
    each neighbor receives

        Δ = n_surv · (θ_d − x̄_surv) / (m · (n_surv + 1))

    so the survivors' post-departure mean equals the pre-departure global
    mean ``(n_surv · x̄_surv + θ_d) / (n_surv + 1)`` exactly — the departing
    replica's information is handed to its neighborhood instead of being
    dropped, and Ξ_t over the survivors sees no membership discontinuity.
    Shared by both engines (like ``adopt_neighbor_average``); with no
    surviving neighbor the state is returned unchanged (the information is
    unreachable, as for a hard crash of an isolated node).
    """
    nbrs = [int(i) for i in neighbors]
    surv = np.asarray(alive) != 0
    surv = surv.copy()
    surv[node] = False
    n_surv = int(surv.sum())
    if not nbrs or n_surv == 0:
        return stacked
    sidx = jnp.asarray(np.nonzero(surv)[0])
    nidx = jnp.asarray(nbrs)
    m = len(nbrs)

    def _hand(x):
        xf = x.astype(jnp.float32)
        mean_surv = jnp.mean(jnp.take(xf, sidx, axis=0), axis=0)
        delta = (n_surv * (xf[node] - mean_surv)) / (m * (n_surv + 1))
        return x.at[nidx].add(delta[None].astype(x.dtype))

    return jax.tree.map(_hand, stacked)


def admit_node(stacked: PyTree, neighbors) -> PyTree:
    """Elastic growth: append one new node row = its neighbors' average.

    The mid-run-join analogue of ``adopt_neighbor_average``: every leaf of
    ``stacked`` grows its leading node axis by one, seeded with the mean of
    ``neighbors`` (the joining node's neighborhood in the RESIZED graph) —
    or the global mean when the neighbor list is empty.  Joins are rare
    membership events, executed eagerly outside the step cache.
    """
    nbrs = [int(i) for i in neighbors]

    def _grow(x):
        xf = x.astype(jnp.float32)
        seed = (
            jnp.mean(jnp.take(xf, jnp.asarray(nbrs), axis=0), axis=0)
            if nbrs
            else jnp.mean(xf, axis=0)
        )
        return jnp.concatenate([x, seed.astype(x.dtype)[None]], axis=0)

    return jax.tree.map(_grow, stacked)


def realization_arrays(fr: FaultRealization) -> dict:
    """The runtime-mask pytree the jitted fault-aware step consumes.

    Fixed structure per fault model — every realization maps to the same
    executable signature.  Models that never produce link faults carry
    ``"link": None`` (an empty pytree subtree): the O(n²) all-ones matrix
    would otherwise be rebuilt, transferred, and multiplied through on
    every step of the hot path for nothing.
    """
    return {
        "update": jnp.asarray(fr.update, jnp.float32),
        "alive": jnp.asarray(fr.alive, jnp.float32),
        "link": (
            None if fr.link_up is None
            else jnp.asarray(fr.link_up.astype(np.float32))
        ),
    }
