"""Fault models + elastic membership for decentralized training (resilience).

The paper motivates decentralized learning with production stability, yet
repro runs only ever see pristine graphs.  This module injects the fault
classes production training must survive (arXiv:2410.11998 "from promise to
practice") as *seeded, step-deterministic* models both engines consume
identically: the realization at step t is a pure function of
``(seed, t)`` — the simulator and the SPMD trainer draw the same masks
without any cross-engine communication, so fault runs stay reproducible
and engine-equivalence tests stay exact.

Fault classes (``make_fault_model``):

  * ``crash``     — one permanent node crash: a seeded victim dies at a
    seeded step and stays dead (optionally rejoining after ``down_steps``).
    The engines switch to the pre-enumerated degraded program
    (``GossipProgram.degrade``) — the *single-node-out program set* folded
    into ``Topology.distinct_programs`` — so a crash changes which cached
    executable runs, never compiles a new one mid-run.
  * ``dropout``   — transient node dropout: per-step i.i.d. Bernoulli(rate)
    per node.  A dropped node skips this round's gossip (its row degrades
    to identity, its neighbors renormalize onto self) but still takes its
    local update.  Realized through *runtime masks* — same executable for
    every realization.
  * ``link``      — per-edge Bernoulli(rate) link failure per step,
    symmetric (both directions die together).  Runtime masks.
  * ``straggler`` — per-step Bernoulli(rate) stragglers: the node skips its
    local optimizer update (gradient discarded, momentum untouched) but
    still participates in gossip — the "slow worker" regime.

How the masks act (shared by both engines):

  * ``update`` gates the local optimizer step per node (stragglers, dead).
  * ``alive`` + ``link_up`` degrade the mixing matrix at runtime exactly as
    ``schedule.degraded_matrix``: dropped edges renormalize onto the
    receiver's self weight (in-kernel for the fused Pallas apply).
  * ``rejoin`` lists nodes re-entering *this* step: elastic membership —
    a recovered node adopts its alive neighbors' average (params and
    optimizer state) before the step runs, then trains normally.

``ConsensusController`` integration: a membership change spikes the
measured consensus distance; the engines call ``controller.rearm`` so the
per-phase peak Ξ_0 re-arms on the new membership instead of a stale ladder
reference ratcheting the schedule down.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import degraded_matrix  # noqa: F401  (re-export)

PyTree = Any

__all__ = [
    "FAULT_MODELS",
    "FaultModel",
    "FaultRealization",
    "LinkFailure",
    "NoFaults",
    "PermanentCrash",
    "Straggler",
    "TransientDropout",
    "adopt_neighbor_average",
    "degraded_matrix",
    "fold_degraded_programs",
    "make_fault_model",
    "realization_arrays",
    "rejoin_neighbors",
    "track_membership",
]


@dataclasses.dataclass(frozen=True, eq=False)
class FaultRealization:
    """What the fault model says about ONE training step (numpy, host-side).

    alive:         (n,) bool — node participates in this step's gossip.
    update:        (n,) bool — node performs its local optimizer update.
    program_alive: (n,) bool — the slowly-varying *membership* (all ones
        except permanent crashes).  Engines select the degraded program by
        this mask; the per-step ``alive``/``link_up`` ride as runtime
        inputs so transient realizations never change the executable.
    link_up:       optional (n, n) bool, symmetric — per-link liveness.
    rejoin:        nodes re-entering at this step (adopt neighbor average).
    """

    alive: np.ndarray
    update: np.ndarray
    program_alive: np.ndarray
    link_up: Optional[np.ndarray] = None
    rejoin: tuple[int, ...] = ()

    @property
    def faulty(self) -> bool:
        return (
            not self.alive.all()
            or not self.update.all()
            or (self.link_up is not None and not self.link_up.all())
        )

    def membership_key(self) -> tuple:
        """Hashable membership identity (drives controller re-arming)."""
        return tuple(bool(a) for a in self.program_alive)


def _rng(seed: int, step: int, salt: int = 0) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, salt, step]))


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Base: a seeded, step-deterministic fault process over n nodes."""

    n: int
    rate: float
    seed: int = 0
    name: str = "none"

    def __post_init__(self):
        if self.n < 1:
            raise ValueError(f"fault model needs >=1 node, got n={self.n}")
        if not (0.0 <= self.rate <= 1.0):
            raise ValueError(f"fault rate must be in [0, 1], got {self.rate}")

    def _ones(self) -> np.ndarray:
        return np.ones(self.n, dtype=bool)

    def at(self, step: int) -> FaultRealization:  # pragma: no cover - base
        raise NotImplementedError

    def program_masks(self) -> tuple[tuple[bool, ...], ...]:
        """Every membership mask this model can realize beyond all-alive —
        the alive-sets ``Topology.distinct_programs`` pre-enumerates
        degraded programs for (empty for purely transient models)."""
        return ()

    @property
    def has_link_faults(self) -> bool:
        """Whether realizations may carry a per-edge ``link_up`` mask —
        models that never do skip the (n, n) link operand entirely."""
        return False

    def describe(self) -> str:
        return f"{self.name}(n={self.n}, rate={self.rate}, seed={self.seed})"


@dataclasses.dataclass(frozen=True)
class NoFaults(FaultModel):
    name: str = "none"

    def at(self, step: int) -> FaultRealization:
        ones = self._ones()
        return FaultRealization(alive=ones, update=ones, program_alive=ones)


@dataclasses.dataclass(frozen=True)
class PermanentCrash(FaultModel):
    """One seeded victim crashes at a seeded step (single-node-out).

    The victim and crash step derive from the seed: the crash step is a
    geometric draw with parameter ``rate`` (expected onset ~1/rate steps).
    ``down_steps`` (elastic membership) brings the victim back after that
    many dead steps — it rejoins by adopting its neighbors' average.
    Exactly one node is ever out at a time, so the degraded-program set the
    engines must cache is bounded by one extra program per base program.
    """

    name: str = "crash"
    down_steps: Optional[int] = None

    def __post_init__(self):
        super().__post_init__()
        if self.down_steps is not None and int(self.down_steps) < 1:
            # 0 would fire a rejoin for a node that never went down
            # (neighbor-average overwrites healthy state); negative values
            # would silently empty the crash window
            raise ValueError(
                f"down_steps must be >= 1, got {self.down_steps}"
            )
        r = _rng(self.seed, 0, salt=101)
        victim = int(r.integers(self.n))
        # first success of a Bernoulli(rate) sequence; rate 0 => never
        crash_step = int(r.geometric(self.rate)) if self.rate > 0 else None
        object.__setattr__(self, "_victim", victim)
        object.__setattr__(self, "_crash_step", crash_step)

    @property
    def victim(self) -> int:
        return self._victim

    @property
    def crash_step(self) -> Optional[int]:
        return self._crash_step

    @property
    def rejoin_step(self) -> Optional[int]:
        if self._crash_step is None or self.down_steps is None:
            return None
        return self._crash_step + int(self.down_steps)

    def at(self, step: int) -> FaultRealization:
        ones = self._ones()
        c, r = self._crash_step, self.rejoin_step
        down = c is not None and c <= step and (r is None or step < r)
        if not down:
            return FaultRealization(
                alive=ones, update=ones, program_alive=ones,
                rejoin=(self._victim,) if (r is not None and step == r) else (),
            )
        alive = ones.copy()
        alive[self._victim] = False
        return FaultRealization(
            alive=alive, update=alive.copy(), program_alive=alive.copy()
        )

    def program_masks(self):
        if self._crash_step is None:
            return ()
        mask = [True] * self.n
        mask[self._victim] = False
        return (tuple(mask),)


@dataclasses.dataclass(frozen=True)
class TransientDropout(FaultModel):
    """Per-step i.i.d. node dropout: skips gossip, keeps the local update."""

    name: str = "dropout"

    def at(self, step: int) -> FaultRealization:
        ones = self._ones()
        drop = _rng(self.seed, step, salt=1).random(self.n) < self.rate
        if drop.all():  # keep at least one node in the round
            drop[int(_rng(self.seed, step, salt=2).integers(self.n))] = False
        return FaultRealization(alive=~drop, update=ones, program_alive=ones)


@dataclasses.dataclass(frozen=True)
class LinkFailure(FaultModel):
    """Per-step i.i.d. symmetric link failures (both directions die)."""

    name: str = "link"

    @property
    def has_link_faults(self) -> bool:
        return True

    def at(self, step: int) -> FaultRealization:
        ones = self._ones()
        u = _rng(self.seed, step, salt=3).random((self.n, self.n))
        up = np.triu(u >= self.rate, k=1)
        link_up = up | up.T
        np.fill_diagonal(link_up, True)
        return FaultRealization(
            alive=ones, update=ones.copy(), program_alive=ones.copy(),
            link_up=link_up,
        )


@dataclasses.dataclass(frozen=True)
class Straggler(FaultModel):
    """Per-step stragglers: skip the local update but still mix."""

    name: str = "straggler"

    def at(self, step: int) -> FaultRealization:
        ones = self._ones()
        slow = _rng(self.seed, step, salt=4).random(self.n) < self.rate
        return FaultRealization(
            alive=ones, update=~slow, program_alive=ones.copy()
        )


FAULT_MODELS = ("none", "crash", "dropout", "link", "straggler")


def make_fault_model(
    kind: str,
    n: int,
    *,
    rate: float = 0.1,
    seed: int = 0,
    down_steps: Optional[int] = None,
) -> Optional[FaultModel]:
    """Factory: ``make_fault_model("dropout", 16, rate=0.05, seed=3)``.

    ``kind="none"`` (or rate 0 for transient models) returns ``None`` so
    engines keep their exact fault-free hot path.
    """
    if kind in (None, "none"):
        return None
    if kind == "crash":
        m = PermanentCrash(n=n, rate=rate, seed=seed, down_steps=down_steps)
        # rate 0 => crash_step None: the model can never realize a fault;
        # keep the documented contract that engines stay on the exact
        # fault-free hot path instead of paying the mask plumbing for nothing
        return m if m.crash_step is not None else None
    if down_steps is not None:
        raise ValueError("down_steps is a crash (permanent-fault) option")
    if rate == 0.0:
        return None
    if kind == "dropout":
        return TransientDropout(n=n, rate=rate, seed=seed)
    if kind == "link":
        return LinkFailure(n=n, rate=rate, seed=seed)
    if kind == "straggler":
        return Straggler(n=n, rate=rate, seed=seed)
    raise ValueError(f"unknown fault model {kind!r}; one of {FAULT_MODELS}")


def fold_degraded_programs(programs, fault_model: FaultModel):
    """(base, degraded) pairs for every membership mask the model can
    realize over the given base programs, deduped against the bases and
    each other by cache key.

    The single enumeration used by both ``Topology.distinct_programs`` and
    ``SPMDTrainer.precompile_programs`` — crash semantics (e.g. a future
    multi-node mask set) must change in exactly one place or the trainer's
    precompiled set drifts from the Topology's asserted cache bound.
    """
    programs = list(programs)
    seen = {p.cache_key for p in programs}
    out = []
    for mask in fault_model.program_masks():
        for p in programs:
            d = p.degrade(mask)
            if d.cache_key not in seen:
                seen.add(d.cache_key)
                out.append((p, d))
    return out


# ---------------------------------------------------------------------------
# Elastic rejoin
# ---------------------------------------------------------------------------

def rejoin_neighbors(topology, fr: FaultRealization, node: int, *,
                     step: int, epoch: int, mix_every: int = 1) -> list[int]:
    """The alive peers a recovering node averages over: its neighborhood in
    the graph in force at the rejoin step (every alive node for the
    centralized/no-graph case).  Shared by both engines — the rejoin
    semantics must stay in lockstep or the engine-equivalence guarantee
    breaks."""
    graph = topology.graph_at(epoch, step // max(int(mix_every), 1))
    if graph is None:
        return [i for i in range(len(fr.alive)) if fr.alive[i] and i != node]
    return [i for i in graph.neighbors(node) if fr.alive[i] and i != node]


def track_membership(last, fr: FaultRealization, controller, step: int):
    """Fold one step's realization into the engine's membership tracking.

    Returns the new membership key; on a change after the first step it
    re-arms the consensus controller's phase reference (a crash/rejoin
    spikes Ξ — comparing it against the pre-fault peak would ratchet the
    ladder on a stale reference).  Shared by both engines.
    """
    membership = fr.membership_key()
    if membership != last and last is not None and controller is not None:
        controller.rearm(step)
    return membership


def adopt_neighbor_average(stacked: PyTree, node: int, neighbors) -> PyTree:
    """Elastic re-entry: ``node`` adopts the average of ``neighbors``.

    ``stacked`` carries a leading (n, ...) node axis (both engines' global
    state).  The recovered node's stale parameters (and optimizer state)
    are replaced by the mean of its alive neighbors' values — the gossip
    average it would have converged to had it kept mixing; with no alive
    neighbor it keeps its own values.  Rejoins are rare membership events,
    executed eagerly: they never enter the step-executable cache.
    """
    nbrs = [int(i) for i in neighbors]
    if not nbrs:
        return stacked
    idx = jnp.asarray(nbrs)

    def _adopt(x):
        mean = jnp.mean(
            jnp.take(x, idx, axis=0).astype(jnp.float32), axis=0
        ).astype(x.dtype)
        return x.at[node].set(mean)

    return jax.tree.map(_adopt, stacked)


def realization_arrays(fr: FaultRealization) -> dict:
    """The runtime-mask pytree the jitted fault-aware step consumes.

    Fixed structure per fault model — every realization maps to the same
    executable signature.  Models that never produce link faults carry
    ``"link": None`` (an empty pytree subtree): the O(n²) all-ones matrix
    would otherwise be rebuilt, transferred, and multiplied through on
    every step of the hot path for nothing.
    """
    return {
        "update": jnp.asarray(fr.update, jnp.float32),
        "alive": jnp.asarray(fr.alive, jnp.float32),
        "link": (
            None if fr.link_up is None
            else jnp.asarray(fr.link_up.astype(np.float32))
        ),
    }
