"""Deterministic synthetic LM data with per-node disjoint shards.

Decentralized data parallelism requires each gossip node to see a *different*
shard of the stream (paper §2.1: "each accelerator processes a different
shard of training data").  The generator is seeded per (node, step) so runs
are exactly reproducible across engines (sim vs SPMD) and across restarts —
checkpoint resume replays from the step counter, no iterator state needed.

The token stream is a learnable-structure Markov-ish source (next token =
affine function of current + noise) so that training loss decreases
meaningfully — pure-uniform tokens would make convergence benchmarks
degenerate.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLM", "node_batch_iterator"]


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Synthetic language-model token source."""

    vocab: int
    seq_len: int
    seed: int = 0
    structure: float = 0.85  # P(next token follows the deterministic rule)

    def _rng(self, node: int, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, node, step])
        )

    def sample(self, node: int, step: int, batch: int) -> dict[str, np.ndarray]:
        """One (tokens, targets) batch for a node at a step.

        targets[t] = tokens[t+1]; last position masked with -1.
        """
        rng = self._rng(node, step)
        s = self.seq_len
        toks = np.empty((batch, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch)
        mult = 6364136223846793005 % self.vocab
        for t in range(s):
            follow = rng.random(batch) < self.structure
            nxt = (toks[:, t] * mult + 12345) % self.vocab
            rand = rng.integers(0, self.vocab, batch)
            toks[:, t + 1] = np.where(follow, nxt, rand)
        tokens = toks[:, :-1]
        targets = toks[:, 1:].copy()
        targets[:, -1] = -1
        return {"tokens": tokens, "targets": targets}

    def stacked(self, n_nodes: int, step: int, per_node_batch: int) -> dict[str, np.ndarray]:
        """Disjoint shards for all nodes, stacked (n_nodes, B, S)."""
        outs = [self.sample(i, step, per_node_batch) for i in range(n_nodes)]
        return {k: np.stack([o[k] for o in outs]) for k in outs[0]}


def node_batch_iterator(
    source: SyntheticLM,
    n_nodes: int,
    per_node_batch: int,
    *,
    start_step: int = 0,
    extra: Optional[dict] = None,
) -> Iterator[dict]:
    """Infinite iterator of stacked per-node batches (jnp arrays)."""
    step = start_step
    while True:
        b = source.stacked(n_nodes, step, per_node_batch)
        out = {k: jnp.asarray(v) for k, v in b.items()}
        if extra:
            out.update(extra)
        yield out
        step += 1
