from repro.data.synthetic import SyntheticLM, node_batch_iterator
