"""Compatibility shim for older jax (this container ships 0.4.37).

The codebase targets the modern jax API (``jax.set_mesh``, ``jax.shard_map``
with explicit ``axis_names`` leaving the rest of the mesh automatic,
``jax.sharding.AxisType``).  On jax 0.4.37 none of those exist, and the
partial-manual ``shard_map`` (``auto=`` nonempty) fatally crashes XLA:CPU's
SPMD partitioner (``Check failed: IsManualSubgroup``) — the crash cannot be
caught from Python.  Every mesh / shard_map call site therefore routes
through this module:

  * ``HAS_MANUAL_AXES_API``  — True on modern jax.  When False, callers that
    need a *partial*-manual shard_map (manual gossip axes + auto model axis)
    must use a different realization; ``SPMDTrainer`` switches to the stacked
    GSPMD engine (vmap over the gossip axis + the ``GossipProgram`` stacked
    interpreter, whose rolls/gathers XLA lowers to collective-permutes on a
    sharded axis).
  * ``shard_map``            — full-manual (auto = ∅) lowering on old jax via
    ``jax.experimental.shard_map``; safe when the mesh has only gossip axes.
  * ``set_mesh``             — context manager; ``jax.set_mesh`` on modern
    jax, the plain ``with mesh:`` context on old jax.
  * ``make_mesh``            — drops the ``axis_types`` kwarg on old jax.
  * ``axis_size``            — ``jax.lax.axis_size`` or a psum(1) fallback.
  * ``cost_analysis``        — normalizes the per-device list old jax returns.

Old jax also defaults ``jax_threefry_partitionable=False``, which makes
random values under ``jit(..., out_shardings=...)`` differ from eager for
model-sharded leaves (breaking engine == simulator equivalence); importing
this module flips the flag on old jax.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Sequence

import jax

__all__ = [
    "HAS_MANUAL_AXES_API",
    "make_mesh",
    "set_mesh",
    "shard_map",
    "axis_size",
    "cost_analysis",
]

#: Modern jax exposes AxisType + jax.shard_map and supports partial-manual
#: shard_map (auto axes).  0.4.37 has neither.
HAS_MANUAL_AXES_API = hasattr(jax.sharding, "AxisType") and hasattr(jax, "shard_map")

if not HAS_MANUAL_AXES_API:
    # Equivalence-critical on old jax: without partitionable threefry, RNG
    # under jit+out_shardings diverges from eager for sharded leaves.
    try:
        jax.config.update("jax_threefry_partitionable", True)
    except Exception:  # pragma: no cover - flag removed on some versions
        pass


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with explicit Auto axis types where supported."""
    if HAS_MANUAL_AXES_API:
        return jax.make_mesh(
            tuple(shape),
            tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    return jax.make_mesh(tuple(shape), tuple(axes))


def set_mesh(mesh: jax.sharding.Mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    # Old jax: Mesh is itself a context manager (the pjit mesh context);
    # NamedSharding-carrying jits do not strictly need it, but sharding
    # constraints inside traced code do.
    return mesh


def shard_map(
    f: Callable,
    *,
    mesh: jax.sharding.Mesh,
    in_specs: Any,
    out_specs: Any,
    axis_names: set | frozenset | None = None,
    check_vma: bool = False,
) -> Callable:
    """``jax.shard_map`` on modern jax; full-manual fallback on old jax.

    On old jax the fallback lowers *all* mesh axes manual (auto = ∅) — only
    call it when every mesh axis is a gossip axis (e.g. a 1-D mixing mesh).
    Callers needing manual-gossip × auto-model must branch on
    ``HAS_MANUAL_AXES_API`` instead (see ``SPMDTrainer``).
    """
    if HAS_MANUAL_AXES_API:
        return jax.shard_map(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            axis_names=axis_names if axis_names is not None else set(mesh.axis_names),
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    if axis_names is not None and set(axis_names) != set(mesh.axis_names):
        raise NotImplementedError(
            "partial-manual shard_map is unavailable on jax "
            f"{jax.__version__}: manual axes {set(axis_names)} != mesh axes "
            f"{set(mesh.axis_names)} (it would crash the XLA:CPU partitioner). "
            "Use the stacked GSPMD realization instead."
        )
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def axis_size(axis_name) -> int:
    """Size of a mapped axis inside shard_map/vmap."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    import jax.numpy as jnp

    return jax.lax.psum(jnp.ones((), jnp.int32), axis_name)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a dict on every jax version.

    Old jax returns a per-device *list* of dicts; new jax returns one dict.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})
