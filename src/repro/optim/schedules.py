"""Learning-rate schedules and graph-aware scaling policies (paper Table 2).

The paper's central LR finding (Observation 3): the *linear* batch-size
scaling convention breaks decentralized training earlier than centralized —
*square-root* scaling rescues convergence at large scale (tuned_* runs,
§3.2).  Both policies are first-class here, parameterized by the
communication-graph degree exactly as Table 2 does:

    linear:  s = global_batch * (k + 1) / base_batch
    sqrt:    s = sqrt(global_batch * (k + 1) / base_batch)

where k is the node degree of the graph in force (k = n-1 for complete /
centralized).  Schedules are pure ``step -> lr`` callables (float step ok).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

__all__ = [
    "lr_scale",
    "warmup_multistep",
    "one_cycle",
    "constant",
    "Schedule",
]

Schedule = Callable[[float], float]


def lr_scale(
    policy: str,
    *,
    global_batch: int,
    base_batch: int = 256,
    graph_degree: int = 0,
) -> float:
    """Table 2 scaling factor ``s`` (linear or sqrt; Obs. 3)."""
    s = global_batch * (graph_degree + 1) / base_batch
    if policy == "linear":
        return s
    if policy == "sqrt":
        return math.sqrt(s)
    if policy == "none":
        return 1.0
    raise ValueError(f"unknown lr scaling policy {policy!r}")


def constant(lr: float) -> Schedule:
    return lambda step: lr


def warmup_multistep(
    base_lr: float,
    steps_per_epoch: int,
    warmup_epochs: float = 5,
    milestones: Sequence[float] = (30, 60, 80),
    decay: float = 0.1,
    scale: float = 1.0,
) -> Schedule:
    """Warmup + multi-step decay (the paper's ResNet50/LSTM recipe)."""
    peak = base_lr * scale
    warm = warmup_epochs * steps_per_epoch

    def f(step: float) -> float:
        if warm > 0 and step < warm:
            return peak * (step + 1) / warm
        epoch = step / steps_per_epoch
        mult = 1.0
        for m in milestones:
            if epoch >= m:
                mult *= decay
        return peak * mult

    return f


def one_cycle(
    base_lr: float,
    steps_per_epoch: int,
    phases: Sequence[tuple[float, float]] = ((1, 23), (23, 46), (46, 300)),
    lrs: Sequence[tuple[float, float]] = ((0.15, 3.0), (3.0, 0.15), (0.15, 0.015)),
    scale: float = 1.0,
) -> Schedule:
    """One-cycle schedule (the paper's ResNet20/DenseNet100 recipe).

    ``phases[i] = (e0, e1)`` epochs map linearly from ``lrs[i][0]*scale`` to
    ``lrs[i][1]*scale`` (the paper applies the graph scale ``s`` to selected
    endpoints; applying it uniformly keeps the shape identical).
    """

    def f(step: float) -> float:
        epoch = step / steps_per_epoch
        for (e0, e1), (l0, l1) in zip(phases, lrs):
            if epoch < e1 or (e0, e1) == tuple(phases[-1]):
                e = min(max(epoch, e0), e1)
                t = 0.0 if e1 == e0 else (e - e0) / (e1 - e0)
                return (l0 + (l1 - l0) * t) * scale
        l_last = lrs[-1][1] * scale
        return l_last

    return f
