"""Optimizers (self-contained, optax-style init/update pairs).

The paper's experiments use SGD with momentum; we additionally provide AdamW
and LARS (the paper proposes LARS-in-decentralized as future work — included
here as a beyond-paper feature).

All optimizers are pure pytree transforms usable per-node under
vmap (simulator) or shard_map (SPMD engine): state lives alongside params
with the same leading gossip axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = ["Optimizer", "sgd", "adamw", "lars"]


class Optimizer(NamedTuple):
    """init(params) -> state; update(grads, state, params, lr) -> (new_params, new_state)."""

    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jax.Array], tuple[PyTree, PyTree]]
    name: str
    state_specs: Callable[[PyTree], PyTree] = lambda param_specs: ()
    """Maps a logical param-spec tree to the optimizer-state spec tree
    (used by the launcher to shard optimizer state like its parameters)."""
    hyper: Any = None
    """Introspectable hyperparameters (``{"kind": ..., ...}``) for engines
    that re-implement the update inside a fused kernel (``fused_apply``)."""


def _zeros_like_f32(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def sgd(momentum: float = 0.9, weight_decay: float = 0.0, nesterov: bool = False) -> Optimizer:
    """SGD + heavy-ball momentum (+ optional decoupled weight decay)."""

    def init(params):
        if momentum == 0.0:
            return ()
        return _zeros_like_f32(params)

    def update(grads, state, params, lr):
        lr = jnp.asarray(lr, jnp.float32)

        def upd(g, m, p):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            if momentum == 0.0:
                step = g
                new_m = m
            else:
                new_m = momentum * m + g
                step = g + momentum * new_m if nesterov else new_m
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), new_m

        if momentum == 0.0:
            new = jax.tree.map(lambda g, p: upd(g, None, p)[0], grads, params)
            return new, state
        flat = jax.tree.map(upd, grads, state, params)
        new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, new_state

    state_specs = (lambda ps: ()) if momentum == 0.0 else (lambda ps: ps)
    return Optimizer(
        init, update, f"sgd(m={momentum},wd={weight_decay})", state_specs,
        hyper={
            "kind": "sgd", "momentum": momentum,
            "weight_decay": weight_decay, "nesterov": nesterov,
        },
    )


def adamw(
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    """AdamW with decoupled weight decay."""

    def init(params):
        return {
            "mu": _zeros_like_f32(params),
            "nu": _zeros_like_f32(params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        t = state["t"] + 1
        lr = jnp.asarray(lr, jnp.float32)
        c1 = 1.0 - b1 ** t.astype(jnp.float32)
        c2 = 1.0 - b2 ** t.astype(jnp.float32)

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            step = (mu / c1) / (jnp.sqrt(nu / c2) + eps)
            p32 = p.astype(jnp.float32)
            if weight_decay:
                step = step + weight_decay * p32
            return (p32 - lr * step).astype(p.dtype), mu, nu

        flat = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        is3 = lambda x: isinstance(x, tuple)
        return (
            jax.tree.map(lambda t_: t_[0], flat, is_leaf=is3),
            {
                "mu": jax.tree.map(lambda t_: t_[1], flat, is_leaf=is3),
                "nu": jax.tree.map(lambda t_: t_[2], flat, is_leaf=is3),
                "t": t,
            },
        )

    return Optimizer(
        init, update, f"adamw(b1={b1},b2={b2},wd={weight_decay})",
        lambda ps: {"mu": ps, "nu": ps, "t": ()},
    )


def lars(
    momentum: float = 0.9,
    weight_decay: float = 1e-4,
    trust_coefficient: float = 0.001,
    eps: float = 1e-9,
) -> Optimizer:
    """Layer-wise Adaptive Rate Scaling (You et al., 2017).

    The paper flags LARS-in-decentralized-training as future work (§4.2) —
    provided here so the large-batch generalization gap at 16K global batch
    can be attacked directly.
    """

    def init(params):
        return _zeros_like_f32(params)

    def update(grads, state, params, lr):
        lr = jnp.asarray(lr, jnp.float32)

        def upd(g, m, p):
            g = g.astype(jnp.float32)
            p32 = p.astype(jnp.float32)
            g = g + weight_decay * p32
            p_norm = jnp.linalg.norm(p32)
            g_norm = jnp.linalg.norm(g)
            trust = jnp.where(
                (p_norm > 0) & (g_norm > 0),
                trust_coefficient * p_norm / (g_norm + eps),
                1.0,
            )
            new_m = momentum * m + trust * g
            return (p32 - lr * new_m).astype(p.dtype), new_m

        flat = jax.tree.map(upd, grads, state, params)
        is2 = lambda x: isinstance(x, tuple)
        return (
            jax.tree.map(lambda t: t[0], flat, is_leaf=is2),
            jax.tree.map(lambda t: t[1], flat, is_leaf=is2),
        )

    return Optimizer(init, update, f"lars(m={momentum},wd={weight_decay})", lambda ps: ps)


def get_optimizer(name: str, **kw) -> Optimizer:
    try:
        return {"sgd": sgd, "adamw": adamw, "lars": lars}[name](**kw)
    except KeyError:
        raise ValueError(f"unknown optimizer {name!r}") from None
