from repro.optim.sgd import Optimizer, adamw, get_optimizer, lars, sgd
from repro.optim.schedules import constant, lr_scale, one_cycle, warmup_multistep
