"""Mamba2 (SSD) mixer block — the recurrent half of Zamba2 (arXiv:2411.15242).

Structure: RMSNorm → [z | x | B | C | dt] projections → short causal
depthwise conv on x → SSD recurrence (scalar-per-head decay) → gated RMSNorm
→ out projection, with residual.  n_groups = 1 (B/C shared across heads).
The reference Mamba2 also convolves B and C; we convolve x only (B/C are
N=64-dim — negligible compute; noted in DESIGN.md).

Decode state: (h (B, H, P, N), conv tail (B, K-1, d_inner)).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import (
    ParamDef,
    he_normal,
    normal_init,
    ones_init,
    rms_norm,
    zeros_init,
)
from repro.models.recurrence import ssd_chunked, ssd_step

__all__ = ["mamba_block_defs", "apply_mamba_block", "mamba_block_decode", "MambaState"]

_CONV_K = 4
_HEAD_P = 64  # channels per SSD head


class MambaState(NamedTuple):
    h: jax.Array     # (B, H, P, N) float32
    conv: jax.Array  # (B, K-1, d_inner)

    @classmethod
    def empty(cls, batch, n_heads, d_state, d_inner, dtype=jnp.float32):
        return cls(
            h=jnp.zeros((batch, n_heads, _HEAD_P, d_state), jnp.float32),
            conv=jnp.zeros((batch, _CONV_K - 1, d_inner), dtype),
        )


def mamba_n_heads(d_model: int, expand: int = 2) -> int:
    return d_model * expand // _HEAD_P


def mamba_block_defs(d_model: int, d_state: int, *, expand: int = 2, dtype=jnp.float32):
    d_inner = d_model * expand
    h = d_inner // _HEAD_P

    def a_init(key, shape, _dtype):
        return jnp.log(jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)).astype(_dtype)

    return {
        "norm_g": ParamDef((d_model,), ones_init(), (None,), dtype),
        "w_z": ParamDef((d_model, d_inner), he_normal((-2,)), (None, "model"), dtype),
        "w_x": ParamDef((d_model, d_inner), he_normal((-2,)), (None, "model"), dtype),
        "w_b": ParamDef((d_model, d_state), he_normal((-2,)), (None, None), dtype),
        "w_c": ParamDef((d_model, d_state), he_normal((-2,)), (None, None), dtype),
        "w_dt": ParamDef((d_model, h), he_normal((-2,)), (None, None), dtype),
        "dt_bias": ParamDef((h,), zeros_init(), (None,), dtype),
        "conv_w": ParamDef((_CONV_K, d_inner), normal_init(0.2), (None, "model"), dtype),
        "conv_b": ParamDef((d_inner,), zeros_init(), ("model",), dtype),
        "a_log": ParamDef((h,), a_init, (None,), jnp.float32),
        "d_skip": ParamDef((h,), ones_init(), (None,), jnp.float32),
        "gn_g": ParamDef((d_inner,), ones_init(), ("model",), dtype),
        "w_out": ParamDef((d_inner, d_model), he_normal((-2,)), ("model", None), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, tail: jax.Array):
    """Depthwise causal conv, kernel K, via shifts.

    x: (B, S, C); w: (K, C); tail: (B, K-1, C) — inputs preceding x.
    Returns (y (B, S, C), new_tail (B, K-1, C)).
    """
    k = w.shape[0]
    ext = jnp.concatenate([tail, x], axis=1)  # (B, S+K-1, C)
    s = x.shape[1]
    y = sum(ext[:, i : i + s] * w[i] for i in range(k)) + b
    return y, ext[:, -(k - 1) :] if k > 1 else tail


def apply_mamba_block(
    params, x: jax.Array, state: MambaState, *, d_state: int, chunk: int = 64
) -> tuple[jax.Array, MambaState]:
    """x: (B, S, D) residual stream."""
    bsz, s, d = x.shape
    xn = rms_norm(x, params["norm_g"])

    z = xn @ params["w_z"]                      # (B, S, d_inner)
    xi = xn @ params["w_x"]
    b_in = xn @ params["w_b"]                   # (B, S, N)
    c_in = xn @ params["w_c"]
    dt = jax.nn.softplus(xn @ params["w_dt"] + params["dt_bias"])  # (B, S, H)

    xi, conv_tail = _causal_conv(xi, params["conv_w"], params["conv_b"], state.conv)
    xi = jax.nn.silu(xi)

    h_heads = xi.shape[-1] // _HEAD_P
    xh = xi.reshape(bsz, s, h_heads, _HEAD_P)
    y, h_new = ssd_chunked(
        xh, dt, params["a_log"], b_in, c_in, params["d_skip"], state.h, chunk=chunk
    )
    y = y.reshape(bsz, s, -1)
    y = rms_norm(y * jax.nn.silu(z), params["gn_g"])
    out = x + y @ params["w_out"]
    return out, MambaState(h=h_new, conv=conv_tail)


def mamba_block_decode(
    params, x: jax.Array, state: MambaState, *, d_state: int
) -> tuple[jax.Array, MambaState]:
    """Single-token step. x: (B, D)."""
    bsz, d = x.shape
    xn = rms_norm(x[:, None], params["norm_g"])[:, 0]

    z = xn @ params["w_z"]
    xi = xn @ params["w_x"]
    b_in = xn @ params["w_b"]
    c_in = xn @ params["w_c"]
    dt = jax.nn.softplus(xn @ params["w_dt"] + params["dt_bias"])

    xi1, new_tail = _causal_conv(
        xi[:, None], params["conv_w"], params["conv_b"], state.conv
    )
    xi1 = jax.nn.silu(xi1[:, 0])

    h_heads = xi1.shape[-1] // _HEAD_P
    xh = xi1.reshape(bsz, h_heads, _HEAD_P)
    y, h_new = ssd_step(
        xh, dt, params["a_log"], b_in, c_in, params["d_skip"], state.h
    )
    y = y.reshape(bsz, -1)
    y = rms_norm((y * jax.nn.silu(z))[:, None], params["gn_g"])[:, 0]
    out = x + y @ params["w_out"]
    return out, MambaState(h=h_new, conv=new_tail)
