"""GQA attention: reference, chunked (flash-style XLA), and decode paths.

Implementations (selected by ``impl``):
  * "reference" — full (B, H, Q, S) score materialization.  Oracle + small-S.
  * "chunked"   — online-softmax over KV chunks via ``lax.scan`` (the flash
    algorithm expressed in XLA): O(chunk) score memory, CPU-compilable.
    Used for the 32k shapes in the dry-run.
  * the Pallas TPU kernel lives in ``repro.kernels.flash_attention`` and is
    selected by the launcher on TPU backends (``cfg.attn_impl = "pallas"``).

Supports causal masking, sliding windows (the long-context carve-in for
full-attention archs on ``long_500k``), GQA head grouping, and single-token
decode against a (optionally ring-buffered) KV cache.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "multihead_attention",
    "decode_attention",
    "KVCache",
    "head_padding",
]

_NEG_INF = -1e30


def head_padding(
    n_heads: int, n_kv: int, tp: int, *, pad_kv: bool = False
) -> tuple[int, int, int]:
    """Grouped head padding so heads shard on a ``tp``-way model axis.

    Returns (h_pad, kv_pad, group_pad) with h_pad = kv_pad * group_pad.
    Semantics stay exact: query head ``h`` maps to kv head ``h // group_pad``;
    a head is *active* iff its kv index is an original kv head AND its
    within-group index is below the original group size — padded heads are
    masked out of the output, so forward values and gradients of the original
    parameters are untouched.

      * default: grow the per-group size until kv * g_pad % tp == 0
        (q heads shard; kv stays as-is).
      * pad_kv: additionally pad kv itself to a multiple of tp (so KV caches
        shard on the kv-head dim — the decode-path fix).
    """
    group = n_heads // max(n_kv, 1)
    kv_pad = n_kv
    if pad_kv and n_kv % tp:
        kv_pad = -(-n_kv // tp) * tp
    g_pad = group
    while (kv_pad * g_pad) % tp:
        g_pad += 1
    return kv_pad * g_pad, kv_pad, g_pad


def active_head_mask(n_heads: int, n_kv: int, h_pad: int, kv_pad: int, g_pad: int):
    """(h_pad,) bool — True for original heads under the padded grouping."""
    group = n_heads // max(n_kv, 1)
    idx = jnp.arange(h_pad)
    return ((idx // g_pad) < n_kv) & ((idx % g_pad) < group)


def _split_gqa(q: jax.Array, n_kv: int) -> jax.Array:
    """(B, Q, H, D) -> (B, Q, KV, G, D)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def _mask(
    q_pos: jax.Array,
    k_pos: jax.Array,
    causal: bool,
    window: Optional[int],
    k_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """Boolean (..., Q, S) mask of allowed attention pairs."""
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > qp - window
    if k_valid is not None:
        m &= k_valid[..., None, :]
    return m


def multihead_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_positions: jax.Array,
    k_positions: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    k_valid: Optional[jax.Array] = None,
    impl: str = "reference",
    chunk_size: int = 1024,
) -> jax.Array:
    """GQA attention.

    Args:
      q: (B, Q, H, D); k/v: (B, S, KV, D) with H % KV == 0.
      q_positions/k_positions: (B, Q) / (B, S) absolute positions (drive the
        causal/window masks; RoPE is applied by the caller).
      k_valid: optional (B, S) validity mask (cache slots in use).
    Returns:
      (B, Q, H, D).
    """
    b, sq, h, d = q.shape
    n_kv = k.shape[2]
    scale = d ** -0.5
    qg = _split_gqa(q, n_kv) * scale  # (B, Q, KV, G, D)

    if impl == "reference":
        scores = jnp.einsum(
            "bqhgd,bshd->bhgqs", qg.astype(jnp.float32), k.astype(jnp.float32)
        )
        m = _mask(q_positions, k_positions, causal, window, k_valid)
        scores = jnp.where(m[:, None, None], scores, _NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhgqs,bshd->bqhgd", p.astype(v.dtype), v)
        return out.reshape(b, sq, h, d)

    if impl == "chunked":
        return _chunked_attention(
            qg, k, v, q_positions, k_positions, causal, window, k_valid, chunk_size
        ).reshape(b, sq, h, d)

    if impl == "chunked_skip":
        # Causal block skipping: q processed in blocks, each attending only
        # to its kv prefix (and, with a window, only the kv suffix in range).
        # Cuts the full-S² chunked compute to ~S²/2 (less with windows).
        # Assumes aligned, monotone positions (training/prefill layout).
        s = k.shape[1]
        qb = max(chunk_size, 1)
        nq = -(-sq // qb)
        outs = []
        for i in range(nq):
            q_sl = qg[:, i * qb : (i + 1) * qb]
            qp = q_positions[:, i * qb : (i + 1) * qb]
            hi = min((i + 1) * qb, s) if causal else s
            lo = max(0, i * qb - (window or 0)) if window is not None else 0
            outs.append(
                _chunked_attention(
                    q_sl,
                    k[:, lo:hi],
                    v[:, lo:hi],
                    qp,
                    k_positions[:, lo:hi],
                    causal,
                    window,
                    None if k_valid is None else k_valid[:, lo:hi],
                    chunk_size,
                )
            )
        return jnp.concatenate(outs, axis=1).reshape(b, sq, h, d)

    raise ValueError(f"unknown attention impl {impl!r}")


def _chunked_attention(
    qg, k, v, q_pos, k_pos, causal, window, k_valid, chunk: int
) -> jax.Array:
    """Online-softmax (flash) over KV chunks; O(Q * chunk) score memory."""
    b, sq, n_kv, g, d = qg.shape
    s = k.shape[1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        pad_valid = jnp.pad(
            jnp.ones((b, s), bool) if k_valid is None else k_valid,
            ((0, 0), (0, pad)),
        )
        k_valid = pad_valid
    n_chunks = k.shape[1] // chunk

    kc = k.reshape(b, n_chunks, chunk, n_kv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, n_kv, d).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    valc = (
        k_valid.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
        if k_valid is not None
        else jnp.ones((n_chunks, b, chunk), bool)
    )

    qf = qg.astype(jnp.float32)
    m0 = jnp.full((b, n_kv, g, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_kv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, sq, n_kv, g, d), jnp.float32)

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        kb, vb, pb, valb = inp
        scores = jnp.einsum("bqhgd,bshd->bhgqs", qf, kb.astype(jnp.float32))
        msk = _mask(q_pos, pb, causal, window, valb)  # (B, Q, C)
        scores = jnp.where(msk[:, None, None], scores, _NEG_INF)
        m_new = jnp.maximum(m_prev, scores.max(axis=-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(m_new <= _NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(scores - m_safe[..., None])
        p = jnp.where(msk[:, None, None], p, 0.0)
        corr = jnp.exp(
            jnp.where(m_prev <= _NEG_INF / 2, _NEG_INF, m_prev) - m_safe
        )
        corr = jnp.where(m_prev <= _NEG_INF / 2, 0.0, corr)
        l_new = l_prev * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgqs,bshd->bqhgd", p, vb.astype(jnp.float32))
        acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kc, vc, pc, valc))
    l = jnp.where(l == 0.0, 1.0, l)
    out = acc / l.transpose(0, 3, 1, 2)[..., None]
    return out.astype(v.dtype)


# ---------------------------------------------------------------------------
# KV cache & decode
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    """Per-layer KV cache.

    k/v: (L, B, S_slots, KV, D).  For sliding-window archs ``S_slots`` is the
    window and slots are a ring buffer indexed by ``pos % window``;
    otherwise ``S_slots == max_seq`` and slot == absolute position.
    ``positions``: (L, B, S_slots) absolute position stored in each slot
    (-1 = empty).  RoPE is applied to K *before* caching.
    """

    k: jax.Array
    v: jax.Array
    positions: jax.Array

    @property
    def n_slots(self) -> int:
        return self.k.shape[2]

    @classmethod
    def empty(cls, n_layers, batch, n_slots, n_kv, d_head, dtype=jnp.bfloat16):
        return cls(
            k=jnp.zeros((n_layers, batch, n_slots, n_kv, d_head), dtype),
            v=jnp.zeros((n_layers, batch, n_slots, n_kv, d_head), dtype),
            positions=jnp.full((n_layers, batch, n_slots), -1, jnp.int32),
        )


def cache_update(
    cache_k: jax.Array,
    cache_v: jax.Array,
    cache_pos: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    pos: jax.Array,
    *,
    ring: bool,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Insert one step (B, 1, KV, D) at absolute position ``pos`` (scalar)."""
    n_slots = cache_k.shape[1]
    slot = jnp.where(ring, pos % n_slots, jnp.minimum(pos, n_slots - 1))
    ck = jax.lax.dynamic_update_slice(cache_k, k_new, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v_new, (0, slot, 0, 0))
    b = cache_pos.shape[0]
    cp = jax.lax.dynamic_update_slice(
        cache_pos, jnp.full((b, 1), pos, jnp.int32), (0, slot)
    )
    return ck, cv, cp


def decode_attention(
    q: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    cache_pos: jax.Array,
    *,
    pos: jax.Array,
    window: Optional[int] = None,
) -> jax.Array:
    """Single-token attention against the cache.

    q: (B, 1, H, D); cache_k/v: (B, S_slots, KV, D); cache_pos: (B, S_slots).
    ``pos``: scalar absolute position of the query token.
    """
    b = q.shape[0]
    q_positions = jnp.full((b, 1), pos, jnp.int32)
    valid = cache_pos >= 0
    if window is not None:
        valid &= cache_pos > pos - window
    return multihead_attention(
        q,
        cache_k,
        cache_v,
        q_positions=q_positions,
        k_positions=jnp.maximum(cache_pos, 0),
        causal=True,
        window=window,
        k_valid=valid,
        impl="reference",
    )
