"""Dense feed-forward blocks (gated SiLU / GELU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, he_normal

__all__ = ["mlp_defs", "apply_mlp"]


def mlp_defs(d_model: int, d_ff: int, *, gated: bool = True, dtype=jnp.float32):
    """Column-parallel up-projections, row-parallel down-projection."""
    defs = {
        "w_up": ParamDef(
            (d_model, d_ff), he_normal((-2,)), (None, "model"), dtype
        ),
        "w_down": ParamDef(
            (d_ff, d_model), he_normal((-2,)), ("model", None), dtype
        ),
    }
    if gated:
        defs["w_gate"] = ParamDef(
            (d_model, d_ff), he_normal((-2,)), (None, "model"), dtype
        )
    return defs


def apply_mlp(params, x: jax.Array, *, act: str = "silu") -> jax.Array:
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    if "w_gate" in params:
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        h = jax.nn.silu(gate) * up if act == "silu" else jax.nn.gelu(gate) * up
    else:
        h = jax.nn.silu(up) if act == "silu" else jax.nn.gelu(up)
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])
