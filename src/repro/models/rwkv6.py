"""RWKV6 ("Finch", arXiv:2404.05892) block: data-dependent decay WKV.

Faithful structure: LayerNormed sublayers, token-shift lerps, LoRA-modulated
data-dependent decay ``w_t = exp(-exp(w0 + lora_w(x̄_t)))``, bonus ``u``,
per-head group norm, SiLU-gated output, squared-ReLU channel mix.  (The full
Finch also LoRA-modulates the token-shift lerp coefficients; we keep static
lerp coefficients there — noted in DESIGN.md — while the decay, Finch's
headline data-dependence, is fully dynamic.)

State per layer: (wkv (B, H, N, N), previous *normed* token for each of the
two token-shifted sublayers).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import (
    ParamDef,
    he_normal,
    layer_norm,
    normal_init,
    ones_init,
    zeros_init,
)
from repro.models.recurrence import rwkv_chunked, rwkv_step

__all__ = ["rwkv_block_defs", "apply_rwkv_block", "rwkv_block_decode", "RWKVState"]

_LORA_RANK = 64


class RWKVState(NamedTuple):
    wkv: jax.Array       # (B, H, N, N)
    shift_tm: jax.Array  # (B, D) previous normed token (time mix)
    shift_cm: jax.Array  # (B, D) previous normed token (channel mix)

    @classmethod
    def empty(cls, batch, n_heads, d_head, d_model, dtype=jnp.float32):
        return cls(
            wkv=jnp.zeros((batch, n_heads, d_head, d_head), jnp.float32),
            shift_tm=jnp.zeros((batch, d_model), dtype),
            shift_cm=jnp.zeros((batch, d_model), dtype),
        )


def rwkv_block_defs(d_model: int, n_heads: int, d_ff: int, dtype=jnp.float32):
    d, h = d_model, n_heads
    n = d // h
    lin = lambda i, o: ParamDef((i, o), he_normal((-2,)), (None, "model"), dtype)
    vec1 = lambda init: ParamDef((d,), init, (None,), dtype)
    return {
        "ln1_g": vec1(ones_init()),
        "ln1_b": vec1(zeros_init()),
        "ln2_g": vec1(ones_init()),
        "ln2_b": vec1(zeros_init()),
        "time_mix": {
            "mu": ParamDef((5, d), normal_init(0.1), (None, None), dtype),
            "w_r": lin(d, d),
            "w_k": lin(d, d),
            "w_v": lin(d, d),
            "w_g": lin(d, d),
            "w_o": ParamDef((d, d), he_normal((-2,)), ("model", None), dtype),
            "decay_w0": vec1(zeros_init()),
            "decay_a": ParamDef((d, _LORA_RANK), normal_init(0.02), (None, None), dtype),
            "decay_b": ParamDef((_LORA_RANK, d), zeros_init(), (None, None), dtype),
            "bonus_u": ParamDef((h, n), normal_init(0.1), (None, None), dtype),
            "gn_g": vec1(ones_init()),
            "gn_b": vec1(zeros_init()),
        },
        "channel_mix": {
            "mu": ParamDef((2, d), normal_init(0.1), (None, None), dtype),
            "w_k": lin(d, d_ff),
            "w_v": ParamDef((d_ff, d), he_normal((-2,)), ("model", None), dtype),
            "w_r": ParamDef((d, d), he_normal((-2,)), (None, None), dtype),
        },
    }


def _shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """Token shift: x̄_t = x_{t-1} (prev fills t=0). x: (B, S, D), prev: (B, D)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _lerp(x, xx, mu):
    return x + (xx - x) * mu


def _decay_logw(tm, xw: jax.Array) -> jax.Array:
    """log w_t = -exp(w0 + lora(x)) < 0; clipped for stability."""
    lora = jnp.tanh(xw @ tm["decay_a"]) @ tm["decay_b"]
    return -jnp.exp(jnp.clip(tm["decay_w0"] + lora, -8.0, 6.0))


def _group_norm(x: jax.Array, n_heads: int, g, b, eps=1e-5) -> jax.Array:
    """Per-head LayerNorm of (B, S, D)."""
    bsz, s, d = x.shape
    xh = x.reshape(bsz, s, n_heads, d // n_heads).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(bsz, s, d) * g + b).astype(x.dtype)


def _time_mix_inputs(tm, x, shifted, n_heads):
    b, s, d = x.shape
    n = d // n_heads
    mu = tm["mu"]
    xr, xk, xv, xg, xw = (_lerp(x, shifted, mu[i]) for i in range(5))
    r = (xr @ tm["w_r"]).reshape(b, s, n_heads, n)
    k = (xk @ tm["w_k"]).reshape(b, s, n_heads, n)
    v = (xv @ tm["w_v"]).reshape(b, s, n_heads, n)
    g = jax.nn.silu(xg @ tm["w_g"])
    logw = _decay_logw(tm, xw).reshape(b, s, n_heads, n)
    return r, k, v, g, logw


def _channel_mix(cm, xn, shifted):
    mu = cm["mu"]
    xk = _lerp(xn, shifted, mu[0])
    xr = _lerp(xn, shifted, mu[1])
    kk = jnp.square(jax.nn.relu(xk @ cm["w_k"]))
    return jax.nn.sigmoid(xr @ cm["w_r"]) * (kk @ cm["w_v"])


def apply_rwkv_block(
    params, x: jax.Array, state: RWKVState, *, n_heads: int, chunk: int = 32
) -> tuple[jax.Array, RWKVState]:
    """Full block (time mix + channel mix, own norms/residuals). x: (B, S, D)."""
    b, s, d = x.shape
    tm, cm = params["time_mix"], params["channel_mix"]

    xn = layer_norm(x, params["ln1_g"], params["ln1_b"])
    shifted = _shift(xn, state.shift_tm)
    r, k, v, g, logw = _time_mix_inputs(tm, xn, shifted, n_heads)
    o, wkv = rwkv_chunked(r, k, v, logw, tm["bonus_u"], state.wkv, chunk=chunk)
    o = _group_norm(o.reshape(b, s, d), n_heads, tm["gn_g"], tm["gn_b"])
    h = x + (o * g) @ tm["w_o"]

    hn = layer_norm(h, params["ln2_g"], params["ln2_b"])
    shifted_c = _shift(hn, state.shift_cm)
    out = h + _channel_mix(cm, hn, shifted_c)

    return out, RWKVState(wkv=wkv, shift_tm=xn[:, -1], shift_cm=hn[:, -1])


def rwkv_block_decode(
    params, x: jax.Array, state: RWKVState, *, n_heads: int
) -> tuple[jax.Array, RWKVState]:
    """Single-token step. x: (B, D)."""
    b, d = x.shape
    tm, cm = params["time_mix"], params["channel_mix"]

    xn = layer_norm(x[:, None], params["ln1_g"], params["ln1_b"])[:, 0]
    r, k, v, g, logw = _time_mix_inputs(
        tm, xn[:, None], state.shift_tm[:, None], n_heads
    )
    o, wkv = rwkv_step(r[:, 0], k[:, 0], v[:, 0], logw[:, 0], tm["bonus_u"], state.wkv)
    o = _group_norm(o.reshape(b, 1, d), n_heads, tm["gn_g"], tm["gn_b"])[:, 0]
    h = x + (o * g[:, 0]) @ tm["w_o"]

    hn = layer_norm(h[:, None], params["ln2_g"], params["ln2_b"])[:, 0]
    out = h + _channel_mix(cm, hn[:, None], state.shift_cm[:, None])[:, 0]

    return out, RWKVState(wkv=wkv, shift_tm=xn, shift_cm=hn)
