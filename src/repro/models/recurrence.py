"""Chunked linear recurrences for the SSM/linear-attention families.

Two exact, numerically-safe chunked algorithms (chunk-parallel within a
chunk, ``lax.scan`` across chunks):

  * ``rwkv_chunked``  — vector (per-channel) decay with bonus term
        S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
        o_t = r_tᵀ (S_{t-1} + diag(u) k_t v_tᵀ)
    (RWKV6 "Finch" WKV recurrence; decay w_t is data-dependent.)

  * ``ssd_chunked``   — scalar-per-head decay (Mamba2 SSD)
        h_t = a_t h_{t-1} + dt_t · x_t B_tᵀ
        y_t = h_t C_t + D ⊙ x_t       (a_t = exp(dt_t A) ∈ (0,1))

Both express intra-chunk interactions with *pairwise relative decays*
``exp(la_t - la_s), s ≤ t`` where ``la = cumsum(log decay)``; since log-decays
are ≤ 0 and s ≤ t, every exponent is ≤ 0 — no overflow at any chunk length
(this is why we don't use the q·exp(la) / k·exp(-la) factorization, which
overflows for strongly-decaying channels).

Single-step ``*_step`` variants drive decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "rwkv_chunked",
    "rwkv_step",
    "rwkv_scan_reference",
    "ssd_chunked",
    "ssd_step",
    "ssd_scan_reference",
]


def _chunk(x: jax.Array, c: int) -> jax.Array:
    """(B, L, ...) -> (n, B, c, ...) — scan-major chunking (L % c == 0)."""
    b, l = x.shape[:2]
    return x.reshape(b, l // c, c, *x.shape[2:]).swapaxes(0, 1)


def _unchunk(x: jax.Array) -> jax.Array:
    """(n, B, c, ...) -> (B, L, ...)."""
    n, b, c = x.shape[:3]
    return x.swapaxes(0, 1).reshape(b, n * c, *x.shape[3:])


# ---------------------------------------------------------------------------
# RWKV6: vector decay + bonus
# ---------------------------------------------------------------------------

def rwkv_chunked(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,
    u: jax.Array,
    s0: jax.Array,
    *,
    chunk: int = 32,
):
    """Args:
      r/k/v: (B, L, H, N); logw: (B, L, H, N) (log decay, ≤ 0);
      u: (H, N) bonus; s0: (B, H, N, N) initial state (k-dim × v-dim).
    Returns: (o (B, L, H, N), s_final).
    """
    b, l, h, n = r.shape
    c = min(chunk, l)
    pad = (-l) % c
    if pad:
        z = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))

    rf = _chunk(r.astype(jnp.float32), c)
    kf = _chunk(k.astype(jnp.float32), c)
    vf = _chunk(v.astype(jnp.float32), c)
    lw = _chunk(logw.astype(jnp.float32), c)
    uf = u.astype(jnp.float32)

    tri_strict = jnp.tril(jnp.ones((c, c), bool), -1)

    def body(s, inp):
        rc, kc, vc, lwc = inp  # (B, c, H, N)
        la = jnp.cumsum(lwc, axis=1)            # inclusive:  la_t = Σ_{j<=t} logw_j
        la_prev = la - lwc                      # exclusive:  Σ_{j<t}
        # pairwise per-channel decay exp(la_prev_t - la_s), strictly lower tri
        dmat = la_prev[:, :, None] - la[:, None, :, :, :]      # (B, t, s, H, N)
        dmat = jnp.where(tri_strict[None, :, :, None, None], dmat, -jnp.inf)
        scores = jnp.einsum("bthn,bshn,btshn->bths", rc, kc, jnp.exp(dmat))
        diag = jnp.einsum("bthn,hn,bthn->bth", rc, uf, kc)
        o = jnp.einsum("bths,bshn->bthn", scores, vc)
        o = o + diag[..., None] * vc
        # inter-chunk: r_t diag(exp(la_prev_t)) S
        o = o + jnp.einsum("bthn,bhnm->bthm", rc * jnp.exp(la_prev), s)
        # state: S' = diag(exp(la_C)) S + Σ_s exp(la_C - la_s) k_s v_sᵀ
        la_end = la[:, -1:]                      # (B, 1, H, N)
        k_scaled = kc * jnp.exp(la_end - la)
        s = jnp.exp(la_end[:, 0])[..., None] * s + jnp.einsum(
            "bshn,bshm->bhnm", k_scaled, vc
        )
        return s, o

    s_final, o = jax.lax.scan(body, s0.astype(jnp.float32), (rf, kf, vf, lw))
    o = _unchunk(o)[:, :l]
    return o.astype(v.dtype), s_final


def rwkv_step(r, k, v, logw, u, s):
    """Single decode step. r/k/v/logw: (B, H, N); s: (B, H, N, N)."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    sf = s.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]            # (B, H, N, N)
    o = jnp.einsum("bhn,bhnm->bhm", rf, sf + u.astype(jnp.float32)[..., None] * kv)
    s_new = jnp.exp(logw.astype(jnp.float32))[..., None] * sf + kv
    return o.astype(v.dtype), s_new


def rwkv_scan_reference(r, k, v, logw, u, s0):
    """Step-by-step oracle (tests)."""

    def body(s, inp):
        rt, kt, vt, wt = inp
        o, s = rwkv_step(rt, kt, vt, wt, u, s)
        return s, o

    xs = tuple(x.swapaxes(0, 1) for x in (r, k, v, logw))
    s, o = jax.lax.scan(body, s0.astype(jnp.float32), xs)
    return o.swapaxes(0, 1), s


# ---------------------------------------------------------------------------
# Mamba2 SSD: scalar-per-head decay
# ---------------------------------------------------------------------------

def ssd_chunked(
    x: jax.Array,
    dt: jax.Array,
    a_log: jax.Array,
    b_in: jax.Array,
    c_in: jax.Array,
    d_skip: jax.Array,
    h0: jax.Array,
    *,
    chunk: int = 64,
):
    """Args:
      x: (B, L, H, P); dt: (B, L, H) (post-softplus, > 0);
      a_log: (H,) (A = -exp(a_log) < 0); b_in/c_in: (B, L, N) (n_groups=1);
      d_skip: (H,); h0: (B, H, P, N).
    Returns: (y (B, L, H, P), h_final).
    """
    b, l, h, p = x.shape
    c = min(chunk, l)
    pad = (-l) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))

    a = -jnp.exp(a_log.astype(jnp.float32))               # (H,)
    xf = _chunk(x.astype(jnp.float32), c)
    dtf = _chunk(dt.astype(jnp.float32), c)
    bf = _chunk(b_in.astype(jnp.float32), c)
    cf = _chunk(c_in.astype(jnp.float32), c)
    tri = jnp.tril(jnp.ones((c, c), bool))

    def body(hst, inp):
        xc, dtc, bc, cc = inp                              # (B,c,H,P), (B,c,H), (B,c,N)
        la = jnp.cumsum(dtc * a, axis=1)                   # (B, c, H), ≤ 0, decreasing
        dmat = la[:, :, None] - la[:, None, :, :]          # (B, t, s, H) ≤ 0 for s<=t
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
        cb = jnp.einsum("btn,bsn->bts", cc, bc)
        scores = cb[..., None] * jnp.exp(dmat) * dtc[:, None]   # (B,t,s,H)
        y = jnp.einsum("btsh,bshp->bthp", scores, xc)
        # inter-chunk: y_t += C_t · exp(la_t) h0   (h: (B,H,P,N))
        y = y + jnp.einsum("btn,bhpn,bth->bthp", cc, hst, jnp.exp(la))
        # state update
        la_end = la[:, -1:]                                # (B,1,H)
        w = jnp.exp(la_end - la) * dtc                     # (B,c,H)
        hst = jnp.exp(la_end[:, 0])[..., None, None] * hst + jnp.einsum(
            "bshp,bsn,bsh->bhpn", xc, bc, w
        )
        return hst, y

    h_final, y = jax.lax.scan(body, h0.astype(jnp.float32), (xf, dtf, bf, cf))
    y = _unchunk(y)[:, :l]
    y = y + d_skip.astype(jnp.float32)[None, None, :, None] * x[:, :l].astype(jnp.float32)
    return y.astype(x.dtype), h_final


def ssd_step(x, dt, a_log, b_in, c_in, d_skip, h):
    """Single decode step. x: (B,H,P); dt: (B,H); b/c: (B,N); h: (B,H,P,N)."""
    xf = x.astype(jnp.float32)
    a = -jnp.exp(a_log.astype(jnp.float32))
    decay = jnp.exp(dt.astype(jnp.float32) * a)            # (B, H)
    h_new = decay[..., None, None] * h.astype(jnp.float32) + jnp.einsum(
        "bhp,bn,bh->bhpn", xf, b_in.astype(jnp.float32), dt.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bn->bhp", h_new, c_in.astype(jnp.float32))
    y = y + d_skip.astype(jnp.float32)[None, :, None] * xf
    return y.astype(x.dtype), h_new


def ssd_scan_reference(x, dt, a_log, b_in, c_in, d_skip, h0):
    def body(h, inp):
        xt, dtt, bt, ct = inp
        y, h = ssd_step(xt, dtt, a_log, bt, ct, d_skip, h)
        return h, y

    xs = (
        x.swapaxes(0, 1),
        dt.swapaxes(0, 1),
        b_in.swapaxes(0, 1),
        c_in.swapaxes(0, 1),
    )
    h, y = jax.lax.scan(body, h0.astype(jnp.float32), xs)
    return y.swapaxes(0, 1), h
