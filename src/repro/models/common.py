"""Shared model machinery: param definitions (+ partition specs), norms, RoPE.

Parameters are declared as trees of ``ParamDef`` (shape, init, logical
partition spec) so that a single declaration produces both the materialized
weights and the mesh shardings used by ``launch/sharding.py``.  Partition
specs here name only the ``model`` axis; the launcher prepends the gossip
axes for the stacked-replica layout.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = [
    "ParamDef",
    "init_params",
    "spec_tree",
    "param_count",
    "rms_norm",
    "layer_norm",
    "rope",
    "apply_rope",
    "he_normal",
    "normal_init",
    "zeros_init",
    "ones_init",
]


# ---------------------------------------------------------------------------
# Param declaration
# ---------------------------------------------------------------------------

Initializer = Callable[[jax.Array, tuple, Any], jax.Array]


def normal_init(stddev: float = 0.02) -> Initializer:
    return lambda key, shape, dtype: stddev * jax.random.normal(key, shape, dtype)


def he_normal(fan_in_axes: tuple[int, ...] = (-2,)) -> Initializer:
    def init(key, shape, dtype):
        fan_in = 1
        for a in fan_in_axes:
            fan_in *= shape[a]
        std = math.sqrt(2.0 / max(fan_in, 1))
        return std * jax.random.normal(key, shape, dtype)

    return init


def zeros_init() -> Initializer:
    return lambda key, shape, dtype: jnp.zeros(shape, dtype)


def ones_init() -> Initializer:
    return lambda key, shape, dtype: jnp.ones(shape, dtype)


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declaration of one weight tensor.

    spec: logical partition per dim — entries are None or mesh-axis names
      (only "model" is used at the module level).  len(spec) == len(shape).
    """

    shape: tuple[int, ...]
    init: Initializer = normal_init()
    spec: tuple[Optional[str], ...] = ()
    dtype: Any = jnp.float32

    def __post_init__(self):
        if not self.spec:
            object.__setattr__(self, "spec", (None,) * len(self.shape))
        if len(self.spec) != len(self.shape):
            raise ValueError(f"spec {self.spec} rank != shape {self.shape}")


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(defs: PyTree, key: jax.Array, dtype=None) -> PyTree:
    """Materialize a ParamDef tree into arrays (one fresh key per leaf)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [
        d.init(k, d.shape, dtype if dtype is not None else d.dtype)
        for d, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(defs: PyTree, dtype=None) -> PyTree:
    """ShapeDtypeStruct tree (for dry-run lowering without allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(
            d.shape, dtype if dtype is not None else d.dtype
        ),
        defs,
        is_leaf=_is_def,
    )


def spec_tree(defs: PyTree) -> PyTree:
    """Extract the logical partition-spec tree (tuples per leaf)."""
    return jax.tree.map(lambda d: d.spec, defs, is_leaf=_is_def)


def param_count(tree: PyTree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=_is_def)
    total = 0
    for x in leaves:
        shape = x.shape if not isinstance(x, ParamDef) else x.shape
        n = 1
        for s in shape:
            n *= s
        total += n
    return total


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)).astype(x.dtype)


def layer_norm(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5
) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(positions: jax.Array, d_head: int, theta: float = 10000.0):
    """(sin, cos) tables for ``positions`` (any leading shape) -> (..., d_head/2)."""
    half = d_head // 2
    freqs = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """Rotate (..., S, H, Dh) by per-position (.., S, Dh/2) tables."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[..., None, :]  # broadcast over heads
    cos = cos[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)
