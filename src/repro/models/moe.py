"""Mixture-of-Experts with top-k routing and capacity-based dispatch.

Dispatch uses the sort-free scatter/gather scheme (no (T, E, C) one-hot
einsums, which are infeasible at 384 experts):

  1. router: top-k expert ids + renormalized softmax weights per token
  2. position-in-expert via a stable argsort over the flat (T*k,) expert
     assignment; tokens beyond expert capacity C are *dropped* (standard
     capacity-factor semantics)
  3. scatter tokens into an (E, C, D) buffer (experts sharded over the
     ``model`` mesh axis = expert parallelism), batched expert GEMMs,
     gather back, weighted combine.

The router's load-balance auxiliary loss (Shazeer-style f·p) is **node-local**
under decentralized training — router statistics are never globally averaged,
mirroring how every other gradient signal stays local (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, he_normal, normal_init

__all__ = ["moe_defs", "apply_moe"]


def moe_defs(
    d_model: int,
    d_ff: int,
    n_experts: int,
    *,
    n_shared: int = 0,
    shard_ff: bool = False,
    dtype=jnp.float32,
):
    """shard_ff: additionally shard the expert d_ff dim over the ``data``
    mesh axis (2-level expert TP).  Up/gate become column-parallel and
    down-proj row-parallel over ``data`` — no per-layer expert weight
    gathers, at the cost of one (E_local, C, D) partial-sum all-reduce.
    Used for 1T-scale single-replica placements (kimi-k2, G=1)."""
    up_spec = ("model", None, "data") if shard_ff else ("model", None, None)
    down_spec = ("model", "data", None) if shard_ff else ("model", None, None)
    defs = {
        "router": ParamDef(
            (d_model, n_experts), normal_init(0.02), (None, None), dtype
        ),
        "w_gate": ParamDef(
            (n_experts, d_model, d_ff), he_normal((-2,)), up_spec, dtype
        ),
        "w_up": ParamDef(
            (n_experts, d_model, d_ff), he_normal((-2,)), up_spec, dtype
        ),
        "w_down": ParamDef(
            (n_experts, d_ff, d_model), he_normal((-2,)), down_spec, dtype
        ),
    }
    if n_shared:
        defs["shared"] = {
            "w_gate": ParamDef(
                (d_model, n_shared * d_ff), he_normal((-2,)), (None, "model"), dtype
            ),
            "w_up": ParamDef(
                (d_model, n_shared * d_ff), he_normal((-2,)), (None, "model"), dtype
            ),
            "w_down": ParamDef(
                (n_shared * d_ff, d_model), he_normal((-2,)), ("model", None), dtype
            ),
        }
    return defs


def _top_k_router(logits: jax.Array, k: int):
    """-> (weights (T, k) renormalized softmax, ids (T, k))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    return top_p, top_ids


def apply_moe(
    params,
    x: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    capacity: Optional[int] = None,
    buf_constraint: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B, S, D), aux load-balance loss scalar)."""
    b, s, d = x.shape
    e = params["router"].shape[1]
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt, params["router"])
    weights, ids = _top_k_router(logits, top_k)  # (T, k)

    # Load-balance aux loss (node-local): E * sum_e f_e * p_e
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    f = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (t * top_k)
    p_mean = probs.mean(axis=0)
    aux = e * jnp.sum(f * p_mean)

    if capacity is None:
        capacity = int(max(top_k * t * capacity_factor / e, 4))

    # --- position-in-expert via stable sort over flat assignments ----------
    flat_e = ids.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # start offset of each expert group inside the sorted list
    group_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos_sorted = jnp.arange(t * top_k) - group_start[sorted_e]
    keep_sorted = pos_sorted < capacity
    pos_sorted = jnp.minimum(pos_sorted, capacity - 1)

    token_idx_sorted = order // top_k
    gathered = xt[token_idx_sorted]  # (T*k, D)
    gathered = jnp.where(keep_sorted[:, None], gathered, 0.0)

    buf = jnp.zeros((e, capacity, d), x.dtype)
    buf = buf.at[sorted_e, pos_sorted].add(gathered.astype(x.dtype))
    if buf_constraint:
        # pin the dispatch buffer to expert-parallel layout so GSPMD cannot
        # replicate it ("involuntary full rematerialization" on the scatter)
        from jax.sharding import PartitionSpec as _P

        buf = jax.lax.with_sharding_constraint(buf, _P("model", None, None))

    # --- expert GEMMs (E sharded over `model`) ------------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    if buf_constraint:
        from jax.sharding import PartitionSpec as _P

        out_buf = jax.lax.with_sharding_constraint(out_buf, _P("model", None, None))

    # --- combine -------------------------------------------------------------
    picked = out_buf[sorted_e, pos_sorted]  # (T*k, D)
    w_sorted = weights.reshape(-1)[order]
    picked = picked.astype(jnp.float32) * jnp.where(keep_sorted, w_sorted, 0.0)[:, None]
    out = (
        jnp.zeros((t, d), jnp.float32).at[token_idx_sorted].add(picked)
    ).astype(x.dtype)
    out = out.reshape(b, s, d)

    if "shared" in params:
        sh = params["shared"]
        gate = jnp.einsum("bsd,df->bsf", x, sh["w_gate"])
        up = jnp.einsum("bsd,df->bsf", x, sh["w_up"])
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(gate) * up, sh["w_down"])
    return out, aux


# ---------------------------------------------------------------------------
# Manual expert parallelism (explicit collectives; §Perf H2/H4 follow-up)
# ---------------------------------------------------------------------------

def apply_moe_manual_ep(
    params,
    x: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    capacity: Optional[int] = None,
    axis: str = "model",
) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE with *hand-written* collectives.

    GSPMD's auto-partitioning of the scatter/gather dispatch replicates the
    (E, C, D) buffers per layer (§Perf H2/H4: the measured collective wall).
    This variant pins the schedule instead: a nested ``shard_map`` manual
    over the ``model`` axis — activations replicated, expert weights sharded
    on E, every device dispatches the full token set to *its own* experts
    locally and the partial outputs are combined with one ``psum``:

        wire/device/layer = 2·T·D bytes (the psum), deterministically,
        vs. the (E, C, D) buffer replication GSPMD chooses (~1.3–2.6×
        more for the assigned MoE shapes, and unpredictable).

    Semantics are identical to ``apply_moe`` (same router, same capacity
    rule — tested).  Requires E % axis_size == 0.
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    e = params["router"].shape[1]

    def body(router, w_gate, w_up, w_down, xs):
        n_shards = jax.lax.axis_size(axis)
        shard = jax.lax.axis_index(axis)
        e_local = w_gate.shape[0]
        b, s, d = xs.shape
        t = b * s
        xt = xs.reshape(t, d)

        logits = jnp.einsum("td,de->te", xt, router)
        weights, ids = _top_k_router(logits, top_k)

        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        f = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (t * top_k)
        aux = e * jnp.sum(f * probs.mean(axis=0))

        cap = capacity
        if cap is None:
            cap = int(max(top_k * t * capacity_factor / e, 4))

        flat_e = ids.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        group_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
        pos_sorted = jnp.arange(t * top_k) - group_start[sorted_e]
        keep = pos_sorted < cap
        pos_sorted = jnp.minimum(pos_sorted, cap - 1)
        token_idx = order // top_k

        # ownership: only my experts land in my local buffer
        local_e = sorted_e - shard * e_local
        mine = keep & (local_e >= 0) & (local_e < e_local)
        local_e = jnp.clip(local_e, 0, e_local - 1)

        gathered = jnp.where(mine[:, None], xt[token_idx], 0.0)
        buf = jnp.zeros((e_local, cap, d), xs.dtype)
        buf = buf.at[local_e, pos_sorted].add(gathered.astype(xs.dtype))

        g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
        u = jnp.einsum("ecd,edf->ecf", buf, w_up)
        out_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down)

        picked = out_buf[local_e, pos_sorted]
        w_sorted = weights.reshape(-1)[order]
        picked = picked.astype(jnp.float32) * jnp.where(mine, w_sorted, 0.0)[:, None]
        partial = jnp.zeros((t, d), jnp.float32).at[token_idx].add(picked)
        # NOTE: a bf16 psum would halve this wire, but XLA:CPU's SPMD
        # partitioner hard-crashes on it at 512 partitions (hlo_instruction
        # "Invalid binary instruction opcode copy") — kept in f32.
        out = jax.lax.psum(partial, axis)          # ONE collective per layer
        return out.reshape(b, s, d).astype(xs.dtype), aux

    out, aux = jax.shard_map(
        body,
        in_specs=(P(), P(axis, None, None), P(axis, None, None), P(axis, None, None), P()),
        out_specs=(P(), P()),
        axis_names={axis},
        check_vma=True,
    )(params["router"], params["w_gate"], params["w_up"], params["w_down"], x)

    if "shared" in params:
        sh = params["shared"]
        gate = jnp.einsum("bsd,df->bsf", x, sh["w_gate"])
        up = jnp.einsum("bsd,df->bsf", x, sh["w_up"])
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(gate) * up, sh["w_down"])
    return out, aux
