"""The paper's own benchmark models, in JAX: a compact ResNet-style CNN
(CIFAR-class, the ResNet20/DenseNet100 stand-in at CPU-benchmark scale) and
an LSTM language model (the WikiText2 subject).

These drive the DBench white-box benchmarks (benchmarks/*), reproducing the
paper's experiment *structure* — image classification + language modeling
across five SGD implementations — at a scale a CPU can sweep.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, he_normal, init_params, normal_init, ones_init, zeros_init

__all__ = [
    "mini_resnet_defs", "mini_resnet_apply", "mini_resnet_loss",
    "lstm_defs", "lstm_apply", "lstm_loss",
    "synthetic_images",
]


# ---------------------------------------------------------------------------
# Mini ResNet (image classification)
# ---------------------------------------------------------------------------

def _conv_def(cin, cout, k=3):
    return ParamDef((k, k, cin, cout), he_normal((-4, -3, -2)), (None,) * 4)


def mini_resnet_defs(channels: int = 16, n_classes: int = 10, depth: int = 2):
    defs = {"stem": _conv_def(3, channels)}
    for i in range(depth):
        defs[f"block{i}"] = {
            "conv1": _conv_def(channels, channels),
            "conv2": _conv_def(channels, channels),
            "g1": ParamDef((channels,), ones_init(), (None,)),
            "g2": ParamDef((channels,), ones_init(), (None,)),
        }
    defs["head"] = ParamDef((channels, n_classes), normal_init(0.05), (None, None))
    defs["head_b"] = ParamDef((n_classes,), zeros_init(), (None,))
    return defs


def _conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _chan_norm(x, g, eps=1e-5):
    """Per-channel norm over (H, W) — BN's stateless, replica-local cousin
    (keeps cross-replica stats local, mirroring the paper's per-GPU BN)."""
    mu = x.mean(axis=(1, 2), keepdims=True)
    var = x.var(axis=(1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g


def mini_resnet_apply(params, images):
    """images: (B, H, W, 3) -> logits (B, n_classes)."""
    h = jax.nn.relu(_conv(images, params["stem"]))
    i = 0
    while f"block{i}" in params:
        b = params[f"block{i}"]
        r = jax.nn.relu(_chan_norm(_conv(h, b["conv1"]), b["g1"]))
        r = _chan_norm(_conv(r, b["conv2"]), b["g2"])
        h = jax.nn.relu(h + r)
        i += 1
    pooled = h.mean(axis=(1, 2))
    return pooled @ params["head"] + params["head_b"]


def mini_resnet_loss(params, batch):
    logits = mini_resnet_apply(params, batch["images"])
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def synthetic_images(key, n_classes=10, batch=32, size=16, noise=0.6):
    """Class-conditional Gaussian images: learnable but non-trivial."""
    kl, kp, kn = jax.random.split(key, 3)
    labels = jax.random.randint(kl, (batch,), 0, n_classes)
    protos = jax.random.normal(
        jax.random.PRNGKey(7), (n_classes, size, size, 3)
    )  # fixed prototypes
    imgs = protos[labels] + noise * jax.random.normal(kn, (batch, size, size, 3))
    return {"images": imgs, "labels": labels}


# ---------------------------------------------------------------------------
# LSTM language model
# ---------------------------------------------------------------------------

def lstm_defs(vocab: int = 256, d: int = 128):
    return {
        "embed": ParamDef((vocab, d), normal_init(0.05), (None, None)),
        "wx": ParamDef((d, 4 * d), he_normal((-2,)), (None, None)),
        "wh": ParamDef((d, 4 * d), he_normal((-2,)), (None, None)),
        "b": ParamDef((4 * d,), zeros_init(), (None,)),
        "head": ParamDef((d, vocab), normal_init(0.05), (None, None)),
    }


def lstm_apply(params, tokens):
    """tokens (B, S) -> logits (B, S, V)."""
    x = jnp.take(params["embed"], tokens, axis=0)  # (B, S, D)
    b, s, d = x.shape
    h0 = jnp.zeros((b, d))
    c0 = jnp.zeros((b, d))

    def cell(carry, xt):
        h, c = carry
        z = xt @ params["wx"] + h @ params["wh"] + params["b"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    _, hs = jax.lax.scan(cell, (h0, c0), x.swapaxes(0, 1))
    return hs.swapaxes(0, 1) @ params["head"]


def lstm_loss(params, batch):
    logits = lstm_apply(params, batch["tokens"])
    t = batch["targets"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    tgt = jnp.take_along_axis(logp, jnp.maximum(t, 0)[..., None], axis=-1)[..., 0]
    valid = (t >= 0).astype(jnp.float32)
    return -jnp.sum(tgt * valid) / jnp.maximum(valid.sum(), 1.0)


def lstm_perplexity(params, batch):
    return jnp.exp(lstm_loss(params, batch))
